#!/usr/bin/env python3
"""Schema, ratchet, and regression gates for the committed BENCH_*.json
perf trajectory (docs/BENCHES.md, docs/EXPERIMENTS.md §Baselines).

Two subcommands, both exiting non-zero on violation:

  schema {hotpath|serving} FILE
      Validate the documented schema. Placeholder files (provenance
      containing "placeholder") are legal ONLY while ``smoke`` is true
      and rows are empty — the bootstrap state before the first refresh
      from a Rust-toolchain machine. Once a file carries ``smoke:
      false`` rows, empty rows and placeholder provenance are rejected:
      the trajectory is a one-way ratchet and cannot silently regress
      to empty.

  regression {hotpath|serving} --fresh FILE --committed FILE
             [--tolerance FRACTION]
      Compare a fresh smoke run against the committed trajectory on
      machine-portable relative metrics and fail on a regression beyond
      the tolerance band. Skips (exit 0, loud note) while the committed
      file is still a placeholder — there is nothing to regress against
      yet.

      hotpath: compares ``speedup_vs_scalar`` on shared (m, mode) pairs
      for the batched and parallel modes. Speedups are ratios on the
      same machine, so they transfer between the refresh machine and CI
      runners far better than absolute ns/point; the default tolerance
      (0.5) only catches the kernel *losing its multiplier* — e.g. a
      committed 2.4x batched row collapsing below 1.2x — not runner
      jitter.

      serving: compares ``throughput_rps`` on shared (feeders, devices)
      rows with a catastrophic-only default tolerance (0.8), since
      absolute throughput does vary across hardware.

Dependency-free (stdlib json/argparse only), mirroring the repo rule
that CI gates must not pull packages.
"""

from __future__ import annotations

import argparse
import json
import sys

NUM = (int, float)


class Gate:
    """Collects violations, then reports them all at once."""

    def __init__(self, label: str) -> None:
        self.label = label
        self.errs: list[str] = []

    def err(self, msg: str) -> None:
        self.errs.append(msg)

    def check_keys(self, obj: dict, spec: dict, where: str) -> None:
        for key, ty in spec.items():
            if key not in obj:
                self.err(f"{where}: missing key {key!r}")
            elif not isinstance(obj[key], ty) or isinstance(obj[key], bool) and ty is not bool:
                self.err(f"{where}: {key!r} has type {type(obj[key]).__name__}")

    def finish(self, ok_note: str) -> None:
        if self.errs:
            print(f"{self.label}:\n  " + "\n  ".join(self.errs))
            sys.exit(1)
        print(ok_note)


def is_placeholder(doc: dict) -> bool:
    return "placeholder" in str(doc.get("provenance", ""))


def check_ratchet(gate: Gate, doc: dict, extra_row_keys: tuple[str, ...] = ()) -> None:
    """The empty-rows ratchet shared by both benches: a placeholder is
    only legal in the smoke bootstrap state; real (smoke: false) files
    must carry rows and must not claim to be placeholders."""
    placeholder = is_placeholder(doc)
    smoke = doc.get("smoke")
    rows_empty = not doc.get("rows")
    if rows_empty and not placeholder:
        gate.err("rows is empty but provenance does not mark a placeholder")
    if rows_empty and placeholder and smoke is not True:
        gate.err("placeholder with empty rows requires smoke: true (bootstrap state only)")
    if placeholder and smoke is False:
        gate.err("smoke: false with placeholder provenance — refresh must rewrite provenance")
    if smoke is False:
        if rows_empty:
            gate.err("smoke: false requires non-empty rows (the ratchet: no silent regression " "to empty)")
        for key in extra_row_keys:
            if not doc.get(key):
                gate.err(f"smoke: false requires non-empty {key!r}")


def schema_hotpath(path: str) -> None:
    doc = json.load(open(path))
    gate = Gate(f"{path} schema drift")
    top = {
        "bench": str,
        "schema_version": NUM,
        "provenance": str,
        "workers": NUM,
        "chunk": NUM,
        "lanes": NUM,
        "lane_backend": str,
        "smoke": bool,
        "rows": list,
        "kernel_rows": list,
    }
    gate.check_keys(doc, top, "top-level")
    if doc.get("bench") != "fig_hotpath":
        gate.err(f"bench != fig_hotpath: {doc.get('bench')!r}")
    if doc.get("schema_version") != 2:
        gate.err(f"schema_version != 2: {doc.get('schema_version')!r}")
    if doc.get("lanes") != 8:
        gate.err(f"lanes != 8 (the exec::simd::LANES contract): {doc.get('lanes')!r}")
    row_keys = {
        "m": NUM,
        "mode": str,
        "points": NUM,
        "ns_per_point": NUM,
        "points_per_s": NUM,
        "speedup_vs_scalar": NUM,
    }
    modes = set()
    for i, row in enumerate(doc.get("rows", [])):
        gate.check_keys(row, row_keys, f"row {i}")
        modes.add(row.get("mode"))
    if doc.get("rows") and not {"scalar", "batched", "parallel"} <= modes:
        gate.err(f"modes incomplete: {sorted(m for m in modes if m)}")
    kernel_keys = {"kernel": str, "calls_per_point": NUM, "ns_per_point": NUM}
    kernels = set()
    for i, row in enumerate(doc.get("kernel_rows", [])):
        gate.check_keys(row, kernel_keys, f"kernel_row {i}")
        kernels.add(row.get("kernel"))
    want_kernels = {"interpolate", "dot_f32", "accum_scaled", "accum_grad", "commit_row"}
    if doc.get("kernel_rows") and not want_kernels <= kernels:
        gate.err(f"kernel_rows incomplete: {sorted(k for k in kernels if k)}")
    check_ratchet(gate, doc, extra_row_keys=("kernel_rows",))
    if doc.get("smoke") is False and doc.get("lane_backend") not in ("portable", "avx2", "neon"):
        gate.err(f"smoke: false requires a measured lane_backend, got {doc.get('lane_backend')!r}")
    state = "placeholder (bootstrap)" if is_placeholder(doc) else f"{len(doc.get('rows', []))} rows"
    gate.finish(f"{path} schema OK ({state}, {len(doc.get('kernel_rows', []))} kernel rows)")


def schema_serving(path: str) -> None:
    doc = json.load(open(path))
    gate = Gate(f"{path} schema drift")
    top = {
        "bench": str,
        "schema_version": NUM,
        "provenance": str,
        "chunk": NUM,
        "requests": NUM,
        "smoke": bool,
        "rows": list,
        "tier_rows": list,
        "frontend_rows": list,
    }
    gate.check_keys(doc, top, "top-level")
    if doc.get("bench") != "fig_serving":
        gate.err(f"bench != fig_serving: {doc.get('bench')!r}")
    if doc.get("schema_version") != 1:
        gate.err(f"schema_version != 1: {doc.get('schema_version')!r}")
    row_keys = {
        "feeders": NUM,
        "devices": NUM,
        "occupancy": NUM,
        "chunks": NUM,
        "host_bytes_per_chunk": NUM,
        "legacy_host_bytes_per_chunk": NUM,
        "throughput_rps": NUM,
        "bit_identical": NUM,
        "respawn_latency_us": NUM,
        "shed_rate": NUM,
    }
    for i, row in enumerate(doc.get("rows", [])):
        gate.check_keys(row, row_keys, f"row {i}")
        if row.get("bit_identical") != 1:
            gate.err(f"row {i}: bit_identical != 1")
        if row.get("shed_rate") != 0.5:
            gate.err(f"row {i}: shed_rate != 0.5 (the half-tight burst)")
    tier_keys = {
        "stealing": NUM,
        "tier": str,
        "completed": NUM,
        "p99_ms": NUM,
        "steal_rate": NUM,
    }
    tiers_seen: dict[int, set] = {1: set(), 0: set()}
    for i, row in enumerate(doc.get("tier_rows", [])):
        gate.check_keys(row, tier_keys, f"tier_row {i}")
        if row.get("stealing") in (0, 1):
            tiers_seen[int(row["stealing"])].add(row.get("tier"))
        if row.get("stealing") == 0 and row.get("steal_rate") != 0:
            gate.err(f"tier_row {i}: steal_rate != 0 with stealing off")
    if doc.get("tier_rows"):
        want = {"unbounded", "tight", "standard", "thorough"}
        for mode, seen in tiers_seen.items():
            if not want <= seen:
                gate.err(f"stealing={mode}: tiers incomplete: {sorted(seen)}")
    fe_keys = {
        "requests": NUM,
        "deadline_ms": NUM,
        "deadline_hit_rate": NUM,
        "partial_rate": NUM,
        "rounds_streamed": NUM,
        "throughput_rps": NUM,
    }
    fe_deadlines = set()
    for i, row in enumerate(doc.get("frontend_rows", [])):
        gate.check_keys(row, fe_keys, f"frontend_row {i}")
        deadlined = row.get("deadline_ms", 0) > 0
        fe_deadlines.add(deadlined)
        expect = 1.0 if deadlined else 0.0
        if row.get("deadline_hit_rate") != expect:
            gate.err(f"frontend_row {i}: deadline_hit_rate != {expect}")
        if row.get("partial_rate") != expect:
            gate.err(f"frontend_row {i}: partial_rate != {expect}")
    if doc.get("frontend_rows") and fe_deadlines != {True, False}:
        gate.err("frontend_rows must cover a deadlined burst and a control")
    check_ratchet(gate, doc, extra_row_keys=("tier_rows", "frontend_rows"))
    state = "placeholder (bootstrap)" if is_placeholder(doc) else f"{len(doc.get('rows', []))} rows"
    gate.finish(
        f"{path} schema OK ({state}, {len(doc.get('tier_rows', []))} tier rows, "
        f"{len(doc.get('frontend_rows', []))} frontend rows)"
    )


def regression(kind: str, fresh_path: str, committed_path: str, tolerance: float) -> None:
    fresh = json.load(open(fresh_path))
    committed = json.load(open(committed_path))
    if is_placeholder(committed):
        print(
            f"NOTE: committed {committed_path} is still the bootstrap placeholder — "
            "no trajectory to regress against yet. Refresh per docs/EXPERIMENTS.md "
            "§Baselines to arm this gate."
        )
        return
    gate = Gate(f"{kind} perf regression vs committed trajectory")
    if kind == "hotpath":
        metric, key = "speedup_vs_scalar", lambda r: (r.get("m"), r.get("mode"))
        keep = lambda r: r.get("mode") in ("batched", "parallel")
    else:
        metric, key = "throughput_rps", lambda r: (r.get("feeders"), r.get("devices"))
        keep = lambda r: True
    committed_rows = {key(r): r for r in committed.get("rows", []) if keep(r)}
    compared = 0
    for row in fresh.get("rows", []):
        if not keep(row):
            continue
        base = committed_rows.get(key(row))
        if base is None:
            continue
        compared += 1
        have, want = row.get(metric), base.get(metric)
        if not isinstance(have, NUM) or not isinstance(want, NUM):
            gate.err(f"{key(row)}: non-numeric {metric}: fresh={have!r} committed={want!r}")
            continue
        floor = want * (1.0 - tolerance)
        if have < floor:
            gate.err(
                f"{key(row)}: {metric} {have:.3f} fell below committed {want:.3f} "
                f"x (1 - {tolerance}) = {floor:.3f}"
            )
    if compared == 0:
        gate.err(
            f"no shared rows between {fresh_path} and {committed_path} — the regression "
            "gate compared nothing; refresh grids must overlap (smoke m=16 is in both)"
        )
    gate.finish(f"{kind} regression gate OK ({compared} shared rows within tolerance {tolerance})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("schema", help="validate a BENCH_*.json against its documented schema")
    s.add_argument("kind", choices=("hotpath", "serving"))
    s.add_argument("file")
    r = sub.add_parser("regression", help="compare a fresh run against the committed trajectory")
    r.add_argument("kind", choices=("hotpath", "serving"))
    r.add_argument("--fresh", required=True)
    r.add_argument("--committed", required=True)
    r.add_argument("--tolerance", type=float, default=None)
    args = ap.parse_args()
    if args.cmd == "schema":
        (schema_hotpath if args.kind == "hotpath" else schema_serving)(args.file)
    else:
        tol = args.tolerance
        if tol is None:
            tol = 0.5 if args.kind == "hotpath" else 0.8
        regression(args.kind, args.fresh, args.committed, tol)


if __name__ == "__main__":
    main()
