//! Repo-specific invariant lints for the nuig serving substrate (ISSUE 6
//! tentpole a). Five lints, each guarding one of the invariants cataloged
//! in `docs/INVARIANTS.md`:
//!
//! * `float-reduce` — no `.sum()` / `.product()` / `.fold(` over
//!   f32/f64 outside `exec/batch.rs` (the one blessed ordered-reduce
//!   site). Floating-point addition is non-associative; an unordered
//!   reduction silently breaks the 0-ULP determinism contract. Inside
//!   `exec/simd.rs` the lint is **non-waivable**: the lane-major
//!   reduction order there is the cross-backend bit-identity invariant
//!   itself (docs/INVARIANTS.md §I13), not a style choice — every
//!   reduction must be an explicit indexed lane loop.
//! * `hash-iter` — no iteration over `HashMap`/`HashSet` bindings:
//!   `std` hash iteration order is randomized per process, so anything
//!   accumulated or committed in that order is nondeterministic.
//! * `wallclock-kernel` — no `Instant::now` / `SystemTime::now` inside
//!   the deterministic kernels (`src/ig/`, `src/exec/batch.rs`,
//!   `src/exec/simd.rs`) or the
//!   lane-dispatch path (`src/coordinator/scheduler.rs`, since the
//!   tiered work-stealing scheduler): stage timing belongs to
//!   `metrics::StageTimer`, owned by the callers, and the scheduler's
//!   pop-deadline reads must each carry an explicit waiver so new
//!   wall-clock dependences cannot slip into the dispatch stream
//!   unreviewed.
//! * `lock-unwrap-serving` — no `.unwrap()` / `.expect()` on
//!   lock/condvar/channel results in the serving path
//!   (`src/coordinator/`, `src/runtime/service.rs`); those modules must
//!   go through the poison-recovering `exec::sync` helpers so one
//!   panicked request cannot cascade into a dead coordinator.
//! * `unsafe-safety` — every `unsafe` token carries a `// SAFETY:`
//!   comment within the preceding 24 lines.
//!
//! The scanner is lexical: comments and string/char literals are blanked
//! (layout-preserving) before matching, so neither doc text nor string
//! contents can trip a lint. Lints other than `unsafe-safety` stop at
//! the file's first `#[cfg(test)]` (test modules sit at the end of every
//! file in this repo by convention); determinism lints protect what the
//! serving path commits, not test-internal arithmetic.
//!
//! # Waivers
//!
//! A finding is waived by a comment on the flagged line or the line
//! directly above:
//!
//! ```text
//! // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
//! let sum: f64 = values.iter().sum();
//! ```
//!
//! The justification is mandatory: a waiver without one is itself a
//! finding, as is a waiver naming an unknown lint. Waive only sites that
//! are provably order-independent or sequentially ordered; anything
//! load-bearing gets fixed, not waived.

use std::fmt;
use std::path::{Path, PathBuf};

/// Lint identifiers, in reporting order.
pub const LINTS: [&str; 5] = [
    "float-reduce",
    "hash-iter",
    "wallclock-kernel",
    "lock-unwrap-serving",
    "unsafe-safety",
];

/// Pseudo-lint under which malformed waivers (unknown lint name, missing
/// justification) are reported. Not waivable itself.
pub const WAIVER_LINT: &str = "waiver";

/// One lint hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Lint identifier (one of [`LINTS`]).
    pub lint: &'static str,
    /// Human-readable description.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.message)
    }
}

// ---------------------------------------------------------------------
// Lexical preprocessing
// ---------------------------------------------------------------------

/// Blank comments and string/char literals, preserving the line layout
/// exactly (every `\n` survives, including string line-continuations),
/// so that byte offsets map to the same line numbers in raw and code
/// text. Quote delimiters are kept so strings still read as opaque
/// tokens; their contents become spaces.
pub fn strip_code(text: &str) -> String {
    #[derive(PartialEq)]
    enum Mode {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(usize),
        Char,
    }
    let b = text.as_bytes();
    let n = b.len();
    let mut out = Vec::with_capacity(n);
    let mut mode = Mode::Code;
    let mut i = 0;
    // Push `b[i]` if it is a newline, else a space.
    fn blank(out: &mut Vec<u8>, c: u8) {
        out.push(if c == b'\n' { b'\n' } else { b' ' });
    }
    while i < n {
        let c = b[i];
        let nxt = if i + 1 < n { b[i + 1] } else { 0 };
        match mode {
            Mode::Code => {
                if c == b'/' && nxt == b'/' {
                    mode = Mode::LineComment;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'/' && nxt == b'*' {
                    mode = Mode::BlockComment(1);
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    mode = Mode::Str;
                    out.push(b'"');
                    i += 1;
                } else if c == b'r' && (nxt == b'"' || nxt == b'#') {
                    // Possible raw string: r"..." or r#"..."#. Only enter
                    // raw mode when the hashes are followed by a quote
                    // (`r#foo` is a raw identifier, not a string).
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && b[j] == b'#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && b[j] == b'"' {
                        mode = Mode::RawStr(hashes);
                        for _ in i..=j {
                            out.push(b' ');
                        }
                        i = j + 1;
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else if c == b'\''
                    && (nxt == b'\\' || (i + 2 < n && b[i + 2] == b'\''))
                {
                    // Char literal ('x' or '\x'); lifetimes ('a) stay code.
                    mode = Mode::Char;
                    out.push(b'\'');
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                if c == b'\n' {
                    mode = Mode::Code;
                    out.push(b'\n');
                } else {
                    out.push(b' ');
                }
                i += 1;
            }
            Mode::BlockComment(d) => {
                if c == b'*' && nxt == b'/' {
                    mode = if d == 1 { Mode::Code } else { Mode::BlockComment(d - 1) };
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'/' && nxt == b'*' {
                    mode = Mode::BlockComment(d + 1);
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else {
                    blank(&mut out, c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == b'\\' {
                    // Keep a continuation's newline so lines stay aligned.
                    out.push(b' ');
                    if i + 1 < n {
                        blank(&mut out, b[i + 1]);
                    }
                    i += 2;
                } else if c == b'"' {
                    mode = Mode::Code;
                    out.push(b'"');
                    i += 1;
                } else {
                    blank(&mut out, c);
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == b'"' && i + hashes < n && b[i + 1..].starts_with(&vec![b'#'; hashes]) {
                    mode = Mode::Code;
                    for _ in 0..=hashes {
                        out.push(b' ');
                    }
                    i += 1 + hashes;
                } else {
                    blank(&mut out, c);
                    i += 1;
                }
            }
            Mode::Char => {
                if c == b'\\' {
                    out.push(b' ');
                    if i + 1 < n {
                        blank(&mut out, b[i + 1]);
                    }
                    i += 2;
                } else if c == b'\'' {
                    mode = Mode::Code;
                    out.push(b'\'');
                    i += 1;
                } else {
                    blank(&mut out, c);
                    i += 1;
                }
            }
        }
    }
    String::from_utf8(out).expect("blanking preserves UTF-8 (multibyte only inside literals)")
}

fn is_word(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Does `hay` contain `needle` as a whole word (identifier boundaries)?
fn has_token(hay: &str, needle: &str) -> bool {
    find_token(hay, needle, 0).is_some()
}

/// Position of the next whole-word occurrence of `needle` at or after
/// `from`.
fn find_token(hay: &str, needle: &str, from: usize) -> Option<usize> {
    let hb = hay.as_bytes();
    let mut start = from;
    while let Some(p) = hay[start..].find(needle) {
        let p = start + p;
        let before_ok = p == 0 || !is_word(hb[p - 1]);
        let end = p + needle.len();
        let after_ok = end >= hb.len() || !is_word(hb[end]);
        if before_ok && after_ok {
            return Some(p);
        }
        start = p + 1;
    }
    None
}

// ---------------------------------------------------------------------
// Waivers
// ---------------------------------------------------------------------

struct Waiver {
    lint: String,
    justification: String,
}

/// Parse `// nuig:allow(<lint>): <justification>` waivers from the raw
/// lines; returns `(line -> waiver)` entries (0-based index).
fn parse_waivers(raw_lines: &[&str]) -> Vec<(usize, Waiver)> {
    let mut out = Vec::new();
    for (idx, line) in raw_lines.iter().enumerate() {
        let Some(p) = line.find("nuig:allow(") else { continue };
        let rest = &line[p + "nuig:allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let lint = rest[..close].trim().to_string();
        let after = rest[close + 1..].trim_start();
        let justification = after.strip_prefix(':').unwrap_or("").trim().to_string();
        out.push((idx, Waiver { lint, justification }));
    }
    out
}

// ---------------------------------------------------------------------
// Per-file analysis
// ---------------------------------------------------------------------

/// Scope/allowlist decisions, all on `/`-separated paths relative to the
/// scan root (mirroring `rust/src`).
fn in_kernel_scope(rel: &str) -> bool {
    rel.starts_with("ig/") || rel == "exec/batch.rs" || rel == "exec/simd.rs"
}

fn in_serving_scope(rel: &str) -> bool {
    rel.starts_with("coordinator/") || rel == "runtime/service.rs"
}

/// `wallclock-kernel` also covers the lane scheduler: chunk dispatch
/// order feeds the 0-ULP serving contract, so its bounded pop-deadline
/// arithmetic is the only blessed wall-clock use there — and each read
/// must carry an explicit `nuig:allow` waiver naming why it cannot leak
/// into attribution math.
fn in_wallclock_scope(rel: &str) -> bool {
    in_kernel_scope(rel) || rel == "coordinator/scheduler.rs"
}

fn float_reduce_allowlisted(rel: &str) -> bool {
    // The ordered-reduce site: exec::batch commits partials in a fixed
    // chunk order by construction (its module doc carries the proof
    // obligation) and is property-tested for 0-ULP at any worker count.
    rel == "exec/batch.rs"
}

/// `float-reduce` waivers are rejected outright in `exec/simd.rs`: the
/// lane-major reduction order there IS the cross-backend bit-identity
/// invariant (docs/INVARIANTS.md §I13). A reduction that cannot be
/// written as an explicit indexed lane loop does not belong in that
/// module.
fn float_reduce_unwaivable(rel: &str) -> bool {
    rel == "exec/simd.rs"
}

/// Analyze one file's text; `rel` is its `/`-separated path relative to
/// the scan root.
pub fn analyze_file(rel: &str, text: &str) -> Vec<Finding> {
    let code = strip_code(text);
    let raw_lines: Vec<&str> = text.split('\n').collect();
    let code_lines: Vec<&str> = code.split('\n').collect();
    debug_assert_eq!(raw_lines.len(), code_lines.len(), "{rel}: stripper shifted lines");
    let waivers = parse_waivers(&raw_lines);
    let mut findings = Vec::new();

    // Waiver hygiene: unknown lint names and missing justifications are
    // findings in their own right (a waiver must say *why*).
    for (idx, w) in &waivers {
        if !LINTS.contains(&w.lint.as_str()) {
            findings.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                lint: WAIVER_LINT,
                message: format!("waiver names unknown lint `{}`", w.lint),
            });
        } else if w.lint == "float-reduce" && float_reduce_unwaivable(rel) {
            findings.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                lint: WAIVER_LINT,
                message: "float-reduce cannot be waived in exec/simd.rs — the lane-major \
                          reduction order is an invariant (I13), not a style choice"
                    .to_string(),
            });
        } else if w.justification.is_empty() {
            findings.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                lint: WAIVER_LINT,
                message: format!("waiver for `{}` missing a justification", w.lint),
            });
        }
    }

    // First `#[cfg(test)]`: the non-unsafe lints stop there (test
    // modules close out every file in this repo).
    let test_start = code_lines.iter().position(|l| l.contains("#[cfg(test)]"));
    let prod_end = test_start.unwrap_or(code_lines.len());

    let waived = |lint: &str, line_idx: usize| -> bool {
        if lint == "float-reduce" && float_reduce_unwaivable(rel) {
            return false;
        }
        waivers.iter().any(|(idx, w)| {
            w.lint == lint
                && !w.justification.is_empty()
                && (*idx == line_idx || idx + 1 == line_idx)
        })
    };
    let mut emit = |lint: &'static str, line_idx: usize, message: String| {
        if !waived(lint, line_idx) {
            findings.push(Finding { file: rel.to_string(), line: line_idx + 1, lint, message });
        }
    };

    // ---- float-reduce -------------------------------------------------
    if !float_reduce_allowlisted(rel) {
        for i in 0..prod_end {
            if !has_reduce_call(code_lines[i]) {
                continue;
            }
            let stmt = statement_window(&code_lines, i);
            if has_token(&stmt, "f32") || has_token(&stmt, "f64") {
                emit(
                    "float-reduce",
                    i,
                    "unordered float reduction (sum/product/fold over f32/f64); \
                     order-sensitive math must go through exec::batch's ordered \
                     reduce or be waived as provably order-independent"
                        .to_string(),
                );
            }
        }
    }

    // ---- hash-iter ----------------------------------------------------
    let names = hash_bindings(&code);
    if !names.is_empty() {
        for i in 0..prod_end {
            if let Some(name) = hash_iteration_on(code_lines[i], &names) {
                emit(
                    "hash-iter",
                    i,
                    format!(
                        "iteration over hash collection `{name}`: std hash order is \
                         per-process random, so anything accumulated or committed \
                         in this order is nondeterministic"
                    ),
                );
            }
        }
    }

    // ---- wallclock-kernel ---------------------------------------------
    if in_wallclock_scope(rel) {
        for i in 0..prod_end {
            let l = code_lines[i];
            if l.contains("Instant::now") || l.contains("SystemTime::now") {
                emit(
                    "wallclock-kernel",
                    i,
                    "wall-clock read inside a deterministic kernel or the \
                     lane-dispatch path; stage timing belongs to the caller via \
                     metrics::StageTimer"
                        .to_string(),
                );
            }
        }
    }

    // ---- lock-unwrap-serving ------------------------------------------
    if in_serving_scope(rel) {
        for i in 0..prod_end {
            if let Some(m) = lockish_unwrap(code_lines[i]) {
                emit(
                    "lock-unwrap-serving",
                    i,
                    format!(
                        "`.{m}(..).unwrap()/expect()` in the serving path; use the \
                         poison-recovering exec::sync helpers (one panicked request \
                         must not cascade into a dead coordinator)"
                    ),
                );
            }
        }
    }

    // ---- unsafe-safety (whole file, tests included) --------------------
    for i in 0..code_lines.len() {
        if !has_token(code_lines[i], "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(24);
        let documented = raw_lines[lo..=i].iter().any(|l| l.contains("SAFETY:"));
        if !documented {
            emit(
                "unsafe-safety",
                i,
                "unsafe without a `// SAFETY:` comment in the preceding 24 lines"
                    .to_string(),
            );
        }
    }

    findings
}

/// Does the code line contain a reduction call (`.sum()`, `.sum::<..>()`,
/// `.product()`, `.fold(`)?
fn has_reduce_call(line: &str) -> bool {
    for pat in [".sum(", ".sum::<", ".product(", ".product::<", ".fold("] {
        if line.contains(pat) {
            return true;
        }
    }
    false
}

/// The enclosing statement around line `i`, approximated as the lines
/// from the previous terminator (`;`, `{`, `}`, or blank) through the
/// next `;`, capped at 8 lines each way — enough for every rustfmt'd
/// chain in this repo.
fn statement_window(code_lines: &[&str], i: usize) -> String {
    let mut lo = i;
    for k in (i.saturating_sub(8)..i).rev() {
        let s = code_lines[k].trim_end();
        if s.ends_with(';') || s.ends_with('{') || s.ends_with('}') || s.trim().is_empty() {
            break;
        }
        lo = k;
    }
    let mut hi = i;
    for (k, line) in code_lines.iter().enumerate().skip(i).take(9) {
        hi = k;
        if line.trim_end().ends_with(';') {
            break;
        }
    }
    code_lines[lo..=hi].join("\n")
}

/// Identifiers bound to `HashMap`/`HashSet` in this file: type
/// ascriptions (`name: HashMap<..>`, fields and params alike) and
/// constructor bindings (`let name = HashMap::new()`).
fn hash_bindings(code: &str) -> Vec<String> {
    let mut names = Vec::new();
    for ty in ["HashMap", "HashSet"] {
        let mut from = 0;
        while let Some(p) = find_token(code, ty, from) {
            from = p + ty.len();
            let after = &code[from..];
            let b = code.as_bytes();
            if after.trim_start().starts_with('<') {
                // `name : [&][mut] [std::collections::] HashMap<`
                let mut q = p;
                q = skip_back_ws(b, q);
                q = skip_back_path_prefix(b, q, "std::collections::");
                q = skip_back_ws(b, q);
                q = skip_back_kw(b, q, "mut");
                q = skip_back_ws(b, q);
                if q > 0 && b[q - 1] == b'&' {
                    q -= 1;
                    q = skip_back_ws(b, q);
                }
                if q > 0 && b[q - 1] == b':' && !(q > 1 && b[q - 2] == b':') {
                    q -= 1;
                    q = skip_back_ws(b, q);
                    if let Some(name) = ident_ending_at(code, q) {
                        names.push(name);
                    }
                }
            } else if after.starts_with("::") {
                // `let [mut] name [ : .. ] = [std::collections::]HashMap::..`
                let mut q = p;
                q = skip_back_ws(b, q);
                q = skip_back_path_prefix(b, q, "std::collections::");
                q = skip_back_ws(b, q);
                if q > 0 && b[q - 1] == b'=' {
                    q -= 1;
                    q = skip_back_ws(b, q);
                    // Optional type ascription between name and `=` is
                    // rare for constructor bindings; handle the plain
                    // `let name =` shape.
                    if let Some(name) = ident_ending_at(code, q) {
                        names.push(name);
                    }
                }
            }
        }
    }
    names.sort();
    names.dedup();
    names
}

fn skip_back_ws(b: &[u8], mut q: usize) -> usize {
    while q > 0 && (b[q - 1] as char).is_whitespace() {
        q -= 1;
    }
    q
}

fn skip_back_kw(b: &[u8], q: usize, kw: &str) -> usize {
    let k = kw.as_bytes();
    if q >= k.len() && &b[q - k.len()..q] == k && (q == k.len() || !is_word(b[q - k.len() - 1])) {
        q - k.len()
    } else {
        q
    }
}

fn skip_back_path_prefix(b: &[u8], q: usize, prefix: &str) -> usize {
    let p = prefix.as_bytes();
    if q >= p.len() && &b[q - p.len()..q] == p {
        q - p.len()
    } else {
        q
    }
}

fn ident_ending_at(code: &str, q: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut s = q;
    while s > 0 && is_word(b[s - 1]) {
        s -= 1;
    }
    if s == q {
        return None;
    }
    let name = &code[s..q];
    if name.as_bytes()[0].is_ascii_digit() || name == "let" || name == "mut" {
        return None;
    }
    Some(name.to_string())
}

const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
    "min_by_key",
    "max_by_key",
];

/// If this line iterates one of the hash-bound `names` (method call or
/// `for .. in name`), return that name.
fn hash_iteration_on(line: &str, names: &[String]) -> Option<String> {
    for name in names {
        let mut from = 0;
        while let Some(p) = find_token(line, name, from) {
            from = p + name.len();
            let after = line[from..].trim_start();
            if let Some(rest) = after.strip_prefix('.') {
                let rest = rest.trim_start();
                for m in ITER_METHODS {
                    if rest.starts_with(m)
                        && rest[m.len()..].trim_start().starts_with('(')
                    {
                        return Some(name.clone());
                    }
                }
            }
            // `for x in name` / `for x in &name` / `for x in &mut name`
            let before = &line[..p];
            let trimmed = before.trim_end();
            let bare = trimmed
                .strip_suffix("&mut")
                .or_else(|| trimmed.strip_suffix('&'))
                .unwrap_or(trimmed);
            if bare.trim_end().ends_with(" in") && find_token(line, "for", 0).is_some() {
                return Some(name.clone());
            }
        }
    }
    None
}

const LOCKISH: [&str; 8] = [
    "lock",
    "wait",
    "wait_timeout",
    "send",
    "try_send",
    "recv",
    "try_recv",
    "recv_timeout",
];

/// If this line calls a lock/condvar/channel method and immediately
/// unwraps/expects its result, return the method name.
fn lockish_unwrap(line: &str) -> Option<&'static str> {
    let b = line.as_bytes();
    for m in LOCKISH {
        let pat = format!(".{m}(");
        let mut from = 0;
        while let Some(p) = line[from..].find(&pat) {
            let p = from + p;
            from = p + 1;
            // Method-name boundary: `.lock(` must not match `.unlock(`.
            let end = p + 1 + m.len();
            if end < b.len() && is_word(b[end]) {
                continue;
            }
            // Find the matching close paren of the call.
            let open = p + pat.len() - 1;
            let mut depth = 0i32;
            let mut close = None;
            for (k, &c) in b.iter().enumerate().skip(open) {
                if c == b'(' {
                    depth += 1;
                } else if c == b')' {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(k);
                        break;
                    }
                }
            }
            let Some(close) = close else { continue };
            let rest = line[close + 1..].trim_start();
            if rest.starts_with(".unwrap()") || rest.starts_with(".expect(") {
                return Some(m);
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------

/// Recursively analyze every `.rs` file under `root` (sorted walk, so
/// output order is stable). Returns findings plus the number of files
/// scanned.
pub fn analyze_tree(root: &Path) -> std::io::Result<(Vec<Finding>, usize)> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .expect("collected under root")
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(analyze_file(&rel, &text));
    }
    Ok((findings, files.len()))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripper_preserves_line_count_and_blanks_literals() {
        let src = "let a = \"has // no comment\"; // real comment\n\
                   let b = r#\"raw \"quoted\" text\"#;\n\
                   /* block\n   spanning */ let c = 'x';\n\
                   let d = \"continued \\\n    string\";\n";
        let code = strip_code(src);
        assert_eq!(src.matches('\n').count(), code.matches('\n').count());
        assert!(!code.contains("no comment"));
        assert!(!code.contains("real comment"));
        assert!(!code.contains("raw"));
        assert!(!code.contains("spanning"));
        assert!(code.contains("let a"));
        assert!(code.contains("let c"));
        // The continuation backslash's newline survives.
        assert_eq!(code.split('\n').count(), src.split('\n').count());
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let code = strip_code("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(code.contains("'a>"));
        assert!(code.contains("&'a str"));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("let x: f64 = 0.0;", "f64"));
        assert!(!has_token("let f64x = 0;", "f64"));
        assert!(!has_token("unsafe_op_in_unsafe_fn", "unsafe"));
        assert!(has_token("unsafe { }", "unsafe"));
    }

    #[test]
    fn statement_window_spans_chains() {
        let lines = ["let x = v", "    .iter()", "    .sum();", "let y = 1;"];
        let refs: Vec<&str> = lines.to_vec();
        let w = statement_window(&refs, 2);
        assert!(w.contains("let x"));
        assert!(!w.contains("let y"));
    }

    #[test]
    fn hash_bindings_found() {
        let code = "struct S { entries: Mutex<u32>, m: HashMap<u64, u32> }\n\
                    fn f() { let mut set = HashSet::new(); let v: Vec<u32> = vec![]; }";
        let names = hash_bindings(code);
        assert_eq!(names, vec!["m".to_string(), "set".to_string()]);
    }

    #[test]
    fn lockish_unwrap_matches_calls_with_args() {
        assert_eq!(lockish_unwrap("self.cv.wait(guard).unwrap();"), Some("wait"));
        assert_eq!(lockish_unwrap("let g = self.state.lock().unwrap();"), Some("lock"));
        assert_eq!(lockish_unwrap("tx.send(Ok(resp)).expect(\"x\");"), Some("send"));
        assert_eq!(lockish_unwrap("let _ = tx.send(Ok(resp));"), None);
        assert_eq!(lockish_unwrap("sync::lock(&self.state)"), None);
    }

    #[test]
    fn waiver_requires_justification() {
        let findings = analyze_file(
            "ig/x.rs",
            "// nuig:allow(float-reduce):\nfn f(v: &[f64]) -> f64 { v.iter().sum() }\n",
        );
        assert!(findings.iter().any(|f| f.message.contains("missing a justification")));
        assert!(
            findings.iter().any(|f| f.lint == "float-reduce"),
            "unjustified waiver must not suppress"
        );
    }

    #[test]
    fn waiver_with_justification_suppresses() {
        let findings = analyze_file(
            "ig/x.rs",
            "// nuig:allow(float-reduce): ordered Vec iteration\n\
             fn f(v: &[f64]) -> f64 { v.iter().sum() }\n",
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn float_reduce_covers_exec_simd() {
        let findings = analyze_file(
            "exec/simd.rs",
            "fn f(acc: &[f64]) -> f64 { acc.iter().sum() }\n",
        );
        assert!(
            findings.iter().any(|f| f.lint == "float-reduce"),
            "exec/simd.rs is in float-reduce scope: {findings:?}"
        );
    }

    #[test]
    fn float_reduce_unwaivable_in_exec_simd() {
        // A fully-justified waiver that would suppress anywhere else is
        // itself a finding in exec/simd.rs, and does not suppress.
        let findings = analyze_file(
            "exec/simd.rs",
            "// nuig:allow(float-reduce): looks ordered to me\n\
             fn f(acc: &[f64]) -> f64 { acc.iter().sum() }\n",
        );
        assert!(
            findings.iter().any(|f| f.lint == WAIVER_LINT && f.message.contains("cannot be waived")),
            "waiver must be rejected: {findings:?}"
        );
        assert!(
            findings.iter().any(|f| f.lint == "float-reduce"),
            "rejected waiver must not suppress: {findings:?}"
        );
        // The same waiver in kernel scope outside simd still suppresses.
        let ok = analyze_file(
            "ig/x.rs",
            "// nuig:allow(float-reduce): looks ordered to me\n\
             fn f(acc: &[f64]) -> f64 { acc.iter().sum() }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn wallclock_covers_exec_simd() {
        let findings = analyze_file(
            "exec/simd.rs",
            "fn f() { let t = std::time::Instant::now(); let _ = t; }\n",
        );
        assert!(
            findings.iter().any(|f| f.lint == "wallclock-kernel"),
            "exec/simd.rs is kernel scope for wallclock: {findings:?}"
        );
    }
}
