//! `cargo run -p nuig-analyze [-- <path>]` — scan a Rust source tree
//! (default: the repo's `rust/src`) with the nuig invariant lints and
//! exit nonzero on any finding. CI runs this on every push; see
//! `docs/INVARIANTS.md` for what each lint protects.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        None => {
            // tools/nuig-analyze -> repo root -> rust/src
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
        }
    };
    let (findings, scanned) = match nuig_analyze::analyze_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("nuig-analyze: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("nuig-analyze: {scanned} files clean ({} lints)", nuig_analyze::LINTS.len());
        ExitCode::SUCCESS
    } else {
        println!("nuig-analyze: {} finding(s) in {scanned} files", findings.len());
        ExitCode::FAILURE
    }
}
