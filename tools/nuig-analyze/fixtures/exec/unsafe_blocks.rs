//! unsafe-safety fixtures: an undocumented `unsafe` flags everywhere —
//! including inside `#[cfg(test)]`, which exempts the determinism lints
//! but never this one. Never compiled — analyzer input only.

pub fn undocumented(ptr: *const u32) -> u32 {
    unsafe { *ptr } //~ unsafe-safety
}

pub fn documented(slice: &[u32]) -> u32 {
    // SAFETY: index 0 is in bounds — the caller guarantees a non-empty
    // slice, asserted in debug builds on the line below.
    debug_assert!(!slice.is_empty());
    unsafe { *slice.get_unchecked(0) }
}

// Padding so the documented block's SAFETY comment falls outside the
// 24-line lookback window of the test-module unsafe below — the flag
// there must come from its own missing comment, not window spillover.
pub fn pad_a(x: u32) -> u32 {
    x + 1
}

pub fn pad_b(x: u32) -> u32 {
    x + 2
}

pub fn pad_c(x: u32) -> u32 {
    x + 3
}

pub fn pad_d(x: u32) -> u32 {
    x + 4
}

pub fn pad_e(x: u32) -> u32 {
    x + 5
}

pub fn pad_f(x: u32) -> u32 {
    x + 6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsafe_in_tests_still_needs_safety() {
        let x = 7u32;
        let r = unsafe { *(&x as *const u32) }; //~ unsafe-safety
        assert_eq!(undocumented(&r), 7);
    }
}
