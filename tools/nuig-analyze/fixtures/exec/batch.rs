//! Allowlist proof: `exec/batch.rs` is the one blessed ordered-reduce
//! site, so the float `.sum()` below is NOT a finding (no marker). The
//! allowlist is per-lint: wall-clock reads in the same file still flag.
//! Never compiled — analyzer input only.

pub fn ordered_commit(partials: &[f64]) -> f64 {
    let total: f64 = partials.iter().sum();
    total
}

pub fn timed_commit(partials: &[f64]) -> (f64, std::time::Duration) {
    let start = std::time::Instant::now(); //~ wallclock-kernel
    let total: f64 = partials.iter().sum();
    (total, start.elapsed())
}
