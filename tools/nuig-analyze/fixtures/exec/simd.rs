//! Seeded violations for the `exec/simd.rs` lane-kernel scope: the
//! float-reduce lint applies there (lane-major reduction order is the
//! I13 invariant, not a style choice) and — unlike everywhere else —
//! cannot be waived: a justified `nuig:allow(float-reduce)` is itself
//! a waiver finding and does not suppress. Wallclock-kernel also
//! covers the module (kernel scope).
//!
//! This file is never compiled — it is input data for the analyzer.

use std::time::Instant;

pub fn out_of_order_lane_reduce(acc: &[f64; 8]) -> f64 {
    // A reversed horizontal reduce: different bits than the canonical
    // sequential left fold, so the lint must flag it.
    let total: f64 = acc.iter().rev().fold(0.0, |t, v| t + v); //~ float-reduce
    total
}

pub fn waived_lane_reduce(acc: &[f64; 8]) -> f64 {
    // nuig:allow(float-reduce): lanes reduce in slice order — looks sequential
    let total: f64 = acc.iter().sum(); //~ float-reduce
    //~^^ waiver
    total
}

pub fn in_order_lane_reduce(acc: &[f64; 8]) -> f64 {
    // The canonical form: an explicit indexed left fold. Clean.
    let mut total = acc[0];
    for &v in &acc[1..] {
        total += v;
    }
    total
}

pub fn timed_reduce(acc: &[f64; 8]) -> f64 {
    let start = Instant::now(); //~ wallclock-kernel
    let _ = start.elapsed();
    in_order_lane_reduce(acc)
}
