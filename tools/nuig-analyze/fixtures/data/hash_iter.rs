//! hash-iter fixtures outside the serving scope: the lint is global,
//! because hash-order nondeterminism poisons whatever accumulates the
//! result, wherever it lives. Never compiled — analyzer input only.

use std::collections::{HashMap, HashSet};

pub fn sum_in_hash_order(weights: &HashMap<u64, f64>) -> f64 {
    let mut total = 0.0;
    for (_, w) in weights.iter() { //~ hash-iter
        total += w;
    }
    total
}

pub fn collect_in_hash_order(seen: &HashSet<u64>) -> Vec<u64> {
    let mut out: Vec<u64> = seen.iter().copied().collect(); //~ hash-iter
    out.sort();
    out
}

pub fn keyed_lookup_is_fine(weights: &HashMap<u64, f64>, order: &[u64]) -> f64 {
    // The blessed shape: iterate an explicitly ordered key list and use
    // the hash map only for point lookups.
    let mut total = 0.0;
    for id in order {
        total += weights.get(id).copied().unwrap_or(0.0);
    }
    total
}
