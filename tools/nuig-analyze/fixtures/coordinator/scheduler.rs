//! Tiered-scheduler violations: `coordinator/scheduler.rs` sits in the
//! serving scope for `lock-unwrap-serving`, hash-order iteration is
//! banned everywhere, and — since the work-stealing scheduler — the
//! `wallclock-kernel` lint covers this path too, so pop-deadline reads
//! must each carry an explicit waiver. Never compiled — analyzer input
//! only.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

pub struct TieredQueue {
    buckets: Mutex<Vec<Vec<u64>>>,
    not_empty: Condvar,
    staged_by_feeder: HashMap<usize, Vec<u64>>,
}

impl TieredQueue {
    pub fn pop_deadline(&self, wait: Duration) -> Instant {
        Instant::now() + wait //~ wallclock-kernel
    }

    pub fn waived_deadline(&self, wait: Duration) -> Instant {
        // nuig:allow(wallclock-kernel): pop-deadline timeout; never feeds attribution math
        Instant::now() + wait
    }

    pub fn park(&self) {
        let g = self.buckets.lock().unwrap(); //~ lock-unwrap-serving
        let _g = self.not_empty.wait(g).expect("scheduler poisoned"); //~ lock-unwrap-serving
    }

    pub fn steal_victim_order(&self) -> Vec<usize> {
        // Victim selection must be index-deterministic, never hash-order.
        let mut victims = Vec::new();
        for (feeder, _) in self.staged_by_feeder.iter() { //~ hash-iter
            victims.push(*feeder);
        }
        victims
    }
}
