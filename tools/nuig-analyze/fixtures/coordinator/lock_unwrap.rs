//! Serving-path violations: `.unwrap()`/`.expect()` on lock, condvar,
//! and channel results inside `coordinator/`, plus hash-order iteration
//! feeding a committed ordering. Never compiled — analyzer input only.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

pub struct Queue {
    state: Mutex<Vec<u64>>,
    cv: Condvar,
    by_id: HashMap<u64, usize>,
}

impl Queue {
    pub fn drain(&self) -> Vec<u64> {
        let mut g = self.state.lock().unwrap(); //~ lock-unwrap-serving
        std::mem::take(&mut g)
    }

    pub fn park(&self) {
        let g = self.state.lock().unwrap(); //~ lock-unwrap-serving
        let _g = self.cv.wait(g).expect("queue poisoned"); //~ lock-unwrap-serving
    }

    pub fn commit_order(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for (id, _) in self.by_id.iter() { //~ hash-iter
            out.push(*id);
        }
        out
    }

    pub fn helper_mediated_is_fine(&self) -> usize {
        // The blessed shape: poison-recovering helper, no raw unwrap.
        fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
            m.lock().unwrap_or_else(|p| p.into_inner())
        }
        lock(&self.state).len()
    }
}
