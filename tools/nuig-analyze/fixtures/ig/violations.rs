//! Seeded violations for the `ig/` kernel scope: float-reduce,
//! wallclock-kernel, and waiver hygiene. Expected findings carry a
//! trailing tilde-comment marker naming the lint (carets point the
//! marker N lines up, rustc-UI style); `tests/fixtures.rs` diffs the
//! marker set against the analyzer output.
//!
//! This file is never compiled — it is input data for the analyzer.

use std::time::Instant;

pub fn unordered_sum(values: &[f64]) -> f64 {
    let total: f64 = values.iter().sum(); //~ float-reduce
    total
}

pub fn unordered_fold(values: &[f32]) -> f32 {
    let total: f32 = values.iter().fold(0.0, |a, b| a + b); //~ float-reduce
    total
}

pub fn chained_sum(values: &[f64]) -> f64 {
    values
        .iter()
        .map(|v| v * 2.0)
        .sum::<f64>() //~ float-reduce
}

pub fn integer_sum_is_fine(values: &[u64]) -> u64 {
    let total: u64 = values.iter().sum();
    total
}

pub fn waived_sum(values: &[f64]) -> f64 {
    // nuig:allow(float-reduce): sequential in-order slice iteration — fixed order
    let total: f64 = values.iter().sum();
    total
}

pub fn badly_waived_sum(values: &[f64]) -> f64 {
    // nuig:allow(float-reduce):
    let total: f64 = values.iter().sum(); //~ float-reduce
    //~^^ waiver
    total
}

// nuig:allow(no-such-lint): believed harmless
//~^ waiver
pub fn misnamed_waiver() {}

pub fn timed_kernel() -> std::time::Duration {
    let start = Instant::now(); //~ wallclock-kernel
    start.elapsed()
}

#[cfg(test)]
mod tests {
    // The determinism lints stop at the first #[cfg(test)]: test-internal
    // sums never feed a committed attribution, so none of these flag.
    #[test]
    fn sums_in_tests_are_exempt() {
        let v: Vec<f64> = vec![1.0, 2.0];
        let s: f64 = v.iter().sum();
        let start = std::time::Instant::now();
        assert!(s == 3.0 && start.elapsed().as_secs() < 1);
    }
}
