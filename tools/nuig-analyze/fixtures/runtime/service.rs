//! Serving-path violations in the `runtime/service.rs` scope: channel
//! unwraps that would cascade a panicked peer into a dead service.
//! Never compiled — analyzer input only.

use std::sync::mpsc::{Receiver, Sender};

pub fn reply(tx: &Sender<u64>, value: u64) {
    tx.send(value).unwrap(); //~ lock-unwrap-serving
}

pub fn next(rx: &Receiver<u64>) -> u64 {
    rx.recv().unwrap() //~ lock-unwrap-serving
}

pub fn reply_checked(tx: &Sender<u64>, value: u64) -> bool {
    // The blessed shape: handle the disconnect, don't unwrap it.
    tx.send(value).is_ok()
}
