//! The analyzer's acceptance gate on the real tree: `rust/src` must scan
//! clean. This runs in the default test tier, so a PR that introduces an
//! unordered float reduction, hash-order commit, kernel wall-clock read,
//! serving-path unwrap, or undocumented `unsafe` fails `cargo test`
//! before CI even reaches the dedicated analyzer job.

use std::path::PathBuf;

#[test]
fn nuig_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let (findings, scanned) = nuig_analyze::analyze_tree(&root).expect("rust/src readable");
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(
        findings.is_empty(),
        "{} finding(s) in rust/src — fix or waive with a justification",
        findings.len()
    );
    // The walk found the whole tree, not a stray subdirectory.
    assert!(scanned >= 45, "expected the full nuig tree, scanned only {scanned} files");
}
