//! Fixture expectations: every `//~ <lint>` marker in a fixture file
//! (with `//~^` / `//~^^` pointing one / two lines up, rustc-UI style)
//! must correspond to exactly one analyzer finding, and vice versa —
//! the diff is asserted per file, so a lint that over- or under-fires
//! names the exact line it got wrong.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn fixtures_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

/// `(1-based line, lint)` pairs declared by `//~` markers.
fn expected_markers(rel: &str, text: &str) -> BTreeSet<(usize, String)> {
    let mut out = BTreeSet::new();
    for (idx, line) in text.split('\n').enumerate() {
        let Some(p) = line.find("//~") else { continue };
        let rest = &line[p + 3..];
        let carets = rest.bytes().take_while(|&b| b == b'^').count();
        let lint = rest[carets..].trim();
        assert!(!lint.is_empty(), "{rel}:{}: empty //~ marker", idx + 1);
        assert!(
            idx + 1 > carets,
            "{rel}:{}: marker points above the file start",
            idx + 1
        );
        out.insert((idx + 1 - carets, lint.to_string()));
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("fixtures dir readable") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn fixtures_match_their_markers() {
    let root = fixtures_root();
    let mut files = Vec::new();
    collect_rs(&root, &mut files);
    files.sort();
    assert!(files.len() >= 6, "fixture suite went missing: {files:?}");

    let mut total_expected = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path).expect("fixture readable");
        let rel = path
            .strip_prefix(&root)
            .expect("under fixtures root")
            .to_string_lossy()
            .replace('\\', "/");
        let want = expected_markers(&rel, &text);
        let got: BTreeSet<(usize, String)> = nuig_analyze::analyze_file(&rel, &text)
            .into_iter()
            .map(|f| (f.line, f.lint.to_string()))
            .collect();
        assert_eq!(
            got, want,
            "{rel}: analyzer findings (left) diverge from //~ markers (right)"
        );
        total_expected += want.len();
    }
    // Guard against a marker-parsing regression silently emptying the
    // suite: the seeded violations cover every lint at least once.
    assert!(total_expected >= 12, "only {total_expected} markers found");
}

#[test]
fn every_lint_is_exercised_by_a_fixture() {
    let root = fixtures_root();
    let mut files = Vec::new();
    collect_rs(&root, &mut files);
    let mut seen = BTreeSet::new();
    for path in &files {
        let text = std::fs::read_to_string(path).expect("fixture readable");
        let rel = path
            .strip_prefix(&root)
            .expect("under fixtures root")
            .to_string_lossy()
            .replace('\\', "/");
        for f in nuig_analyze::analyze_file(&rel, &text) {
            seen.insert(f.lint);
        }
    }
    for lint in nuig_analyze::LINTS {
        assert!(seen.contains(lint), "no fixture exercises `{lint}`");
    }
    assert!(
        seen.contains(nuig_analyze::WAIVER_LINT),
        "no fixture exercises waiver hygiene"
    );
}
