//! Convergence sweep (Fig. 5 working data): δ vs m for the uniform
//! baseline and the non-uniform scheme at several interval counts,
//! averaged over a small corpus, plus the iso-convergence step counts.
//!
//!     cargo run --release --example convergence_sweep -- [per_class_images]

use nuig::bench::{fmt3, Table};
use nuig::data::Corpus;
use nuig::ig::{self, convergence::ConvergencePolicy, IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let per_class: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(1);
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let corpus = Corpus::eval_set(4 * per_class.max(1));

    let schemes = [
        Scheme::Uniform,
        Scheme::NonUniform { n_int: 2 },
        Scheme::NonUniform { n_int: 4 },
        Scheme::NonUniform { n_int: 8 },
    ];
    let grid = [8usize, 16, 32, 64, 128];

    let mut table = Table::new("delta vs m (mean over corpus)", &["m", "scheme", "delta"]);
    let mut uniform_curve = Vec::new();
    for &m in &grid {
        for &scheme in &schemes {
            if let Scheme::NonUniform { n_int } = scheme {
                if m < n_int {
                    continue;
                }
            }
            let mut acc = 0.0;
            for li in corpus.iter() {
                let opts = IgOptions { scheme, m, ..Default::default() };
                acc += ig::explain(&model, &li.pixels, None, &opts)?.delta;
            }
            let mean = acc / corpus.len() as f64;
            if scheme == Scheme::Uniform {
                uniform_curve.push((m, mean));
            }
            table.row(vec![m.to_string(), scheme.to_string(), fmt3(mean)]);
        }
    }
    table.print();

    // Iso-convergence: steps to reach the uniform baseline's delta at
    // m in {16, 32, 64} (relative thresholds; see DESIGN.md §4).
    let mut iso = Table::new(
        "steps to reach threshold (first image)",
        &["delta_th", "scheme", "m_required", "reduction_vs_uniform"],
    );
    let img = &corpus.images[0].pixels;
    for &(m_ref, th) in &uniform_curve {
        if !(16..=64).contains(&m_ref) {
            continue;
        }
        let policy = ConvergencePolicy::new(th);
        let mut m_uniform = None;
        for &scheme in &schemes {
            let (m_req, _, ok) = policy.search(|m| {
                if let Scheme::NonUniform { n_int } = scheme {
                    if m < n_int {
                        return Ok::<f64, anyhow::Error>(f64::INFINITY);
                    }
                }
                Ok(ig::explain(&model, img, None, &IgOptions { scheme, m, ..Default::default() })?.delta)
            })?;
            if scheme == Scheme::Uniform {
                m_uniform = Some(m_req);
            }
            let red = m_uniform.map(|mu| mu as f64 / m_req as f64).unwrap_or(1.0);
            iso.row(vec![
                format!("{th:.5}"),
                scheme.to_string(),
                if ok { m_req.to_string() } else { format!(">{m_req}") },
                format!("{red:.2}x"),
            ]);
        }
    }
    iso.print();
    Ok(())
}
