//! Heatmap gallery: render overlay heatmaps (the paper's Fig. 1(c)
//! presentation) for one image per class, with both schemes, and verify
//! they agree visually (cosine similarity) — then write PPMs to
//! `heatmaps/`.
//!
//!     cargo run --release --example heatmap_gallery

use nuig::data::Corpus;
use nuig::ig::{self, IgOptions, Scheme};
use nuig::runtime::Runtime;
use nuig::viz::{self, HeatmapOptions};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let out_dir = std::path::Path::new("heatmaps");
    std::fs::create_dir_all(out_dir)?;

    println!("{:<8} {:>7} {:>11} {:>11} {:>9}  file", "class", "target", "delta(uni)", "delta(non)", "cosine");
    for li in Corpus::eval_set(8).iter() {
        let uni = ig::explain(
            &model,
            &li.pixels,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 64, ..Default::default() },
        )?;
        let non = ig::explain(
            &model,
            &li.pixels,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 64, ..Default::default() },
        )?;

        let overlay = viz::render_overlay(&li.pixels, &non.values, &HeatmapOptions::default())?;
        let heat = viz::render_heatmap(&non.values, &HeatmapOptions::default())?;
        let f_overlay = out_dir.join(format!("class{}_overlay.ppm", li.class));
        let f_heat = out_dir.join(format!("class{}_heat.ppm", li.class));
        overlay.write(&f_overlay)?;
        heat.write(&f_heat)?;

        println!(
            "{:<8} {:>7} {:>11.6} {:>11.6} {:>9.5}  {}",
            li.class,
            non.target,
            uni.delta,
            non.delta,
            uni.cosine_similarity(&non),
            f_overlay.display()
        );
    }
    println!("\nwrote 16 PPM files to {}/", out_dir.display());
    Ok(())
}
