//! Latency-budgeted serving quickstart — the README's serving snippet,
//! kept compiling by CI (`cargo test` builds every example; clippy runs
//! `--all-targets`). If you edit this file, update the README's
//! "Serving with latency budgets" snippet to match.
//!
//!     make artifacts && cargo run --release --example serving
//!
//! What it shows, end to end:
//!
//! 1. a coordinator started with the probe-schedule cache enabled;
//! 2. one **cold** tight-tier request (pays the stage-1 probe, populates
//!    the cache), then warm tight-tier traffic (zero probe passes);
//! 3. a thorough-tier request on the same stack (anytime refinement to
//!    the tier's convergence target);
//! 4. the per-tier and cache counters the coordinator exposes.

use nuig::config::{AdmissionConfig, CoordinatorConfig};
use nuig::coordinator::{Coordinator, ExplainRequest, LatencyBudget};
use nuig::data::synth;
use nuig::ig::{IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // --- README snippet starts here -------------------------------------
    let rt = Runtime::load_default("artifacts")?;
    let cfg = CoordinatorConfig {
        // Enable the probe-schedule cache (off by default).
        admission: AdmissionConfig { cache_capacity: 256, ..Default::default() },
        ..Default::default()
    };
    let coord = Coordinator::start(&rt, cfg)?;

    let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() };

    // Tight tier, pinned target: the first request probes and populates
    // the cache; later requests for the same class skip stage 1 entirely.
    for index in 0..4 {
        let req = ExplainRequest::new(synth::gen_image(2, index), opts)
            .with_budget(LatencyBudget::Tight)
            .with_target(2);
        let resp = coord.explain(req)?;
        println!(
            "tight    #{index}: {} gradient evals + {} probe passes, delta {:.5}, {:?}",
            resp.attribution.steps,
            resp.attribution.probe_passes,
            resp.attribution.delta,
            resp.total_latency
        );
    }

    // Thorough tier: anytime refinement to the tier's convergence target.
    let req = ExplainRequest::new(synth::gen_image(2, 9), opts)
        .with_budget(LatencyBudget::Thorough);
    let resp = coord.explain(req)?;
    println!(
        "thorough   : {} evals over {} rounds, delta {:.5}",
        resp.attribution.steps, resp.attribution.rounds, resp.attribution.delta
    );

    // Per-tier + cache accounting.
    let stats = coord.stats();
    let tight = stats.tier(LatencyBudget::Tight);
    println!(
        "tight tier : {} completed, {} warm (zero-probe), e2e {}",
        tight.completed.get(),
        tight.warm_admissions.get(),
        tight.e2e_latency.format_ms()
    );
    println!(
        "cache      : {:.0}% hit rate ({} hits / {} misses / {} evictions)",
        100.0 * stats.cache.hit_rate(),
        stats.cache.hits.get(),
        stats.cache.misses.get(),
        stats.cache.evictions.get()
    );
    coord.shutdown();
    // --- README snippet ends here ---------------------------------------

    Ok(())
}
