//! End-to-end serving driver (the repo's mandated E2E validation): load
//! the real AOT model, run the coordinator under a concurrent stream of
//! explanation requests over the synthetic corpus, and report latency /
//! throughput / batching / correctness — proving all three layers
//! (Pallas kernels → JAX model → Rust coordinator) compose.
//!
//!     make artifacts && cargo run --release --example serve -- [requests] [workers]
//!
//! The run is recorded in docs/EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use nuig::config::CoordinatorConfig;
use nuig::coordinator::{Coordinator, ExplainRequest};
use nuig::data::Corpus;
use nuig::ig::{IgOptions, Scheme};
use nuig::metrics::Summary;
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let workers: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(2);

    println!("== nuig end-to-end serving driver ==");
    let t0 = Instant::now();
    let rt = Runtime::load_default("artifacts")?;
    println!(
        "loaded {} executables ({} params) in {:.2?}",
        rt.manifest.executables.len(),
        rt.manifest.num_params,
        t0.elapsed()
    );

    let coord = Coordinator::start(&rt, CoordinatorConfig { workers, ..Default::default() })?;
    let corpus = Corpus::generate(4); // 32 distinct images

    // Mixed workload: 75% non-uniform (the paper's scheme), 25% uniform
    // baseline, m spread over the working range.
    let mk_req = |i: usize| {
        let img = corpus.images[i % corpus.len()].pixels.clone();
        let scheme = if i % 4 == 3 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
        let m = [16, 32, 48, 64][i % 4];
        ExplainRequest::new(img, IgOptions { scheme, m, ..Default::default() })
    };

    // Warm-up (compile paths, caches) — mirrors the paper's profiler
    // protocol of unmeasured warm-up iterations.
    for i in 0..4 {
        coord.explain(mk_req(i))?;
    }

    println!("submitting {n_requests} requests ({workers} router workers, chunk 16)...");
    let t1 = Instant::now();
    let handles: Vec<_> = (0..n_requests).map(|i| coord.submit(mk_req(i))).collect::<Result<_, _>>()?;

    let mut latencies = Summary::new();
    let mut stage1 = Summary::new();
    let mut max_delta = 0f64;
    let mut steps_total = 0usize;
    for h in handles {
        let resp = h.wait()?;
        latencies.record(resp.total_latency.as_secs_f64());
        stage1.record(resp.attribution.breakdown.stage1_fraction());
        max_delta = max_delta.max(resp.attribution.delta);
        steps_total += resp.attribution.steps;
    }
    let wall = t1.elapsed();

    let stats = coord.stats();
    let rstats = rt.stats();
    println!("\n-- results --------------------------------------------");
    println!("completed            : {} requests in {wall:.2?}", stats.completed.get());
    println!("throughput           : {:.2} explanations/s", n_requests as f64 / wall.as_secs_f64());
    println!(
        "gradient-point rate  : {:.0} points/s",
        steps_total as f64 / wall.as_secs_f64()
    );
    println!(
        "e2e latency          : p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  max {:.1} ms",
        latencies.quantile(0.50) * 1e3,
        latencies.quantile(0.95) * 1e3,
        latencies.quantile(0.99) * 1e3,
        latencies.max() * 1e3
    );
    println!("queue wait           : {}", stats.queue_wait.format_ms());
    println!(
        "batch occupancy      : {:.1}% (cross-request continuous batching)",
        100.0 * stats.mean_occupancy(coord.config().chunk)
    );
    println!(
        "stage-1 overhead     : mean {:.2}% of request latency (paper: 0.2-3.2%)",
        100.0 * stage1.mean()
    );
    println!("max delta            : {max_delta:.6} (completeness residual, Eq. 3)");
    println!("device executions    : {}", rstats.total_executions());
    println!("failed               : {}", stats.failed.get());

    assert_eq!(stats.failed.get(), 0, "no request may fail");
    assert!(max_delta.is_finite());
    coord.shutdown();
    println!("\nOK — three-layer stack (Pallas → JAX/HLO → Rust coordinator) verified end-to-end");
    Ok(())
}
