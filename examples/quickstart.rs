//! Quickstart: explain one image with the paper's non-uniform IG and
//! compare against the uniform baseline at the same step budget.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Expected output: the non-uniform scheme reaches a smaller completeness
//! residual δ than uniform at identical m (the paper's headline effect),
//! plus an ASCII heatmap of the explanation.

use nuig::data::synth;
use nuig::ig::{self, IgOptions, Scheme};
use nuig::runtime::Runtime;
use nuig::viz;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (compiled once at startup; Python is not
    //    involved from here on).
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();

    // 2. A synthetic "ImageNet stand-in" image (class 0 = blob texture).
    let image = synth::gen_image(0, 0);

    // 3. Explain with both schemes at the same step budget m.
    let m = 32;
    let uniform = ig::explain(
        &model,
        &image,
        None, // black baseline, the paper's default
        &IgOptions { scheme: Scheme::Uniform, m, ..Default::default() },
    )?;
    let nonuniform = ig::explain(
        &model,
        &image,
        None,
        &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m, ..Default::default() },
    )?;

    println!("MiniInception predicts class {} for this image\n", uniform.target);
    println!("scheme        steps  probe  delta (Eq.3)   rel.delta");
    for (name, a) in [("uniform", &uniform), ("nonuniform:4", &nonuniform)] {
        println!(
            "{name:<13} {:>5} {:>6} {:>13.6} {:>11.4}",
            a.steps, a.probe_passes, a.delta, a.relative_delta()
        );
    }
    let improvement = uniform.delta / nonuniform.delta.max(1e-12);
    println!("\niso-step improvement: {improvement:.2}x smaller delta (paper: Fig. 5a)");
    println!(
        "attribution agreement (cosine): {:.5}\n",
        uniform.cosine_similarity(&nonuniform)
    );

    println!("non-uniform IG heatmap (attribution magnitude):");
    println!("{}", viz::ascii_heatmap(&nonuniform.values)?);
    Ok(())
}
