//! Reproduce the paper's headline claims in one run and print a
//! paper-vs-measured scorecard. The full per-figure series come from the
//! bench targets (`cargo bench`); this example distills the four headline
//! numbers:
//!
//!   1. iso-convergence step reduction      (paper: 2.7-3.6x, Fig. 5b)
//!   2. iso-convergence latency reduction   (paper: 2.6-3.6x, Fig. 6a)
//!   3. stage-1 overhead                    (paper: 0.2-3.2%, Fig. 6b)
//!   4. n_int sweet spot                    (paper: benefits up to ~8)
//!
//!     cargo run --release --example reproduce_paper

use std::time::Instant;

use nuig::bench::Table;
use nuig::data::Corpus;
use nuig::ig::{self, convergence::ConvergencePolicy, IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let corpus = Corpus::eval_set(3);

    // Warm up compile/caches so timings are steady-state.
    for li in corpus.iter() {
        ig::explain(&model, &li.pixels, None, &IgOptions { m: 8, ..Default::default() })?;
    }

    // δ_th values taken as the uniform baseline's δ at m ∈ {32, 64, 128}
    // (relative thresholds — our δ scale differs from InceptionV3's; see
    // DESIGN.md §4 "δ-scale note").
    let mut summary = Table::new(
        "headline scorecard (mean over eval images)",
        &["metric", "paper", "measured"],
    );

    let mut step_reductions: Vec<f64> = Vec::new();
    let mut latency_reductions: Vec<f64> = Vec::new();
    let mut overheads: Vec<f64> = Vec::new();

    for li in corpus.iter() {
        let img = &li.pixels;
        for m_ref in [64usize, 128, 256] {
            let base =
                ig::explain(&model, img, None, &IgOptions { scheme: Scheme::Uniform, m: m_ref, ..Default::default() })?;
            // Fine (~1.2x-spaced) grid so the measured reduction is not
            // quantized by the instrument.
            let fine: Vec<usize> = vec![
                8, 10, 12, 14, 17, 20, 24, 29, 35, 42, 50, 60, 72, 86, 104, 125, 150, 180,
                216, 260, 312, 374, 449, 539,
            ];
            let policy = ConvergencePolicy::with_grid(base.delta, fine)?;

            let mut results = std::collections::BTreeMap::new();
            for scheme in [Scheme::Uniform, Scheme::NonUniform { n_int: 4 }] {
                // Steps to threshold.
                let (m_req, _, ok) = policy.search(|m| {
                    if let Scheme::NonUniform { n_int } = scheme {
                        if m < n_int {
                            return Ok::<f64, anyhow::Error>(f64::INFINITY);
                        }
                    }
                    Ok(ig::explain(&model, img, None, &IgOptions { scheme, m, ..Default::default() })?.delta)
                })?;
                if !ok {
                    continue;
                }
                // Wall latency at that m (median of 3).
                let mut times: Vec<f64> = (0..3)
                    .map(|_| {
                        let t = Instant::now();
                        ig::explain(&model, img, None, &IgOptions { scheme, m: m_req, ..Default::default() })
                            .map(|a| (t.elapsed().as_secs_f64(), a))
                    })
                    .collect::<Result<Vec<_>, _>>()?
                    .into_iter()
                    .map(|(t, a)| {
                        if let Scheme::NonUniform { .. } = scheme {
                            overheads.push(
                                (a.breakdown.probe + a.breakdown.schedule).as_secs_f64() / t,
                            );
                        }
                        t
                    })
                    .collect();
                times.sort_by(|a, b| a.partial_cmp(b).unwrap());
                results.insert(format!("{scheme}"), (m_req, times[1]));
            }
            if let (Some(&(mu, tu)), Some(&(mn, tn))) =
                (results.get("uniform"), results.get("nonuniform(n_int=4)"))
            {
                step_reductions.push(mu as f64 / mn as f64);
                latency_reductions.push(tu / tn);
            }
        }
    }

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let minmax = |v: &[f64]| {
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (s.first().copied().unwrap_or(0.0), s.last().copied().unwrap_or(0.0))
    };

    let (sr_lo, sr_hi) = minmax(&step_reductions);
    let (lr_lo, lr_hi) = minmax(&latency_reductions);
    let (ov_lo, ov_hi) = minmax(&overheads);

    summary.row(vec![
        "iso-convergence step reduction".into(),
        "2.7x - 3.6x".into(),
        format!("{sr_lo:.1}x - {sr_hi:.1}x (mean {:.1}x)", mean(&step_reductions)),
    ]);
    summary.row(vec![
        "iso-convergence latency reduction".into(),
        "2.6x - 3.6x".into(),
        format!("{lr_lo:.1}x - {lr_hi:.1}x (mean {:.1}x)", mean(&latency_reductions)),
    ]);
    summary.row(vec![
        "stage-1 overhead (% of latency)".into(),
        "0.2% - 3.2%".into(),
        format!("{:.1}% - {:.1}% (mean {:.1}%)", 100.0 * ov_lo, 100.0 * ov_hi, 100.0 * mean(&overheads)),
    ]);

    // n_int sweep at fixed m: benefit should grow to ~4-8 then flatten or
    // degrade (the paper's "n_int > 8 manifests this issue").
    let img = &corpus.images[0].pixels;
    let m = 32;
    let base = ig::explain(&model, img, None, &IgOptions { scheme: Scheme::Uniform, m, ..Default::default() })?;
    let mut n_int_row = Vec::new();
    for n_int in [2usize, 4, 8, 16] {
        let a = ig::explain(&model, img, None, &IgOptions { scheme: Scheme::NonUniform { n_int }, m, ..Default::default() })?;
        n_int_row.push((n_int, base.delta / a.delta));
    }
    let best = n_int_row.iter().cloned().max_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
    summary.row(vec![
        "n_int sweet spot (m=32)".into(),
        "<= 8".into(),
        format!(
            "best n_int={} ({:.1}x); n_int=16 gives {:.1}x",
            best.0,
            best.1,
            n_int_row.last().unwrap().1
        ),
    ]);

    summary.print();
    println!("full series: cargo bench (fig2/fig3/fig5/fig6 + ablations); raw data in bench_output.txt");
    Ok(())
}
