//! Anytime IG: explain to a completeness target instead of a fixed step
//! count, with convergence-gated early exit and full gradient reuse
//! across refinement rounds.
//!
//!     make artifacts && cargo run --release --example anytime
//!
//! Three drivers answer the same question — "give me an explanation with
//! δ ≤ δ_th" — and report their total gradient bills:
//!
//! * the adaptive driver on the uniform baseline (refinement rounds over
//!   the step grid);
//! * the adaptive driver on the paper's non-uniform scheme (same rounds,
//!   fewer needed);
//! * `explain_anytime` directly: one coarse non-uniform schedule, then
//!   nested refinement paying only the novel midpoints each round.
//!
//! Also demos the served path: `ExplainRequest::with_anytime` makes the
//! coordinator run the rounds, re-enqueuing only novel lanes between them.

use nuig::config::CoordinatorConfig;
use nuig::coordinator::{Coordinator, ExplainRequest};
use nuig::data::synth;
use nuig::ig::{self, convergence::ConvergencePolicy, AnytimePolicy, IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let image = synth::gen_image(0, 0);

    // Target: the residual the uniform baseline reaches at m = 64.
    let delta_th = ig::explain(
        &model,
        &image,
        None,
        &IgOptions { scheme: Scheme::Uniform, m: 64, ..Default::default() },
    )?
    .delta;
    println!("target residual: delta_th = {delta_th:.6} (uniform baseline at m = 64)\n");

    // Adaptive drivers: grid-derived refinement rounds with reuse (the
    // total cost is the final round's schedule, not the sum over rounds).
    let policy = ConvergencePolicy::new(delta_th);
    for scheme in [Scheme::Uniform, Scheme::NonUniform { n_int: 4 }] {
        let opts = IgOptions { scheme, ..Default::default() };
        let res = ig::explain_to_threshold(&model, &image, None, &opts, &policy)?;
        println!(
            "adaptive {:<16} converged={} rounds={:?} total gradient evals={}",
            scheme.to_string(),
            res.converged,
            res.rounds,
            res.total_steps
        );
    }

    // Anytime: coarse start (m0 = 4 * n_int, the resolution floor for the
    // sqrt allocation), refinement reuse, early exit.
    let anytime = AnytimePolicy::new(delta_th);
    let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
    let a = ig::explain_anytime(&model, &image, None, &opts, &anytime)?;
    println!(
        "anytime  nonuniform:4     delta={:.6} rounds={} total gradient evals={}",
        a.delta, a.rounds, a.steps
    );
    println!("residual trajectory: {:?}\n", a.residuals.iter().map(|d| (d * 1e6).round() / 1e6).collect::<Vec<_>>());

    // Served: the coordinator runs the same rounds, re-enqueuing only the
    // novel midpoint lanes between them (converged requests exit early
    // and free device chunk capacity).
    let coord = Coordinator::start(&rt, CoordinatorConfig::default())?;
    let req = ExplainRequest::new(image.clone(), opts).with_anytime(anytime);
    let resp = coord.explain(req)?;
    println!(
        "served anytime: delta={:.6} rounds={} steps={} (refine rounds dispatched: {})",
        resp.attribution.delta,
        resp.attribution.rounds,
        resp.attribution.steps,
        coord.stats().refine_rounds.get()
    );
    println!(
        "mean rounds/request: {:.1}",
        coord.stats().rounds_per_request.mean()
    );
    coord.shutdown();
    Ok(())
}
