//! Interleaving models for the serving-path concurrency invariants
//! (ISSUE 6 tentpole b): run with `cargo test -p nuig --features
//! loom-models`.
//!
//! Each model re-runs its closure under every thread schedule the
//! vendored explorer (`nuig::exec::interleave`) can enumerate, with the
//! production code routed through the instrumented shims via
//! `nuig::exec::sync`. A lost notification shows up as a deadlock (the
//! modeled condvar never wakes spuriously); a broken invariant shows up
//! as an assertion failure with the offending decision trace.
//!
//! Models covered, mirroring `docs/INVARIANTS.md`:
//! * `exec::channel::bounded` — close/sender-drop wakeups, no lost
//!   notifications, parked senders observe receiver-side close.
//! * `coordinator::state::Accum` — ordered commit: the f64 sum is
//!   bit-identical under every arrival interleaving.
//! * `exec::gather::ResidentPool` — RAII eviction: an in-flight gather
//!   lane's `Arc` entry stays intact across a concurrent evict.
//! * `coordinator::scheduler::LaneScheduler` — shutdown: a closed-queue
//!   refill settles its request exactly once; parked pushes are woken by
//!   close, never leaked.
//! * `coordinator::scheduler::LaneScheduler` — work stealing (ISSUE 8):
//!   a bucket activation wakes a parked feeder (no lost wakeup), and a
//!   steal racing close delivers every staged chunk exactly once —
//!   never dropped, never double-executed.
//! * `exec::fault::FaultInjector` + `coordinator::dispatch_failover` —
//!   the elastic lifecycle handshake (ISSUE 7): the drain fence routes
//!   chunks off a draining shard, and a respawn replay racing a fresh
//!   registration lands every resident slot exactly once (no stranding,
//!   no double registration).
//! * `exec::CancelToken` + `coordinator::state` — the serving front-end's
//!   cancellation tree (ISSUE 9): a child registered concurrently with
//!   the parent's cancel never escapes it, and a deadline's partial
//!   settlement racing the feeder's completion settles the request
//!   exactly once (one reply, partial bit matching the winner — I11/I12).

#![cfg(feature = "loom-models")]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use nuig::coordinator::request::{ExplainResponse, LatencyBudget};
use nuig::coordinator::scheduler::{LaneScheduler, Policy, Popped};
use nuig::coordinator::state::{Accum, AnytimeRounds, ChunkPlan, RequestState, RoundOutcome};
use nuig::coordinator::dispatch_failover;
use nuig::exec::channel::{bounded, Receiver, RecvError};
use nuig::exec::gather::{GatherExec, GatherLane, GatherOut, ResidentPool, ShardHealth};
use nuig::exec::interleave::{explore, shim};
use nuig::exec::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use nuig::exec::sync::Mutex;
use nuig::exec::{CancelToken, FaultAction, FaultEvent, FaultInjector, FaultPlan};
use nuig::ig::schedule::Schedule;
use nuig::ig::{AnytimePolicy, IgOptions, Rule};
use nuig::metrics::StageBreakdown;

type ReplyRx = Receiver<anyhow::Result<ExplainResponse>>;

/// A minimal in-flight request for the models: `features`-wide
/// accumulator, `n_lanes` outstanding, reply over a fresh shim-routed
/// channel. Everything is created inside the model closure (resource
/// identity is per-execution).
fn mk_state(
    n_lanes: usize,
    features: usize,
    gap: f64,
    anytime: Option<AnytimeRounds>,
) -> (Arc<RequestState>, ReplyRx) {
    let (tx, rx) = bounded(1);
    let st = Arc::new(RequestState {
        id: 1,
        image: Arc::new(vec![1.0; features]),
        baseline: Arc::new(vec![0.0; features]),
        target: 0,
        opts: IgOptions::default(),
        budget: LatencyBudget::Unbounded,
        acc: Mutex::new(Accum::new(features)),
        remaining: AtomicUsize::new(n_lanes),
        steps: n_lanes,
        probe_passes: 0,
        endpoint_gap: gap,
        breakdown: Mutex::new(StageBreakdown::default()),
        submitted_at: Instant::now(),
        queue_wait: Duration::ZERO,
        reply: tx,
        completed: AtomicBool::new(false),
        in_flight: Arc::new(AtomicUsize::new(1)),
        anytime,
        resident: None,
        last_round: Mutex::new(None),
        round_tx: None,
    });
    (st, rx)
}

/// Anytime state that refines exactly once: m0 = 2 (3 lanes) with
/// `max_m` = 4, so round 1 refines to the two novel midpoints and
/// round 2 must finalize regardless of the residual.
fn one_refinement_round() -> AnytimeRounds {
    let schedule = Schedule::uniform(2, Rule::Trapezoid).expect("valid uniform schedule");
    AnytimeRounds {
        policy: AnytimePolicy::with_max_m(1e-12, 4).unwrap(),
        evals: AtomicUsize::new(schedule.len()),
        schedule: Mutex::new(schedule),
        residuals: Mutex::new(Vec::new()),
    }
}

// ---------------------------------------------------------------------
// exec::channel::bounded
// ---------------------------------------------------------------------

#[test]
fn channel_sender_drop_wakes_receiver() {
    // The receiver may park before the send, after the send, or after
    // the drop; in every schedule it must get the item and then the
    // close — never a lost wakeup (deadlock), never a lost item.
    let report = explore(|| {
        let (tx, rx) = bounded::<u32>(1);
        let h = shim::spawn(move || {
            tx.send(7).unwrap();
            // tx drops here: last sender gone => channel closes.
        });
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError));
        h.join().unwrap();
    });
    assert!(report.exhausted, "explored {} schedules", report.executions);
    assert!(report.executions > 1);
}

#[test]
fn channel_receiver_close_wakes_parked_sender() {
    // Queue full, a sender parked on backpressure, the receiver closes:
    // the parked send must fail — not succeed, not park forever.
    let report = explore(|| {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let tx2 = tx.clone();
        let h = shim::spawn(move || tx2.send(2));
        rx.close();
        assert!(h.join().unwrap().is_err(), "send must observe the close");
        // In-flight items still drain after close.
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Err(RecvError));
    });
    assert!(report.exhausted, "explored {} schedules", report.executions);
}

#[test]
fn channel_send_recv_no_lost_notification() {
    // Two sends through a capacity-1 queue: the second send parks until
    // the first recv; both wakeup directions (not_empty, not_full) are
    // exercised under every schedule.
    let report = explore(|| {
        let (tx, rx) = bounded::<u32>(1);
        let h = shim::spawn(move || {
            tx.send(1).unwrap();
            tx.send(2).unwrap();
        });
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.recv(), Err(RecvError));
        h.join().unwrap();
    });
    assert!(report.exhausted, "explored {} schedules", report.executions);
}

// ---------------------------------------------------------------------
// coordinator::state::Accum — ordered commit + parking
// ---------------------------------------------------------------------

#[test]
fn accum_commit_is_schedule_order_invariant() {
    // Two feeder threads land one lane each, in every interleaving the
    // explorer can produce (including the out-of-order one that parks
    // lane 1). The committed f64 sums must be BIT-identical across all
    // schedules: commits happen in lane-index order, not arrival order.
    let row_a: [f32; 2] = [0.1, -2.5];
    let row_b: [f32; 2] = [0.37, 1.0];
    let expected: Vec<u64> = (0..2)
        .map(|j| (row_a[j] as f64 + row_b[j] as f64).to_bits())
        .collect();
    let report = explore(move || {
        let (st, rx) = mk_state(2, 2, 0.0, None);
        let st1 = st.clone();
        let h1 = shim::spawn(move || {
            if st1.add_lane(0, &[0.1, -2.5]) {
                assert!(st1.finalize());
            }
        });
        let st2 = st.clone();
        let h2 = shim::spawn(move || {
            if st2.add_lane(1, &[0.37, 1.0]) {
                assert!(st2.finalize());
            }
        });
        h1.join().unwrap();
        h2.join().unwrap();
        let resp = rx.recv().unwrap().unwrap();
        let bits: Vec<u64> = resp.attribution.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, expected, "ordered commit must be 0 ULP across schedules");
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0, "settled exactly once");
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

#[test]
fn settlement_race_completes_exactly_once() {
    // A late device failure racing the finalizing feeder: exactly one
    // side settles the request (in_flight hits 0, never underflows, the
    // reply channel carries exactly one message).
    let report = explore(|| {
        let (st, rx) = mk_state(1, 1, 0.0, None);
        let st1 = st.clone();
        let h = shim::spawn(move || {
            if st1.add_lane(0, &[1.0]) {
                st1.finalize();
            }
        });
        let failed = st.fail(anyhow::anyhow!("device down"));
        h.join().unwrap();
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        // Exactly one settlement message, whichever side won.
        let first = rx.recv().expect("one settlement must be delivered");
        assert_eq!(first.is_err(), failed);
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

// ---------------------------------------------------------------------
// exec::gather::ResidentPool — RAII eviction vs in-flight lanes
// ---------------------------------------------------------------------

#[test]
fn resident_entry_survives_concurrent_evict() {
    // A gather lane that resolved its slot to an `Arc` entry keeps
    // working data even when settlement evicts the slot mid-chunk; a
    // lane that resolves after the evict sees a clean None — never torn
    // state, in every schedule.
    let report = explore(|| {
        let pool = Arc::new(ResidentPool::new());
        pool.register(1, &[3.5, 0.5], &[0.0, 0.25]).unwrap();
        let pool2 = pool.clone();
        let h = shim::spawn(move || match pool2.entry(1) {
            Some(e) => {
                // In-flight lane: the entry must be fully intact.
                assert_eq!(e.0, vec![3.5, 0.5]);
                assert_eq!(e.1, vec![0.0, 0.25]);
                true
            }
            None => false,
        });
        let evicted = pool.evict(1);
        assert!(evicted, "first evict always wins");
        let lane_saw_entry = h.join().unwrap();
        // Whichever order the schedule chose, the slot is gone now.
        assert!(pool.entry(1).is_none());
        assert!(pool.is_empty());
        let _ = lane_saw_entry; // both outcomes are legal; torn state is not
    });
    assert!(report.exhausted, "explored {} schedules", report.executions);
    assert!(report.executions > 1);
}

// ---------------------------------------------------------------------
// coordinator::scheduler::LaneScheduler — shutdown protocol
// ---------------------------------------------------------------------

/// One request's chunk plans for the scheduler models (built on a fresh
/// shim-routed `RequestState`).
fn mk_plans(
    n: usize,
    chunk: usize,
    anytime: Option<AnytimeRounds>,
) -> (Arc<RequestState>, ReplyRx, Vec<ChunkPlan>) {
    let (st, rx) = mk_state(n, 1, 0.0, anytime);
    let points: Vec<(f32, f32)> = (0..n).map(|k| (k as f32 / n as f32, 1.0)).collect();
    let plans = ChunkPlan::build(&st, &points, chunk);
    (st, rx, plans)
}

#[test]
fn scheduler_close_wakes_parked_push() {
    // A router parked on the capacity gate must fail cleanly when the
    // coordinator closes the queue — not park forever (lost not_full
    // notification), not enqueue after close.
    let report = explore(|| {
        let s = Arc::new(LaneScheduler::new(Policy::Fifo, 2));
        let (_st1, _rx1, plans1) = mk_plans(2, 2, None);
        s.push_request(1, plans1).unwrap();
        let s2 = s.clone();
        let h = shim::spawn(move || {
            let (_st2, _rx2, plans2) = mk_plans(1, 1, None);
            s2.push_request(2, plans2).is_err()
        });
        s.close();
        assert!(h.join().unwrap(), "push must fail after close, not block");
        // The admitted request still drains, then Closed.
        match s.pop_chunk(4, Duration::ZERO) {
            Popped::Chunk(c) => assert_eq!(c.len(), 2),
            Popped::Closed => panic!("queued lanes must drain before Closed"),
        }
        assert!(matches!(s.pop_chunk(4, Duration::ZERO), Popped::Closed));
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

#[test]
fn scheduler_refill_vs_close_settles_exactly_once() {
    // Satellite 3: the feeder completes an anytime round while the
    // coordinator shuts the lane queue down. In every interleaving the
    // request must settle exactly once with an Ok response — either the
    // refill lands (round 2 runs to completion) or the closed queue
    // rejects it (the refinement is rolled back and the completed
    // round's attribution is delivered).
    let report = explore(|| {
        let s = Arc::new(LaneScheduler::new(Policy::Fifo, 64));
        let (st, rx, plans) = mk_plans(3, 3, Some(one_refinement_round()));
        s.push_request(1, plans).unwrap();
        let s2 = s.clone();
        let closer = shim::spawn(move || s2.close());

        // Feeder: drain round 1 (3 lanes are already queued; pop drains
        // them even after close).
        let lanes = match s.pop_chunk(3, Duration::ZERO) {
            Popped::Chunk(c) => c,
            Popped::Closed => panic!("queued round-1 lanes must drain"),
        };
        assert_eq!(lanes.len(), 3);
        let mut complete = false;
        for l in &lanes {
            complete = l.state.add_lane(l.idx, &[1.0]);
        }
        assert!(complete, "last lane of the round flips the countdown");
        match st.on_round_complete(3) {
            RoundOutcome::Refine(next) => {
                let novel: usize = next.iter().map(|p| p.len()).sum();
                assert_eq!(novel, 2, "m 2 -> 4 adds the two midpoints");
                if s.push_refill(1, next).is_ok() {
                    // Refill won the race: run round 2 to completion.
                    let lanes = match s.pop_chunk(2, Duration::ZERO) {
                        Popped::Chunk(c) => c,
                        Popped::Closed => panic!("refill lanes must drain"),
                    };
                    assert_eq!(lanes.len(), 2);
                    let mut done = false;
                    for l in &lanes {
                        done = l.state.add_lane(l.idx, &[1.0]);
                    }
                    assert!(done);
                    assert!(matches!(st.on_round_complete(3), RoundOutcome::Finalize));
                } else {
                    // Close won: roll the refinement back, deliver the
                    // completed round unchanged.
                    st.abort_refinement(novel);
                }
            }
            RoundOutcome::Finalize => panic!("round 1 must refine (target 1e-12)"),
        }
        assert!(st.finalize(), "exactly one settlement");
        assert!(!st.finalize(), "second finalize must be a no-op");
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        let resp = rx.recv().unwrap().expect("anytime best effort is Ok");
        // Round-1 sum is 3.0; a rolled-back refinement must deliver it
        // bit-exactly, a completed round 2 delivers 1.5 + 2.0.
        let v = resp.attribution.values[0];
        assert!(v == 3.0 || v == 3.5, "got {v}");
        closer.join().unwrap();
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

// ---------------------------------------------------------------------
// coordinator::scheduler::LaneScheduler — tiered buckets + stealing
// ---------------------------------------------------------------------

#[test]
fn scheduler_bucket_activation_wakes_parked_feeder() {
    // ISSUE 8 model a: a feeder may park on the empty queue before the
    // router's push activates a bucket. In every schedule the push's
    // notification must reach the parked feeder (a lost wakeup is a
    // deadlock here — the modeled condvar never wakes spuriously), the
    // lanes must all commit, and close must wake the re-parked feeder
    // into Closed.
    let report = explore(|| {
        let s = Arc::new(LaneScheduler::new(Policy::Fifo, 64));
        let (st, rx, plans) = mk_plans(2, 2, None);
        let s2 = s.clone();
        let feeder = shim::spawn(move || {
            let mut committed = 0usize;
            loop {
                match s2.pop_chunk(2, Duration::ZERO) {
                    Popped::Chunk(lanes) => {
                        for l in &lanes {
                            if l.state.add_lane(l.idx, &[1.0]) {
                                assert!(l.state.finalize());
                            }
                        }
                        committed += lanes.len();
                    }
                    Popped::Closed => return committed,
                }
            }
        });
        s.push_tiered(1, LatencyBudget::Tight, plans).unwrap();
        s.close();
        assert_eq!(feeder.join().unwrap(), 2, "both lanes pop exactly once");
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.attribution.values[0], 2.0, "both lanes committed");
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

#[test]
fn scheduler_steal_vs_close_delivers_staged_chunk_exactly_once() {
    // ISSUE 8 model b: feeder 0's bucket pull stages a surplus chunk in
    // its local deque; a thief (feeder 1) races the coordinator's close
    // for it. In every interleaving the staged chunk is delivered
    // exactly once — stolen live, or stolen by the close-drain path —
    // never dropped (the request would underflow its countdown), never
    // double-executed (add_lane would see a duplicate commit), and the
    // thief parked after its steal must be woken by close into Closed.
    let report = explore(|| {
        let steal = nuig::coordinator::scheduler::StealConfig {
            stealing: true,
            local_prefetch: 2,
            starvation_limit: 64,
        };
        let counters = Arc::new(nuig::metrics::StealCounters::default());
        let s = Arc::new(LaneScheduler::with_feeders(Policy::Fifo, 64, 2, steal, counters));
        let (st, rx, plans) = mk_plans(4, 2, None);
        s.push_request(1, plans).unwrap();

        // Feeder 0's bucket pull: returns lanes 0-1, stages lanes 2-3.
        let own = match s.pop_chunk_for(0, 2, Duration::ZERO) {
            Popped::Chunk(c) => c,
            Popped::Closed => panic!("queued lanes must pop"),
        };
        assert_eq!(own.len(), 2);

        let s2 = s.clone();
        let thief = shim::spawn(move || {
            let mut got = 0usize;
            loop {
                match s2.pop_chunk_for(1, 2, Duration::ZERO) {
                    Popped::Chunk(lanes) => {
                        for l in &lanes {
                            if l.state.add_lane(l.idx, &[1.0]) {
                                assert!(l.state.finalize());
                            }
                        }
                        got += lanes.len();
                    }
                    Popped::Closed => return got,
                }
            }
        });
        let s3 = s.clone();
        let closer = shim::spawn(move || s3.close());

        for l in &own {
            if l.state.add_lane(l.idx, &[1.0]) {
                assert!(l.state.finalize());
            }
        }
        assert_eq!(thief.join().unwrap(), 2, "the staged chunk is stolen exactly once");
        closer.join().unwrap();
        assert!(matches!(s.pop_chunk_for(0, 2, Duration::ZERO), Popped::Closed));
        assert_eq!(s.counters().steals.get(), 1, "delivery path was a steal");
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.attribution.values[0], 4.0, "all four lanes, each exactly once");
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

// ---------------------------------------------------------------------
// exec::fault + coordinator::dispatch_failover — elastic lifecycle
// ---------------------------------------------------------------------

/// Minimal pure backend for the lifecycle models: shim-routed resident
/// pool, a register-call counter (the double-registration witness), and
/// lane rows that are a pure function of the lane.
struct TinyExec {
    pool: ResidentPool,
    shards: usize,
    registers: AtomicUsize,
}

impl TinyExec {
    fn new(shards: usize) -> TinyExec {
        TinyExec { pool: ResidentPool::new(), shards, registers: AtomicUsize::new(0) }
    }
}

impl GatherExec for TinyExec {
    fn features(&self) -> usize {
        2
    }
    fn num_classes(&self) -> usize {
        2
    }
    fn forward(&self, _imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(vec![0.5; rows * 2])
    }
    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        self.registers.fetch_add(1, Ordering::Relaxed);
        self.pool.register(slot, x, baseline)
    }
    fn evict_request(&self, slot: u64) {
        self.pool.evict(slot);
    }
    fn resident_len(&self) -> usize {
        self.pool.len()
    }
    fn shards(&self) -> usize {
        self.shards
    }
    fn eval_gather(&self, _shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        let mut rows = Vec::with_capacity(lanes.len() * 2);
        for lane in lanes {
            anyhow::ensure!(self.pool.entry(lane.slot).is_some(), "slot {} unknown", lane.slot);
            let v = lane.alpha * lane.weight + lane.slot as f32;
            rows.push(v);
            rows.push(v + 1.0);
        }
        Ok(GatherOut { rows, features: 2 })
    }
}

#[test]
fn drain_fence_migrates_chunks_in_every_interleaving() {
    // A feeder dispatching through dispatch_failover races an operator
    // draining its home shard. In every schedule the chunk must be
    // served (home before the fence lands, the sibling after — both are
    // legal), and once drain_shard has returned, dispatch MUST route to
    // the sibling: no chunk executes on a draining shard. Respawn then
    // clears the fence and home routing resumes.
    let report = explore(|| {
        let inner = Arc::new(TinyExec::new(2));
        let inj = Arc::new(FaultInjector::new(inner, &FaultPlan::new(vec![])).unwrap());
        inj.register_request(5, &[1.0, 0.0], &[0.0, 0.0]).unwrap();
        let lane = [GatherLane { slot: 5, alpha: 0.5, weight: 1.0, target: 0 }];

        let inj2 = inj.clone();
        let drainer = shim::spawn(move || inj2.drain_shard(0));
        // Concurrent with the drain: the chunk is never dropped, never
        // respawns anything, and lands on a legal shard.
        let (ex1, respawned1, out1) = dispatch_failover(&*inj, 0, &lane).unwrap();
        assert!(!respawned1);
        assert!(ex1 == 0 || ex1 == 1, "executed on unknown shard {ex1}");
        assert_eq!(out1.row(0), &[0.5 + 5.0, 0.5 + 6.0], "migration cannot move bits");
        drainer.join().unwrap();

        // Fence established: chunks migrate, the draining shard is idle.
        assert_eq!(inj.shard_health(0), ShardHealth::Draining);
        let (ex2, respawned2, _) = dispatch_failover(&*inj, 0, &lane).unwrap();
        assert_eq!(ex2, 1, "post-drain chunks must execute on the sibling");
        assert!(!respawned2);
        assert_eq!(inj.respawn_count(), 0, "drain never triggers a respawn");

        // Respawn un-drains; home routing resumes.
        inj.respawn_shard(0).unwrap();
        assert_eq!(inj.shard_health(0), ShardHealth::Live);
        let (ex3, _, _) = dispatch_failover(&*inj, 0, &lane).unwrap();
        assert_eq!(ex3, 0, "an un-drained home serves its own chunks again");
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

#[test]
fn respawn_replay_vs_registration_lands_each_slot_exactly_once() {
    // Satellite 3's second invariant: a respawn replaying the resident
    // pool races a fresh registration. Whichever order the schedule
    // picks (register first and the replay snapshot carries the slot;
    // respawn first and the post-respawn Live shard takes the direct
    // insert; or interleaved through the pool-first ordering), the shard
    // view must end up with BOTH slots exactly once, the inner backend
    // must see each slot registered exactly once (no double
    // registration), and a gather over both slots must serve.
    let report = explore(|| {
        let inner = Arc::new(TinyExec::new(1));
        let plan =
            FaultPlan::new(vec![FaultEvent { shard: 0, at: 0, action: FaultAction::Kill }]);
        let inj = Arc::new(FaultInjector::new(inner.clone(), &plan).unwrap());
        inj.register_request(7, &[1.0, 0.0], &[0.0, 0.0]).unwrap();
        let lane7 = GatherLane { slot: 7, alpha: 0.5, weight: 1.0, target: 0 };
        // Fire the kill: shard 0 dies, its resident view is wiped.
        assert!(inj.eval_gather(0, &[lane7]).is_err());

        let inj2 = inj.clone();
        let registrar =
            shim::spawn(move || inj2.register_request(9, &[2.0, 0.0], &[0.0, 0.0]).unwrap());
        inj.respawn_shard(0).unwrap();
        registrar.join().unwrap();

        assert_eq!(inj.shard_health(0), ShardHealth::Live);
        assert_eq!(inj.resident_on(0), vec![7, 9], "both slots, each exactly once");
        assert_eq!(inj.pool_slots(), vec![7, 9]);
        assert_eq!(
            inner.registers.load(Ordering::Relaxed),
            2,
            "the inner backend saw each slot registered exactly once"
        );
        let lane9 = GatherLane { slot: 9, alpha: 0.25, weight: 1.0, target: 1 };
        inj.eval_gather(0, &[lane7, lane9]).unwrap();
        assert_eq!(inj.respawn_count(), 1);
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}

// ---------------------------------------------------------------------
// exec::CancelToken + coordinator::state — the front-end cancellation
// tree (ISSUE 9)
// ---------------------------------------------------------------------

#[test]
fn token_child_registration_never_escapes_concurrent_cancel() {
    // The registration handshake (register, THEN check the parent flag)
    // against the cancel protocol (set the flag, THEN snapshot the
    // children): in every interleaving the child must end up cancelled —
    // a child that escaped would be a request the deadline wheel or a
    // disconnect could never reach.
    let report = explore(|| {
        let root = CancelToken::new();
        let spawner = root.clone();
        let h = shim::spawn(move || spawner.child());
        root.cancel();
        let kid = h.join().unwrap();
        assert!(kid.is_cancelled(), "no interleaving lets a child escape the cancel");
    });
    assert!(report.exhausted, "explored {} schedules", report.executions);
    assert!(report.executions > 1);
}

#[test]
fn token_child_cancel_is_subtree_scoped_under_races() {
    // I11 under concurrency: one request's deadline cancel racing a
    // sibling's creation never leaks across the subtree boundary.
    let report = explore(|| {
        let conn = CancelToken::new();
        let req_a = conn.child();
        let conn2 = conn.clone();
        let spawner = shim::spawn(move || conn2.child());
        req_a.cancel();
        let req_b = spawner.join().unwrap();
        assert!(req_a.is_cancelled());
        assert!(!req_b.is_cancelled(), "sibling created during the cancel is untouched");
        assert!(!conn.is_cancelled(), "a leaf cancel never climbs the tree");
    });
    assert!(report.exhausted, "explored {} schedules", report.executions);
}

#[test]
fn deadline_partial_vs_completion_settles_exactly_once() {
    // ISSUE 9 satellite: the deadline path's partial settlement
    // (`finalize_partial`, driven by the connection writer observing a
    // fired deadline token) races the feeder finishing the final round.
    // In every interleaving exactly one side settles, the reply channel
    // carries exactly one message, and the partial bit + values match
    // the winner: round-1 bits for the deadline (I12), refined bits for
    // the completion.
    let report = explore(|| {
        let s = Arc::new(LaneScheduler::new(Policy::Fifo, 64));
        let (st, rx, plans) = mk_plans(3, 3, Some(one_refinement_round()));
        s.push_request(1, plans).unwrap();

        // Drive round 1 to completion deterministically (snapshot taken).
        let lanes = match s.pop_chunk(3, Duration::ZERO) {
            Popped::Chunk(c) => c,
            Popped::Closed => panic!("queued round-1 lanes must pop"),
        };
        let mut complete = false;
        for l in &lanes {
            complete = l.state.add_lane(l.idx, &[1.0]);
        }
        assert!(complete);
        let next = match st.on_round_complete(3) {
            RoundOutcome::Refine(next) => next,
            RoundOutcome::Finalize => panic!("round 1 must refine (target 1e-12)"),
        };
        s.push_refill(1, next).unwrap();

        // The race: feeder completes round 2 vs the deadline's partial.
        let s2 = s.clone();
        let st_feeder = st.clone();
        let feeder = shim::spawn(move || {
            let lanes = match s2.pop_chunk(2, Duration::ZERO) {
                Popped::Chunk(c) => c,
                Popped::Closed => panic!("refill lanes must pop"),
            };
            let mut done = false;
            for l in &lanes {
                done = l.state.add_lane(l.idx, &[1.0]);
            }
            assert!(done);
            match st_feeder.on_round_complete(3) {
                RoundOutcome::Finalize => st_feeder.finalize(),
                RoundOutcome::Refine(_) => panic!("max_m 4 is exhausted after round 2"),
            }
        });
        let partialled = st.finalize_partial();
        let completed = feeder.join().unwrap();

        assert!(partialled != completed, "exactly one settlement path wins");
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        let resp = rx.recv().unwrap().expect("both paths settle Ok");
        assert!(
            rx.try_recv().expect("channel stays open").is_none(),
            "at most one reply is ever sent"
        );
        if partialled {
            assert!(resp.partial, "deadline winner is flagged partial");
            assert_eq!(resp.attribution.rounds, 1);
            assert_eq!(
                resp.attribution.values[0].to_bits(),
                3.0f64.to_bits(),
                "partial bits are the round-1 snapshot (I12)"
            );
        } else {
            assert!(!resp.partial);
            assert_eq!(resp.attribution.rounds, 2);
            assert_eq!(resp.attribution.values[0].to_bits(), 3.5f64.to_bits());
        }
    });
    assert!(report.executions > 1, "explored {} schedules", report.executions);
}
