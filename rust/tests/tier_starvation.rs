//! Tier-starvation regression: the bounded-progress guard.
//!
//! Strict priority buckets (refill → tight → standard → thorough) would
//! let a sustained tight-tier stream starve thorough-tier requests
//! forever. The scheduler's guard (`StealConfig::starvation_limit`)
//! bounds the damage: after `limit` consecutive lane draws that passed
//! over a non-empty lower-priority bucket, the next draw is forced from
//! the **lowest**-priority non-empty bucket. These tests pin the
//! resulting contract (docs/INVARIANTS.md §I10):
//!
//! * under a sustained tight stream, a thorough request's `T` lanes all
//!   dispatch within `T × (limit + 1)` drawn lanes — never unbounded;
//! * the forced draw serves the *most* starved bucket first (thorough
//!   before standard);
//! * the guard state persists across pops, so the bound holds over the
//!   whole dispatch stream, not per chunk.
//!
//! All tests drive the scheduler directly and deterministically — one
//! feeder, staging disabled — so the expected dispatch sequence is exact.

use std::sync::Arc;
use std::time::{Duration, Instant};

use nuig::coordinator::request::{ExplainResponse, LatencyBudget};
use nuig::coordinator::scheduler::{LaneScheduler, Policy, Popped, StealConfig};
use nuig::coordinator::state::{Accum, ChunkPlan, RequestState};
use nuig::exec::channel::{bounded, Receiver};
use nuig::exec::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use nuig::exec::sync::Mutex;
use nuig::ig::IgOptions;
use nuig::metrics::{StageBreakdown, StealCounters};

type ReplyRx = Receiver<anyhow::Result<ExplainResponse>>;

fn mk_request(id: u64, n_lanes: usize) -> (Arc<RequestState>, ReplyRx, Vec<ChunkPlan>) {
    let (tx, rx) = bounded(1);
    let st = Arc::new(RequestState {
        id,
        image: Arc::new(vec![1.0]),
        baseline: Arc::new(vec![0.0]),
        target: 0,
        opts: IgOptions::default(),
        budget: LatencyBudget::Unbounded,
        acc: Mutex::new(Accum::new(1)),
        remaining: AtomicUsize::new(n_lanes),
        steps: n_lanes,
        probe_passes: 0,
        endpoint_gap: 0.0,
        breakdown: Mutex::new(StageBreakdown::default()),
        submitted_at: Instant::now(),
        queue_wait: Duration::ZERO,
        reply: tx,
        completed: AtomicBool::new(false),
        in_flight: Arc::new(AtomicUsize::new(1)),
        anytime: None,
        resident: None,
    });
    let points: Vec<(f32, f32)> = (0..n_lanes).map(|k| (k as f32 / n_lanes as f32, 1.0)).collect();
    let plans = ChunkPlan::build(&st, &points, n_lanes);
    (st, rx, plans)
}

/// A single-feeder scheduler with staging disabled (prefetch 1), so
/// every popped lane comes straight out of the buckets and the guard's
/// dispatch sequence is exact.
fn sched(limit: usize) -> LaneScheduler {
    let steal = StealConfig { stealing: false, local_prefetch: 1, starvation_limit: limit };
    LaneScheduler::with_feeders(Policy::Fifo, 1024, 1, steal, Arc::new(StealCounters::default()))
}

/// Pop one lane and return the owning request's id.
fn pop_id(s: &LaneScheduler) -> u64 {
    match s.pop_chunk(1, Duration::ZERO) {
        Popped::Chunk(c) => {
            assert_eq!(c.len(), 1);
            c[0].state.id
        }
        Popped::Closed => panic!("queue closed mid-test"),
    }
}

const THOROUGH_ID: u64 = 1_000;
const STANDARD_ID: u64 = 2_000;

#[test]
fn sustained_tight_load_cannot_starve_thorough() {
    // A thorough request of 8 lanes, then an adversarial stream: one
    // fresh tight lane pushed before every pop, so the tight bucket is
    // never empty. With limit 4 the guard forces every 5th draw to the
    // thorough bucket — the dispatch sequence is exactly periodic and
    // the request drains in 8 × (4 + 1) = 40 draws.
    let s = sched(4);
    let mut keep = Vec::new();
    let (st, rx, plans) = mk_request(THOROUGH_ID, 8);
    s.push_tiered(THOROUGH_ID, LatencyBudget::Thorough, plans).unwrap();
    keep.push((st, rx));
    let mut thorough_at = Vec::new();
    for i in 0..40u64 {
        let (st, rx, plans) = mk_request(i, 1);
        s.push_tiered(i, LatencyBudget::Tight, plans).unwrap();
        keep.push((st, rx));
        if pop_id(&s) == THOROUGH_ID {
            thorough_at.push(i);
        }
    }
    assert_eq!(
        thorough_at,
        vec![4, 9, 14, 19, 24, 29, 34, 39],
        "the guard dispatches exactly one thorough lane per limit+1 draws"
    );
    assert_eq!(s.len(), 32, "the tight backlog is what remains");
}

#[test]
fn guard_serves_the_lowest_bucket_first() {
    // Standard AND thorough both waiting behind the tight stream: the
    // forced draw must go to the *lowest*-priority non-empty bucket —
    // thorough drains before standard sees a single forced lane, because
    // thorough is the bucket the plain priority order starves hardest.
    let s = sched(2);
    let mut keep = Vec::new();
    let (st, rx, plans) = mk_request(STANDARD_ID, 2);
    s.push_tiered(STANDARD_ID, LatencyBudget::Standard, plans).unwrap();
    keep.push((st, rx));
    let (st, rx, plans) = mk_request(THOROUGH_ID, 2);
    s.push_tiered(THOROUGH_ID, LatencyBudget::Thorough, plans).unwrap();
    keep.push((st, rx));
    let mut forced = Vec::new();
    for i in 0..12u64 {
        let (st, rx, plans) = mk_request(i, 1);
        s.push_tiered(i, LatencyBudget::Tight, plans).unwrap();
        keep.push((st, rx));
        let id = pop_id(&s);
        if id == THOROUGH_ID || id == STANDARD_ID {
            forced.push(id);
        }
    }
    assert_eq!(
        forced,
        vec![THOROUGH_ID, THOROUGH_ID, STANDARD_ID, STANDARD_ID],
        "forced draws serve thorough to empty before touching standard"
    );
}

#[test]
fn progress_bound_scales_with_the_limit() {
    // The advertised bound, not the exact sequence: for several
    // (limit, lanes) pairs, a thorough request fully dispatches within
    // lanes × (limit + 1) draws of adversarial tight load — and the
    // guard state carries across pops (the stream here never aligns
    // with a chunk boundary).
    for (limit, lanes) in [(1usize, 3usize), (3, 5), (8, 2), (64, 1)] {
        let s = sched(limit);
        let mut keep = Vec::new();
        let (st, rx, plans) = mk_request(THOROUGH_ID, lanes);
        s.push_tiered(THOROUGH_ID, LatencyBudget::Thorough, plans).unwrap();
        keep.push((st, rx));
        let bound = lanes * (limit + 1);
        let mut seen = 0usize;
        for i in 0..bound as u64 {
            let (st, rx, plans) = mk_request(i, 1);
            s.push_tiered(i, LatencyBudget::Tight, plans).unwrap();
            keep.push((st, rx));
            if pop_id(&s) == THOROUGH_ID {
                seen += 1;
            }
        }
        assert_eq!(
            seen, lanes,
            "limit {limit}: {lanes} thorough lanes must dispatch within {bound} draws"
        );
    }
}
