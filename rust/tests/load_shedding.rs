//! Admission load shedding: overload marks, typed `ShedRejection`
//! replies with deterministic retry hints, and the zero-probe-passes
//! guarantee (docs/INVARIANTS.md §I9).
//!
//! Artifact-free: runs over `AnalyticExec` in every tier-1 `cargo test`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use nuig::config::{CoordinatorConfig, FrontendConfig};
use nuig::coordinator::frontend::framing::{self, Frame, FrameReader, RequestFrame, REJECT_OVERLOAD};
use nuig::coordinator::frontend::listener;
use nuig::coordinator::{Coordinator, ExplainRequest, Frontend, LatencyBudget, ShedRejection};
use nuig::exec::gather::{GatherExec, GatherLane, GatherOut};
use nuig::ig::{AnalyticExec, AnalyticModel, IgOptions, Scheme};

const F: usize = 32;
const C: usize = 4;

fn model() -> AnalyticModel {
    AnalyticModel::new(F, C, 0xFEED, 12.0)
}

fn image(i: usize) -> Vec<f32> {
    (0..F).map(|k| (((i * 31 + k * 7) % 64) as f32) / 64.0).collect()
}

fn request(i: usize) -> ExplainRequest {
    ExplainRequest::new(
        image(i),
        IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 8, ..Default::default() },
    )
}

/// Wraps `AnalyticExec`, counting `forward` calls — the witness that a
/// shed request paid zero stage-1 probe passes.
struct ProbeCountingExec {
    inner: AnalyticExec,
    forwards: AtomicU64,
}

impl ProbeCountingExec {
    fn new(inner: AnalyticExec) -> ProbeCountingExec {
        ProbeCountingExec { inner, forwards: AtomicU64::new(0) }
    }
}

impl GatherExec for ProbeCountingExec {
    fn features(&self) -> usize {
        self.inner.features()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.forwards.fetch_add(1, Ordering::Relaxed);
        self.inner.forward(imgs, rows)
    }
    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        self.inner.register_request(slot, x, baseline)
    }
    fn evict_request(&self, slot: u64) {
        self.inner.evict_request(slot);
    }
    fn resident_len(&self) -> usize {
        self.inner.resident_len()
    }
    fn shards(&self) -> usize {
        self.inner.shards()
    }
    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        self.inner.eval_gather(shard, lanes)
    }
}

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig { feeders: 1, devices: 1, workers: 1, ..Default::default() }
}

#[test]
fn above_mark_sheds_tight_before_stage_one() {
    // Saturate the resident gauge out-of-band, then submit a tight-tier
    // request: it must be rejected with a typed ShedRejection BEFORE any
    // probe pass, with the deterministic retry hint and the shed
    // counters bumped.
    let backend = Arc::new(ProbeCountingExec::new(AnalyticExec::new(model())));
    backend.register_request(9_999, &image(0), &[0f32; F]).unwrap();
    let mut c = cfg();
    c.shed.resident_high_water = 1;
    c.shed.retry_after_ms = 25;
    let coord = Coordinator::start_with_backend(backend.clone(), c).unwrap();

    let err = coord.explain(request(1).with_budget(LatencyBudget::Tight)).unwrap_err();
    let shed = err
        .downcast_ref::<ShedRejection>()
        .unwrap_or_else(|| panic!("expected a typed ShedRejection, got: {err}"));
    // Gauge 1 at mark 1 ⇒ overload factor 1 ⇒ base hint.
    assert_eq!(shed.retry_after, Duration::from_millis(25));
    assert!(shed.retry_after > Duration::ZERO, "the hint is always actionable");
    assert_eq!(shed.resident_len, 1);
    assert!(err.to_string().contains("shed under overload"), "{err}");

    assert_eq!(backend.forwards.load(Ordering::Relaxed), 0, "shed = zero probe passes");
    let stats = coord.stats();
    assert_eq!(stats.shed_rejections.get(), 1);
    assert_eq!(stats.tier(LatencyBudget::Tight).shed.get(), 1);
    assert_eq!(stats.failed.get(), 1, "a shed settles the request's accounting");
    assert_eq!(stats.resident_rejections.get(), 0, "shed outranks the resident-cap gate");
    assert!(stats.resident_peak.get() >= 1, "admission sampled the overload gauges");
    assert_eq!(coord.in_flight(), 0);

    // Draining the gauge un-wedges tight admission on the same coordinator.
    backend.evict_request(9_999);
    let resp = coord.explain(request(1).with_budget(LatencyBudget::Tight)).unwrap();
    assert!(resp.attribution.delta.is_finite());
    assert_eq!(stats.shed_rejections.get(), 1, "no further sheds below the mark");
    coord.shutdown();
}

#[test]
fn soft_tiers_ride_through_overload_unshed() {
    // The same overloaded gauge must NOT shed Standard (or Unbounded)
    // traffic — soft tiers queue through; only the hard-deadline tier
    // prefers a fast typed reject.
    let backend = Arc::new(ProbeCountingExec::new(AnalyticExec::new(model())));
    backend.register_request(9_999, &image(0), &[0f32; F]).unwrap();
    let mut c = cfg();
    c.shed.resident_high_water = 1;
    let coord = Coordinator::start_with_backend(backend.clone(), c).unwrap();

    let resp = coord.explain(request(2).with_budget(LatencyBudget::Standard)).unwrap();
    assert!(resp.attribution.delta.is_finite());
    let resp = coord.explain(request(3)).unwrap(); // Unbounded
    assert!(resp.attribution.delta.is_finite());

    let stats = coord.stats();
    assert_eq!(stats.shed_rejections.get(), 0);
    assert_eq!(stats.tier(LatencyBudget::Standard).shed.get(), 0);
    assert_eq!(stats.tier(LatencyBudget::Standard).completed.get(), 1);
    assert!(backend.forwards.load(Ordering::Relaxed) > 0, "soft tiers really probed");
    coord.shutdown();
}

#[test]
fn below_mark_tight_serves_with_untouched_shed_stats() {
    // Marks configured but not crossed: tight traffic is served
    // normally and every shed counter stays zero — enabling the knobs
    // must be a no-op until overload actually happens.
    let backend = Arc::new(ProbeCountingExec::new(AnalyticExec::new(model())));
    let mut c = cfg();
    c.shed.resident_high_water = 100;
    c.shed.lane_high_water = 10_000;
    let coord = Coordinator::start_with_backend(backend.clone(), c).unwrap();
    let resp = coord.explain(request(4).with_budget(LatencyBudget::Tight)).unwrap();
    assert!(resp.attribution.delta.is_finite());
    let stats = coord.stats();
    assert_eq!(stats.shed_rejections.get(), 0);
    assert_eq!(stats.tier(LatencyBudget::Tight).shed.get(), 0);
    assert_eq!(stats.tier(LatencyBudget::Tight).completed.get(), 1);
    assert_eq!(stats.failed.get(), 0);
    coord.shutdown();
    assert_eq!(backend.resident_len(), 0);
}

#[test]
fn shed_retry_hint_is_integer_deterministic_end_to_end() {
    // The typed ShedRejection must survive the full serving path: an
    // overloaded admission settles a tight-tier wire request as a
    // REJECT frame whose retry hint is the exact integer the shed
    // config computes — no float drift, no clock dependence — and the
    // frame round-trips bit-for-bit through encode/decode.
    let backend = Arc::new(ProbeCountingExec::new(AnalyticExec::new(model())));
    backend.register_request(9_999, &image(0), &[0f32; F]).unwrap();
    let mut c = cfg();
    c.shed.resident_high_water = 1;
    c.shed.retry_after_ms = 25;
    let expect_ms = c.shed.retry_after(1, 0).as_millis() as u64;
    assert_eq!(expect_ms, 25, "gauge at the mark ⇒ factor 1 ⇒ the base hint, exactly");

    let coord = Arc::new(Coordinator::start_with_backend(backend.clone(), c).unwrap());
    let fe = Frontend::start(
        Arc::clone(&coord),
        FrontendConfig { listen: "tcp:127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();

    let stream = listener::connect(fe.local_spec()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = FrameReader::new(stream, 1 << 20);
    w.write_all(&framing::encode(&Frame::Request(RequestFrame {
        tag: 77,
        deadline_ms: 0,
        budget: LatencyBudget::Tight.index() as u8,
        target: -1,
        m: 8,
        anytime: None,
        image: image(1),
        baseline: None,
    })))
    .unwrap();

    let rej = match r.next().unwrap().expect("the shed settles a REJECT on the wire") {
        Frame::Reject(rj) => rj,
        other => panic!("expected REJECT, got {other:?}"),
    };
    assert_eq!(rej.tag, 77);
    assert_eq!(rej.reason, REJECT_OVERLOAD);
    assert_eq!(rej.retry_after_ms, expect_ms, "wire hint == ShedConfig::retry_after, integer-exact");
    assert_eq!(rej.resident, 1, "the decision's gauge sample rides along");
    assert_eq!(backend.forwards.load(Ordering::Relaxed), 0, "shed = zero probe passes");
    assert_eq!(coord.stats().shed_rejections.get(), 1);

    // Bit-for-bit wire stability of the typed rejection.
    let bytes = framing::encode(&Frame::Reject(rej.clone()));
    match framing::decode(&bytes[4..]).unwrap() {
        Frame::Reject(back) => assert_eq!(back, rej),
        other => panic!("REJECT decoded as {other:?}"),
    }

    drop(w);
    drop(r);
    fe.shutdown();
    drop(fe);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn shedding_disabled_by_default() {
    // Default config has both marks at 0 (disabled): even a saturated
    // resident gauge sheds nothing — only the resident-cap gate applies,
    // exactly the pre-shedding behaviour.
    let backend = Arc::new(ProbeCountingExec::new(AnalyticExec::new(model())));
    backend.register_request(9_999, &image(0), &[0f32; F]).unwrap();
    let coord = Coordinator::start_with_backend(backend.clone(), cfg()).unwrap();
    let resp = coord.explain(request(5).with_budget(LatencyBudget::Tight)).unwrap();
    assert!(resp.attribution.delta.is_finite());
    assert_eq!(coord.stats().shed_rejections.get(), 0);
    coord.shutdown();
}
