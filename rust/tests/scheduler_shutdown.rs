//! Plain (always-on) stress tests for the `LaneScheduler` shutdown
//! protocol — ISSUE 6 satellite 3's non-model half, run by the default
//! `cargo test` tier.
//!
//! The exhaustive interleaving models live in `tests/interleave_models.rs`
//! (`--features loom-models`); these tests hammer the same race — a
//! feeder completing an anytime round while the coordinator closes the
//! lane queue — with real OS threads and varied close timing, asserting
//! the exactly-once settlement invariant end to end (see
//! `docs/INVARIANTS.md`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use nuig::coordinator::request::{ExplainResponse, LatencyBudget};
use nuig::coordinator::scheduler::{LaneScheduler, Policy, Popped};
use nuig::coordinator::state::{Accum, AnytimeRounds, ChunkPlan, RequestState, RoundOutcome};
use nuig::exec::channel::{bounded, Receiver};
use nuig::exec::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use nuig::exec::sync::Mutex;
use nuig::ig::schedule::Schedule;
use nuig::ig::{AnytimePolicy, IgOptions, Rule};
use nuig::metrics::StageBreakdown;

type ReplyRx = Receiver<anyhow::Result<ExplainResponse>>;

fn mk_request(
    id: u64,
    n_lanes: usize,
    chunk: usize,
    anytime: Option<AnytimeRounds>,
) -> (Arc<RequestState>, ReplyRx, Vec<ChunkPlan>) {
    let (tx, rx) = bounded(1);
    let st = Arc::new(RequestState {
        id,
        image: Arc::new(vec![1.0]),
        baseline: Arc::new(vec![0.0]),
        target: 0,
        opts: IgOptions::default(),
        budget: LatencyBudget::Unbounded,
        acc: Mutex::new(Accum::new(1)),
        remaining: AtomicUsize::new(n_lanes),
        steps: n_lanes,
        probe_passes: 0,
        endpoint_gap: 0.0,
        breakdown: Mutex::new(StageBreakdown::default()),
        submitted_at: Instant::now(),
        queue_wait: Duration::ZERO,
        reply: tx,
        completed: AtomicBool::new(false),
        in_flight: Arc::new(AtomicUsize::new(1)),
        anytime,
        resident: None,
    });
    let points: Vec<(f32, f32)> = (0..n_lanes).map(|k| (k as f32 / n_lanes as f32, 1.0)).collect();
    let plans = ChunkPlan::build(&st, &points, chunk);
    (st, rx, plans)
}

/// Anytime state that refines exactly once (m 2 -> 4, capped).
fn one_refinement_round() -> AnytimeRounds {
    let schedule = Schedule::uniform(2, Rule::Trapezoid).expect("valid uniform schedule");
    AnytimeRounds {
        policy: AnytimePolicy::with_max_m(1e-12, 4).unwrap(),
        evals: AtomicUsize::new(schedule.len()),
        schedule: Mutex::new(schedule),
        residuals: Mutex::new(Vec::new()),
    }
}

/// The feeder's refill-or-rollback protocol for one drained round,
/// exactly as `coordinator::server`'s feeder loop runs it.
fn feed_to_settlement(s: &LaneScheduler, st: &Arc<RequestState>) {
    loop {
        let lanes = match s.pop_chunk(8, Duration::ZERO) {
            Popped::Chunk(c) => c,
            Popped::Closed => break,
        };
        let mut complete = false;
        for l in &lanes {
            complete = l.state.add_lane(l.idx, &[1.0]);
        }
        if !complete {
            continue;
        }
        match st.on_round_complete(8) {
            RoundOutcome::Refine(next) => {
                let novel: usize = next.iter().map(|p| p.len()).sum();
                if s.push_refill(st.id, next).is_err() {
                    // Closed mid-refinement: roll back, deliver the
                    // completed round (the anytime best-effort contract).
                    st.abort_refinement(novel);
                    assert!(st.finalize(), "rollback path settles once");
                    return;
                }
            }
            RoundOutcome::Finalize => {
                assert!(st.finalize(), "finalize path settles once");
                return;
            }
        }
    }
    panic!("queue closed with the round's lanes already drained — unreachable");
}

#[test]
fn refill_racing_close_settles_exactly_once() {
    // 200 rounds of the race with the closer's timing swept from
    // "immediately" to "well after the refill": whichever side wins,
    // the request settles exactly once with an Ok attribution that is
    // either the completed round 1 (3.0) or the full round 2 (3.5).
    for iter in 0..200u32 {
        let s = Arc::new(LaneScheduler::new(Policy::Fifo, 64));
        let (st, rx, plans) = mk_request(1, 3, 3, Some(one_refinement_round()));
        s.push_request(1, plans).unwrap();
        let s2 = s.clone();
        let closer = std::thread::spawn(move || {
            for _ in 0..(iter % 40) * 25 {
                std::hint::spin_loop();
            }
            s2.close();
        });
        feed_to_settlement(&s, &st);
        closer.join().unwrap();

        assert!(!st.finalize(), "second settlement must be a no-op (iter {iter})");
        assert!(!st.fail(anyhow::anyhow!("late")), "late failure must be a no-op");
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0, "iter {iter}");
        let resp = rx.recv().unwrap().expect("anytime settles Ok under shutdown");
        let v = resp.attribution.values[0];
        assert!(v == 3.0 || v == 3.5, "iter {iter}: best-effort sum was {v}");
    }
}

#[test]
fn close_during_multi_request_drain_loses_nothing() {
    // Several plain requests queued, a feeder draining, close landing
    // mid-drain: every admitted lane still pops (close drains before
    // reporting Closed), so every admitted request settles exactly once.
    for iter in 0..50u32 {
        let s = Arc::new(LaneScheduler::new(Policy::RoundRobin, 256));
        let mut reqs = Vec::new();
        for id in 0..6u64 {
            let (st, rx, plans) = mk_request(id, 5, 2, None);
            s.push_request(id, plans).unwrap();
            reqs.push((st, rx));
        }
        let s2 = s.clone();
        let closer = std::thread::spawn(move || {
            for _ in 0..(iter % 10) * 40 {
                std::hint::spin_loop();
            }
            s2.close();
        });
        loop {
            let lanes = match s.pop_chunk(4, Duration::ZERO) {
                Popped::Chunk(c) => c,
                Popped::Closed => break,
            };
            for l in &lanes {
                if l.state.add_lane(l.idx, &[1.0]) {
                    assert!(l.state.finalize());
                }
            }
        }
        closer.join().unwrap();
        for (st, rx) in reqs {
            assert_eq!(st.in_flight.load(Ordering::Acquire), 0, "iter {iter}");
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.attribution.values[0], 5.0, "iter {iter}: all 5 lanes landed");
        }
    }
}
