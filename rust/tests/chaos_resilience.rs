//! Deterministic chaos: the elastic-resilience acceptance suite.
//!
//! A seeded, step-indexed [`FaultPlan`] drives kill/revive/stall events
//! into the serving path at the `GatherExec` seam (`exec::fault`), and
//! the suite asserts the resilience contracts of docs/INVARIANTS.md
//! §I7–§I9 over the artifact-free `AnalyticExec` backend:
//!
//! * surviving requests are **bit-identical** (0 ULP) to an unfaulted
//!   run, at feeder counts {1, 2, 4} — migration, failover retries, and
//!   respawn replay cannot move a bit;
//! * killed requests settle (and are counted) **exactly once**;
//! * the resident pool and every shard's resident view drain to empty —
//!   no stranded slots after kill/revive/respawn churn;
//! * the same plan driven over the same chunk sequence produces the
//!   same settlement log (direct-drive reproducibility).
//!
//! Seed coverage scales with `NUIG_CHAOS_SEEDS` (default 4 in tier-1;
//! the nightly sweep raises it).

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use nuig::config::{CoordinatorConfig, FrontendConfig};
use nuig::coordinator::frontend::framing::{self, Frame, FrameReader, RequestFrame, REJECT_DEADLINE};
use nuig::coordinator::frontend::listener;
use nuig::coordinator::{dispatch_failover, Coordinator, ExplainRequest, Frontend, LatencyBudget};
use nuig::exec::gather::{GatherExec, GatherLane, ShardHealth};
use nuig::exec::{
    ClientFaultAction, ClientFaultPlan, FaultAction, FaultEvent, FaultInjector, FaultPlan,
};
use nuig::ig::{AnalyticExec, AnalyticModel, IgOptions, Scheme};

const F: usize = 32;
const C: usize = 4;
const N: usize = 12;

fn model() -> AnalyticModel {
    AnalyticModel::new(F, C, 0xFEED, 12.0)
}

fn image(i: usize) -> Vec<f32> {
    (0..F).map(|k| (((i * 31 + k * 7) % 64) as f32) / 64.0).collect()
}

/// The same deterministic mixed workload the sharded-feeder suite uses:
/// both schemes, several m levels, and an anytime slice so refinement
/// rounds are in flight while faults fire.
fn workload(n: usize) -> Vec<ExplainRequest> {
    (0..n)
        .map(|i| {
            let scheme =
                if i % 4 == 3 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
            let m = [8, 12, 16, 24][i % 4];
            let req =
                ExplainRequest::new(image(i), IgOptions { scheme, m, ..Default::default() });
            if i % 3 == 0 && scheme != Scheme::Uniform {
                req.with_budget(LatencyBudget::Standard)
            } else {
                req
            }
        })
        .collect()
}

fn cfg(feeders: usize, devices: usize) -> CoordinatorConfig {
    CoordinatorConfig { feeders, devices, workers: 2, ..Default::default() }
}

/// Everything a chaos run yields: per-request outcome (bit patterns for
/// survivors, error text for casualties), the settled counters, and the
/// injector for post-mortem inspection.
struct ChaosRun {
    results: Vec<Result<Vec<u64>, String>>,
    completed: u64,
    failed: u64,
    injector: Arc<FaultInjector>,
}

/// Run `n` workload requests through a coordinator whose backend is a
/// [`FaultInjector`] armed with `plan`, over `feeders` feeders pinned
/// 1:1 to `feeders` shards. Asserts the universal post-conditions every
/// chaos scenario must satisfy: exactly-once settlement accounting, a
/// drained resident pool, and no stranded per-shard resident slots.
fn run_chaos(feeders: usize, n: usize, plan: &FaultPlan) -> ChaosRun {
    let inner = Arc::new(AnalyticExec::with_shards(model(), feeders));
    let injector = Arc::new(FaultInjector::new(inner, plan).unwrap());
    let coord = Coordinator::start_with_backend(injector.clone(), cfg(feeders, feeders)).unwrap();
    let handles: Vec<_> =
        workload(n).into_iter().map(|r| coord.submit(r)).collect::<Result<_, _>>().unwrap();
    let results: Vec<Result<Vec<u64>, String>> = handles
        .into_iter()
        .map(|h| {
            h.wait()
                .map(|r| r.attribution.values.iter().map(|v| v.to_bits()).collect())
                .map_err(|e| e.to_string())
        })
        .collect();
    let completed = coord.stats().completed.get();
    let failed = coord.stats().failed.get();
    let ok = results.iter().filter(|r| r.is_ok()).count() as u64;
    assert_eq!(completed, ok, "completed counter matches delivered responses");
    assert_eq!(completed + failed, n as u64, "every request settles exactly once");
    assert_eq!(coord.in_flight(), 0, "settled run leaves nothing in flight");
    coord.shutdown();
    assert_eq!(injector.resident_len(), 0, "resident pool drains after shutdown");
    for shard in 0..feeders {
        assert!(
            injector.resident_on(shard).is_empty(),
            "shard {shard} strands resident slots: {:?}",
            injector.resident_on(shard)
        );
    }
    ChaosRun { results, completed, failed, injector }
}

/// Unfaulted single-feeder reference: the bit patterns every chaos
/// survivor is measured against (cross-feeder bit-identity of the
/// unfaulted path is covered by tests/sharded_feeder.rs).
fn reference(n: usize) -> Vec<Vec<u64>> {
    run_chaos(1, n, &FaultPlan::new(vec![]))
        .results
        .into_iter()
        .map(|r| r.expect("unfaulted run completes everything"))
        .collect()
}

fn assert_survivors_bit_identical(run: &ChaosRun, reference: &[Vec<u64>], ctx: &str) {
    for (i, res) in run.results.iter().enumerate() {
        if let Ok(bits) = res {
            assert_eq!(bits, &reference[i], "{ctx}: request {i} survived with different bits");
        }
    }
}

#[test]
fn kill_without_revive_is_rescued_bitwise_at_every_feeder_count() {
    // A kill with no revive pending leaves the shard respawnable: the
    // chunk that took the hit fails over to a live sibling — or, with no
    // sibling, respawns the dead home in-line (resident replay) and
    // retries. Either way NO request fails, and every attribution is
    // bit-identical to the unfaulted run: at feeders {1, 2, 4}, with the
    // kill landing at several different gather-call ordinals.
    let reference = reference(N);
    for feeders in [1usize, 2, 4] {
        for at in [0u64, 2, 5] {
            let shard = (at as usize) % feeders;
            let plan = FaultPlan::new(vec![FaultEvent {
                shard,
                at,
                action: FaultAction::Kill,
            }]);
            let run = run_chaos(feeders, N, &plan);
            assert_eq!(
                run.failed, 0,
                "feeders {feeders}, kill shard {shard}@{at}: failover must rescue every request"
            );
            assert_eq!(run.completed, N as u64);
            assert_survivors_bit_identical(&run, &reference, "kill-only");
        }
    }
}

#[test]
fn kill_revive_window_fails_only_the_window_exactly_once() {
    // Single shard, single feeder — no sibling to hide behind. The shard
    // is dead for gather calls 1..4 and the plan's pending revive holds
    // respawn down, so chunks dispatched in the window fail their
    // requests; the revive then replays the resident pool and the rest
    // of the run proceeds bit-identically. Survivors must not wobble.
    let reference = reference(N);
    let plan = FaultPlan::new(vec![
        FaultEvent { shard: 0, at: 1, action: FaultAction::Kill },
        FaultEvent { shard: 0, at: 4, action: FaultAction::Revive },
    ]);
    let run = run_chaos(1, N, &plan);
    assert!(run.failed >= 1, "the dead-window chunk fails its requests");
    assert!(run.completed >= 1, "requests outside the window survive");
    assert_survivors_bit_identical(&run, &reference, "kill-revive window");
    // The window really happened, in order, at the planned steps.
    let log = run.injector.event_log();
    assert_eq!(log.len(), 2);
    assert_eq!((log[0].0, log[0].1.action), (1, FaultAction::Kill));
    assert_eq!((log[1].0, log[1].1.action), (4, FaultAction::Revive));
    assert_eq!(run.injector.respawn_count(), 0, "respawn stays held down until the revive");
}

#[test]
fn permanent_shard_outage_reroutes_everything_to_the_sibling() {
    // kill_forever: shard 1 dies on its first gather call and its
    // hold-down sentinel keeps respawn refusing — the pure re-routing
    // scenario. Every chunk lands on shard 0 and every request survives
    // with reference bits.
    let reference = reference(N);
    let plan = FaultPlan::with_seed(1, FaultPlan::kill_forever(1, 0));
    let run = run_chaos(2, N, &plan);
    assert_eq!(run.failed, 0, "a live sibling absorbs the whole outage");
    assert_eq!(run.completed, N as u64);
    assert_survivors_bit_identical(&run, &reference, "kill-forever");
    assert_eq!(run.injector.respawn_count(), 0, "held-down shard must not respawn");
    // The kill fires on shard 1's first dispatched chunk; the only way
    // it can still read Live is if scheduling starved feeder 1 of every
    // single chunk (legal, vanishingly rare) — never a half-applied plan.
    if run.injector.calls_on(1) > 0 {
        assert_eq!(run.injector.shard_health(1), ShardHealth::Dead);
    }
}

#[test]
fn seeded_kill_revive_sweep_settles_exactly_once_with_bitwise_survivors() {
    // The seed sweep: derived kill/revive(/stall) scenarios across both
    // shards. Overlapping dead windows may fail requests — that is the
    // point — but settlement is exactly-once, survivors are bit-exact,
    // and nothing strands (all asserted inside run_chaos). Tier-1 runs a
    // handful of seeds; the nightly sweep sets NUIG_CHAOS_SEEDS higher.
    let seeds: u64 = std::env::var("NUIG_CHAOS_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let reference = reference(N);
    for seed in 0..seeds {
        let plan = FaultPlan::from_seed(seed, 2, 16);
        let run = run_chaos(2, N, &plan);
        assert_survivors_bit_identical(&run, &reference, &format!("seed {seed}"));
    }
}

// ---- Client-side chaos through the serving front-end ------------------
//
// The wire-facing half of the fault model: seeded Disconnect /
// DeadlineExpire client events (`exec::ClientFaultPlan`) drive real
// socket connections against a live `Frontend`, concurrently with an
// untouched survivor stream on its own connection. Contracts:
// every request settles exactly once (completed + failed == n, nothing
// in flight, resident pool drained), and survivors are bit-identical
// to the unfaulted run — a neighbour's disconnect or deadline cancels
// only its own cancellation subtree (docs/INVARIANTS.md §I11).

/// The wire-expressible workload slice: the frame protocol carries m
/// but pins the engine-default scheme, so the mixed-scheme `workload`
/// above cannot ride the socket verbatim.
fn wire_frame(i: usize, deadline_ms: u64, anytime: Option<(f64, u64)>) -> Frame {
    Frame::Request(RequestFrame {
        tag: i as u64 + 1,
        deadline_ms,
        budget: if i % 3 == 0 { LatencyBudget::Standard.index() as u8 } else { 0 },
        target: -1,
        m: [8, 12, 16, 24][i % 4] as u32,
        anytime,
        image: image(i),
        baseline: None,
    })
}

/// Unfaulted single-feeder reference bits for the wire workload.
fn wire_reference(n: usize) -> Vec<Vec<u64>> {
    let inner = Arc::new(AnalyticExec::with_shards(model(), 1));
    let coord = Coordinator::start_with_backend(inner, cfg(1, 1)).unwrap();
    let out = (0..n)
        .map(|i| {
            let req = ExplainRequest::new(
                image(i),
                IgOptions {
                    scheme: Scheme::NonUniform { n_int: 4 },
                    m: [8, 12, 16, 24][i % 4],
                    ..Default::default()
                },
            );
            let req = if i % 3 == 0 { req.with_budget(LatencyBudget::Standard) } else { req };
            coord
                .explain(req)
                .unwrap()
                .attribution
                .values
                .iter()
                .map(|v| v.to_bits())
                .collect()
        })
        .collect();
    coord.shutdown();
    out
}

fn wait_until(what: &str, mut ready: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !ready() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn run_client_chaos(feeders: usize, n: usize, plan: &ClientFaultPlan, reference: &[Vec<u64>]) {
    let ctx = format!("seed {}, feeders {feeders}", plan.seed());
    let inner = Arc::new(AnalyticExec::with_shards(model(), feeders));
    let coord =
        Arc::new(Coordinator::start_with_backend(inner.clone(), cfg(feeders, feeders)).unwrap());
    let fe = Frontend::start(
        Arc::clone(&coord),
        FrontendConfig { listen: "tcp:127.0.0.1:0".into(), conn_workers: 2, ..Default::default() },
    )
    .unwrap();

    // Survivors share one long-lived connection; every faulted request
    // brings (and loses) its own, so a fault can only take down its own
    // cancellation subtree.
    let survivor_conn = listener::connect(fe.local_spec()).unwrap();
    let mut sw = survivor_conn.try_clone().unwrap();
    let mut sr = FrameReader::new(survivor_conn, 1 << 20);
    let mut survivors: Vec<u64> = Vec::new();
    let mut deadline_conns = Vec::new();
    for i in 0..n {
        match plan.action_for(i as u64) {
            None => {
                sw.write_all(&framing::encode(&wire_frame(i, 0, None))).unwrap();
                survivors.push(i as u64 + 1);
            }
            Some(ClientFaultAction::Disconnect) => {
                // Mid-refinement vanishing act: a bounded anytime
                // request streams rounds, and the client slams the
                // socket shut without reading any of them.
                let conn = listener::connect(fe.local_spec()).unwrap();
                let mut w = conn.try_clone().unwrap();
                w.write_all(&framing::encode(&wire_frame(i, 0, Some((0.0, 256))))).unwrap();
                w.flush().unwrap();
                conn.shutdown();
            }
            Some(ClientFaultAction::DeadlineExpire) => {
                // An unconvergeable refinement under a short deadline:
                // settles as a partial FINAL (≥1 round converged) or a
                // typed deadline REJECT (none did) — never silence.
                let conn = listener::connect(fe.local_spec()).unwrap();
                let mut w = conn.try_clone().unwrap();
                w.write_all(&framing::encode(&wire_frame(i, 5, Some((0.0, 1 << 20)))))
                    .unwrap();
                w.flush().unwrap();
                deadline_conns.push((i as u64 + 1, w, FrameReader::new(conn, 1 << 20)));
            }
        }
    }

    // Survivor settlements: bit-identical to the unfaulted reference.
    let mut finals: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    while finals.len() < survivors.len() {
        match sr.next().unwrap() {
            Some(Frame::Final(f)) => {
                assert!(!f.partial, "{ctx}: survivor tag {} settled partial", f.tag);
                finals.insert(f.tag, f.values.iter().map(|v| v.to_bits()).collect());
            }
            Some(Frame::Round(_)) => {}
            Some(other) => panic!("{ctx}: unexpected survivor frame {other:?}"),
            None => panic!(
                "{ctx}: survivor stream closed with {}/{} settled",
                finals.len(),
                survivors.len()
            ),
        }
    }
    for &tag in &survivors {
        let got = finals.get(&tag).unwrap_or_else(|| panic!("{ctx}: tag {tag} never settled"));
        assert_eq!(
            got,
            &reference[(tag - 1) as usize],
            "{ctx}: a neighbour's fault moved survivor {tag}'s bits"
        );
    }

    // Deadline-faulted requests settle on their own wire exactly once.
    for (tag, _w, mut rdr) in deadline_conns {
        loop {
            match rdr.next().unwrap() {
                Some(Frame::Round(_)) => continue,
                Some(Frame::Final(f)) => {
                    assert_eq!(f.tag, tag, "{ctx}");
                    assert!(f.partial, "{ctx}: an unconvergeable deadline FINAL is partial");
                    assert!(f.rounds >= 1);
                    break;
                }
                Some(Frame::Reject(r)) => {
                    assert_eq!(r.tag, tag, "{ctx}");
                    assert_eq!(r.reason, REJECT_DEADLINE, "{ctx}");
                    assert!(r.retry_after_ms > 0, "{ctx}: the hint is always actionable");
                    break;
                }
                other => panic!("{ctx}: unexpected settlement {other:?}"),
            }
        }
    }

    // Exactly-once settlement accounting over the whole run.
    wait_until("all requests to settle", || coord.in_flight() == 0);
    wait_until("the resident pool to drain", || coord.resident_len() == 0);
    let stats = coord.stats();
    assert_eq!(
        stats.completed.get() + stats.failed.get(),
        n as u64,
        "{ctx}: every request settles exactly once"
    );

    drop(sw);
    drop(sr);
    fe.shutdown();
    drop(fe);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
    assert_eq!(inner.resident_len(), 0, "{ctx}: resident pool drains after shutdown");
}

#[test]
fn seeded_client_fault_sweep_settles_exactly_once_with_bitwise_survivors() {
    // Disconnect/DeadlineExpire client chaos at feeders {1, 2, 4}.
    // Tier-1 runs a handful of seeds; the nightly sweep raises
    // NUIG_CHAOS_SEEDS to 64.
    let seeds: u64 = std::env::var("NUIG_CHAOS_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let reference = wire_reference(N);
    for seed in 0..seeds {
        let plan = ClientFaultPlan::from_seed(seed, N as u64);
        for feeders in [1usize, 2, 4] {
            run_client_chaos(feeders, N, &plan, &reference);
        }
    }
}

#[test]
fn drain_rebalances_chunks_and_respawn_restores_the_shard() {
    // Operator-driven drain: shard 1 stops receiving chunks mid-run, its
    // queued work migrates to shard 0 through the failover dispatch, and
    // results stay bit-identical. Respawning the drained shard puts it
    // back in rotation.
    let reference = reference(N);
    let inner = Arc::new(AnalyticExec::with_shards(model(), 2));
    let injector = Arc::new(FaultInjector::new(inner, &FaultPlan::new(vec![])).unwrap());
    let coord = Coordinator::start_with_backend(injector.clone(), cfg(2, 2)).unwrap();
    let reqs = workload(N);
    let mut handles = Vec::new();
    for (i, req) in reqs.into_iter().enumerate() {
        if i == N / 2 {
            coord.drain_shard(1).unwrap();
            assert_eq!(coord.shard_health(1).unwrap(), ShardHealth::Draining);
        }
        handles.push(coord.submit(req).unwrap());
    }
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap_or_else(|e| panic!("request {i} failed under drain: {e}"));
        let bits: Vec<u64> = resp.attribution.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, reference[i], "request {i}: drain migration moved bits");
    }
    assert_eq!(coord.stats().failed.get(), 0);
    assert_eq!(
        coord.shard_health(1).unwrap(),
        ShardHealth::Draining,
        "drain persists until an explicit respawn"
    );
    // Bring it back: respawn clears the fence and the shard serves again.
    coord.respawn_shard(1).unwrap();
    assert_eq!(coord.shard_health(1).unwrap(), ShardHealth::Live);
    let resp = coord
        .explain(ExplainRequest::new(image(0), IgOptions { m: 8, ..Default::default() }))
        .unwrap();
    assert!(resp.attribution.delta.is_finite());
    assert!(coord.shard_health(7).is_err(), "out-of-range shard is a loud error");
    coord.shutdown();
    assert_eq!(injector.resident_len(), 0);
}

#[test]
fn same_plan_same_chunk_sequence_same_settlement_log() {
    // Direct drive — no coordinator threads — so the chunk sequence is
    // exactly reproducible: two runs of the same seeded plan through
    // dispatch_failover must produce identical per-chunk outcomes
    // (executed shard, respawn flag, row bits, or failure) AND identical
    // injector event logs. This is the replay contract that makes a
    // failing chaos run debuggable from its seed.
    let plan = FaultPlan::from_seed(0xD00F, 2, 12);
    let drive = |plan: &FaultPlan| {
        let inner = Arc::new(AnalyticExec::with_shards(model(), 2));
        let inj = FaultInjector::new(inner, plan).unwrap();
        let black = [0f32; F];
        inj.register_request(1, &image(1), &black).unwrap();
        inj.register_request(2, &image(2), &black).unwrap();
        let lanes = [
            GatherLane { slot: 1, alpha: 0.25, weight: 0.5, target: 0 },
            GatherLane { slot: 2, alpha: 0.75, weight: 0.5, target: 1 },
        ];
        let mut outcomes = Vec::new();
        for step in 0..30usize {
            let home = step % 2;
            match dispatch_failover(&inj, home, &lanes) {
                Ok((executed, respawned, out)) => outcomes.push(Ok((
                    executed,
                    respawned,
                    out.rows.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                ))),
                Err(e) => outcomes.push(Err(e.to_string())),
            }
        }
        (outcomes, inj.event_log(), inj.respawn_count())
    };
    let a = drive(&plan);
    let b = drive(&plan);
    assert_eq!(a, b, "same plan + same chunk sequence must replay identically");
    assert!(!a.1.is_empty(), "the seeded plan actually fired events");
}
