//! Forced-steal determinism: the work-stealing acceptance suite.
//!
//! The tiered scheduler lets an idle feeder steal staged chunks from a
//! sibling's deque (`coordinator::scheduler`). Stealing is only legal
//! because the ordered-commit accumulator folds lane rows in lane-index
//! order no matter which feeder executed them — docs/INVARIANTS.md §I10.
//! This suite forces steals to actually happen and asserts the contract:
//!
//! * seeded, step-indexed [`FaultAction::Stall`] events slow shards at
//!   known gather-call ordinals so feeders drift and steal; attributions
//!   must stay **bit-identical** (0 ULP) to the unfaulted single-feeder
//!   reference at feeder counts {1, 2, 4, 8};
//! * a direct-drive script (no coordinator threads) makes the steal
//!   deterministic — the thief provably pops a sibling's staged chunk —
//!   and the committed attribution still cannot move a bit;
//! * a stolen chunk whose thief's home shard is dead rides the PR 7
//!   failover ladder unchanged: rerouted, replayed, bit-identical.
//!
//! Seed coverage scales with `NUIG_CHAOS_SEEDS` (default 4 in tier-1;
//! the nightly sweep raises it).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use nuig::config::CoordinatorConfig;
use nuig::coordinator::request::ExplainResponse;
use nuig::coordinator::scheduler::{LaneScheduler, Policy, Popped, StealConfig};
use nuig::coordinator::state::{Accum, ChunkPlan, RequestState};
use nuig::coordinator::{dispatch_failover, Coordinator, ExplainRequest, LatencyBudget};
use nuig::exec::channel::{bounded, Receiver};
use nuig::exec::gather::{GatherExec, GatherLane};
use nuig::exec::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use nuig::exec::sync::Mutex;
use nuig::exec::{FaultAction, FaultEvent, FaultInjector, FaultPlan};
use nuig::ig::{AnalyticExec, AnalyticModel, IgOptions, Scheme};
use nuig::metrics::{StageBreakdown, StealCounters};

const F: usize = 32;
const C: usize = 4;
const N: usize = 12;

fn model() -> AnalyticModel {
    AnalyticModel::new(F, C, 0xFEED, 12.0)
}

fn image(i: usize) -> Vec<f32> {
    (0..F).map(|k| (((i * 31 + k * 7) % 64) as f32) / 64.0).collect()
}

/// The chaos suite's deterministic mixed workload: both schemes, several
/// m levels, and a tier slice so every bucket sees traffic while stalls
/// skew the feeders.
fn workload(n: usize) -> Vec<ExplainRequest> {
    (0..n)
        .map(|i| {
            let scheme =
                if i % 4 == 3 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
            let m = [8, 12, 16, 24][i % 4];
            let req =
                ExplainRequest::new(image(i), IgOptions { scheme, m, ..Default::default() });
            match i % 3 {
                0 if scheme != Scheme::Uniform => req.with_budget(LatencyBudget::Standard),
                1 => req.with_budget(LatencyBudget::Thorough),
                _ => req,
            }
        })
        .collect()
}

/// Steal-heavy serving config: a deep prefetch keeps sibling deques full
/// so a stalled shard's feeder leaves plenty to steal.
fn cfg(feeders: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        feeders,
        devices: feeders,
        workers: 2,
        steal: StealConfig { stealing: true, local_prefetch: 4, starvation_limit: 64 },
        ..Default::default()
    }
}

/// Run `n` workload requests over `feeders` feeders with `plan` armed at
/// the gather seam, asserting the universal post-conditions (exactly-once
/// settlement, drained resident pool) and returning per-request bits.
fn run_stalled(feeders: usize, n: usize, plan: &FaultPlan) -> Vec<Vec<u64>> {
    let inner = Arc::new(AnalyticExec::with_shards(model(), feeders));
    let injector = Arc::new(FaultInjector::new(inner, plan).unwrap());
    let coord = Coordinator::start_with_backend(injector.clone(), cfg(feeders)).unwrap();
    let handles: Vec<_> =
        workload(n).into_iter().map(|r| coord.submit(r)).collect::<Result<_, _>>().unwrap();
    let bits: Vec<Vec<u64>> = handles
        .into_iter()
        .enumerate()
        .map(|(i, h)| {
            let resp = h.wait().unwrap_or_else(|e| panic!("request {i} failed under stalls: {e}"));
            resp.attribution.values.iter().map(|v| v.to_bits()).collect()
        })
        .collect();
    assert_eq!(coord.stats().failed.get(), 0, "stalls are outcome-neutral");
    assert_eq!(coord.stats().completed.get(), n as u64);
    assert_eq!(coord.in_flight(), 0);
    coord.shutdown();
    assert_eq!(injector.resident_len(), 0, "resident pool drains after shutdown");
    bits
}

/// Stall-only plan: slow `shards` round-robin at fixed gather ordinals.
fn stall_plan(shards: usize, ordinals: &[u64], spins: u32) -> FaultPlan {
    FaultPlan::new(
        ordinals
            .iter()
            .enumerate()
            .map(|(i, &at)| FaultEvent {
                shard: i % shards,
                at,
                action: FaultAction::Stall { spins },
            })
            .collect(),
    )
}

#[test]
fn forced_stalls_cannot_move_bits_at_any_feeder_count() {
    // Known-ordinal stalls skew shard pacing so idle feeders steal from
    // the slowed shard's deque. Whatever interleaving results, every
    // attribution must match the unfaulted single-feeder reference
    // bit for bit, at feeders {1, 2, 4, 8}.
    let reference = run_stalled(1, N, &FaultPlan::new(vec![]));
    for feeders in [1usize, 2, 4, 8] {
        let plan = stall_plan(feeders, &[0, 2, 5, 9, 14], 4096);
        let bits = run_stalled(feeders, N, &plan);
        assert_eq!(bits, reference, "feeders {feeders}: stall-induced stealing moved bits");
    }
}

#[test]
fn seeded_stall_sweep_is_bit_identical() {
    // The seed sweep: stall ordinals, targets, and depths derived from a
    // counter-keyed LCG so every scenario replays from its seed alone.
    // Tier-1 runs a handful of seeds; nightly sets NUIG_CHAOS_SEEDS
    // higher.
    let seeds: u64 = std::env::var("NUIG_CHAOS_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let reference = run_stalled(1, N, &FaultPlan::new(vec![]));
    for seed in 0..seeds {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xD1B5);
        let mut rand = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut at = 0u64;
        let events: Vec<FaultEvent> = (0..8)
            .map(|_| {
                at += 1 + rand() % 4;
                FaultEvent {
                    shard: (rand() % 4) as usize,
                    at,
                    action: FaultAction::Stall { spins: (512 + rand() % 4096) as u32 },
                }
            })
            .collect();
        let bits = run_stalled(4, N, &FaultPlan::with_seed(seed, events));
        assert_eq!(bits, reference, "seed {seed}: seeded stalls moved bits");
    }
}

// ---------------------------------------------------------------------
// Direct drive: deterministic steals, no coordinator threads.
// ---------------------------------------------------------------------

type ReplyRx = Receiver<anyhow::Result<ExplainResponse>>;

/// A fixed-round request whose lanes gather against resident slot `id`
/// (registered by the caller on the injector), mirroring the request
/// state the router builds at admission.
fn mk_request(
    id: u64,
    n_lanes: usize,
    chunk: usize,
) -> (Arc<RequestState>, ReplyRx, Vec<ChunkPlan>) {
    let (tx, rx) = bounded(1);
    let st = Arc::new(RequestState {
        id,
        image: Arc::new(image(id as usize)),
        baseline: Arc::new(vec![0.0; F]),
        target: (id as usize) % C,
        opts: IgOptions::default(),
        budget: LatencyBudget::Unbounded,
        acc: Mutex::new(Accum::new(F)),
        remaining: AtomicUsize::new(n_lanes),
        steps: n_lanes,
        probe_passes: 0,
        endpoint_gap: 0.0,
        breakdown: Mutex::new(StageBreakdown::default()),
        submitted_at: Instant::now(),
        queue_wait: Duration::ZERO,
        reply: tx,
        completed: AtomicBool::new(false),
        in_flight: Arc::new(AtomicUsize::new(1)),
        anytime: None,
        resident: None,
    });
    let points: Vec<(f32, f32)> = (0..n_lanes)
        .map(|k| ((k + 1) as f32 / n_lanes as f32, 1.0 / n_lanes as f32))
        .collect();
    let plans = ChunkPlan::build(&st, &points, chunk);
    (st, rx, plans)
}

/// Drive the closed scheduler with an explicit per-pop feeder script,
/// dispatching every popped chunk through the failover ladder with the
/// popping feeder's index as home shard — exactly what the feeder loop
/// does, minus the threads. Returns per-request attribution bits.
fn drive_script(
    plan: &FaultPlan,
    feeders: usize,
    steal: StealConfig,
    script: &[usize],
) -> DriveOut {
    let inner = Arc::new(AnalyticExec::with_shards(model(), 2));
    let inj = FaultInjector::new(inner, plan).unwrap();
    let counters = Arc::new(StealCounters::default());
    let s = LaneScheduler::with_feeders(Policy::Fifo, 256, feeders, steal, counters.clone());
    let mut replies = Vec::new();
    for id in [1u64, 2] {
        let (st, rx, plans) = mk_request(id, 12, 3);
        inj.register_request(id, &st.image, &st.baseline).unwrap();
        s.push_request(id, plans).unwrap();
        replies.push((st, rx));
    }
    s.close();
    let mut rerouted = 0usize;
    for &feeder in script {
        let lanes = match s.pop_chunk_for(feeder, 3, Duration::ZERO) {
            Popped::Chunk(l) => l,
            Popped::Closed => continue,
        };
        let recs: Vec<GatherLane> = lanes
            .iter()
            .map(|l| GatherLane {
                slot: l.state.id,
                alpha: l.alpha,
                weight: l.weight,
                target: l.state.target,
            })
            .collect();
        let (executed, _respawned, out) = dispatch_failover(&inj, feeder, &recs).unwrap();
        if executed != feeder {
            rerouted += 1;
        }
        for (k, lane) in lanes.iter().enumerate() {
            if lane.state.add_lane(lane.idx, out.row(k)) {
                assert!(lane.state.finalize(), "each request settles exactly once");
            }
        }
    }
    assert!(matches!(s.pop_chunk_for(0, 3, Duration::ZERO), Popped::Closed));
    let bits = replies
        .into_iter()
        .map(|(_st, rx)| {
            let resp = rx.recv().unwrap().expect("direct drive settles Ok");
            resp.attribution.values.iter().map(|v| v.to_bits()).collect::<Vec<u64>>()
        })
        .collect();
    DriveOut { bits, steals: s.counters().steals.get(), rerouted }
}

struct DriveOut {
    bits: Vec<Vec<u64>>,
    steals: u64,
    rerouted: usize,
}

/// With prefetch 4 over 8 chunks: feeder 0's first pull stages 3 chunks,
/// feeder 1's first pull stages the other 3; feeder 1 then drains its own
/// deque LIFO and — buckets and deque empty — must steal from feeder 0's
/// deque. The trailing pops drain the rest and absorb Closed.
const STEAL_SCRIPT: &[usize] = &[0, 1, 1, 1, 1, 1, 0, 0, 0, 1];

fn steal_heavy() -> StealConfig {
    StealConfig { stealing: true, local_prefetch: 4, starvation_limit: 64 }
}

#[test]
fn forced_steal_direct_drive_is_bit_identical() {
    // Reference: one feeder, staging disabled — the plain sequential
    // drain. Steal run: the scripted two-feeder drive above, where the
    // thief provably pops chunks feeder 0 staged. 0 ULP between them.
    let no_steal = StealConfig { stealing: false, local_prefetch: 1, starvation_limit: 64 };
    let reference = drive_script(&FaultPlan::new(vec![]), 1, no_steal, &[0; 10]);
    assert_eq!(reference.steals, 0, "single-feeder reference cannot steal");
    let stolen = drive_script(&FaultPlan::new(vec![]), 2, steal_heavy(), STEAL_SCRIPT);
    assert!(stolen.steals >= 1, "the script must force at least one steal");
    assert_eq!(stolen.bits, reference.bits, "a stolen chunk moved bits");
}

#[test]
fn stolen_chunk_survives_dead_home_shard() {
    // Same scripted steals, but the thief's home shard (1) is killed on
    // its first gather call and held down forever: every chunk feeder 1
    // dispatches — stolen ones included — rides the failover ladder to
    // shard 0. Nothing fails, and the bits still cannot move (§I7 + §I10
    // compose).
    let no_steal = StealConfig { stealing: false, local_prefetch: 1, starvation_limit: 64 };
    let reference = drive_script(&FaultPlan::new(vec![]), 1, no_steal, &[0; 10]);
    let plan = FaultPlan::with_seed(1, FaultPlan::kill_forever(1, 0));
    let out = drive_script(&plan, 2, steal_heavy(), STEAL_SCRIPT);
    assert!(out.steals >= 1, "the script must force at least one steal");
    assert!(out.rerouted >= 1, "the dead home shard must reroute the thief's chunks");
    assert_eq!(out.bits, reference.bits, "failover of a stolen chunk moved bits");
}
