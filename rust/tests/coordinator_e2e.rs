//! Integration: the serving coordinator end-to-end over real artifacts —
//! correctness under concurrency, cross-request batching, accounting,
//! graceful shutdown, and failure surfaces.

mod common;

use std::time::Duration;

use common::{close, have_artifacts, runtime, skip};
use nuig::config::CoordinatorConfig;
use nuig::coordinator::{Coordinator, ExplainRequest, LatencyBudget};
use nuig::data::synth;
use nuig::ig::{self, IgOptions, Rule, Scheme};

fn cfg(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig { workers, ..Default::default() }
}

#[test]
fn single_request_matches_direct_engine() {
    if !have_artifacts() {
        return skip("single_request_matches_direct_engine");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(1)).unwrap();
    let img = synth::gen_image(0, 0);
    let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 48, ..Default::default() };

    let resp = coord.explain(ExplainRequest::new(img.clone(), opts)).unwrap();
    let direct = ig::explain(&rt.model(), &img, None, &opts).unwrap();

    assert_eq!(resp.attribution.target, direct.target);
    assert_eq!(resp.attribution.steps, direct.steps);
    close(resp.attribution.sum(), direct.sum(), 1e-4, 1e-7);
    close(resp.attribution.delta, direct.delta, 1e-2, 1e-6);
    assert!(resp.attribution.cosine_similarity(&direct) > 0.99999);
    coord.shutdown();
}

#[test]
fn uniform_scheme_served() {
    if !have_artifacts() {
        return skip("uniform_scheme_served");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(1)).unwrap();
    let img = synth::gen_image(5, 0);
    let opts = IgOptions { scheme: Scheme::Uniform, m: 32, rule: Rule::Trapezoid, ..Default::default() };
    let resp = coord.explain(ExplainRequest::new(img.clone(), opts)).unwrap();
    let direct = ig::explain(&rt.model(), &img, None, &opts).unwrap();
    assert_eq!(resp.attribution.steps, 33);
    // The router probes alpha = 0 and 1 for target + gap even for the
    // uniform scheme: 2 forward passes, honestly accounted.
    assert_eq!(resp.attribution.probe_passes, 2);
    close(resp.attribution.sum(), direct.sum(), 1e-4, 1e-7);
    coord.shutdown();
}

#[test]
fn concurrent_mixed_load_is_correct_and_batched() {
    if !have_artifacts() {
        return skip("concurrent_mixed_load_is_correct_and_batched");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(2)).unwrap();

    // 12 concurrent requests across classes and schemes.
    let mut expected = Vec::new();
    let mut handles = Vec::new();
    for i in 0..12 {
        let class = i % 8;
        let scheme = if i % 3 == 0 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
        let opts = IgOptions { scheme, m: 16 + 8 * (i % 4), ..Default::default() };
        let img = synth::gen_image(class, 0);
        expected.push((img.clone(), opts));
        handles.push(coord.submit(ExplainRequest::new(img, opts)).unwrap());
    }
    let model = rt.model();
    for (h, (img, opts)) in handles.into_iter().zip(&expected) {
        let resp = h.wait().unwrap();
        let direct = ig::explain(&model, img, None, opts).unwrap();
        close(resp.attribution.sum(), direct.sum(), 1e-3, 1e-6);
        assert_eq!(resp.attribution.target, direct.target);
        assert!(resp.attribution.cosine_similarity(&direct) > 0.9999);
    }

    let stats = coord.stats();
    assert_eq!(stats.completed.get(), 12);
    assert_eq!(stats.failed.get(), 0);
    // Under concurrent load chunks must be mostly full — the batching
    // property the paper's §V argument needs.
    let occ = stats.mean_occupancy(coord.config().chunk);
    assert!(occ > 0.5, "batch occupancy {occ} too low for concurrent load");
    assert_eq!(coord.in_flight(), 0);
    coord.shutdown();
}

#[test]
fn pinned_target_and_custom_baseline() {
    if !have_artifacts() {
        return skip("pinned_target_and_custom_baseline");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(1)).unwrap();
    let img = synth::gen_image(1, 1);
    let baseline = vec![0.5f32; synth::F]; // gray baseline
    let mut req = ExplainRequest::new(img, IgOptions { m: 24, ..Default::default() });
    req.target = Some(3);
    req.baseline = Some(baseline);
    let resp = coord.explain(req).unwrap();
    assert_eq!(resp.attribution.target, 3);
    coord.shutdown();
}

#[test]
fn rejects_bad_requests_fast() {
    if !have_artifacts() {
        return skip("rejects_bad_requests_fast");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(1)).unwrap();

    // Wrong image width.
    assert!(coord.submit(ExplainRequest::new(vec![0.0; 7], IgOptions::default())).is_err());
    // Wrong baseline width.
    let mut req = ExplainRequest::new(vec![0.0; synth::F], IgOptions::default());
    req.baseline = Some(vec![0.0; 5]);
    assert!(coord.submit(req).is_err());
    // Target out of range.
    let mut req = ExplainRequest::new(vec![0.0; synth::F], IgOptions::default());
    req.target = Some(99);
    assert!(coord.submit(req).is_err());
    // m < n_int.
    let req = ExplainRequest::new(
        vec![0.0; synth::F],
        IgOptions { m: 2, scheme: Scheme::NonUniform { n_int: 8 }, ..Default::default() },
    );
    assert!(coord.submit(req).is_err());

    // Queue state must be clean after rejections.
    assert_eq!(coord.in_flight(), 0);
    coord.shutdown();
}

#[test]
fn drain_then_shutdown() {
    if !have_artifacts() {
        return skip("drain_then_shutdown");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(2)).unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            coord
                .submit(ExplainRequest::new(
                    synth::gen_image(i % 8, 0),
                    IgOptions { m: 16, ..Default::default() },
                ))
                .unwrap()
        })
        .collect();
    coord.drain(Duration::from_secs(120)).unwrap();
    assert_eq!(coord.in_flight(), 0);
    for h in handles {
        assert!(h.wait().is_ok());
    }
    coord.shutdown();
}

#[test]
fn shutdown_completes_in_flight_work() {
    if !have_artifacts() {
        return skip("shutdown_completes_in_flight_work");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(2)).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            coord
                .submit(ExplainRequest::new(
                    synth::gen_image(i, 0),
                    IgOptions { m: 16, ..Default::default() },
                ))
                .unwrap()
        })
        .collect();
    // Shut down immediately: graceful drain must still deliver responses.
    coord.shutdown();
    for h in handles {
        assert!(h.wait().is_ok(), "in-flight request dropped during shutdown");
    }
}

#[test]
fn tight_tier_warm_cache_skips_probe_passes() {
    if !have_artifacts() {
        return skip("tight_tier_warm_cache_skips_probe_passes");
    }
    let rt = runtime();
    let mut c = cfg(1);
    c.admission.cache_capacity = 64;
    let coord = Coordinator::start(rt, c).unwrap();
    let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 64, ..Default::default() };

    // Cold tight-tier request: probes, populates memo + schedule cache.
    // Tight admission rewrites m to the tier's m0 (16): 17 fused evals.
    let req = ExplainRequest::new(synth::gen_image(2, 0), opts)
        .with_budget(LatencyBudget::Tight)
        .with_target(2);
    let cold = coord.explain(req).unwrap();
    assert_eq!(cold.attribution.probe_passes, 5, "cold request pays the probe");
    assert_eq!(cold.attribution.steps, 17, "tight tier serves m0 = 16");

    // Warm: same class + baseline, different input — zero stage-1 passes,
    // the same canonical schedule off the cache.
    let req = ExplainRequest::new(synth::gen_image(2, 1), opts)
        .with_budget(LatencyBudget::Tight)
        .with_target(2);
    let warm = coord.explain(req).unwrap();
    assert_eq!(warm.attribution.probe_passes, 0, "warm tight-tier request must skip stage 1");
    assert_eq!(warm.attribution.steps, 17);
    assert!(warm.attribution.delta.is_finite());

    let stats = coord.stats();
    assert_eq!(stats.tier(LatencyBudget::Tight).submitted.get(), 2);
    assert_eq!(stats.tier(LatencyBudget::Tight).completed.get(), 2);
    assert_eq!(stats.tier(LatencyBudget::Tight).warm_admissions.get(), 1);
    assert!(stats.cache.hits.get() >= 1, "warm round 0 must hit the schedule cache");
    assert_eq!(stats.cache.insertions.get(), 1);
    assert_eq!(coord.schedule_cache().unwrap().memo_len(), 1);
    coord.shutdown();
}

#[test]
fn tier_mix_accounts_per_tier_and_unbounded_is_untouched() {
    if !have_artifacts() {
        return skip("tier_mix_accounts_per_tier_and_unbounded_is_untouched");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(2)).unwrap();
    let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 48, ..Default::default() };

    // Unbounded request: the admission path must not rewrite its m.
    let img = synth::gen_image(0, 0);
    let unb = coord.explain(ExplainRequest::new(img.clone(), opts)).unwrap();
    assert_eq!(unb.attribution.steps, 49, "unbounded keeps the requested m");
    let direct = ig::explain(&rt.model(), &img, None, &opts).unwrap();
    close(unb.attribution.sum(), direct.sum(), 1e-4, 1e-7);

    // Tier requests: m comes from the tier policy, rounds are capped.
    let std_resp = coord
        .explain(ExplainRequest::new(img.clone(), opts).with_budget(LatencyBudget::Standard))
        .unwrap();
    assert!(std_resp.attribution.rounds <= 3, "standard tier caps rounds at 3");
    let tho_resp = coord
        .explain(ExplainRequest::new(img, opts).with_budget(LatencyBudget::Thorough))
        .unwrap();
    assert!(tho_resp.attribution.rounds <= 6);
    assert!(tho_resp.attribution.delta.is_finite());

    let stats = coord.stats();
    assert_eq!(stats.tier(LatencyBudget::Unbounded).completed.get(), 1);
    assert_eq!(stats.tier(LatencyBudget::Standard).completed.get(), 1);
    assert_eq!(stats.tier(LatencyBudget::Thorough).completed.get(), 1);
    assert_eq!(stats.tier(LatencyBudget::Tight).completed.get(), 0);
    assert_eq!(stats.completed.get(), 3);
    assert_eq!(stats.cache.hits.get() + stats.cache.misses.get(), 0, "cache off by default");
    coord.shutdown();
}

#[test]
fn stage_breakdown_populated() {
    if !have_artifacts() {
        return skip("stage_breakdown_populated");
    }
    let rt = runtime();
    let coord = Coordinator::start(rt, cfg(1)).unwrap();
    let resp = coord
        .explain(ExplainRequest::new(
            synth::gen_image(0, 0),
            IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 32, ..Default::default() },
        ))
        .unwrap();
    let bd = &resp.attribution.breakdown;
    assert!(bd.probe.as_nanos() > 0, "probe time missing");
    assert!(bd.execute.as_nanos() > 0, "execute time missing");
    // Stage-1 overhead should be a small fraction (paper: 0.2-3.2%-ish;
    // CPU scales differ, so just assert it's a minority share).
    assert!(bd.stage1_fraction() < 0.5, "stage1 fraction {}", bd.stage1_fraction());
    coord.shutdown();
}
