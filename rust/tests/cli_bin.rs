//! Integration: the `nuig` binary's CLI surface (usage, errors, and the
//! artifact-backed subcommands when artifacts exist).

mod common;

use std::process::Command;

use common::have_artifacts;

fn nuig() -> Command {
    Command::new(env!("CARGO_BIN_EXE_nuig"))
}

#[test]
fn no_args_prints_usage() {
    let out = nuig().output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("USAGE"), "{stdout}");
    assert!(stdout.contains("explain"));
}

#[test]
fn unknown_command_fails() {
    let out = nuig().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown command"), "{stderr}");
}

#[test]
fn unknown_flag_fails() {
    if !have_artifacts() {
        return common::skip("unknown_flag_fails");
    }
    let out = nuig().args(["explain", "--bogus-flag", "1"]).output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bogus-flag"), "{stderr}");
}

#[test]
fn info_lists_executables() {
    if !have_artifacts() {
        return common::skip("info_lists_executables");
    }
    let out = nuig().args(["info"]).current_dir(env!("CARGO_MANIFEST_DIR")).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("igchunk_m16"), "{stdout}");
    assert!(stdout.contains("MiniInception"));
    assert!(stdout.contains("verified"));
}

#[test]
fn explain_reports_delta_and_steps() {
    if !have_artifacts() {
        return common::skip("explain_reports_delta_and_steps");
    }
    let out = nuig()
        .args(["explain", "--class", "2", "--m", "24", "--scheme", "nonuniform:4", "--ascii"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("delta (Eq. 3)"), "{stdout}");
    // Fused schedule: m=24 trapezoid costs exactly 25 gradient evals.
    assert!(stdout.contains("25 gradient evals + 5 probe passes"), "{stdout}");
}

#[test]
fn bad_scheme_rejected() {
    if !have_artifacts() {
        return common::skip("bad_scheme_rejected");
    }
    let out = nuig().args(["explain", "--scheme", "magic"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown scheme"));
}

#[test]
fn adaptive_subcommand_converges() {
    if !have_artifacts() {
        return common::skip("adaptive_subcommand_converges");
    }
    let out = nuig()
        .args(["adaptive", "--class", "0", "--delta-th", "0.05"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("converged        : true"), "{stdout}");
}

#[test]
fn ensemble_subcommand_runs() {
    if !have_artifacts() {
        return common::skip("ensemble_subcommand_runs");
    }
    let out = nuig()
        .args(["ensemble", "--class", "1", "--method", "baselines", "--samples", "3", "--m", "16"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("3 members"), "{stdout}");
    assert!(out.status.success());
}

#[test]
fn ensemble_rejects_unknown_method() {
    if !have_artifacts() {
        return common::skip("ensemble_rejects_unknown_method");
    }
    let out = nuig().args(["ensemble", "--method", "voodoo"]).current_dir(env!("CARGO_MANIFEST_DIR")).output().unwrap();
    assert!(!out.status.success());
}
