//! Loopback round-trips through the serving front-end
//! (`coordinator::frontend`): a real socket, the framed wire protocol,
//! and a gated analytic backend that opens deterministic windows for
//! the graceful-degradation paths.
//!
//! What is pinned here:
//!
//! * **I12 (partial-response determinism)** — a deadline-expired
//!   request settles with a partial FINAL whose values are bit-identical
//!   (0 ULP) to the streamed ROUND frame *and* to a standalone fixed-m
//!   run stopped at the same round.
//! * **I11 (cancellation subtree isolation)** — a client disconnect
//!   cancels that connection's requests only, and the resident slot is
//!   reclaimed exactly once.
//! * Typed backpressure: the accept backlog and the drain window both
//!   answer with REJECT frames carrying the integer-deterministic
//!   retry-after hint (exactly 25 ms under the default shed config).
//! * Graceful drain: shutdown settles every in-flight request on the
//!   wire before the listener goes away — zero lost settlements.

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use nuig::config::{CoordinatorConfig, FrontendConfig};
use nuig::coordinator::frontend::framing::{
    self, Frame, FrameReader, RequestFrame, REJECT_BACKLOG, REJECT_DRAINING,
};
use nuig::coordinator::frontend::listener;
use nuig::coordinator::{Coordinator, Frontend};
use nuig::exec::{GatherExec, GatherLane, GatherOut};
use nuig::ig::{AnalyticExec, AnalyticModel, IgOptions, Scheme};

const FE: usize = 12;

fn analytic() -> AnalyticExec {
    AnalyticExec::new(AnalyticModel::new(FE, 3, 0xC0FFEE, 9.0))
}

/// Wraps [`AnalyticExec`], parking `eval_gather` calls past a
/// configured budget until [`GatedExec::release`] — the same idiom the
/// coordinator's in-crate cancellation tests use to open deterministic
/// windows (round 1 done, round 2 parked on the device).
struct GatedExec {
    inner: AnalyticExec,
    free_evals: Option<u64>,
    gathers: AtomicU64,
    evictions: AtomicU64,
    open: Mutex<bool>,
    cv: Condvar,
}

impl GatedExec {
    fn new(inner: AnalyticExec, free_evals: Option<u64>) -> Self {
        GatedExec {
            inner,
            free_evals,
            gathers: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            open: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

impl GatherExec for GatedExec {
    fn features(&self) -> usize {
        self.inner.features()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
    fn forward(&self, imgs: &[f32], rows: usize) -> anyhow::Result<Vec<f32>> {
        self.inner.forward(imgs, rows)
    }
    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> anyhow::Result<()> {
        self.inner.register_request(slot, x, baseline)
    }
    fn evict_request(&self, slot: u64) {
        self.evictions.fetch_add(1, Ordering::AcqRel);
        self.inner.evict_request(slot);
    }
    fn resident_len(&self) -> usize {
        self.inner.resident_len()
    }
    fn shards(&self) -> usize {
        self.inner.shards()
    }
    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> anyhow::Result<GatherOut> {
        let seen = self.gathers.fetch_add(1, Ordering::AcqRel);
        if let Some(free) = self.free_evals {
            if seen >= free {
                let mut open = self.open.lock().unwrap();
                while !*open {
                    open = self.cv.wait(open).unwrap();
                }
            }
        }
        self.inner.eval_gather(shard, lanes)
    }
}

fn serve_cfg() -> CoordinatorConfig {
    CoordinatorConfig { workers: 1, feeders: 1, devices: 1, ..Default::default() }
}

fn frontend_cfg(listen: &str) -> FrontendConfig {
    FrontendConfig { listen: listen.into(), conn_workers: 1, ..Default::default() }
}

fn image() -> Vec<f32> {
    (0..FE).map(|i| i as f32 / FE as f32).collect()
}

/// An anytime request frame that can never converge (δ target 0, huge
/// budget): it refines until cancelled.
fn endless_frame(tag: u64, deadline_ms: u64) -> Frame {
    Frame::Request(RequestFrame {
        tag,
        deadline_ms,
        budget: 0,
        target: -1,
        m: 8,
        anytime: Some((0.0, 1 << 20)),
        image: image(),
        baseline: None,
    })
}

/// A plain fixed-m request frame (completes in one round once unparked).
fn fixed_frame(tag: u64) -> Frame {
    Frame::Request(RequestFrame {
        tag,
        deadline_ms: 0,
        budget: 0,
        target: -1,
        m: 8,
        anytime: None,
        image: image(),
        baseline: None,
    })
}

fn wait_until(what: &str, mut ready: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !ready() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn shutdown_all(fe: Arc<Frontend>, coord: Arc<Coordinator>) {
    fe.shutdown();
    drop(fe);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

/// The fixed-m reference attribution: what a standalone run stopped at
/// round 1 of the same request produces.
fn round1_reference() -> nuig::ig::Attribution {
    let coord = Coordinator::start_with_backend(Arc::new(analytic()), serve_cfg()).unwrap();
    let req = nuig::coordinator::ExplainRequest::new(
        image(),
        IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 8, ..Default::default() },
    );
    let resp = coord.explain(req).unwrap();
    coord.shutdown();
    resp.attribution
}

#[test]
fn deadline_partial_matches_streamed_round_and_standalone_bits() {
    // Round 1 executes; round 2 parks on the device. The 500 ms wire
    // deadline then fires with exactly one converged round on record.
    let backend = Arc::new(GatedExec::new(analytic(), Some(1)));
    let coord =
        Arc::new(Coordinator::start_with_backend(backend.clone(), serve_cfg()).unwrap());
    let fe = Frontend::start(coord.clone(), frontend_cfg("tcp:127.0.0.1:0")).unwrap();

    let stream = listener::connect(fe.local_spec()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = FrameReader::new(stream, 16 << 20);
    w.write_all(&framing::encode(&endless_frame(7, 500))).unwrap();

    let round = match r.next().unwrap().expect("round 1 streams before the deadline") {
        Frame::Round(rf) => rf,
        other => panic!("expected ROUND, got {other:?}"),
    };
    assert_eq!(round.tag, 7);
    assert_eq!(round.round, 1);
    assert_eq!(round.values.len(), FE);

    let fin = match r.next().unwrap().expect("the deadline settles a FINAL") {
        Frame::Final(ff) => ff,
        other => panic!("expected FINAL, got {other:?}"),
    };
    assert_eq!(fin.tag, 7);
    assert!(fin.partial, "a deadline expiry settles with the partial flag set");
    assert_eq!(fin.rounds, 1, "the last converged round is round 1");

    // I12, leg 1: the streamed round already holds the partial's bits.
    for (s, p) in round.values.iter().zip(&fin.values) {
        assert_eq!(s.to_bits(), p.to_bits(), "streamed round == partial FINAL, 0 ULP");
    }
    assert_eq!(round.delta.to_bits(), fin.delta.to_bits());

    // I12, leg 2: both equal a standalone run stopped at round 1 (a
    // fixed-m run of the same schedule) — bit-identical across the
    // wire, the stream, and the offline path.
    let reference = round1_reference();
    assert_eq!(fin.values.len(), reference.values.len());
    for (wire, refv) in fin.values.iter().zip(&reference.values) {
        assert_eq!(wire.to_bits(), refv.to_bits(), "wire partial == standalone round-1, 0 ULP");
    }
    assert_eq!(fin.delta.to_bits(), reference.delta.to_bits());

    assert_eq!(fe.deadlines_fired(), 1);
    assert_eq!(fe.stats().partials_streamed.get(), 1);
    assert!(fe.stats().rounds_streamed.get() >= 1);
    assert_eq!(coord.stats().deadline_partials.get(), 1);

    drop(w);
    drop(r);
    backend.release(); // the parked round-2 chunk executes harmlessly
    shutdown_all(fe, coord);
    assert_eq!(backend.resident_len(), 0, "resident slot reclaimed");
    assert_eq!(backend.evictions.load(Ordering::Acquire), 1, "… exactly once");
}

#[cfg(unix)]
#[test]
fn disconnect_cancels_subtree_and_frees_resident_slot_exactly_once() {
    // Unix transport: a write after the peer closed fails immediately
    // (EPIPE), so the disconnect window is deterministic.
    let backend = Arc::new(GatedExec::new(analytic(), Some(1)));
    let coord =
        Arc::new(Coordinator::start_with_backend(backend.clone(), serve_cfg()).unwrap());
    let sock = format!(
        "unix:{}/nuig-rt-{}.sock",
        std::env::temp_dir().display(),
        std::process::id()
    );
    let fe = Frontend::start(coord.clone(), frontend_cfg(&sock)).unwrap();

    let stream = listener::connect(fe.local_spec()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = FrameReader::new(stream, 16 << 20);
    w.write_all(&framing::encode(&endless_frame(3, 0))).unwrap();

    // Round 1 reaches the client: the request is routed, resident, and
    // mid-refinement when the client vanishes.
    match r.next().unwrap().expect("round 1 streams") {
        Frame::Round(rf) => assert_eq!(rf.tag, 3),
        other => panic!("expected ROUND, got {other:?}"),
    }
    assert_eq!(coord.resident_len(), 1);

    // Full close, then release the gate: the next streamed round's
    // write hits the dead socket, the connection token cancels, and the
    // writer forwards the disconnect into the coordinator.
    drop(w);
    drop(r);
    backend.release();

    wait_until("the disconnect to settle the request", || {
        coord.stats().disconnect_cancels.get() == 1
    });
    wait_until("the resident slot to drain", || coord.resident_len() == 0);
    assert_eq!(backend.evictions.load(Ordering::Acquire), 1, "slot reclaimed exactly once");
    assert_eq!(fe.stats().disconnects.get(), 1);
    assert_eq!(coord.stats().failed.get(), 1);

    wait_until("the connection worker to retire", || fe.active_connections() == 0);
    shutdown_all(fe, coord);
    assert_eq!(backend.evictions.load(Ordering::Acquire), 1, "shutdown does not re-evict");
}

#[test]
fn accept_backlog_overflow_answers_typed_reject_with_exact_retry_hint() {
    // One connection worker, a one-slot accept backlog: connection A is
    // being served, B fills the backlog, C must be turned away with a
    // typed REJECT carrying the integer-deterministic hint (default
    // shed marks are 0 ⇒ the overload factor clamps to 1 ⇒ exactly the
    // 25 ms base).
    let backend = Arc::new(GatedExec::new(analytic(), None));
    let coord =
        Arc::new(Coordinator::start_with_backend(backend.clone(), serve_cfg()).unwrap());
    let fcfg = FrontendConfig {
        listen: "tcp:127.0.0.1:0".into(),
        conn_backlog: 1,
        conn_workers: 1,
        ..Default::default()
    };
    let fe = Frontend::start(coord.clone(), fcfg).unwrap();

    let a = listener::connect(fe.local_spec()).unwrap();
    wait_until("A to reach its worker", || fe.active_connections() == 1);
    let b = listener::connect(fe.local_spec()).unwrap();
    wait_until("B to queue in the backlog", || fe.stats().conns_accepted.get() == 2);

    let c = listener::connect(fe.local_spec()).unwrap();
    let mut r = FrameReader::new(c, 16 << 20);
    let rej = match r.next().unwrap().expect("C gets a REJECT before close") {
        Frame::Reject(rj) => rj,
        other => panic!("expected REJECT, got {other:?}"),
    };
    assert_eq!(rej.tag, 0, "connection-level reject precedes any request");
    assert_eq!(rej.reason, REJECT_BACKLOG);
    assert_eq!(rej.retry_after_ms, 25, "integer-deterministic backoff hint");
    assert!(r.next().unwrap().is_none(), "the rejected connection is closed");
    assert_eq!(fe.stats().conns_rejected.get(), 1);

    drop(a);
    drop(b);
    shutdown_all(fe, coord);
}

#[test]
fn graceful_drain_settles_in_flight_and_rejects_new_requests() {
    // Round 1 of the in-flight request parks on the device; a drain
    // begins; a request arriving mid-drain gets a typed REJECT; the
    // parked request then completes and its FINAL still reaches the
    // client — zero lost settlements.
    let backend = Arc::new(GatedExec::new(analytic(), Some(0)));
    let coord =
        Arc::new(Coordinator::start_with_backend(backend.clone(), serve_cfg()).unwrap());
    let fe = Frontend::start(coord.clone(), frontend_cfg("tcp:127.0.0.1:0")).unwrap();

    let stream = listener::connect(fe.local_spec()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = FrameReader::new(stream, 16 << 20);
    w.write_all(&framing::encode(&fixed_frame(11))).unwrap();
    wait_until("the request to route", || coord.resident_len() == 1);

    let drainer = {
        let fe = fe.clone();
        std::thread::spawn(move || fe.shutdown())
    };
    wait_until("the drain to fence admissions", || !fe.is_accepting());

    // A request submitted into the drain window is refused, typed.
    w.write_all(&framing::encode(&fixed_frame(12))).unwrap();
    let rej = match r.next().unwrap().expect("the drain answers a REJECT") {
        Frame::Reject(rj) => rj,
        other => panic!("expected REJECT, got {other:?}"),
    };
    assert_eq!(rej.tag, 12);
    assert_eq!(rej.reason, REJECT_DRAINING);
    assert_eq!(rej.retry_after_ms, 25, "integer-deterministic backoff hint");

    // Unpark the device: the in-flight request completes and settles on
    // the wire even though the front-end is mid-drain.
    backend.release();
    let fin = match r.next().unwrap().expect("the drained request still settles") {
        Frame::Final(ff) => ff,
        other => panic!("expected FINAL, got {other:?}"),
    };
    assert_eq!(fin.tag, 11);
    assert!(!fin.partial, "a drain is not a deadline: the result is complete");
    assert_eq!(fin.rounds, 1);

    assert!(r.next().unwrap().is_none(), "the connection closes after the drain");
    drainer.join().unwrap();
    assert_eq!(fe.stats().draining_rejects.get(), 1);
    assert_eq!(coord.stats().completed.get(), 1);
    assert_eq!(coord.in_flight(), 0, "zero lost settlements");

    shutdown_all(fe, coord);
    assert_eq!(backend.evictions.load(Ordering::Acquire), 1);
}
