//! Integration: the sharded gather feeder over the artifact-free
//! `AnalyticExec` backend — the serving-layer determinism and
//! exactly-once contracts that gate the device-sharding refactor.
//!
//! No artifacts needed: these run in every tier-1 `cargo test`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;
use nuig::config::CoordinatorConfig;
use nuig::coordinator::{Coordinator, ExplainRequest, LatencyBudget};
use nuig::exec::gather::{GatherExec, GatherLane, GatherOut};
use nuig::ig::{AnalyticExec, AnalyticModel, IgOptions, Scheme};

const F: usize = 32;
const C: usize = 4;

fn model() -> AnalyticModel {
    AnalyticModel::new(F, C, 0xFEED, 12.0)
}

fn image(i: usize) -> Vec<f32> {
    (0..F).map(|k| (((i * 31 + k * 7) % 64) as f32) / 64.0).collect()
}

/// A deterministic mixed workload: both schemes, several m levels, and
/// a standard-tier (anytime) slice so refinement rounds cross feeders.
fn workload(n: usize) -> Vec<ExplainRequest> {
    (0..n)
        .map(|i| {
            let scheme =
                if i % 4 == 3 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
            let m = [8, 12, 16, 24][i % 4];
            let req =
                ExplainRequest::new(image(i), IgOptions { scheme, m, ..Default::default() });
            if i % 3 == 0 && scheme != Scheme::Uniform {
                req.with_budget(LatencyBudget::Standard)
            } else {
                req
            }
        })
        .collect()
}

fn cfg(feeders: usize, devices: usize) -> CoordinatorConfig {
    CoordinatorConfig { feeders, devices, workers: 2, ..Default::default() }
}

fn run_workload(feeders: usize, n: usize) -> Result<Vec<Vec<u64>>> {
    let backend = Arc::new(AnalyticExec::with_shards(model(), feeders));
    let coord = Coordinator::start_with_backend(backend.clone(), cfg(feeders, feeders))?;
    let handles: Vec<_> =
        workload(n).into_iter().map(|r| coord.submit(r)).collect::<Result<_, _>>()?;
    let mut out = Vec::with_capacity(n);
    for h in handles {
        let resp = h.wait()?;
        out.push(resp.attribution.values.iter().map(|v| v.to_bits()).collect());
    }
    coord.shutdown();
    assert_eq!(backend.resident_len(), 0, "resident pool must drain after shutdown");
    Ok(out)
}

#[test]
fn attributions_bit_identical_across_feeder_counts() {
    // THE acceptance property of the sharded feeder: for a fixed
    // workload, attributions are bit-identical (0 ULP) at feeder counts
    // {1, 2, 4} — chunk-completion races cannot move a single bit
    // because rows commit in lane-index order.
    let reference = run_workload(1, 12).unwrap();
    for feeders in [2usize, 4] {
        let got = run_workload(feeders, 12).unwrap();
        assert_eq!(got.len(), reference.len());
        for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "request {i}: bits diverged at {feeders} feeders");
        }
    }
}

/// Wraps `AnalyticExec`, failing `eval_gather` according to the mode —
/// the device-failure stand-in for the exactly-once tests.
struct FlakyExec {
    inner: AnalyticExec,
    /// Shards whose gather executions fail (bitmask by shard index).
    fail_shards: u64,
    calls: AtomicU64,
}

impl FlakyExec {
    fn new(inner: AnalyticExec, fail_shards: u64) -> FlakyExec {
        FlakyExec { inner, fail_shards, calls: AtomicU64::new(0) }
    }
}

impl GatherExec for FlakyExec {
    fn features(&self) -> usize {
        self.inner.features()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.inner.forward(imgs, rows)
    }
    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        self.inner.register_request(slot, x, baseline)
    }
    fn evict_request(&self, slot: u64) {
        self.inner.evict_request(slot);
    }
    fn resident_len(&self) -> usize {
        self.inner.resident_len()
    }
    fn shards(&self) -> usize {
        self.inner.shards()
    }
    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        if self.fail_shards & (1 << shard) != 0 {
            anyhow::bail!("injected device failure on shard {shard}");
        }
        self.inner.eval_gather(shard, lanes)
    }
}

#[test]
fn total_device_failure_fails_each_request_exactly_once() {
    // Every gather chunk fails on every shard: requests spanning several
    // chunks — dispatched concurrently by 4 feeders — must each settle
    // (and be counted) exactly once. Extends the single-feeder
    // exactly-once test of the batched-backend PR to the sharded pool.
    let n = 10;
    let backend = Arc::new(FlakyExec::new(AnalyticExec::with_shards(model(), 2), 0b11));
    let coord = Coordinator::start_with_backend(backend.clone(), cfg(4, 2)).unwrap();
    let handles: Vec<_> =
        workload(n).into_iter().map(|r| coord.submit(r)).collect::<Result<_, _>>().unwrap();
    for h in handles {
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("device"), "{err}");
    }
    let stats = coord.stats();
    assert_eq!(stats.failed.get(), n as u64, "each request fails exactly once");
    assert_eq!(stats.completed.get(), 0);
    assert_eq!(coord.in_flight(), 0);
    assert!(backend.calls.load(Ordering::Relaxed) >= 1);
    coord.shutdown();
    assert_eq!(backend.resident_len(), 0, "failed requests still evict their residents");
}

#[test]
fn partial_shard_failure_settles_every_request_exactly_once() {
    // Shard 1 is dead, shard 0 healthy, 2 feeders racing: a request's
    // chunks may split across both. Whatever the interleaving, every
    // request settles exactly once (completed XOR failed), the gauges
    // return to zero, and the resident pool drains.
    let n = 14;
    let backend = Arc::new(FlakyExec::new(AnalyticExec::with_shards(model(), 2), 0b10));
    let coord = Coordinator::start_with_backend(backend.clone(), cfg(2, 2)).unwrap();
    let handles: Vec<_> =
        workload(n).into_iter().map(|r| coord.submit(r)).collect::<Result<_, _>>().unwrap();
    let mut completed = 0u64;
    let mut failed = 0u64;
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                completed += 1;
                assert!(resp.attribution.delta.is_finite());
            }
            Err(e) => {
                failed += 1;
                assert!(e.to_string().contains("device"), "{e}");
            }
        }
    }
    let stats = coord.stats();
    assert_eq!(completed + failed, n as u64, "every request settles exactly once");
    assert_eq!(stats.completed.get(), completed);
    assert_eq!(stats.failed.get(), failed);
    assert_eq!(coord.in_flight(), 0);
    coord.shutdown();
    assert_eq!(backend.resident_len(), 0);
}

#[test]
fn resident_cap_rejects_at_admission() {
    // Fill the pool to the cap out-of-band: the next admission must be
    // rejected with a pointed error (and counted), not wedged.
    let backend = Arc::new(AnalyticExec::new(model()));
    let black = vec![0f32; F];
    backend.register_request(9_999, &image(0), &black).unwrap();
    let mut c = cfg(1, 1);
    c.resident_cap = 1;
    let coord = Coordinator::start_with_backend(backend.clone(), c).unwrap();
    let err = coord
        .explain(ExplainRequest::new(image(1), IgOptions { m: 8, ..Default::default() }))
        .unwrap_err()
        .to_string();
    assert!(err.contains("resident pool full"), "{err}");
    assert_eq!(coord.stats().resident_rejections.get(), 1);
    assert_eq!(coord.stats().failed.get(), 1);
    assert_eq!(coord.in_flight(), 0);
    // Freeing the pool un-wedges admission.
    backend.evict_request(9_999);
    let resp = coord
        .explain(ExplainRequest::new(image(1), IgOptions { m: 8, ..Default::default() }))
        .unwrap();
    assert!(resp.attribution.delta.is_finite());
    // Eviction fires when the feeder drops its last lane reference —
    // deterministic only once the feeders have joined.
    coord.shutdown();
    assert_eq!(backend.resident_len(), 0, "settled + drained request evicted its resident");
}

#[test]
fn sharded_serving_matches_direct_engine() {
    // Correctness anchor: the gather path over resident tensors computes
    // the same attribution the direct engine does (f32 row scatter vs
    // the engine's f64 partial accumulation ⇒ tolerance, not bits).
    let backend = Arc::new(AnalyticExec::with_shards(model(), 2));
    let coord = Coordinator::start_with_backend(backend.clone(), cfg(2, 2)).unwrap();
    let img = image(3);
    let opts =
        IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
    let resp = coord.explain(ExplainRequest::new(img.clone(), opts)).unwrap();
    let direct = nuig::ig::explain(backend.model(), &img, None, &opts).unwrap();
    assert_eq!(resp.attribution.target, direct.target);
    // The coordinator probes through the backend's f32 forward surface
    // while the direct engine probes in f64, so the two stage-1 deltas
    // (and in rare tie cases the per-interval allocation) can differ at
    // rounding scale — compare the attributions, not the schedules.
    let sum_served: f64 = resp.attribution.values.iter().sum();
    let sum_direct: f64 = direct.values.iter().sum();
    assert!(
        (sum_served - sum_direct).abs() < 1e-2,
        "served {sum_served} vs direct {sum_direct}"
    );
    assert!(resp.attribution.cosine_similarity(&direct) > 0.999);
    coord.shutdown();
}
