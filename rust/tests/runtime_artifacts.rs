//! Integration: the Rust runtime executing the real AOT artifacts must
//! reproduce the golden numbers Python wrote at export time
//! (`artifacts/testvectors.json`) — the cross-language numerics contract.

mod common;

use common::{close, have_artifacts, runtime, skip, testvectors};
use nuig::data::synth;
use nuig::ig::{self, IgOptions, Model, Rule, Scheme};
use nuig::runtime::{Arg, ExeKind, ProbeMode};

#[test]
fn manifest_sane() {
    if !have_artifacts() {
        return skip("manifest_sane");
    }
    let rt = runtime();
    assert_eq!(rt.manifest.features, synth::F);
    assert_eq!(rt.manifest.num_classes, synth::NUM_CLASSES);
    assert_eq!(rt.manifest.executables.len(), 5);
    rt.manifest.verify_corpus().unwrap();
}

#[test]
fn fwd_probs_match_testvectors() {
    if !have_artifacts() {
        return skip("fwd_probs_match_testvectors");
    }
    let rt = runtime();
    let model = rt.model();
    let tv = testvectors();
    for case in tv.get("images").unwrap().as_arr().unwrap() {
        let class = case.get("class").unwrap().as_usize().unwrap();
        let index = case.get("index").unwrap().as_usize().unwrap();
        let expect = case.get("probs").unwrap().as_f64_vec().unwrap();
        let target = case.get("target").unwrap().as_usize().unwrap();

        let img = synth::gen_image(class, index);
        // Image itself must match Python bit-for-bit.
        close(
            synth::image_sum(&img),
            case.get("image_sum").unwrap().as_f64().unwrap(),
            0.0,
            1e-9,
        );
        for (idx_str, val) in case.get("image_probe").unwrap().as_obj().unwrap() {
            let i: usize = idx_str.parse().unwrap();
            assert_eq!(img[i] as f64, val.as_f64().unwrap(), "pixel {i} differs");
        }

        let probs = model.probs(&[&img]).unwrap();
        assert_eq!(probs[0].len(), synth::NUM_CLASSES);
        for (c, (&got, &want)) in probs[0].iter().zip(&expect).enumerate() {
            close(got, want, 1e-4, 1e-6);
            let _ = c;
        }
        assert_eq!(ig::engine::argmax(&probs[0]), target);
    }
}

#[test]
fn fwd_batched_equals_sequential() {
    if !have_artifacts() {
        return skip("fwd_batched_equals_sequential");
    }
    let rt = runtime();
    let batched = rt.model();
    let sequential = rt.model().with_probe_mode(ProbeMode::Sequential);
    let imgs: Vec<Vec<f32>> = (0..5).map(|i| synth::gen_image(i % 8, i / 8)).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let a = batched.probs(&refs).unwrap();
    let b = sequential.probs(&refs).unwrap();
    for (pa, pb) in a.iter().zip(&b) {
        for (&x, &y) in pa.iter().zip(pb) {
            close(x, y, 1e-5, 1e-7);
        }
    }
}

#[test]
fn ig_chunk_matches_testvectors() {
    if !have_artifacts() {
        return skip("ig_chunk_matches_testvectors");
    }
    let rt = runtime();
    let handle = rt.handle();
    let tv = testvectors();
    for case in tv.get("images").unwrap().as_arr().unwrap() {
        let class = case.get("class").unwrap().as_usize().unwrap();
        let index = case.get("index").unwrap().as_usize().unwrap();
        let target = case.get("target").unwrap().as_usize().unwrap();
        let chunk = case.get("chunk").unwrap();
        let alphas: Vec<f32> =
            chunk.get("alphas").unwrap().as_f64_vec().unwrap().iter().map(|&v| v as f32).collect();
        let weights: Vec<f32> =
            chunk.get("weights").unwrap().as_f64_vec().unwrap().iter().map(|&v| v as f32).collect();

        let img = synth::gen_image(class, index);
        let mut onehot = vec![0f32; synth::NUM_CLASSES];
        onehot[target] = 1.0;
        let outs = handle
            .execute(
                ExeKind::IgChunk16,
                vec![
                    Arg::vec(img),
                    Arg::vec(vec![0f32; synth::F]),
                    Arg::vec(alphas),
                    Arg::vec(weights),
                    Arg::vec(onehot),
                ],
            )
            .unwrap();
        let partial_sum: f64 = outs[0].iter().map(|&v| v as f64).sum();
        close(partial_sum, chunk.get("partial_sum").unwrap().as_f64().unwrap(), 1e-4, 1e-6);

        let expect_tp = chunk.get("target_probs").unwrap().as_f64_vec().unwrap();
        for (k, &want) in expect_tp.iter().enumerate() {
            let got = outs[1][k * synth::NUM_CLASSES + target] as f64;
            close(got, want, 1e-4, 1e-6);
        }
    }
}

#[test]
fn engine_uniform_matches_python_reference() {
    if !have_artifacts() {
        return skip("engine_uniform_matches_python_reference");
    }
    let rt = runtime();
    let model = rt.model();
    let tv = testvectors();
    for case in tv.get("images").unwrap().as_arr().unwrap() {
        let class = case.get("class").unwrap().as_usize().unwrap();
        let index = case.get("index").unwrap().as_usize().unwrap();
        let target = case.get("target").unwrap().as_usize().unwrap();
        let img = synth::gen_image(class, index);
        let opts = IgOptions { scheme: Scheme::Uniform, m: 64, rule: Rule::Trapezoid, ..Default::default() };
        let attr =
            ig::engine::explain_with_target(&model, &img, &vec![0f32; synth::F], target, &opts)
                .unwrap();

        let uni = case.get("uniform_m64").unwrap();
        close(attr.sum(), uni.get("attr_sum").unwrap().as_f64().unwrap(), 1e-3, 1e-5);
        close(attr.delta, uni.get("delta").unwrap().as_f64().unwrap(), 1e-2, 1e-5);
        for (idx_str, val) in uni.get("attr_probe").unwrap().as_obj().unwrap() {
            let i: usize = idx_str.parse().unwrap();
            close(attr.values[i], val.as_f64().unwrap(), 1e-3, 1e-7);
        }
    }
}

#[test]
fn engine_nonuniform_matches_python_reference() {
    if !have_artifacts() {
        return skip("engine_nonuniform_matches_python_reference");
    }
    let rt = runtime();
    let model = rt.model();
    let tv = testvectors();
    for case in tv.get("images").unwrap().as_arr().unwrap() {
        let class = case.get("class").unwrap().as_usize().unwrap();
        let index = case.get("index").unwrap().as_usize().unwrap();
        let target = case.get("target").unwrap().as_usize().unwrap();
        let img = synth::gen_image(class, index);
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 64, ..Default::default() };
        let attr =
            ig::engine::explain_with_target(&model, &img, &vec![0f32; synth::F], target, &opts)
                .unwrap();

        let non = case.get("nonuniform_m64_n4").unwrap();
        assert_eq!(attr.steps, non.get("steps").unwrap().as_usize().unwrap());
        assert_eq!(attr.probe_passes, non.get("probe_passes").unwrap().as_usize().unwrap());
        close(attr.sum(), non.get("attr_sum").unwrap().as_f64().unwrap(), 1e-3, 1e-5);
        close(attr.delta, non.get("delta").unwrap().as_f64().unwrap(), 2e-2, 1e-5);

        // The paper's iso-step claim on this exact case.
        let uni_delta = case.get("uniform_m64").unwrap().get("delta").unwrap().as_f64().unwrap();
        assert!(attr.delta < uni_delta, "nonuniform {} !< uniform {uni_delta}", attr.delta);
    }
}

#[test]
fn multi_chunk_matches_testvectors() {
    if !have_artifacts() {
        return skip("multi_chunk_matches_testvectors");
    }
    let rt = runtime();
    let handle = rt.handle();
    let tv = testvectors();
    let mc = tv.get("multi_chunk").unwrap();
    let targets = mc.get("targets").unwrap().as_usize_vec().unwrap();
    let lane_sums = mc.get("lane_sums").unwrap().as_f64_vec().unwrap();

    let img_a = synth::gen_image(0, 0);
    let img_b = synth::gen_image(3, 0);
    let f = synth::F;
    let c = synth::NUM_CLASSES;
    let mut xs = vec![0f32; 16 * f];
    let mut onehots = vec![0f32; 16 * c];
    let mut alphas = vec![0f32; 16];
    let mut weights = vec![0f32; 16];
    for k in 0..8 {
        xs[2 * k * f..(2 * k + 1) * f].copy_from_slice(&img_a);
        xs[(2 * k + 1) * f..(2 * k + 2) * f].copy_from_slice(&img_b);
        onehots[2 * k * c + targets[0]] = 1.0;
        onehots[(2 * k + 1) * c + targets[1]] = 1.0;
        alphas[2 * k] = k as f32 / 7.0;
        alphas[2 * k + 1] = k as f32 / 7.0;
        weights[2 * k] = 1.0 / 8.0;
        weights[2 * k + 1] = 1.0 / 8.0;
    }
    let outs = handle
        .execute(
            ExeKind::IgChunkMulti16,
            vec![
                Arg::mat(xs, 16, f),
                Arg::mat(vec![0f32; 16 * f], 16, f),
                Arg::vec(alphas),
                Arg::vec(weights),
                Arg::mat(onehots, 16, c),
            ],
        )
        .unwrap();
    for (k, &want) in lane_sums.iter().enumerate() {
        let got: f64 = outs[0][k * f..(k + 1) * f].iter().map(|&v| v as f64).sum();
        close(got, want, 1e-3, 1e-6);
    }
    // Lane-0 probs row.
    let probs0 = mc.get("probs_lane0").unwrap().as_f64_vec().unwrap();
    for (j, &want) in probs0.iter().enumerate() {
        close(outs[1][j] as f64, want, 1e-4, 1e-6);
    }
}

#[test]
fn runtime_stats_accumulate() {
    if !have_artifacts() {
        return skip("runtime_stats_accumulate");
    }
    let rt = runtime();
    let model = rt.model();
    // ProbeMode::Auto routes a single image through fwd_b1.
    let before1 = rt.stats().count(ExeKind::Fwd1);
    let img = synth::gen_image(1, 0);
    model.probs(&[&img]).unwrap();
    assert!(rt.stats().count(ExeKind::Fwd1) > before1);
    assert!(rt.stats().latency(ExeKind::Fwd1).mean() > 0.0);
    // ...and a 16-image batch through fwd_b16.
    let before16 = rt.stats().count(ExeKind::Fwd16);
    let imgs: Vec<Vec<f32>> = (0..16).map(|i| synth::gen_image(i % 8, 0)).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    model.probs(&refs).unwrap();
    assert!(rt.stats().count(ExeKind::Fwd16) > before16);
}

#[test]
fn resident_path_bit_identical_to_upload_path() {
    if !have_artifacts() {
        return skip("resident_path_bit_identical_to_upload_path");
    }
    // The resident-tensor path (endpoints uploaded once, igchunk_b16 fed
    // O(chunk) bytes per call) must reproduce the per-chunk upload path
    // to the bit: same executable, same buffer contents, only the
    // transport changes.
    let rt = runtime();
    let model = rt.model();
    let img = synth::gen_image(4, 0);
    let baseline = vec![0f32; synth::F];
    let alphas: Vec<f32> = (0..21).map(|k| k as f32 / 20.0).collect();
    let weights: Vec<f32> = vec![1.0 / 21.0; 21];

    let seq = nuig::exec::BatchExec::Sequential;
    let uploaded =
        nuig::ig::eval_points(&model, &img, &baseline, &alphas, &weights, 0, &seq).unwrap();

    model.register_request(7, &img, &baseline).unwrap();
    let resident =
        nuig::ig::eval_points_resident(&model, &img, &baseline, &alphas, &weights, 0, &seq, 7)
            .unwrap();
    model.evict_request(7);

    assert_eq!(resident.target_probs, uploaded.target_probs);
    for (i, (a, b)) in resident.partial.iter().zip(&uploaded.partial).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "feature {i}: resident path moved a bit");
    }
    // An evicted slot fails loudly rather than silently re-uploading.
    let err = nuig::ig::eval_points_resident(&model, &img, &baseline, &alphas, &weights, 0, &seq, 7)
        .unwrap_err()
        .to_string();
    assert!(err.contains("not registered"), "{err}");
}

#[test]
fn gather_chunk_matches_materialized_multi16() {
    if !have_artifacts() {
        return skip("gather_chunk_matches_materialized_multi16");
    }
    // The gather-indexed cross-request path (resident endpoints staged
    // device-side) must reproduce the hand-materialized igchunk_m16 call
    // it replaced, bit for bit.
    use nuig::exec::gather::{GatherExec, GatherLane};
    let rt = runtime();
    let handle = rt.handle();
    let img_a = synth::gen_image(0, 0);
    let img_b = synth::gen_image(3, 0);
    let f = synth::F;
    let c = synth::NUM_CLASSES;
    let black = vec![0f32; f];

    handle.register_request(1, &img_a, &black).unwrap();
    handle.register_request(2, &img_b, &black).unwrap();
    assert_eq!(handle.resident_len(), 2);

    let lanes: Vec<GatherLane> = (0..6)
        .map(|k| GatherLane {
            slot: 1 + (k % 2) as u64,
            alpha: k as f32 / 5.0,
            weight: 1.0 / 6.0,
            target: k % c,
        })
        .collect();
    let gathered = handle.eval_gather(0, &lanes).unwrap();
    assert_eq!(gathered.lanes(), 6);

    // Hand-materialized reference (the pre-gather feeder's exact args).
    let mut xs = vec![0f32; 16 * f];
    let mut onehots = vec![0f32; 16 * c];
    let mut alphas = vec![0f32; 16];
    let mut weights = vec![0f32; 16];
    for (k, lane) in lanes.iter().enumerate() {
        let src = if lane.slot == 1 { &img_a } else { &img_b };
        xs[k * f..(k + 1) * f].copy_from_slice(src);
        onehots[k * c + lane.target] = 1.0;
        alphas[k] = lane.alpha;
        weights[k] = lane.weight;
    }
    let outs = handle
        .execute(
            nuig::runtime::ExeKind::IgChunkMulti16,
            vec![
                Arg::mat(xs, 16, f),
                Arg::mat(vec![0f32; 16 * f], 16, f),
                Arg::vec(alphas),
                Arg::vec(weights),
                Arg::mat(onehots, 16, c),
            ],
        )
        .unwrap();
    for k in 0..6 {
        let got = gathered.row(k);
        let want = &outs[0][k * f..(k + 1) * f];
        for i in 0..f {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "lane {k} feature {i}: gather staging moved a bit"
            );
        }
    }
    handle.evict_request(1);
    handle.evict_request(2);
    assert_eq!(handle.resident_len(), 0);
}

#[test]
fn ragged_tail_padding_is_exact() {
    if !have_artifacts() {
        return skip("ragged_tail_padding_is_exact");
    }
    // 19 points = one full chunk + ragged 3: must equal a single pass of
    // the same points computed 16+3 via zero-padding.
    let rt = runtime();
    let model = rt.model();
    let img = synth::gen_image(2, 0);
    let baseline = vec![0f32; synth::F];
    let alphas: Vec<f32> = (0..19).map(|k| k as f32 / 18.0).collect();
    let weights: Vec<f32> = vec![1.0 / 19.0; 19];
    let out = model.ig_points(&img, &baseline, &alphas, &weights, 0).unwrap();
    assert_eq!(out.target_probs.len(), 19);

    // Same computation split manually 10 + 9.
    let o1 = model.ig_points(&img, &baseline, &alphas[..10], &weights[..10], 0).unwrap();
    let o2 = model.ig_points(&img, &baseline, &alphas[10..], &weights[10..], 0).unwrap();
    let merged: Vec<f64> = o1.partial.iter().zip(&o2.partial).map(|(a, b)| a + b).collect();
    for (i, (&a, &b)) in out.partial.iter().zip(&merged).enumerate() {
        close(a, b, 1e-6, 1e-9);
        let _ = i;
    }
}
