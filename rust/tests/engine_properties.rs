//! Property-level integration over the analytic model (no artifacts):
//! the paper's claims as statistical facts across many random inputs,
//! plus closed-form quadrature checks the PJRT path can't do.

use nuig::ig::{self, Allocation, AnalyticModel, IgOptions, Rule, Scheme};
use nuig::ig::convergence::ConvergencePolicy;
use nuig::testutil::{self, TestRng};

fn model() -> AnalyticModel {
    // Gain chosen so random [0,1) inputs produce the saturating p(alpha)
    // shape (the paper's Fig. 3b regime, which the calibrated
    // MiniInception exhibits on the synthetic corpus).
    AnalyticModel::new(64, 4, 7, 300.0)
}

fn rand_input(rng: &mut TestRng) -> Vec<f32> {
    rng.vec_f32(64, 0.0, 1.0)
}

#[test]
fn nonuniform_wins_or_ties_across_inputs() {
    // Across many random inputs, non-uniform at iso-steps must beat the
    // uniform baseline on average and almost always pointwise.
    let m = model();
    let mut wins = 0;
    let mut total = 0;
    let mut ratio_sum = 0.0;
    testutil::prop(30, 1234, |rng| {
        let x = rand_input(rng);
        let steps = 24;
        let uni = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: steps, ..Default::default() }).unwrap();
        let non = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: steps, ..Default::default() }).unwrap();
        total += 1;
        if non.delta <= uni.delta {
            wins += 1;
        }
        if non.delta > 0.0 {
            ratio_sum += uni.delta / non.delta;
        }
    });
    // Pointwise: non-uniform wins the large majority (ties at the sharp-
    // saturation tail are noisy); on average the improvement is large.
    assert!(wins * 10 >= total * 7, "nonuniform won only {wins}/{total}");
    assert!(ratio_sum / total as f64 > 1.5, "mean improvement {:.2}x too small", ratio_sum / total as f64);
}

#[test]
fn iso_convergence_step_reduction() {
    // Fig. 5b protocol on the analytic model: steps to hit the uniform
    // baseline's m=64 delta.
    let m = model();
    let mut rng = TestRng::new(99);
    let x = rand_input(&mut rng);
    let uni64 = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: 64, ..Default::default() }).unwrap();
    let policy = ConvergencePolicy::new(uni64.delta);

    let run = |scheme: Scheme| {
        policy
            .search(|steps| {
                if let Scheme::NonUniform { n_int } = scheme {
                    if steps < n_int {
                        return Ok::<f64, anyhow::Error>(f64::INFINITY);
                    }
                }
                Ok(ig::explain(&m, &x, None, &IgOptions { scheme, m: steps, ..Default::default() })
                    .unwrap()
                    .delta)
            })
            .unwrap()
    };
    let (m_uni, _, ok_u) = run(Scheme::Uniform);
    let (m_non, _, ok_n) = run(Scheme::NonUniform { n_int: 4 });
    assert!(ok_u && ok_n);
    assert!(
        m_non * 2 <= m_uni,
        "expected >= 2x step reduction, got uniform {m_uni} vs nonuniform {m_non}"
    );
}

#[test]
fn exactness_for_linear_target_gap() {
    // On a *linear* model (gain so small softmax ≈ affine), the trapezoid
    // rule should integrate almost exactly even at tiny m.
    let m = AnalyticModel::new(32, 3, 5, 0.05);
    let mut rng = TestRng::new(7);
    let x = rng.vec_f32(32, 0.0, 1.0);
    let attr = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: 4, ..Default::default() }).unwrap();
    assert!(
        attr.relative_delta() < 1e-4,
        "near-linear integrand should converge instantly: rel delta {}",
        attr.relative_delta()
    );
}

#[test]
fn eq2_rule_biased_vs_trapezoid() {
    // The paper's literal Eq. 2 weights over-count (sum (m+1)/m): on the
    // same schedule its delta is systematically worse than trapezoid.
    let m = model();
    let mut rng = TestRng::new(11);
    let mut eq2_worse = 0;
    for _ in 0..10 {
        let x = rand_input(&mut rng);
        let trap = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: 32, rule: Rule::Trapezoid, ..Default::default() }).unwrap();
        let eq2 = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: 32, rule: Rule::Eq2, ..Default::default() }).unwrap();
        if eq2.delta > trap.delta {
            eq2_worse += 1;
        }
    }
    assert!(eq2_worse >= 8, "eq2 beat trapezoid too often ({})", 10 - eq2_worse);
}

#[test]
fn allocation_ablation_sqrt_vs_linear_vs_even() {
    // sqrt should (on average) dominate even; linear sits between or
    // worse at the tails — reproduce the paper's motivation numerically.
    let m = model();
    let mut rng = TestRng::new(21);
    let (mut d_sqrt, mut d_lin, mut d_even) = (0.0, 0.0, 0.0);
    let n = 15;
    for _ in 0..n {
        let x = rand_input(&mut rng);
        for (alloc, acc) in [
            (Allocation::Sqrt, &mut d_sqrt),
            (Allocation::Linear, &mut d_lin),
            (Allocation::Even, &mut d_even),
        ] {
            let opts = IgOptions {
                scheme: Scheme::NonUniform { n_int: 4 },
                m: 24,
                allocation: alloc,
                ..Default::default()
            };
            *acc += ig::explain(&m, &x, None, &opts).unwrap().delta;
        }
    }
    assert!(d_sqrt < d_even, "sqrt {d_sqrt} should beat even {d_even}");
}

#[test]
fn attribution_stable_across_scheme_at_high_m() {
    let m = model();
    testutil::prop(10, 33, |rng| {
        let x = rand_input(rng);
        let u = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: 256, ..Default::default() }).unwrap();
        let n = ig::explain(&m, &x, None, &IgOptions { scheme: Scheme::NonUniform { n_int: 8 }, m: 256, ..Default::default() }).unwrap();
        assert!(u.cosine_similarity(&n) > 0.999, "{}", u.cosine_similarity(&n));
    });
}

#[test]
fn probe_passes_scale_with_n_int() {
    let m = model();
    let mut rng = TestRng::new(55);
    let x = rand_input(&mut rng);
    for n_int in [1usize, 2, 4, 8] {
        let attr = ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int }, m: 32, ..Default::default() },
        )
        .unwrap();
        assert_eq!(attr.probe_passes, n_int + 1);
        // Fused schedules: boundary evaluations are shared, so stage-2
        // cost is m + 1 regardless of n_int (the unfused concatenation
        // used to pay m + n_int).
        assert_eq!(attr.steps, 32 + 1);
    }
}

#[test]
fn n_int_cost_model_after_fusion() {
    // Fusion changes the paper's n_int trade-off shape: stage 2 now costs
    // exactly m + 1 gradient evals for EVERY n_int (boundary points are
    // shared), so the only per-explanation cost that grows with n_int is
    // stage 1's n_int + 1 forward passes. Large n_int therefore has to
    // earn its keep purely through better step allocation — the
    // accounting the iso-convergence comparisons (Fig. 5/6) rely on.
    let m = model();
    let mut rng = TestRng::new(77);
    let x = rand_input(&mut rng);
    let steps_m = 32usize;
    let mut prev_total = 0usize;
    for n_int in [2usize, 4, 8, 16] {
        let attr = ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int }, m: steps_m, ..Default::default() },
        )
        .unwrap();
        assert_eq!(attr.steps, steps_m + 1, "stage-2 cost must not depend on n_int");
        assert_eq!(attr.probe_passes, n_int + 1);
        // Total model evaluations strictly increase with n_int at iso-m.
        let total = attr.steps + attr.probe_passes;
        assert!(total > prev_total, "total evals must grow with n_int: {total} !> {prev_total}");
        prev_total = total;
    }
}

#[test]
fn n_int_quality_bounded_at_iso_total_cost() {
    // Quality dimension of the n_int trade-off, restated for fused
    // accounting: at equal TOTAL model evals — (m + 1) gradient points
    // plus the (n_int + 1)-pass probe — every n_int in the paper's
    // working range must stay within 2x of the best. Guards against an
    // allocation regression that starves finely-probed schedules (the
    // failure the paper's "n_int > 8 manifests this issue" points at);
    // measured spread on this model is ~1.4x.
    let m = model();
    let mut rng = TestRng::new(77);
    let total = 40usize;
    let mut delta_by_n = std::collections::BTreeMap::new();
    for _ in 0..10 {
        let x = rand_input(&mut rng);
        for n_int in [2usize, 4, 8, 16] {
            let steps_m = total - (n_int + 1) - 1; // steps + probe_passes == total
            let attr = ig::explain(
                &m,
                &x,
                None,
                &IgOptions { scheme: Scheme::NonUniform { n_int }, m: steps_m, ..Default::default() },
            )
            .unwrap();
            assert_eq!(attr.steps + attr.probe_passes, total);
            *delta_by_n.entry(n_int).or_insert(0.0) += attr.delta;
        }
    }
    let best = delta_by_n.values().fold(f64::INFINITY, |a, &b| a.min(b));
    let worst = delta_by_n.values().fold(0.0f64, |a, &b| a.max(b));
    assert!(
        worst <= 2.0 * best,
        "iso-cost quality spread too wide across n_int: {delta_by_n:?}"
    );
}

#[test]
fn property_parallel_attribution_bit_identical_at_any_worker_count() {
    // The batched backend's determinism contract, end-to-end: for random
    // inputs, schemes, and step counts, the engine's attribution under
    // pool-parallel chunk dispatch is 0 ULP from the sequential path at
    // every worker count in {1, 2, 4, 8}.
    use nuig::exec::{BatchExec, ThreadPool};
    use std::sync::Arc;

    let m = model();
    let pools: Vec<Arc<ThreadPool>> =
        [1usize, 2, 4, 8].iter().map(|&n| Arc::new(ThreadPool::new(n))).collect();
    testutil::prop(12, 5150, |rng| {
        let x = rand_input(rng);
        let steps = rng.range(8, 200);
        let scheme =
            if rng.bool() { Scheme::Uniform } else { Scheme::NonUniform { n_int: rng.range(2, 6) } };
        let opts = IgOptions { scheme, m: steps, ..Default::default() };
        let seq = ig::explain(&m, &x, None, &opts).unwrap();
        for pool in &pools {
            let par =
                ig::explain_exec(&m, &x, None, None, &opts, &BatchExec::parallel(pool.clone()))
                    .unwrap();
            assert_eq!(par.target, seq.target);
            assert_eq!(par.steps, seq.steps);
            for (i, (a, b)) in par.values.iter().zip(&seq.values).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "workers={} feature {i}: {a} vs {b}",
                    pool.worker_count()
                );
            }
            assert_eq!(par.delta.to_bits(), seq.delta.to_bits());
        }
    });
}
