#![allow(dead_code)]

//! Shared fixtures for integration tests: one Runtime per test binary
//! (each Runtime owns a PJRT client + device thread — sharing keeps the
//! process lean and mirrors production wiring).

use std::path::PathBuf;
use std::sync::OnceLock;

use nuig::jsonio::Json;
use nuig::runtime::Runtime;

pub fn artifacts_dir() -> PathBuf {
    // Integration tests run from the crate root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifacts present? If not, tests call `skip()` (the Makefile `test`
/// target builds artifacts first; a bare `cargo test` stays green).
pub fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

pub fn skip(name: &str) {
    eprintln!("SKIP {name}: artifacts not built (run `make artifacts`)");
}

static RT: OnceLock<Runtime> = OnceLock::new();

pub fn runtime() -> &'static Runtime {
    RT.get_or_init(|| Runtime::load_default(artifacts_dir()).expect("loading runtime"))
}

pub fn testvectors() -> Json {
    Json::from_file(&artifacts_dir().join("testvectors.json")).expect("loading testvectors")
}

/// Convenience: assert two f64 values agree within mixed tolerance.
#[track_caller]
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) {
    let tol = atol + rtol * b.abs().max(a.abs());
    assert!((a - b).abs() <= tol, "{a} vs {b} (|diff| {} > tol {tol})", (a - b).abs());
}
