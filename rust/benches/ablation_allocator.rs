//! Allocator ablation (paper §III): the paper chose `m_int ∝ √Δ` because
//! linear allocation starves low-change intervals. Regenerates the
//! evidence: δ at iso-steps for sqrt vs linear vs even allocation.
//!
//!     cargo bench --bench ablation_allocator

use nuig::bench::{fmt3, Table};
use nuig::data::Corpus;
use nuig::ig::{self, Allocation, IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let corpus = Corpus::eval_set(4);

    let mut table = Table::new(
        "allocation ablation: delta (mean over corpus) at n_int=4",
        &["m", "allocation", "delta_mean", "vs_even"],
    );

    for m in [16usize, 32, 64, 128] {
        let mut deltas = std::collections::BTreeMap::new();
        for alloc in [Allocation::Sqrt, Allocation::Linear, Allocation::Even] {
            let mut acc = 0.0;
            for li in corpus.iter() {
                let opts = IgOptions {
                    scheme: Scheme::NonUniform { n_int: 4 },
                    m,
                    allocation: alloc,
                    ..Default::default()
                };
                acc += ig::explain(&model, &li.pixels, None, &opts)?.delta;
            }
            deltas.insert(alloc.to_string(), acc / corpus.len() as f64);
        }
        let even = deltas["even"];
        for (name, d) in &deltas {
            table.row(vec![
                m.to_string(),
                name.clone(),
                fmt3(*d),
                format!("{:.2}x", even / d),
            ]);
        }
        // Shape: probe-informed allocation (sqrt) must beat probe-blind
        // (even) at every m.
        assert!(
            deltas["sqrt"] < even,
            "sqrt should beat even at m={m}: {deltas:?}"
        );
    }
    table.print();
    println!("shape check OK: sqrt < even everywhere (probe information helps)");
    Ok(())
}
