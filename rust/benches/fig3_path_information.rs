//! Fig. 3 regeneration: information distribution along the IG path —
//! (b) classification probability p(target) vs α and the paper's ">90% of
//! final value early" statistic; (c) per-interval share of |dp/dα|
//! (gradient-magnitude proxy / contribution to convergence).
//!
//!     cargo bench --bench fig3_path_information

use nuig::bench::{fmt3, Table};
use nuig::data::Corpus;
use nuig::ig::{analysis, engine::argmax, Model};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let corpus = Corpus::eval_set(4);

    let mut curve = Table::new(
        "Fig 3b: p(target) along the IG path (per image)",
        &["class", "alpha", "p_target"],
    );
    let mut shares = Table::new(
        "Fig 3c: per-interval share of |dp/dalpha| (n_int=8)",
        &["class", "interval", "share"],
    );
    let mut stats = Table::new(
        "Fig 3 summary: change concentration",
        &["class", "target", "alpha_at_50pct", "alpha_at_90pct", "first_quarter_share"],
    );

    for li in corpus.iter() {
        let probs = model.probs(&[&li.pixels])?;
        let target = argmax(&probs[0]);
        let baseline = vec![0f32; li.pixels.len()];
        let info = analysis::path_info(&model, &li.pixels, &baseline, target, 32, 8)?;

        for (a, p) in info.alphas.iter().zip(&info.probs).step_by(4) {
            curve.row(vec![li.class.to_string(), fmt3(*a), fmt3(*p)]);
        }
        for (i, s) in info.interval_share.iter().enumerate() {
            shares.row(vec![li.class.to_string(), i.to_string(), fmt3(*s)]);
        }
        let quarter: f64 = info.interval_share[..2].iter().sum();
        stats.row(vec![
            li.class.to_string(),
            target.to_string(),
            fmt3(info.alpha_at_change_fraction(0.5)),
            fmt3(info.alpha_at_change_fraction(0.9)),
            fmt3(quarter),
        ]);
    }
    curve.print();
    shares.print();
    stats.print();

    println!(
        "paper's claim: most probability change (and gradient mass) concentrates in a small\n\
         alpha-interval; with the black baseline + calibrated softmax it lands early in the path."
    );
    Ok(())
}
