//! Hot-path kernel throughput: the batched execution backend vs the
//! scalar reference, on the closed-form [`AnalyticModel`] at the corpus
//! dimensions (F = 3072, 8 classes) — no artifacts needed.
//!
//! Three modes per operating point m ∈ {16, 64, 256, 1024}:
//!
//!   scalar    — `AnalyticModel::ig_points_scalar`: one point at a time,
//!               fresh buffers per point (the pre-batch engine path);
//!   batched   — `eval_points` with `BatchExec::Sequential`: planar
//!               `PointBatch` fill + per-worker scratch arena, one core;
//!   parallel  — `eval_points` with `BatchExec::parallel`: the same
//!               chunks sharded across the `exec::ThreadPool`.
//!
//!     cargo bench --bench fig_hotpath
//!
//! Emits `BENCH_hotpath.json` (path override: `NUIG_HOTPATH_JSON`) with
//! the schema CI gates on — see `docs/BENCHES.md` §fig_hotpath. Smoke
//! mode (`NUIG_HOTPATH_SMOKE=1`) shrinks the grid to m ∈ {8, 16} and
//! skips the wall-clock speedup assertion (shared CI runners), keeping
//! the bit-identity assertion, which is never timing-dependent.
//!
//! Shape assertions (full mode): batched-parallel reaches ≥ 2× the
//! scalar baseline's points/sec at m = 256 when ≥ 4 workers are
//! available, and every mode's attribution matches the scalar reference
//! (parallel vs sequential-batched: bit-identical at 0 ULP).

use std::sync::Arc;

use nuig::bench::{fmt3, measure, BenchConfig, Table};
use nuig::exec::{batch::DEFAULT_CHUNK, BatchExec, ThreadPool};
use nuig::ig::engine::argmax;
use nuig::ig::model::eval_points;
use nuig::ig::{AnalyticModel, Model, Rule};
use nuig::ig::schedule::Schedule;
use nuig::jsonio::Json;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let smoke = std::env::var("NUIG_HOTPATH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let ms: &[usize] = if smoke { &[8, 16] } else { &[16, 64, 256, 1024] };

    let model = AnalyticModel::standard();
    let x = nuig::data::synth::gen_image(0, 0);
    let baseline = vec![0f32; model.features()];
    let target = argmax(&model.probs(&[&x])?[0]);

    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let pool = Arc::new(ThreadPool::new(workers));
    let seq = BatchExec::Sequential;
    let par = BatchExec::parallel(pool);

    let mut table = Table::new(
        &format!("fig_hotpath: stage-2 kernel throughput ({workers} workers, chunk {DEFAULT_CHUNK})"),
        &["m", "mode", "points", "ns_per_point", "points_per_s", "speedup_vs_scalar"],
    );

    let mut speedup_at_256 = None;
    for &m in ms {
        let schedule = Schedule::uniform(m, Rule::Trapezoid)?;
        let (alphas, weights) = schedule.to_f32();
        let points = schedule.len();

        // Correctness gates before the clocks: the batched kernel matches
        // the scalar reference (chunk reassociation only), and parallel
        // matches sequential-batched to the bit.
        let ref_scalar = model.ig_points_scalar(&x, &baseline, &alphas, &weights, target)?;
        let ref_seq = eval_points(&model, &x, &baseline, &alphas, &weights, target, &seq)?;
        let ref_par = eval_points(&model, &x, &baseline, &alphas, &weights, target, &par)?;
        nuig::testutil::assert_allclose(&ref_seq.partial, &ref_scalar.partial, 1e-10, 1e-13);
        for (a, b) in ref_par.partial.iter().zip(&ref_seq.partial) {
            assert_eq!(a.to_bits(), b.to_bits(), "parallel must be bit-identical to sequential");
        }

        let runs = [
            ("scalar", None),
            ("batched", Some(&seq)),
            ("parallel", Some(&par)),
        ];
        let mut scalar_pps = 0.0;
        for (mode, exec) in runs {
            let meas = match exec {
                None => measure(&cfg, mode, || {
                    model.ig_points_scalar(&x, &baseline, &alphas, &weights, target).unwrap();
                }),
                Some(exec) => measure(&cfg, mode, || {
                    eval_points(&model, &x, &baseline, &alphas, &weights, target, exec).unwrap();
                }),
            };
            let secs = meas.mean_s();
            let pps = points as f64 / secs;
            let ns_per_point = secs * 1e9 / points as f64;
            if mode == "scalar" {
                scalar_pps = pps;
            }
            let speedup = pps / scalar_pps;
            if mode == "parallel" && m == 256 {
                speedup_at_256 = Some(speedup);
            }
            table.row(vec![
                m.to_string(),
                mode.to_string(),
                points.to_string(),
                fmt3(ns_per_point),
                fmt3(pps),
                fmt3(speedup),
            ]);
        }
    }
    table.print();

    // ---- Machine-readable trajectory point: BENCH_hotpath.json. ---------
    let path = std::env::var("NUIG_HOTPATH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    let json = Json::obj(vec![
        ("bench", Json::Str("fig_hotpath".into())),
        ("schema_version", Json::Num(1.0)),
        ("workers", Json::Num(workers as f64)),
        ("chunk", Json::Num(DEFAULT_CHUNK as f64)),
        ("smoke", Json::Bool(smoke)),
        ("rows", table.to_json().get("rows").expect("table has rows").clone()),
    ]);
    std::fs::write(&path, json.to_string_pretty())?;
    println!("wrote {path}");

    // ---- Shape assertion: the acceptance claim (full mode only; smoke
    // runs on shared CI runners where wall-clock claims flake). ----------
    if !smoke {
        let speedup = speedup_at_256.expect("m=256 parallel row present");
        if workers >= 4 {
            assert!(
                speedup >= 2.0,
                "batched-parallel must reach >= 2x scalar points/sec at m=256 on {workers} workers, got {speedup:.2}x"
            );
        } else {
            eprintln!("NOTE: only {workers} workers available; 2x speedup assertion skipped");
        }
    }
    Ok(())
}
