//! Hot-path kernel throughput: the lane-kernel batched backend vs the
//! scalar reference, on the closed-form [`AnalyticModel`] at the corpus
//! dimensions (F = 3072, 8 classes) — no artifacts needed.
//!
//! Three modes per operating point m ∈ {16, 64, 256, 1024}:
//!
//!   scalar    — `AnalyticModel::ig_points_scalar`: one point at a time,
//!               fresh buffers per point (the pre-batch engine path);
//!   batched   — `eval_points` with `BatchExec::Sequential`: planar
//!               `PointBatch` fill + scratch arena + `exec::simd` lane
//!               kernels, one core;
//!   parallel  — `eval_points` with `BatchExec::parallel`: the same
//!               chunks sharded across the `exec::ThreadPool`.
//!
//! plus a per-kernel ns/point breakdown of the `exec::simd` lane
//! primitives (interpolate / dot / accum_scaled / accum_grad /
//! commit_row) at the same dimensions, labelled with the dispatched dot
//! backend (`simd::backend()`).
//!
//!     cargo bench --bench fig_hotpath
//!     cargo bench --bench fig_hotpath --features simd-intrinsics
//!
//! Emits `BENCH_hotpath.json` (path override: `NUIG_HOTPATH_JSON`) with
//! the schema-v2 layout `tools/bench_gate.py` gates on — see
//! `docs/BENCHES.md` §fig_hotpath. Smoke mode (`NUIG_HOTPATH_SMOKE=1`)
//! shrinks the grid to m ∈ {8, 16} and skips the wall-clock speedup
//! assertions (shared CI runners), keeping the bit-identity assertions,
//! which are never timing-dependent.
//!
//! Shape assertions (full mode): batched reaches ≥ 2× the scalar
//! baseline's single-thread points/sec at m ∈ {64, 256, 1024}, parallel
//! reaches ≥ 2× scalar at m = 256 when ≥ 4 workers are available, and
//! every mode's attribution matches the scalar reference (parallel vs
//! sequential-batched: bit-identical at 0 ULP).

use std::hint::black_box;
use std::sync::Arc;

use nuig::bench::{fmt3, measure, BenchConfig, Table};
use nuig::exec::simd;
use nuig::exec::{batch::DEFAULT_CHUNK, BatchExec, ThreadPool};
use nuig::ig::engine::argmax;
use nuig::ig::model::eval_points;
use nuig::ig::schedule::Schedule;
use nuig::ig::{AnalyticModel, Model, Rule};
use nuig::jsonio::Json;

/// Clock the `exec::simd` primitives one point-equivalent at a time:
/// what one interpolated point costs in each kernel at (F, C). Rows are
/// `(kernel, calls_per_point, ns_per_point)`.
fn kernel_breakdown(cfg: &BenchConfig, model: &AnalyticModel, x: &[f32], baseline: &[f32]) -> Table {
    let f = model.features();
    let c = model.num_classes();
    // Amortize timer resolution: each measured iteration performs REPS
    // point-equivalents of the kernel.
    const REPS: usize = 64;

    let mut row = vec![0f32; f];
    simd::interpolate(&mut row, x, baseline, 0.37);
    let probs: Vec<f64> = (0..c).map(|cc| (cc + 1) as f64 / (c * (c + 1) / 2) as f64).collect();
    let mut wavg = vec![0f64; f];
    for cc in 0..c {
        simd::accum_scaled(&mut wavg, probs[cc], model.class_row(cc));
    }
    let mut partial = vec![0f64; f];
    let mut values = vec![0f64; f];
    let row32: Vec<f32> = wavg.iter().map(|&v| v as f32).collect();

    let mut table = Table::new(
        &format!("fig_hotpath kernels: ns/point at F={f}, C={c} (dot backend: {})", simd::backend()),
        &["kernel", "calls_per_point", "ns_per_point"],
    );
    let mut push = |name: &str, calls_per_point: usize, meas_secs: f64| {
        let ns_per_point = meas_secs * 1e9 / REPS as f64;
        table.row(vec![name.to_string(), calls_per_point.to_string(), fmt3(ns_per_point)]);
    };

    let m = measure(cfg, "interpolate", || {
        for _ in 0..REPS {
            simd::interpolate(black_box(&mut row), black_box(x), black_box(baseline), 0.37);
        }
    });
    push("interpolate", 1, m.mean_s());

    let m = measure(cfg, "dot_f32", || {
        for _ in 0..REPS {
            for cc in 0..c {
                black_box(simd::dot_f32(black_box(model.class_row(cc)), black_box(&row)));
            }
        }
    });
    push("dot_f32", c, m.mean_s());

    let m = measure(cfg, "accum_scaled", || {
        for _ in 0..REPS {
            for cc in 0..c {
                simd::accum_scaled(black_box(&mut wavg), probs[cc], black_box(model.class_row(cc)));
            }
        }
    });
    push("accum_scaled", c, m.mean_s());

    let m = measure(cfg, "accum_grad", || {
        for _ in 0..REPS {
            simd::accum_grad(
                black_box(&mut partial),
                0.21,
                0.62,
                0.0044,
                black_box(model.class_row(0)),
                black_box(&wavg),
                black_box(x),
                black_box(baseline),
            );
        }
    });
    push("accum_grad", 1, m.mean_s());

    let m = measure(cfg, "commit_row", || {
        for _ in 0..REPS {
            simd::commit_row(black_box(&mut values), black_box(&row32));
        }
    });
    push("commit_row", 1, m.mean_s());

    table
}

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let smoke = std::env::var("NUIG_HOTPATH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let ms: &[usize] = if smoke { &[8, 16] } else { &[16, 64, 256, 1024] };

    let model = AnalyticModel::standard();
    let x = nuig::data::synth::gen_image(0, 0);
    let baseline = vec![0f32; model.features()];
    let target = argmax(&model.probs(&[&x])?[0]);

    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let pool = Arc::new(ThreadPool::new(workers));
    let seq = BatchExec::Sequential;
    let par = BatchExec::parallel(pool);

    let mut table = Table::new(
        &format!(
            "fig_hotpath: stage-2 kernel throughput ({workers} workers, chunk {DEFAULT_CHUNK}, \
             lanes {}, dot backend {})",
            simd::LANES,
            simd::backend()
        ),
        &["m", "mode", "points", "ns_per_point", "points_per_s", "speedup_vs_scalar"],
    );

    let mut batched_speedups = Vec::new();
    let mut parallel_speedup_at_256 = None;
    for &m in ms {
        let schedule = Schedule::uniform(m, Rule::Trapezoid)?;
        let (alphas, weights) = schedule.to_f32();
        let points = schedule.len();

        // Correctness gates before the clocks: the batched kernel matches
        // the scalar reference (bit-identical within one chunk, chunk
        // reassociation beyond), and parallel matches sequential-batched
        // to the bit.
        let ref_scalar = model.ig_points_scalar(&x, &baseline, &alphas, &weights, target)?;
        let ref_seq = eval_points(&model, &x, &baseline, &alphas, &weights, target, &seq)?;
        let ref_par = eval_points(&model, &x, &baseline, &alphas, &weights, target, &par)?;
        nuig::testutil::assert_allclose(&ref_seq.partial, &ref_scalar.partial, 1e-10, 1e-13);
        for (a, b) in ref_par.partial.iter().zip(&ref_seq.partial) {
            assert_eq!(a.to_bits(), b.to_bits(), "parallel must be bit-identical to sequential");
        }

        let runs = [
            ("scalar", None),
            ("batched", Some(&seq)),
            ("parallel", Some(&par)),
        ];
        let mut scalar_pps = 0.0;
        for (mode, exec) in runs {
            let meas = match exec {
                None => measure(&cfg, mode, || {
                    model.ig_points_scalar(&x, &baseline, &alphas, &weights, target).unwrap();
                }),
                Some(exec) => measure(&cfg, mode, || {
                    eval_points(&model, &x, &baseline, &alphas, &weights, target, exec).unwrap();
                }),
            };
            let secs = meas.mean_s();
            let pps = points as f64 / secs;
            let ns_per_point = secs * 1e9 / points as f64;
            if mode == "scalar" {
                scalar_pps = pps;
            }
            let speedup = pps / scalar_pps;
            if mode == "batched" && [64, 256, 1024].contains(&m) {
                batched_speedups.push((m, speedup));
            }
            if mode == "parallel" && m == 256 {
                parallel_speedup_at_256 = Some(speedup);
            }
            table.row(vec![
                m.to_string(),
                mode.to_string(),
                points.to_string(),
                fmt3(ns_per_point),
                fmt3(pps),
                fmt3(speedup),
            ]);
        }
    }
    table.print();

    let kernels = kernel_breakdown(&cfg, &model, &x, &baseline);
    kernels.print();

    // ---- Machine-readable trajectory point: BENCH_hotpath.json. ---------
    let path = std::env::var("NUIG_HOTPATH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    let provenance = format!(
        "fresh fig_hotpath run (smoke: {smoke}, dot backend: {}); commit only full-grid \
         refreshes per docs/EXPERIMENTS.md §Baselines",
        simd::backend()
    );
    let json = Json::obj(vec![
        ("bench", Json::Str("fig_hotpath".into())),
        ("schema_version", Json::Num(2.0)),
        ("provenance", Json::Str(provenance)),
        ("workers", Json::Num(workers as f64)),
        ("chunk", Json::Num(DEFAULT_CHUNK as f64)),
        ("lanes", Json::Num(simd::LANES as f64)),
        ("lane_backend", Json::Str(simd::backend().into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", table.to_json().get("rows").expect("table has rows").clone()),
        ("kernel_rows", kernels.to_json().get("rows").expect("table has rows").clone()),
    ]);
    std::fs::write(&path, json.to_string_pretty())?;
    println!("wrote {path}");

    // ---- Shape assertions: the acceptance claims (full mode only; smoke
    // runs on shared CI runners where wall-clock claims flake). ----------
    if !smoke {
        for (m, speedup) in batched_speedups {
            assert!(
                speedup >= 2.0,
                "batched lane kernel must reach >= 2x scalar points/sec single-thread at m={m}, \
                 got {speedup:.2}x"
            );
        }
        let speedup = parallel_speedup_at_256.expect("m=256 parallel row present");
        if workers >= 4 {
            assert!(
                speedup >= 2.0,
                "batched-parallel must reach >= 2x scalar points/sec at m=256 on {workers} workers, got {speedup:.2}x"
            );
        } else {
            eprintln!("NOTE: only {workers} workers available; 2x speedup assertion skipped");
        }
    }
    Ok(())
}
