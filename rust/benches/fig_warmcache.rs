//! Warm-cache serving cost: per-request stage-1 cost collapsing with
//! cache warmth at the Table-I operating points (m ∈ {16, 32, 64, 128},
//! n_int = 4).
//!
//! The paper prices stage 1 at 0.2–3.2 % of an explanation and pays it
//! per request. The probe-schedule cache (`ig::schedule::cache`)
//! amortizes it across requests: a stream of requests explaining the
//! same class against the same baseline shares one probe memo and one
//! canonical fused schedule. This bench drives the engine-level mirror
//! of the coordinator's tight-tier admission path
//! (`ig::explain_anytime_cached`) with one **cold** request followed by
//! warm traffic, on the closed-form [`AnalyticModel`] (no artifacts
//! needed).
//!
//!     cargo bench --bench fig_warmcache
//!
//! JSON output fields per row: `m`, `mode` (cold/warm), `probe_passes`
//! (stage-1 forward passes per request — the acceptance claim is warm
//! == 0), `evals` (gradient evals; identical cold vs warm: the cache
//! changes *which* stage-1 work runs, never the stage-2 bill),
//! `stage1_us` (probe + schedule wall time per request), `delta_mean`
//! (completeness residual; warm δ is measured against the class-level
//! memoized gap), and `hit_rate` (schedule-cache hits / lookups).

use nuig::bench::{fmt3, Table};
use nuig::ig::engine::argmax;
use nuig::ig::{self, AnalyticModel, AnytimePolicy, IgOptions, Model, ScheduleCache, Scheme};
use nuig::testutil::TestRng;

const N_INT: usize = 4;
/// Requests per operating point: 1 cold + (REQUESTS - 1) warm.
const REQUESTS: usize = 8;

fn main() -> anyhow::Result<()> {
    let model = AnalyticModel::new(64, 4, 7, 300.0);
    let mut rng = TestRng::new(0xCAC4E);

    // A stream of distinct inputs of the SAME class (pinned target) — the
    // serving pattern the probe memo amortizes. Perturbations keep the
    // inputs near the base image so the pinned class stays the honest
    // explanation target.
    let base: Vec<f32> = (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect();
    let target = argmax(&model.probs(&[&base])?[0]);
    let inputs: Vec<Vec<f32>> = (0..REQUESTS)
        .map(|_| {
            base.iter()
                .map(|&v| (v * rng.range_f64(0.85, 1.0) as f32).clamp(0.0, 1.0))
                .collect()
        })
        .collect();

    let mut table = Table::new(
        "fig_warmcache: per-request stage-1 cost, cold vs warm (pinned class, n_int = 4)",
        &["m", "mode", "probe_passes", "evals", "stage1_us", "delta_mean", "hit_rate"],
    );

    for &m in &[16usize, 32, 64, 128] {
        // Fresh cache per operating point so hit rates are exact.
        let cache = ScheduleCache::new(64, 4);
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: N_INT }, m, ..Default::default() };
        // Single-round gate: the tight-tier shape (a hard round cap, not
        // a convergence search).
        let policy = AnytimePolicy::with_max_m(0.0, m)?;

        // ---- Cold: probes, populates memo + schedule cache. -------------
        let cold =
            ig::explain_anytime_cached(&model, &inputs[0], None, Some(target), &opts, &policy, &cache)?;
        assert_eq!(cold.probe_passes, N_INT + 1, "cold request pays the full probe");
        let cold_stage1_us =
            (cold.breakdown.probe + cold.breakdown.schedule).as_secs_f64() * 1e6;
        table.row(vec![
            m.to_string(),
            "cold".to_string(),
            cold.probe_passes.to_string(),
            cold.steps.to_string(),
            fmt3(cold_stage1_us),
            fmt3(cold.delta),
            fmt3(cache.counters().hit_rate()),
        ]);

        // ---- Warm: every further request skips stage 1 entirely. --------
        let mut warm_stage1_us = 0.0;
        let mut warm_delta = 0.0;
        for x in &inputs[1..] {
            let warm =
                ig::explain_anytime_cached(&model, x, None, Some(target), &opts, &policy, &cache)?;
            assert_eq!(warm.probe_passes, 0, "warm request must pay ZERO probe passes");
            assert_eq!(warm.steps, cold.steps, "the cache never changes the stage-2 bill");
            warm_stage1_us += (warm.breakdown.probe + warm.breakdown.schedule).as_secs_f64() * 1e6;
            warm_delta += warm.delta;
        }
        let n_warm = (REQUESTS - 1) as f64;
        table.row(vec![
            m.to_string(),
            "warm".to_string(),
            "0".to_string(),
            cold.steps.to_string(),
            fmt3(warm_stage1_us / n_warm),
            fmt3(warm_delta / n_warm),
            fmt3(cache.counters().hit_rate()),
        ]);

        // Counter accounting: exactly one miss (the cold populate), one
        // insertion, and a hit per warm request.
        assert_eq!(cache.counters().misses.get(), 1, "one cold miss per operating point");
        assert_eq!(cache.counters().insertions.get(), 1);
        assert_eq!(cache.counters().hits.get() as usize, REQUESTS - 1);
        assert_eq!(cache.counters().evictions.get(), 0);
        assert_eq!(cache.memo_len(), 1, "one class-level probe memo");
    }
    table.print();

    println!(
        "shape check OK: warm requests pay zero stage-1 passes at every operating point \
         (hit rate {}/{} per point), with the stage-2 eval bill unchanged",
        REQUESTS - 1,
        REQUESTS
    );
    Ok(())
}
