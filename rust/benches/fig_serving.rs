//! Serving-path cost under the gather-indexed sharded feeder: chunk
//! occupancy, host bytes moved per chunk, and the feeder-count
//! bit-identity guarantee — on the closed-form [`AnalyticModel`] backend
//! (`AnalyticExec`), no artifacts needed.
//!
//! Before the gather refactor the feeder materialized every device chunk
//! by copying each lane's full image and baseline into fresh
//! `chunk × features` host buffers. The gather-indexed plan moves one
//! 24-byte lane record per lane instead; endpoints are resident tensors
//! registered once at admission. This bench drives the REAL coordinator
//! (routers, lane scheduler, feeder pool) over a mixed request stream at
//! feeder counts {1, 2, 4} and reports both cost models side by side.
//!
//!     cargo bench --bench fig_serving
//!
//! Emits `BENCH_serving.json` (path override: `NUIG_SERVING_JSON`) with
//! the schema CI gates on — see `docs/BENCHES.md` §fig_serving. Smoke
//! mode (`NUIG_SERVING_SMOKE=1`) shrinks the stream and the feeder grid;
//! every assertion below is timing-independent, so smoke keeps them all.
//!
//! Shape assertions:
//! * attributions are **bit-identical (0 ULP)** at every feeder count —
//!   the ordered-lane-commit contract (`coordinator::state::Accum`);
//! * the resident pool drains to zero after shutdown (admit → upload →
//!   gather → evict lifecycle leaks nothing);
//! * gather host-bytes-per-chunk sit ≥ 100× below the legacy copies at
//!   the corpus feature width (3072);
//! * elastic-resilience rows: `respawn_latency_us` times a killed
//!   shard's resident-tensor re-registration replay (chaos harness,
//!   `exec::fault`), and `shed_rate` drives a tight/soft burst through
//!   a shed-configured coordinator over a saturated gauge — every
//!   tight request sheds, every soft one serves (rate exactly 0.5);
//! * mixed-tier rows (`tier_rows`): an all-tier stream served twice at
//!   the top feeder count — work stealing on (deep prefetch) vs off
//!   (chunks pinned to the feeder that pulled them) — reporting per-tier
//!   p99 and the dispatch `steal_rate`, with the two runs asserted
//!   **bit-identical** (stealing is a dispatch-order change only,
//!   docs/INVARIANTS.md §I10);
//! * front-end rows (`frontend_rows`): two bursts over a real
//!   `Frontend` loopback connection — an unconvergeable anytime stream
//!   under a wire deadline (every request settles as a partial carrying
//!   its best converged round: `deadline_hit_rate` and `partial_rate`
//!   exactly 1.0) and an undeadlined control (both exactly 0.0) —
//!   the graceful-degradation contract, docs/INVARIANTS.md §I12.

use std::io::Write;
use std::sync::Arc;

use nuig::bench::{fmt3, Table};
use nuig::config::{CoordinatorConfig, FrontendConfig};
use nuig::coordinator::frontend::framing::{self, Frame, FrameReader, RequestFrame};
use nuig::coordinator::frontend::listener;
use nuig::coordinator::{
    Coordinator, ExplainRequest, Frontend, LatencyBudget, ShedRejection, StealConfig,
};
use nuig::data::synth;
use nuig::exec::gather::{GatherExec, GatherLane};
use nuig::exec::{FaultAction, FaultEvent, FaultInjector, FaultPlan};
use nuig::ig::{AnalyticExec, AnalyticModel, IgOptions, Scheme};
use nuig::jsonio::Json;

/// One deterministic mixed workload: non-uniform + uniform schemes, m
/// spread over the working range, one standard-tier (anytime) request
/// slice so refinement rounds cross the sharded feeders too.
fn requests(n: usize) -> Vec<ExplainRequest> {
    (0..n)
        .map(|i| {
            let img = synth::gen_image(i % synth::NUM_CLASSES, i / synth::NUM_CLASSES);
            let scheme =
                if i % 4 == 3 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
            let m = [16, 32, 48, 64][i % 4];
            let req = ExplainRequest::new(img, IgOptions { scheme, m, ..Default::default() });
            if i % 5 == 0 && scheme != Scheme::Uniform {
                req.with_budget(LatencyBudget::Standard)
            } else {
                req
            }
        })
        .collect()
}

/// Every admission tier in one deterministic stream — unbounded, tight
/// (pinned target), standard, thorough, round-robin — over both schemes,
/// for the stealing-on/off comparison rows.
fn tiered_requests(n: usize) -> Vec<ExplainRequest> {
    (0..n)
        .map(|i| {
            let img = synth::gen_image(i % synth::NUM_CLASSES, i / synth::NUM_CLASSES);
            let scheme =
                if i % 8 == 7 { Scheme::Uniform } else { Scheme::NonUniform { n_int: 4 } };
            let m = [16, 32, 48, 64][i % 4];
            let req = ExplainRequest::new(img, IgOptions { scheme, m, ..Default::default() });
            match LatencyBudget::ALL[i % 4] {
                LatencyBudget::Tight => {
                    req.with_budget(LatencyBudget::Tight).with_target(i % synth::NUM_CLASSES)
                }
                tier => req.with_budget(tier),
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("NUIG_SERVING_SMOKE").map(|v| v == "1").unwrap_or(false);
    let feeder_grid: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let n_requests = if smoke { 12 } else { 48 };

    let chunk = CoordinatorConfig::default().chunk;
    let features = synth::F;
    let classes = synth::NUM_CLASSES;
    // Cost models, per dispatched chunk (see docs/BENCHES.md §fig_serving):
    // legacy = fresh xs/bs endpoint matrices + scalars + one-hots;
    // gather = one GatherLane record per lane.
    let legacy_bytes_per_chunk =
        (2 * chunk * features + 2 * chunk + chunk * classes) * std::mem::size_of::<f32>();
    let lane_record_bytes = std::mem::size_of::<GatherLane>();

    let title =
        format!("fig_serving: sharded gather feeder, {n_requests} mixed requests (chunk {chunk})");
    let mut table = Table::new(
        &title,
        &[
            "feeders",
            "devices",
            "occupancy",
            "chunks",
            "host_bytes_per_chunk",
            "legacy_host_bytes_per_chunk",
            "throughput_rps",
            "bit_identical",
            "respawn_latency_us",
            "shed_rate",
        ],
    );

    let mut reference: Option<Vec<Vec<u64>>> = None;
    for &feeders in feeder_grid {
        // Fresh model per run (same seed ⇒ same weights) so runs are
        // comparable; shards only spread the feeder pool.
        let backend = Arc::new(AnalyticExec::with_shards(AnalyticModel::standard(), feeders));
        let cfg = CoordinatorConfig {
            feeders,
            devices: feeders,
            workers: 2,
            ..Default::default()
        };
        let coord = Coordinator::start_with_backend(backend.clone(), cfg)?;

        let t0 = std::time::Instant::now();
        let handles: Vec<_> = requests(n_requests)
            .into_iter()
            .map(|r| coord.submit(r))
            .collect::<Result<_, _>>()?;
        let mut values: Vec<Vec<u64>> = Vec::with_capacity(handles.len());
        for h in handles {
            let resp = h.wait()?;
            values.push(resp.attribution.values.iter().map(|v| v.to_bits()).collect());
        }
        let wall = t0.elapsed();

        let stats = coord.stats();
        assert_eq!(stats.failed.get(), 0, "no request may fail");
        let occupancy = stats.mean_occupancy(chunk);
        let chunks: u64 = stats.feeders.iter().map(|f| f.chunks.get()).sum();
        let lanes: u64 = stats.feeders.iter().map(|f| f.lanes.get()).sum();
        let gather_bytes_per_chunk = if chunks == 0 {
            0.0
        } else {
            lanes as f64 / chunks as f64 * lane_record_bytes as f64
        };
        // NOTE: per-feeder chunk counts are reported, not asserted — a
        // fast backend can legally let one feeder drain the queue before
        // its siblings wake; the bit-identity assertion below is the
        // contract that matters.

        if let Some(prev) = reference.as_ref() {
            assert_eq!(prev.len(), values.len());
            for (i, (a, b)) in prev.iter().zip(&values).enumerate() {
                assert_eq!(a, b, "request {i}: attribution bits diverged at {feeders} feeders");
            }
        } else {
            reference = Some(values);
        }

        // The headline cost claim, asserted (timing-free).
        assert!(
            gather_bytes_per_chunk * 100.0 <= legacy_bytes_per_chunk as f64,
            "gather bytes/chunk {gather_bytes_per_chunk} not 100x below \
             legacy {legacy_bytes_per_chunk}"
        );

        coord.shutdown();
        assert_eq!(
            backend.resident_len(),
            0,
            "resident pool must drain to zero after shutdown"
        );

        // ---- Respawn latency: plan a kill on shard 0 under the chaos
        // harness, fire it, then time the re-registration replay a
        // respawn performs (ISSUE: the elastic-resilience cost row).
        let zeros = vec![0f32; features];
        let respawn_replay = 8usize;
        let respawn_latency_us = {
            let plan = FaultPlan::new(vec![FaultEvent {
                shard: 0,
                at: 0,
                action: FaultAction::Kill,
            }]);
            let injector = FaultInjector::new(
                Arc::new(AnalyticExec::with_shards(AnalyticModel::standard(), feeders)),
                &plan,
            )?;
            for slot in 0..respawn_replay as u64 {
                let img = synth::gen_image(slot as usize % synth::NUM_CLASSES, slot as usize);
                injector.register_request(slot, &img, &zeros)?;
            }
            let lane = [GatherLane { slot: 0, alpha: 0.5, weight: 1.0, target: 0 }];
            assert!(
                injector.eval_gather(0, &lane).is_err(),
                "the planned kill fires on the shard's first gather call"
            );
            let t0 = std::time::Instant::now();
            injector.respawn_shard(0)?;
            let us = t0.elapsed().as_secs_f64() * 1e6;
            assert_eq!(
                injector.resident_on(0).len(),
                respawn_replay,
                "respawn replays every resident slot"
            );
            injector.eval_gather(0, &lane)?;
            us
        };

        // ---- Shed rate: saturate the overload gauge out-of-band, then
        // drive a half-tight burst — every tight request sheds with a
        // typed rejection, every soft one rides through (rate = 0.5,
        // deterministic at every feeder count).
        let shed_rate = {
            let backend = Arc::new(AnalyticExec::with_shards(AnalyticModel::standard(), feeders));
            backend.register_request(u64::MAX, &synth::gen_image(0, 0), &zeros)?;
            let mut cfg = CoordinatorConfig {
                feeders,
                devices: feeders,
                workers: 2,
                ..Default::default()
            };
            cfg.shed.resident_high_water = 1;
            let coord = Coordinator::start_with_backend(backend.clone(), cfg)?;
            let burst = if smoke { 4u64 } else { 8 };
            let mut shed = 0u64;
            for i in 0..burst as usize {
                let img = synth::gen_image(i % synth::NUM_CLASSES, i);
                let scheme = Scheme::NonUniform { n_int: 4 };
                let req =
                    ExplainRequest::new(img, IgOptions { scheme, m: 16, ..Default::default() });
                let req = if i % 2 == 0 { req.with_budget(LatencyBudget::Tight) } else { req };
                match coord.explain(req) {
                    Ok(resp) => assert!(resp.attribution.delta.is_finite()),
                    Err(e) => {
                        assert!(
                            e.downcast_ref::<ShedRejection>().is_some(),
                            "only typed sheds may fail under the saturated gauge: {e}"
                        );
                        shed += 1;
                    }
                }
            }
            assert_eq!(coord.stats().shed_rejections.get(), shed);
            assert_eq!(shed, burst / 2, "exactly the tight half of the burst sheds");
            coord.shutdown();
            backend.evict_request(u64::MAX);
            assert_eq!(backend.resident_len(), 0);
            shed as f64 / burst as f64
        };

        table.row(vec![
            feeders.to_string(),
            feeders.to_string(),
            fmt3(occupancy),
            chunks.to_string(),
            fmt3(gather_bytes_per_chunk),
            legacy_bytes_per_chunk.to_string(),
            fmt3(n_requests as f64 / wall.as_secs_f64()),
            // Asserted above: reaching this row means the bits matched.
            "1".to_string(),
            fmt3(respawn_latency_us),
            fmt3(shed_rate),
        ]);
    }
    table.print();

    // ---- Mixed-tier p99: work stealing on vs off. -----------------------
    // One all-tier stream, served twice at the top feeder count: once
    // with stealing enabled and a deep prefetch (the steal-heavy shape)
    // and once with staging disabled (every chunk pinned to the feeder
    // whose bucket pull assembled it). Stealing only changes which
    // feeder executes a chunk — the ordered commit makes the two runs
    // bit-identical, asserted below.
    let tier_feeders = *feeder_grid.last().expect("feeder grid is non-empty");
    let tier_requests = if smoke { 16 } else { 48 };
    let mut tier_table = Table::new(
        &format!(
            "fig_serving: mixed-tier p99, stealing on vs off \
             ({tier_requests} requests, {tier_feeders} feeders)"
        ),
        &["stealing", "tier", "completed", "p99_ms", "steal_rate"],
    );
    let mut tier_reference: Option<Vec<Vec<u64>>> = None;
    for stealing in [true, false] {
        let backend =
            Arc::new(AnalyticExec::with_shards(AnalyticModel::standard(), tier_feeders));
        let mut cfg = CoordinatorConfig {
            feeders: tier_feeders,
            devices: tier_feeders,
            workers: 2,
            ..Default::default()
        };
        cfg.steal = if stealing {
            StealConfig { stealing: true, local_prefetch: 4, starvation_limit: 64 }
        } else {
            StealConfig { stealing: false, local_prefetch: 1, starvation_limit: 64 }
        };
        let coord = Coordinator::start_with_backend(backend.clone(), cfg)?;
        let handles: Vec<_> = tiered_requests(tier_requests)
            .into_iter()
            .map(|r| coord.submit(r))
            .collect::<Result<_, _>>()?;
        let mut values: Vec<Vec<u64>> = Vec::with_capacity(handles.len());
        for h in handles {
            let resp = h.wait()?;
            values.push(resp.attribution.values.iter().map(|v| v.to_bits()).collect());
        }
        let stats = coord.stats();
        assert_eq!(stats.failed.get(), 0, "no tiered request may fail");
        let steal_rate = stats.steal.steal_rate();
        if !stealing {
            assert_eq!(stats.steal.steals.get(), 0, "stealing off must never steal");
        }
        for tier in LatencyBudget::ALL {
            let ts = stats.tier(tier);
            tier_table.row(vec![
                (stealing as u64).to_string(),
                tier.label().to_string(),
                ts.completed.get().to_string(),
                fmt3(ts.e2e_latency.quantile(0.99) * 1e3),
                fmt3(steal_rate),
            ]);
        }
        coord.shutdown();
        assert_eq!(backend.resident_len(), 0, "tiered run drains the resident pool");
        match tier_reference.as_ref() {
            Some(prev) => {
                for (i, (a, b)) in prev.iter().zip(&values).enumerate() {
                    assert_eq!(a, b, "request {i}: stealing moved attribution bits");
                }
            }
            None => tier_reference = Some(values),
        }
    }
    tier_table.print();

    // ---- Front-end graceful degradation: deadline hits + partials. ------
    // Two bursts over a REAL `Frontend` loopback connection (framed wire
    // protocol, deadline wheel, streaming writer). The deadline burst
    // pairs an unconvergeable anytime policy (delta target 0) with a wire
    // deadline, so every request MUST settle as a partial carrying its
    // best converged round — hit rate and partial rate are exactly 1.0.
    // The control burst carries no deadline and must settle complete
    // (both rates exactly 0.0). Both are asserted, so smoke keeps them.
    let fe_requests = if smoke { 8usize } else { 24 };
    let fe_deadline_ms = 250u64;
    let mut fe_table = Table::new(
        &format!(
            "fig_serving: front-end graceful degradation \
             ({fe_requests} wire requests per burst)"
        ),
        &[
            "requests",
            "deadline_ms",
            "deadline_hit_rate",
            "partial_rate",
            "rounds_streamed",
            "throughput_rps",
        ],
    );
    for deadline_ms in [fe_deadline_ms, 0] {
        let backend = Arc::new(AnalyticExec::with_shards(AnalyticModel::standard(), 1));
        let cfg = CoordinatorConfig { feeders: 1, devices: 1, workers: 2, ..Default::default() };
        let coord = Arc::new(Coordinator::start_with_backend(backend.clone(), cfg)?);
        let fcfg = FrontendConfig::default();
        let max_frame = fcfg.max_frame_bytes;
        let fe = Frontend::start(Arc::clone(&coord), fcfg)?;
        let stream = listener::connect(fe.local_spec())?;
        let mut wire = stream.try_clone()?;
        let mut frames = FrameReader::new(stream, max_frame);

        let t0 = std::time::Instant::now();
        for i in 0..fe_requests {
            let image = synth::gen_image(i % synth::NUM_CLASSES, i / synth::NUM_CLASSES);
            let anytime = (deadline_ms > 0).then_some((0.0, 1u64 << 20));
            wire.write_all(&framing::encode(&Frame::Request(RequestFrame {
                tag: i as u64 + 1,
                deadline_ms,
                budget: 0,
                target: -1,
                m: 16,
                anytime,
                image,
                baseline: None,
            })))?;
        }
        wire.flush()?;

        let mut settled = 0usize;
        let mut partials = 0u64;
        let mut rounds = 0u64;
        while settled < fe_requests {
            match frames.next()? {
                Some(Frame::Round(_)) => rounds += 1,
                Some(Frame::Final(f)) => {
                    settled += 1;
                    if deadline_ms > 0 {
                        assert!(
                            f.partial && f.rounds >= 1,
                            "deadline'd anytime request must settle as a partial \
                             carrying a converged round (tag {})",
                            f.tag
                        );
                        partials += 1;
                    } else {
                        assert!(!f.partial, "undeadlined request must settle complete");
                    }
                }
                Some(other) => anyhow::bail!("unexpected settlement frame: {other:?}"),
                None => anyhow::bail!("front-end closed with {settled}/{fe_requests} settled"),
            }
        }
        let wall = t0.elapsed();

        let armed = if deadline_ms > 0 { fe_requests as u64 } else { 0 };
        assert_eq!(fe.stats().deadlines_armed.get(), armed);
        assert_eq!(
            fe.deadlines_fired(),
            armed,
            "every armed deadline fires on the unconvergeable stream"
        );
        assert_eq!(fe.stats().partials_streamed.get(), partials);
        let hit_rate =
            if armed == 0 { 0.0 } else { fe.deadlines_fired() as f64 / armed as f64 };
        let partial_rate =
            if armed == 0 { 0.0 } else { partials as f64 / fe_requests as f64 };

        fe_table.row(vec![
            fe_requests.to_string(),
            deadline_ms.to_string(),
            fmt3(hit_rate),
            fmt3(partial_rate),
            rounds.to_string(),
            fmt3(fe_requests as f64 / wall.as_secs_f64()),
        ]);

        drop(wire);
        drop(frames);
        fe.shutdown();
        drop(fe);
        if let Ok(c) = Arc::try_unwrap(coord) {
            c.shutdown();
        }
        assert_eq!(backend.resident_len(), 0, "front-end burst drains the resident pool");
    }
    fe_table.print();

    // ---- Machine-readable trajectory point: BENCH_serving.json. ---------
    let path = std::env::var("NUIG_SERVING_JSON").unwrap_or_else(|_| "BENCH_serving.json".into());
    let provenance = format!(
        "fresh fig_serving run (smoke: {smoke}); commit only full refreshes per \
         docs/EXPERIMENTS.md §Baselines"
    );
    let json = Json::obj(vec![
        ("bench", Json::Str("fig_serving".into())),
        ("schema_version", Json::Num(1.0)),
        ("provenance", Json::Str(provenance)),
        ("chunk", Json::Num(chunk as f64)),
        ("requests", Json::Num(n_requests as f64)),
        ("smoke", Json::Bool(smoke)),
        ("rows", table.to_json().get("rows").expect("table has rows").clone()),
        (
            "tier_rows",
            tier_table.to_json().get("rows").expect("tier table has rows").clone(),
        ),
        (
            "frontend_rows",
            fe_table.to_json().get("rows").expect("frontend table has rows").clone(),
        ),
    ]);
    std::fs::write(&path, json.to_string_pretty())?;
    println!("wrote {path}");

    println!(
        "shape check OK: attributions bit-identical at feeder counts {feeder_grid:?}; \
         gather chunks move ~{}B/lane vs {}B/chunk legacy endpoint copies",
        lane_record_bytes, legacy_bytes_per_chunk
    );
    Ok(())
}
