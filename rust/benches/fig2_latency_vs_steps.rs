//! Fig. 2 regeneration: (a) run-time latency of baseline (uniform) IG vs
//! interpolation step count, normalized to m=1; (b) convergence δ vs m.
//!
//! Paper shape to reproduce: latency grows ~linearly in m (the knee in
//! the paper's Fig. 2a is batch-quantization: cost steps every
//! ceil(points/16) chunks), and δ decreases monotonically in m.
//!
//!     cargo bench --bench fig2_latency_vs_steps

use nuig::bench::{fmt3, measure, BenchConfig, Table};
use nuig::data::synth;
use nuig::ig::{self, IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let img = synth::gen_image(0, 0);

    let grid = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];

    // Warm-up outside measurement (mirrors the paper's profiler protocol).
    ig::explain(&model, &img, None, &IgOptions { scheme: Scheme::Uniform, m: 8, ..Default::default() })?;

    let mut rows = Vec::new();
    for &m in &grid {
        let opts = IgOptions { scheme: Scheme::Uniform, m, ..Default::default() };
        let mut delta = 0.0;
        let mut steps = 0;
        let meas = measure(&cfg, &format!("uniform m={m}"), || {
            let a = ig::explain(&model, &img, None, &opts).unwrap();
            delta = a.delta;
            steps = a.steps;
        });
        rows.push((m, steps, meas.mean_s(), delta));
    }

    let t1 = rows[0].2;
    // `steps` is Attribution.steps — the exact fused model-eval count, the
    // unit of cost the paper's Fig. 2a x-axis measures.
    let mut table = Table::new(
        "Fig 2a/2b: latency (normalized to m=1) and delta vs steps (uniform IG)",
        &["m", "steps", "latency_ms", "latency_norm", "delta"],
    );
    for (m, steps, t, d) in &rows {
        table.row(vec![
            m.to_string(),
            steps.to_string(),
            fmt3(t * 1e3),
            fmt3(t / t1),
            fmt3(*d),
        ]);
    }
    table.print();

    // Shape assertions: the claims Fig. 2 makes.
    let last = rows.last().unwrap();
    assert!(last.2 / t1 > 4.0, "latency must grow with m");
    assert!(last.3 < rows[2].3, "delta must fall with m");
    println!("shape check OK: latency rises ~linearly; delta falls monotonically");
    Ok(())
}
