//! Fig. 5 regeneration: (a) convergence δ vs total steps m for the
//! uniform baseline and non-uniform interpolation at n_int ∈ {2,4,8};
//! (b) steps required to meet a convergence threshold δ_th.
//!
//! Paper shape: non-uniform sits below uniform at every m; iso-δ step
//! reduction grows as δ_th tightens (2.7x at loose, 3.6x at tight).
//!
//!     cargo bench --bench fig5_convergence

use nuig::bench::{fmt3, Table};
use nuig::data::Corpus;
use nuig::ig::{self, convergence::ConvergencePolicy, IgOptions, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let corpus = Corpus::eval_set(4);
    let schemes = [
        Scheme::Uniform,
        Scheme::NonUniform { n_int: 2 },
        Scheme::NonUniform { n_int: 4 },
        Scheme::NonUniform { n_int: 8 },
    ];
    let grid = [8usize, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256];

    // ---- Fig 5a: delta vs m (mean over corpus) -------------------------
    let mut fig5a = Table::new("Fig 5a: delta vs m", &["m", "scheme", "delta_mean"]);
    let mut uniform_curve = Vec::new();
    let mean_delta = |scheme: Scheme, m: usize| -> anyhow::Result<f64> {
        let mut acc = 0.0;
        for li in corpus.iter() {
            acc += ig::explain(&model, &li.pixels, None, &IgOptions { scheme, m, ..Default::default() })?.delta;
        }
        Ok(acc / corpus.len() as f64)
    };
    for &m in &grid {
        for &scheme in &schemes {
            if let Scheme::NonUniform { n_int } = scheme {
                if m < n_int {
                    continue;
                }
            }
            let d = mean_delta(scheme, m)?;
            if scheme == Scheme::Uniform {
                uniform_curve.push((m, d));
            }
            fig5a.row(vec![m.to_string(), scheme.to_string(), fmt3(d)]);
        }
    }
    fig5a.print();

    // ---- Fig 5b: steps to reach delta_th --------------------------------
    // Thresholds = baseline delta at m ∈ {16,32,64,128} (relative sweep,
    // tight→loose; see DESIGN.md §4 delta-scale note).
    let mut fig5b = Table::new(
        "Fig 5b: steps to reach threshold",
        &["delta_th", "scheme", "m_required", "reduction"],
    );
    let mut reductions = Vec::new();
    for &(m_ref, th) in uniform_curve.iter().filter(|(m, _)| [16, 32, 64, 128].contains(m)) {
        let policy = ConvergencePolicy::new(th);
        let mut m_uni = None;
        for &scheme in &schemes {
            let (m_req, _, ok) = policy.search(|m| {
                if let Scheme::NonUniform { n_int } = scheme {
                    if m < n_int {
                        return Ok::<f64, anyhow::Error>(f64::INFINITY);
                    }
                }
                mean_delta(scheme, m)
            })?;
            if scheme == Scheme::Uniform {
                m_uni = Some(m_req);
            }
            let red = m_uni.map(|mu| mu as f64 / m_req as f64).unwrap_or(1.0);
            if scheme == (Scheme::NonUniform { n_int: 4 }) && ok {
                reductions.push((m_ref, red));
            }
            fig5b.row(vec![
                format!("{th:.5}"),
                scheme.to_string(),
                if ok { m_req.to_string() } else { format!(">{m_req} (not reached)") },
                format!("{red:.2}x"),
            ]);
        }
    }
    fig5b.print();

    // Shape assertions (the paper's claims).
    for &m in &[16usize, 32, 64] {
        let u = uniform_curve.iter().find(|(gm, _)| *gm == m).unwrap().1;
        let n = mean_delta(Scheme::NonUniform { n_int: 4 }, m)?;
        assert!(n < u, "Fig5a shape: nonuniform(4) {n} !< uniform {u} at m={m}");
    }
    // Reductions are quantized by the ~1.5x-spaced search grid, so the
    // assertable shape is: benefit everywhere, growing as the threshold
    // tightens (the paper's 2.7x -> 3.6x trend), with >= 2x at the tight
    // end. (Loose thresholds measure 1.33x simply because the grid step
    // below the uniform requirement is 1.33x away.)
    assert!(
        reductions.iter().all(|(_, r)| *r > 1.0),
        "non-uniform must reduce steps at every threshold: {reductions:?}"
    );
    let tight = reductions.iter().map(|(_, r)| *r).fold(0.0, f64::max);
    assert!(tight >= 2.0, "tight-threshold reduction should reach >= 2x: {reductions:?}");
    let first = reductions.first().unwrap().1;
    let last = reductions.last().unwrap().1;
    assert!(last >= first, "benefit should grow as delta_th tightens: {reductions:?}");
    println!("shape check OK: non-uniform below uniform at every m; reduction grows {first:.2}x -> {last:.2}x as threshold tightens");
    Ok(())
}
