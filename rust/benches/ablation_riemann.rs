//! Riemann-rule ablation: the paper's Eq. 2 uses all m+1 points at weight
//! 1/m (which over-counts by (m+1)/m); Captum ships trapezoid. Compare
//! left / right / trapezoid / eq2 convergence under both schemes.
//!
//!     cargo bench --bench ablation_riemann

use nuig::bench::{fmt3, Table};
use nuig::data::synth;
use nuig::ig::{self, IgOptions, Rule, Scheme};
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let img = synth::gen_image(0, 0);

    // `steps` is the fused model-eval count: left/right grids pay m evals
    // (their zero-weight endpoint is pruned at build), trapezoid/eq2 pay
    // m + 1 — the per-rule cost the delta comparison should be read with.
    let mut table = Table::new(
        "Riemann-rule ablation: delta by rule and scheme",
        &["m", "rule", "scheme", "steps", "delta"],
    );
    let mut trap_beats_eq2 = 0usize;
    let mut cases = 0usize;
    for m in [16usize, 32, 64, 128] {
        for rule in [Rule::Left, Rule::Right, Rule::Trapezoid, Rule::Eq2] {
            for scheme in [Scheme::Uniform, Scheme::NonUniform { n_int: 4 }] {
                let opts = IgOptions { scheme, m, rule, ..Default::default() };
                let a = ig::explain(&model, &img, None, &opts)?;
                table.row(vec![
                    m.to_string(),
                    rule.to_string(),
                    scheme.to_string(),
                    a.steps.to_string(),
                    fmt3(a.delta),
                ]);
            }
        }
        // Direct trapezoid-vs-eq2 comparison at this m (uniform scheme).
        let d_trap = ig::explain(&model, &img, None, &IgOptions { scheme: Scheme::Uniform, m, rule: Rule::Trapezoid, ..Default::default() })?.delta;
        let d_eq2 = ig::explain(&model, &img, None, &IgOptions { scheme: Scheme::Uniform, m, rule: Rule::Eq2, ..Default::default() })?.delta;
        cases += 1;
        if d_trap < d_eq2 {
            trap_beats_eq2 += 1;
        }
    }
    table.print();
    assert!(
        trap_beats_eq2 == cases,
        "trapezoid should dominate the paper's literal Eq. 2 weights ({trap_beats_eq2}/{cases})"
    );
    println!("shape check OK: trapezoid < eq2 at every m (Eq. 2's (m+1)/m over-count is visible)");
    Ok(())
}
