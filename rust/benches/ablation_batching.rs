//! Batching ablation — the hardware-awareness claim (§V): the paper's
//! static two-stage schedule *batches*; Guided-IG-style dynamic stepping
//! forces batch size 1. Compare gradient-point throughput:
//!
//!   batch1      — one point per executable call (igchunk_b1), the
//!                 dynamic-path worst case;
//!   chunk16     — one request streamed through igchunk_b16 (this repo's
//!                 single-request engine path);
//!   coordinator — cross-request continuous batching via igchunk_m16
//!                 under concurrent load (this repo's serving path).
//!
//!     cargo bench --bench ablation_batching

use std::time::Instant;

use nuig::bench::{fmt3, Table};
use nuig::config::CoordinatorConfig;
use nuig::coordinator::{Coordinator, ExplainRequest};
use nuig::data::synth;
use nuig::ig::{self, model::IgPointsOut, IgOptions, Model, Scheme};
use nuig::runtime::{Arg, ExeKind, Runtime, RuntimeHandle};

/// Batch-1 model: every gradient point is its own igchunk_b1 call —
/// the GPU-side consequence of dynamically-determined steps.
struct Batch1Model {
    handle: RuntimeHandle,
}

impl Model for Batch1Model {
    fn features(&self) -> usize {
        self.handle.features()
    }
    fn num_classes(&self) -> usize {
        self.handle.num_classes()
    }
    fn probs(&self, imgs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f64>>> {
        imgs.iter()
            .map(|img| {
                let outs =
                    self.handle.execute(ExeKind::Fwd1, vec![Arg::mat(img.to_vec(), 1, self.features())])?;
                Ok(outs[0].iter().map(|&v| v as f64).collect())
            })
            .collect()
    }
    fn ig_points(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> anyhow::Result<IgPointsOut> {
        let mut onehot = vec![0f32; self.num_classes()];
        onehot[target] = 1.0;
        let mut partial = vec![0f64; self.features()];
        let mut target_probs = Vec::new();
        for (&a, &w) in alphas.iter().zip(weights) {
            let outs = self.handle.execute(
                ExeKind::IgChunk1,
                vec![
                    Arg::vec(x.to_vec()),
                    Arg::vec(baseline.to_vec()),
                    Arg::vec(vec![a]),
                    Arg::vec(vec![w]),
                    Arg::vec(onehot.clone()),
                ],
            )?;
            for (acc, &v) in partial.iter_mut().zip(&outs[0]) {
                *acc += v as f64;
            }
            target_probs.push(outs[1][target] as f64);
        }
        Ok(IgPointsOut { partial, target_probs })
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let img = synth::gen_image(0, 0);
    let m = 32;
    let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m, ..Default::default() };

    let mut table = Table::new(
        "batching ablation: gradient-point throughput",
        &["mode", "points", "wall_ms", "points_per_s", "speedup_vs_batch1"],
    );

    // Warm-up all executables.
    let chunked = rt.model();
    ig::explain(&chunked, &img, None, &opts)?;
    let b1 = Batch1Model { handle: rt.handle() };
    ig::explain(&b1, &img, None, &IgOptions { m: 4, ..opts })?;

    // batch1: Guided-IG-style.
    let t0 = Instant::now();
    let a1 = ig::explain(&b1, &img, None, &opts)?;
    let t_b1 = t0.elapsed().as_secs_f64();
    let pts1 = a1.steps as f64;

    // chunk16: single-request chunked path.
    let reps = 4;
    let t0 = Instant::now();
    let mut pts16 = 0f64;
    for _ in 0..reps {
        pts16 += ig::explain(&chunked, &img, None, &opts)?.steps as f64;
    }
    let t_c16 = t0.elapsed().as_secs_f64() / reps as f64;
    pts16 /= reps as f64;

    // coordinator: 16 concurrent requests, cross-request batching.
    let coord = Coordinator::start(&rt, CoordinatorConfig { workers: 2, ..Default::default() })?;
    coord.explain(ExplainRequest::new(img.clone(), IgOptions { m: 8, ..opts }))?; // warm
    let n_req = 16;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_req)
        .map(|i| {
            coord.submit(ExplainRequest::new(synth::gen_image(i % 8, 0), opts))
        })
        .collect::<Result<_, _>>()?;
    let mut pts_coord = 0f64;
    for h in handles {
        pts_coord += h.wait()?.attribution.steps as f64;
    }
    let t_coord = t0.elapsed().as_secs_f64();
    let occ = coord.stats().mean_occupancy(coord.config().chunk);

    let rate1 = pts1 / t_b1;
    let rate16 = pts16 / t_c16;
    let rate_coord = pts_coord / t_coord;
    table.row(vec!["batch1".into(), fmt3(pts1), fmt3(t_b1 * 1e3), fmt3(rate1), "1.000".into()]);
    table.row(vec![
        "chunk16".into(),
        fmt3(pts16),
        fmt3(t_c16 * 1e3),
        fmt3(rate16),
        fmt3(rate16 / rate1),
    ]);
    table.row(vec![
        "coordinator".into(),
        fmt3(pts_coord),
        fmt3(t_coord * 1e3),
        fmt3(rate_coord),
        fmt3(rate_coord / rate1),
    ]);
    table.print();
    println!("coordinator batch occupancy: {:.1}%", occ * 100.0);

    // SUBSTRATE NOTE: on a GPU (the paper's testbed) a batch-16 launch
    // costs barely more than batch-1 because otherwise-idle SMs absorb
    // the extra lanes — that is the paper's §V argument against dynamic
    // batch-1 methods. CPU-PJRT compute scales ~linearly with batch, so
    // the single-request chunk16 path pays for its padding lanes and
    // lands near batch-1 throughput; the *coordinator* restores the win
    // by filling those lanes with other requests' points (occupancy ≈ 1).
    // The assertable shape on this substrate is therefore:
    assert!(
        rate_coord > rate1,
        "continuous batching must beat batch-1 dispatch: {rate_coord:.0} !> {rate1:.0}"
    );
    assert!(occ > 0.8, "coordinator must keep chunks full under load: {occ}");
    println!(
        "shape check OK: cross-request continuous batching beats batch-1 ({:.2}x) at {:.0}% occupancy\n\
         (GPU would additionally favour chunk16 over batch1; see bench source for the mapping)",
        rate_coord / rate1,
        occ * 100.0
    );
    coord.shutdown();
    Ok(())
}
