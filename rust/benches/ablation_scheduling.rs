//! Scheduling-policy ablation: p50/p95 latency and throughput under a
//! heterogeneous request mix (small m=16 requests interleaved with large
//! m=128 ones) for fifo / round-robin / shortest-first lane scheduling.
//!
//! Expected shape: FIFO lets large requests head-of-line-block small
//! ones (high small-request p95); shortest-first minimizes small-request
//! latency; round-robin sits between. Throughput is policy-invariant
//! (the device does the same total work).
//!
//!     cargo bench --bench ablation_scheduling

use std::time::Instant;

use nuig::bench::{fmt3, Table};
use nuig::config::CoordinatorConfig;
use nuig::coordinator::{Coordinator, ExplainRequest, Policy};
use nuig::data::synth;
use nuig::ig::{IgOptions, Scheme};
use nuig::metrics::Summary;
use nuig::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default("artifacts")?;
    let mut table = Table::new(
        "lane-scheduling ablation (mixed m=16 / m=128 load)",
        &["policy", "total_s", "small_p50_ms", "small_p95_ms", "large_p95_ms", "throughput_rps"],
    );

    for policy in [Policy::Fifo, Policy::RoundRobin, Policy::ShortestFirst] {
        let coord = Coordinator::start(
            &rt,
            CoordinatorConfig { workers: 2, policy, ..Default::default() },
        )?;
        // Warm-up.
        coord.explain(ExplainRequest::new(
            synth::gen_image(0, 0),
            IgOptions { m: 8, ..Default::default() },
        ))?;

        // 24 requests: alternating large (m=128) and small (m=16), so
        // small ones queue behind large ones under FIFO.
        let n = 24;
        let t0 = Instant::now();
        let handles: Vec<(bool, _)> = (0..n)
            .map(|i| {
                let small = i % 2 == 1;
                let m = if small { 16 } else { 128 };
                let req = ExplainRequest::new(
                    synth::gen_image(i % 8, 0),
                    IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m, ..Default::default() },
                );
                Ok((small, coord.submit(req)?))
            })
            .collect::<anyhow::Result<_>>()?;

        let mut small_lat = Summary::new();
        let mut large_lat = Summary::new();
        for (small, h) in handles {
            let resp = h.wait()?;
            let l = resp.total_latency.as_secs_f64();
            if small {
                small_lat.record(l);
            } else {
                large_lat.record(l);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            policy.to_string(),
            fmt3(wall),
            fmt3(small_lat.quantile(0.5) * 1e3),
            fmt3(small_lat.quantile(0.95) * 1e3),
            fmt3(large_lat.quantile(0.95) * 1e3),
            fmt3(n as f64 / wall),
        ]);
        coord.shutdown();
    }
    table.print();
    println!(
        "shape: sjf/rr should cut small-request latency vs fifo at ~equal throughput\n\
         (recorded in docs/EXPERIMENTS.md §Perf)"
    );
    Ok(())
}
