//! Iso-convergence cost table (the paper's Table I analogue): gradient
//! evaluations needed to reach a completeness residual δ_th, for
//!
//! * the **uniform** baseline under the fixed-m grid search (each probe
//!   of the grid re-evaluates its whole schedule),
//! * the paper's **non-uniform** engine under the same fixed-m search,
//! * the **anytime** engine: one coarse schedule, then nested refinement
//!   with convergence-gated early exit — every evaluated gradient is
//!   reused, so the total cost is the *final* schedule's length.
//!
//! Runs on the closed-form [`AnalyticModel`] (exact gradients, no
//! artifacts needed), averaged over a small random input set. Thresholds
//! are the uniform baseline's δ at m ∈ {16, 32, 64, 128} — the same
//! tight-to-loose sweep shape as fig5/fig6 (see DESIGN.md §4).
//!
//!     cargo bench --bench fig_isoconv
//!
//! JSON output fields per row: `delta_th`, `driver`, `evals_mean` (total
//! gradient evaluations incl. the grid walk's discarded rounds),
//! `m_final_mean`, `rounds_mean`, `reduction_vs_uniform`.

use nuig::bench::{fmt3, Table};
use nuig::ig::{self, convergence::ConvergencePolicy, AnalyticModel, AnytimePolicy, IgOptions, Scheme};
use nuig::testutil::TestRng;

const N_INT: usize = 4;
/// Anytime starting level: 4 steps per probe interval, the minimum that
/// keeps the sqrt allocation non-degenerate under doubling.
const M0: usize = 16;
const MAX_M: usize = 512;

fn main() -> anyhow::Result<()> {
    let model = AnalyticModel::new(64, 4, 7, 300.0);
    let mut rng = TestRng::new(0x150C0);
    let inputs: Vec<Vec<f32>> = (0..6).map(|_| rng.vec_f32(64, 0.0, 1.0)).collect();

    // Thresholds: mean uniform-baseline delta at the reference step counts.
    let mean_uniform_delta = |m: usize| -> anyhow::Result<f64> {
        let mut acc = 0.0;
        for x in &inputs {
            acc += ig::explain(&model, x, None, &IgOptions { scheme: Scheme::Uniform, m, ..Default::default() })?
                .delta;
        }
        Ok(acc / inputs.len() as f64)
    };

    let mut table = Table::new(
        "fig_isoconv: total gradient evals to reach delta_th (mean over inputs)",
        &["delta_th", "driver", "evals_mean", "m_final_mean", "rounds_mean", "reduction_vs_uniform"],
    );

    let mut cells: Vec<(usize, f64, f64)> = Vec::new(); // (m_ref, nonuniform evals, anytime evals)
    for &m_ref in &[16usize, 32, 64, 128] {
        let th = mean_uniform_delta(m_ref)?;
        let policy = ConvergencePolicy::new(th);

        // Fixed-m grid walks (per input, then averaged): each attempted m
        // pays its full fused schedule — the paper's literal protocol.
        let mut walk = |scheme: Scheme| -> anyhow::Result<(f64, f64, f64)> {
            let (mut evals, mut m_final, mut rounds) = (0.0, 0.0, 0.0);
            for x in &inputs {
                let mut total = 0usize;
                let (m_req, _, _) = policy.search(|m| {
                    if let Scheme::NonUniform { n_int } = scheme {
                        if m < n_int {
                            return Ok::<f64, anyhow::Error>(f64::INFINITY);
                        }
                    }
                    let a = ig::explain(&model, x, None, &IgOptions { scheme, m, ..Default::default() })?;
                    total += a.steps;
                    rounds += 1.0;
                    Ok(a.delta)
                })?;
                evals += total as f64;
                m_final += m_req as f64;
            }
            let n = inputs.len() as f64;
            Ok((evals / n, m_final / n, rounds / n))
        };

        let uni = walk(Scheme::Uniform)?;
        let non = walk(Scheme::NonUniform { n_int: N_INT })?;

        // Anytime: coarse start + convergence-gated refinement (reuse).
        let anytime_policy = AnytimePolicy::with_max_m(th, MAX_M)?;
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: N_INT }, m: M0, ..Default::default() };
        let (mut a_evals, mut a_m, mut a_rounds) = (0.0, 0.0, 0.0);
        for x in &inputs {
            let a = ig::explain_anytime(&model, x, None, &opts, &anytime_policy)?;
            // Reuse accounting: rounds double m from M0, and the total
            // eval count is the FINAL schedule's length (m_final + 1) —
            // no round ever re-evaluates an alpha.
            assert_eq!(a.steps, (M0 << (a.rounds - 1)) + 1);
            assert_eq!(a.residuals.len(), a.rounds);
            a_evals += a.steps as f64;
            a_m += (a.steps - 1) as f64; // trapezoid: steps == m_final + 1
            a_rounds += a.rounds as f64;
        }
        let n = inputs.len() as f64;
        let any = (a_evals / n, a_m / n, a_rounds / n);

        for (driver, cell) in [("uniform fixed-m", uni), ("nonuniform fixed-m", non), ("anytime", any)] {
            table.row(vec![
                format!("{th:.5}"),
                driver.to_string(),
                fmt3(cell.0),
                fmt3(cell.1),
                fmt3(cell.2),
                format!("{:.2}x", uni.0 / cell.0),
            ]);
        }
        cells.push((m_ref, non.0, any.0));
    }
    table.print();

    // The acceptance claim: convergence-gated early exit with gradient
    // reuse reaches the residual target with FEWER total model evals than
    // the fixed-m non-uniform engine's search. The walk's cost is the sum
    // over attempted schedules, so the gap opens as the threshold
    // tightens (more discarded rounds); at the loosest thresholds both
    // converge on their first schedule and can tie, so the hard claim is
    // asserted where it is meaningful — the tight half of the sweep —
    // plus never-worse across the whole sweep.
    for &(m_ref, non_evals, any_evals) in &cells {
        // Loose half: doubling (16→32→64) is coarser than the walk's 1.5x
        // grid (8,12,16,...), so allow the quantization margin of one
        // doubling overshoot; the trend claim lives in the tight half.
        assert!(
            any_evals <= non_evals * 1.2 + 1.0,
            "anytime ({any_evals}) grossly exceeds the fixed-m walk ({non_evals}) at m_ref={m_ref}"
        );
        if m_ref >= 64 {
            assert!(
                any_evals < non_evals,
                "anytime ({any_evals}) must strictly beat the fixed-m walk ({non_evals}) at the tight threshold m_ref={m_ref}"
            );
        }
    }
    println!("shape check OK: anytime early-exit reaches every threshold at <= fixed-m cost, strictly fewer at tight thresholds");
    Ok(())
}
