//! Microbenchmarks of the L3 substrate hot paths — the pieces that must
//! stay invisible next to a multi-millisecond device execution: channel
//! ops, chunk assembly, schedule construction, allocation, accumulator
//! adds, JSON parsing. Used by the §Perf pass to verify the coordinator
//! is not the bottleneck.
//!
//!     cargo bench --bench micro_substrate

use std::time::Instant;

use nuig::bench::{fmt3, Table};
use nuig::data::synth;
use nuig::exec::channel::bounded;
use nuig::ig::allocator::Allocation;
use nuig::ig::riemann::Rule;
use nuig::ig::schedule::Schedule;
use nuig::jsonio;

fn time_per_op<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let mut table = Table::new(
        "L3 substrate microbenchmarks (per-op cost; device exec ~30ms for scale)",
        &["op", "ns_per_op", "ops_per_device_exec_budget"],
    );
    let budget = 30e-3; // one igchunk execution

    // Channel send+recv round trip.
    let (tx, rx) = bounded::<u64>(1024);
    let t = time_per_op(100_000, || {
        tx.send(1).unwrap();
        rx.recv().unwrap();
    });
    table.row(vec!["channel send+recv".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    // Schedule construction (nonuniform, m=64, n_int=4).
    let alloc = Allocation::Sqrt.allocate(64, &[0.6, 0.25, 0.1, 0.05]).unwrap();
    let bounds = Schedule::probe_boundaries(4);
    let t = time_per_op(100_000, || {
        let s = Schedule::nonuniform(&bounds, &alloc, Rule::Trapezoid).unwrap();
        std::hint::black_box(s);
    });
    table.row(vec!["schedule build (m=64)".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    // Allocation itself.
    let t = time_per_op(1_000_000, || {
        let a = Allocation::Sqrt.allocate(128, &[0.5, 0.3, 0.15, 0.05]).unwrap();
        std::hint::black_box(a);
    });
    table.row(vec!["sqrt allocate (4 intervals)".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    // f64 accumulator add (one lane row, F=3072).
    let row = vec![0.5f32; synth::F];
    let mut acc = vec![0f64; synth::F];
    let t = time_per_op(100_000, || {
        for (a, &v) in acc.iter_mut().zip(&row) {
            *a += v as f64;
        }
        std::hint::black_box(&acc);
    });
    table.row(vec!["lane accumulate (F=3072)".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    // Chunk arg packing (16 lanes of xs+baselines+onehots).
    let img = synth::gen_image(0, 0);
    let t = time_per_op(10_000, || {
        let mut xs = vec![0f32; 16 * synth::F];
        for k in 0..16 {
            xs[k * synth::F..(k + 1) * synth::F].copy_from_slice(&img);
        }
        std::hint::black_box(xs);
    });
    table.row(vec!["chunk pack (16xF copy)".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    // Synthetic image generation.
    let t = time_per_op(2_000, || {
        std::hint::black_box(synth::gen_image(0, 0));
    });
    table.row(vec!["gen_image".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    // JSON parse of a manifest-sized document.
    let doc = std::fs::read_to_string("artifacts/manifest.json").unwrap_or_else(|_| {
        r#"{"version":3,"model":{"features":3072},"executables":{}}"#.to_string()
    });
    let t = time_per_op(5_000, || {
        std::hint::black_box(jsonio::parse(&doc).unwrap());
    });
    table.row(vec!["json parse (manifest)".into(), fmt3(t * 1e9), fmt3(budget / t)]);

    table.print();
    println!("interpretation: every op fits >=1000x into one device execution -> L3 is not the bottleneck");
}
