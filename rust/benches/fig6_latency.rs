//! Fig. 6 regeneration: (a) normalized run-time latency to reach a
//! convergence threshold δ_th, per interpolation scheme; (b) the latency
//! overhead of the non-uniform algorithm's first stage (probing +
//! allocation) as a % of total latency.
//!
//! Also reproduces the paper's overhead-scaling claim ("the absolute
//! value of the latency overhead depends only on n_int" because stage 1
//! runs n_int+1 inference passes) with ProbeMode::Sequential, and shows
//! the batched-probe improvement this repo's coordinator uses.
//!
//!     cargo bench --bench fig6_latency

use std::time::Instant;

use nuig::bench::{fmt3, measure, BenchConfig, Table};
use nuig::data::synth;
use nuig::ig::{self, convergence::ConvergencePolicy, IgOptions, Scheme};
use nuig::runtime::{ProbeMode, Runtime};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let rt = Runtime::load_default("artifacts")?;
    let model = rt.model();
    let img = synth::gen_image(0, 0);
    let schemes = [
        Scheme::Uniform,
        Scheme::NonUniform { n_int: 2 },
        Scheme::NonUniform { n_int: 4 },
        Scheme::NonUniform { n_int: 8 },
    ];

    // Warm-up.
    ig::explain(&model, &img, None, &IgOptions { m: 8, ..Default::default() })?;

    // Thresholds from the uniform baseline's delta at m ∈ {32, 64, 128}.
    let thresholds: Vec<(usize, f64)> = [32usize, 64, 128]
        .iter()
        .map(|&m| {
            ig::explain(&model, &img, None, &IgOptions { scheme: Scheme::Uniform, m, ..Default::default() })
                .map(|a| (m, a.delta))
        })
        .collect::<Result<_, _>>()?;

    // ---- Fig 6a: latency to reach delta_th ------------------------------
    // `steps` is the fused model-eval count (Attribution.steps), so the
    // latency-vs-steps relation matches the paper's cost model exactly:
    // one step == one fwd+bwd pass, no duplicated boundary evaluations.
    let mut fig6a = Table::new(
        "Fig 6a: latency to reach threshold (normalized to fastest cell)",
        &["delta_th", "scheme", "m_required", "steps", "latency_ms", "latency_norm"],
    );
    let mut cells = Vec::new();
    for &(_, th) in &thresholds {
        let policy = ConvergencePolicy::new(th);
        for &scheme in &schemes {
            let (m_req, _, ok) = policy.search(|m| {
                if let Scheme::NonUniform { n_int } = scheme {
                    if m < n_int {
                        return Ok::<f64, anyhow::Error>(f64::INFINITY);
                    }
                }
                Ok(ig::explain(&model, &img, None, &IgOptions { scheme, m, ..Default::default() })?.delta)
            })?;
            if !ok {
                continue;
            }
            let opts = IgOptions { scheme, m: m_req, ..Default::default() };
            let mut steps = 0;
            let meas = measure(&cfg, "cell", || {
                steps = ig::explain(&model, &img, None, &opts).unwrap().steps;
            });
            cells.push((th, scheme, m_req, steps, meas.mean_s()));
        }
    }
    let fastest = cells.iter().map(|c| c.4).fold(f64::INFINITY, f64::min);
    let mut reductions = Vec::new();
    for &(th, scheme, m_req, steps, t) in &cells {
        fig6a.row(vec![
            format!("{th:.5}"),
            scheme.to_string(),
            m_req.to_string(),
            steps.to_string(),
            fmt3(t * 1e3),
            fmt3(t / fastest),
        ]);
        if scheme == (Scheme::NonUniform { n_int: 4 }) {
            let uni = cells
                .iter()
                .find(|c| c.0 == th && c.1 == Scheme::Uniform)
                .map(|c| c.4);
            if let Some(tu) = uni {
                reductions.push(tu / t);
            }
        }
    }
    fig6a.print();

    // ---- Schedule-fusion accounting: fused vs unfused stage-2 evals. ----
    let mut fusion = Table::new(
        "Schedule fusion: stage-2 model evals vs the unfused concatenation",
        &["m", "n_int", "fused_evals", "unfused_evals", "saved_pct"],
    );
    let mut saved_at_paper_point = 0.0;
    for &(m, n_int) in &[(16usize, 4usize), (32, 4), (64, 4), (32, 8)] {
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int }, m, ..Default::default() };
        let a = ig::explain(&model, &img, None, &opts)?;
        let unfused = m + n_int; // Σ(m_i + 1): duplicated boundary points
        let saved = 100.0 * (unfused - a.steps) as f64 / unfused as f64;
        if (m, n_int) == (16, 4) {
            saved_at_paper_point = saved;
        }
        fusion.row(vec![
            m.to_string(),
            n_int.to_string(),
            a.steps.to_string(),
            unfused.to_string(),
            fmt3(saved),
        ]);
    }
    fusion.print();
    assert!(
        saved_at_paper_point >= 10.0,
        "fusion must cut >= 10% of stage-2 evals at the paper's operating point \
         (m=16, n_int=4): got {saved_at_paper_point:.1}%"
    );

    // ---- Fig 6b: stage-1 overhead % --------------------------------------
    let mut fig6b = Table::new(
        "Fig 6b: stage-1 overhead as % of total latency",
        &["probe_mode", "n_int", "m", "probe_ms", "total_ms", "overhead_pct"],
    );
    for mode in [ProbeMode::Batched, ProbeMode::Sequential] {
        let pm = rt.model().with_probe_mode(mode);
        for n_int in [2usize, 4, 8] {
            for m in [32usize, 128] {
                let opts = IgOptions { scheme: Scheme::NonUniform { n_int }, m, ..Default::default() };
                // Median of `runs` measured attributions.
                let mut probes = Vec::new();
                let mut totals = Vec::new();
                for _ in 0..cfg.runs.max(3) {
                    let t0 = Instant::now();
                    let a = ig::explain(&pm, &img, None, &opts)?;
                    totals.push(t0.elapsed().as_secs_f64());
                    probes.push((a.breakdown.probe + a.breakdown.schedule).as_secs_f64());
                }
                let med = |v: &mut Vec<f64>| {
                    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    v[v.len() / 2]
                };
                let p = med(&mut probes);
                let t = med(&mut totals);
                fig6b.row(vec![
                    format!("{mode:?}"),
                    n_int.to_string(),
                    m.to_string(),
                    fmt3(p * 1e3),
                    fmt3(t * 1e3),
                    fmt3(100.0 * p / t),
                ]);
            }
        }
    }
    fig6b.print();

    // At the loosest threshold both schemes land on nearby grid points, so
    // the ratio there is noise-sensitive; the robust claims are a win at
    // every threshold and growth toward the tight end (paper: 2.6x->3.6x).
    assert!(
        reductions.iter().all(|r| *r > 1.0),
        "non-uniform must cut iso-convergence latency: {reductions:?}"
    );
    assert!(
        reductions.last().unwrap() > &1.5,
        "tight-threshold latency reduction should exceed 1.5x: {reductions:?}"
    );
    println!(
        "shape check OK: non-uniform cuts latency at every threshold ({:?}x); \n\
         overhead grows with n_int and shrinks with m, as in the paper",
        reductions.iter().map(|r| (r * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    Ok(())
}
