//! Wall-clock benchmarking harness (no `criterion` offline).
//!
//! Mirrors the paper's measurement protocol ("the profiler ... performs an
//! initial warm-up, and averages over multiple runs"): every measurement
//! does `warmup` unmeasured iterations, then `runs` measured ones, and
//! reports the full [`metrics::Summary`] so benches can print mean ± CV
//! and exact medians. Bench binaries (`benches/*.rs`, `harness = false`)
//! print both human tables and machine-readable JSON rows.

use std::time::Instant;

use crate::jsonio::Json;
use crate::metrics::Summary;

/// Measurement configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Unmeasured warm-up iterations before timing starts.
    pub warmup: usize,
    /// Measured iterations.
    pub runs: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 2, runs: 5 }
    }
}

impl BenchConfig {
    /// Honour `NUIG_BENCH_RUNS` / `NUIG_BENCH_WARMUP` so CI can shrink
    /// bench time without code edits.
    pub fn from_env() -> Self {
        let d = Self::default();
        let get = |k: &str, dv: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(dv)
        };
        BenchConfig { warmup: get("NUIG_BENCH_WARMUP", d.warmup), runs: get("NUIG_BENCH_RUNS", d.runs) }
    }
}

/// One measured cell: label + timing summary (seconds).
pub struct Measurement {
    /// What was measured (table-cell label).
    pub label: String,
    /// Exact per-run timing statistics.
    pub summary: Summary,
}

impl Measurement {
    /// Mean run time in seconds.
    pub fn mean_s(&self) -> f64 {
        self.summary.mean()
    }

    /// Mean run time in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean() * 1e3
    }
}

/// Time `f` under `cfg`; `f` is called once per iteration.
pub fn measure<F: FnMut()>(cfg: &BenchConfig, label: &str, mut f: F) -> Measurement {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut summary = Summary::new();
    for _ in 0..cfg.runs {
        let t0 = Instant::now();
        f();
        summary.record(t0.elapsed().as_secs_f64());
    }
    Measurement { label: label.to_string(), summary }
}

/// A printable results table with fixed columns, plus JSON row export.
/// Every figure-bench builds one of these; the `reproduce_paper` example
/// collects the JSON into docs/EXPERIMENTS.md data blocks.
pub struct Table {
    /// Table heading.
    pub title: String,
    /// Column names.
    pub columns: Vec<String>,
    /// Row cells, in insertion order.
    pub rows: Vec<Vec<String>>,
    json_rows: Vec<Json>,
}

impl Table {
    /// An empty table with the given title and columns.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            json_rows: Vec::new(),
        }
    }

    /// Append one row (cell count must match the columns).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row width mismatch");
        let obj = self
            .columns
            .iter()
            .zip(&cells)
            .map(|(k, v)| {
                let val = v
                    .parse::<f64>()
                    .map(Json::Num)
                    .unwrap_or_else(|_| Json::Str(v.clone()));
                (k.clone(), val)
            })
            .collect();
        self.json_rows.push(Json::Obj(obj));
        self.rows.push(cells);
    }

    /// Render the human-readable table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Machine-readable JSON block (one object per row) for docs/EXPERIMENTS.md.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("table", Json::Str(self.title.clone())),
            ("rows", Json::Arr(self.json_rows.clone())),
        ])
    }

    /// Print table followed by a fenced JSON block.
    pub fn print(&self) {
        println!("{}", self.render());
        println!("```json bench:{}", slug(&self.title));
        println!("{}", self.to_json().to_string_pretty());
        println!("```\n");
    }
}

fn slug(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect()
}

/// Format a float with 3 significant-ish decimals for table cells.
pub fn fmt3(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_expected_iterations() {
        let mut calls = 0;
        let cfg = BenchConfig { warmup: 3, runs: 7 };
        let m = measure(&cfg, "t", || calls += 1);
        assert_eq!(calls, 10);
        assert_eq!(m.summary.count(), 7);
        assert!(m.mean_s() >= 0.0);
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new("demo", &["m", "delta"]);
        t.row(vec!["8".into(), "0.125".into()]);
        t.row(vec!["128".into(), "0.001".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("  8"), "m column right-aligned: {s}");
        assert!(s.contains("128  0.001"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn table_json_types() {
        let mut t = Table::new("demo", &["m", "scheme"]);
        t.row(vec!["8".into(), "uniform".into()]);
        let j = t.to_json();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("m").unwrap().as_f64().unwrap(), 8.0);
        assert_eq!(rows[0].get("scheme").unwrap().as_str().unwrap(), "uniform");
    }

    #[test]
    fn fmt3_ranges() {
        assert_eq!(fmt3(0.0), "0");
        assert_eq!(fmt3(0.12345), "0.12345");
        assert_eq!(fmt3(3.14159), "3.142");
        assert_eq!(fmt3(123.456), "123.5");
    }

    #[test]
    fn bench_config_env_parsing() {
        // Only checks the parsing path; avoid mutating the global env in
        // parallel test runs by just exercising the default branch.
        let cfg = BenchConfig::from_env();
        assert!(cfg.runs >= 1);
    }
}
