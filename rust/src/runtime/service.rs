//! The device thread: owns the PJRT client + executables, executes jobs
//! from a channel. See `runtime/mod.rs` for why this is a single thread.
//!
//! Besides raw executions the device thread owns the **resident request
//! pool**: a request's `x`/baseline are uploaded once at admission
//! ([`GatherExec::register_request`]) and referenced by later work —
//! gather chunks stage their `chunk × features` device payload from the
//! resident host copies into one reused buffer (no per-chunk allocation,
//! `O(chunk)` bytes crossing the feeder→device channel), and
//! resident-slot `igchunk_b*` executions pass the uploaded device
//! buffers by reference (`O(chunk)` host bytes total). Entries are
//! evicted on request settlement ([`GatherExec::evict_request`]).

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::exec::channel::{bounded, Sender};
use crate::exec::gather::{GatherExec, GatherLane, GatherOut};
use crate::exec::sync::atomic::{AtomicBool, Ordering};
use crate::exec::sync::{self, Mutex};
use crate::metrics::{Counter, Histogram};

use super::manifest::Manifest;

/// Which compiled executable a job targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExeKind {
    /// Batch-1 forward pass.
    Fwd1,
    /// Batch-16 forward pass.
    Fwd16,
    /// Single-request IG chunk, batch 1.
    IgChunk1,
    /// Single-request IG chunk, batch 16.
    IgChunk16,
    /// Cross-request IG chunk (per-lane endpoints/targets), batch 16.
    IgChunkMulti16,
}

impl ExeKind {
    /// The manifest key this executable is loaded under.
    pub fn manifest_name(&self) -> &'static str {
        match self {
            ExeKind::Fwd1 => "fwd_b1",
            ExeKind::Fwd16 => "fwd_b16",
            ExeKind::IgChunk1 => "igchunk_b1",
            ExeKind::IgChunk16 => "igchunk_b16",
            ExeKind::IgChunkMulti16 => "igchunk_m16",
        }
    }

    /// Every executable kind, in index order.
    pub const ALL: [ExeKind; 5] =
        [ExeKind::Fwd1, ExeKind::Fwd16, ExeKind::IgChunk1, ExeKind::IgChunk16, ExeKind::IgChunkMulti16];

    fn index(&self) -> usize {
        match self {
            ExeKind::Fwd1 => 0,
            ExeKind::Fwd16 => 1,
            ExeKind::IgChunk1 => 2,
            ExeKind::IgChunk16 => 3,
            ExeKind::IgChunkMulti16 => 4,
        }
    }
}

/// Fixed batch width of the `fwd_b16` / `igchunk_*16` executables.
const BATCH16: usize = 16;

/// One argument: flat f32 data + dims to reshape to (rank 1 or 2).
#[derive(Debug, Clone)]
pub struct Arg {
    /// Flat f32 payload.
    pub data: Vec<f32>,
    /// Target shape (rank 1 or 2).
    pub dims: Vec<usize>,
}

impl Arg {
    /// A rank-1 argument.
    pub fn vec(data: Vec<f32>) -> Arg {
        let n = data.len();
        Arg { data, dims: vec![n] }
    }

    /// A rank-2 argument (`rows * cols` must match the payload length).
    pub fn mat(data: Vec<f32>, rows: usize, cols: usize) -> Arg {
        assert_eq!(data.len(), rows * cols, "matrix arg size mismatch");
        Arg { data, dims: vec![rows, cols] }
    }
}

enum Job {
    /// Raw execution: args EXCLUDING the leading params (the device
    /// thread prepends the resident parameter buffer).
    Execute { kind: ExeKind, args: Vec<Arg>, reply: Sender<Result<Vec<Vec<f32>>>> },
    /// Execution whose `x`/`baseline` args are the resident device
    /// buffers of `slot` (args carry only the per-chunk remainder:
    /// alphas/weights/onehot — `O(chunk)` host bytes).
    ExecuteResident {
        kind: ExeKind,
        slot: u64,
        args: Vec<Arg>,
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    /// One gather-indexed cross-request chunk (`igchunk_m16`): per-lane
    /// records only; endpoints come from the resident pool.
    Gather { lanes: Vec<GatherLane>, reply: Sender<Result<GatherOut>> },
    /// Upload a request's endpoints into the resident pool.
    Register { slot: u64, x: Vec<f32>, baseline: Vec<f32>, reply: Sender<Result<()>> },
    /// Drop a request's resident entry (no-op for unknown slots).
    Evict { slot: u64 },
}

impl Job {
    /// Forward-only probes are latency-critical (they gate a request's
    /// schedule fan-out) and ~30x cheaper than gradient chunks, so they
    /// jump the device queue — as do resident-pool registrations and
    /// evictions, which gate admission/settlement and cost one buffer
    /// upload. PERF: without this, a sequential 5-boundary probe waits
    /// behind up to 5 in-flight ~30 ms gradient chunks.
    fn is_priority(&self) -> bool {
        match self {
            Job::Execute { kind, .. } => matches!(kind, ExeKind::Fwd1 | ExeKind::Fwd16),
            Job::ExecuteResident { .. } | Job::Gather { .. } => false,
            Job::Register { .. } | Job::Evict { .. } => true,
        }
    }
}

/// Cumulative per-executable execution statistics (shared, lock-free).
pub struct RuntimeStats {
    /// Executions per [`ExeKind`] (indexed by kind; gather chunks count
    /// under [`ExeKind::IgChunkMulti16`]).
    pub exec_count: [Counter; 5],
    /// Execution latency per [`ExeKind`] (indexed by kind).
    pub exec_latency: [Histogram; 5],
    /// Time jobs spent queued before the device picked them up.
    pub queue_wait: Histogram,
    /// Resident-pool registrations served.
    pub registrations: Counter,
    /// Resident-pool evictions served.
    pub evictions: Counter,
}

impl RuntimeStats {
    fn new() -> Self {
        RuntimeStats {
            exec_count: std::array::from_fn(|_| Counter::new()),
            exec_latency: std::array::from_fn(|_| Histogram::new_latency()),
            queue_wait: Histogram::new_latency(),
            registrations: Counter::new(),
            evictions: Counter::new(),
        }
    }

    /// Executions of `kind` so far.
    pub fn count(&self, kind: ExeKind) -> u64 {
        self.exec_count[kind.index()].get()
    }

    /// Latency histogram for `kind`.
    pub fn latency(&self, kind: ExeKind) -> &Histogram {
        &self.exec_latency[kind.index()]
    }

    /// Executions across all kinds.
    pub fn total_executions(&self) -> u64 {
        self.exec_count.iter().map(|c| c.get()).sum()
    }
}

/// Clonable handle to the device thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx_hi: Sender<Job>,
    tx_lo: Sender<Job>,
    stats: Arc<RuntimeStats>,
    features: usize,
    num_classes: usize,
    /// Live resident slots as seen from the handle side (inserted on
    /// successful register, removed on evict) — the coordinator's pool
    /// gauge without a device round-trip. Tracking slots rather than a
    /// counter keeps evictions of unknown slots exact no-ops (the
    /// [`GatherExec::evict_request`] contract): a double evict can
    /// never make the gauge under-report live registrations.
    resident: Arc<Mutex<HashSet<u64>>>,
    /// Cleared by a drop guard when the device thread's serve loop exits
    /// (clean shutdown *or* panic) — the liveness signal
    /// `ShardedRuntime` polls to classify a shard as dead and eligible
    /// for respawn.
    alive: Arc<AtomicBool>,
}

impl RuntimeHandle {
    /// Execute `kind` with `args` (params prepended device-side); returns
    /// the tuple outputs as flat f32 vectors. Forward probes take the
    /// priority queue (see `Job::is_priority`).
    pub fn execute(&self, kind: ExeKind, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        let (rtx, rrx) = bounded(1);
        self.send(Job::Execute { kind, args, reply: rtx })?;
        rrx.recv().map_err(|_| anyhow!("runtime device thread dropped the reply"))?
    }

    /// Execute `kind` against the resident endpoints of `slot`: the
    /// device passes the registered `x`/`baseline` buffers by reference
    /// and `args` carries only the per-chunk remainder (alphas, weights,
    /// onehot) — `O(chunk)` host bytes instead of `O(features)`. Valid
    /// for the `igchunk_b*` executables, whose first two (post-params)
    /// args are the endpoints. Fails if `slot` is not registered.
    pub fn execute_resident(
        &self,
        kind: ExeKind,
        slot: u64,
        args: Vec<Arg>,
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            matches!(kind, ExeKind::IgChunk1 | ExeKind::IgChunk16),
            "execute_resident only serves igchunk_b* executables, got {}",
            kind.manifest_name()
        );
        let (rtx, rrx) = bounded(1);
        self.send(Job::ExecuteResident { kind, slot, args, reply: rtx })?;
        rrx.recv().map_err(|_| anyhow!("runtime device thread dropped the reply"))?
    }

    fn send(&self, job: Job) -> Result<()> {
        let tx = if job.is_priority() { &self.tx_hi } else { &self.tx_lo };
        tx.send(job).map_err(|_| anyhow!("runtime device thread is down"))
    }

    /// Shared execution statistics.
    pub fn stats(&self) -> Arc<RuntimeStats> {
        self.stats.clone()
    }

    /// Model input width F.
    pub fn features(&self) -> usize {
        self.features
    }

    /// Model class count.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Whether the device thread behind this handle is still serving.
    /// Flips to `false` the moment the thread exits — clean shutdown or
    /// panic alike (a drop guard clears it on unwind).
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }
}

/// Clears the shared liveness flag when the device thread exits, however
/// it exits — the unwind path of a panicking FFI wrapper included.
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

impl GatherExec for RuntimeHandle {
    fn features(&self) -> usize {
        self.features
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        ensure!(rows >= 1 && rows <= BATCH16, "forward rows {rows} outside 1..={BATCH16}");
        ensure!(imgs.len() == rows * self.features, "probe batch size mismatch");
        if rows == 1 {
            let arg = Arg::mat(imgs.to_vec(), 1, self.features);
            let outs = self.execute(ExeKind::Fwd1, vec![arg])?;
            let mut probs = outs.into_iter().next().ok_or_else(|| anyhow!("empty fwd output"))?;
            probs.truncate(self.num_classes);
            Ok(probs)
        } else {
            // Pad to the fixed fwd_b16 width; padding rows are discarded.
            let mut flat = vec![0f32; BATCH16 * self.features];
            flat[..imgs.len()].copy_from_slice(imgs);
            let outs = self.execute(ExeKind::Fwd16, vec![Arg::mat(flat, BATCH16, self.features)])?;
            let mut probs = outs.into_iter().next().ok_or_else(|| anyhow!("empty fwd output"))?;
            probs.truncate(rows * self.num_classes);
            Ok(probs)
        }
    }

    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        ensure!(
            x.len() == self.features && baseline.len() == self.features,
            "endpoint width mismatch"
        );
        let (rtx, rrx) = bounded(1);
        self.send(Job::Register { slot, x: x.to_vec(), baseline: baseline.to_vec(), reply: rtx })?;
        rrx.recv()
            .map_err(|_| anyhow!("runtime device thread dropped the reply"))??;
        sync::lock(&self.resident).insert(slot);
        Ok(())
    }

    fn evict_request(&self, slot: u64) {
        // Unknown slots are exact no-ops; for known ones the device
        // eviction is best-effort (a dead device thread has already
        // dropped its pool, so the gauge removal alone is correct).
        if sync::lock(&self.resident).remove(&slot) {
            let _ = self.send(Job::Evict { slot });
        }
    }

    fn resident_len(&self) -> usize {
        sync::lock(&self.resident).len()
    }

    fn eval_gather(&self, _shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        let n = lanes.len();
        ensure!(n <= BATCH16, "gather chunk {n} exceeds device width {BATCH16}");
        let (rtx, rrx) = bounded(1);
        self.send(Job::Gather { lanes: lanes.to_vec(), reply: rtx })?;
        rrx.recv().map_err(|_| anyhow!("runtime device thread dropped the reply"))?
    }
}

/// Spawn the device thread: compile all executables, pin params, serve.
pub fn spawn(dir: &Path, manifest: &Manifest, params: Vec<f32>) -> Result<RuntimeHandle> {
    let (tx_hi, rx_hi) = bounded::<Job>(64);
    let (tx_lo, rx_lo) = bounded::<Job>(64);
    let stats = Arc::new(RuntimeStats::new());
    let stats2 = stats.clone();
    let dir = dir.to_path_buf();
    let features = manifest.features;
    let num_classes = manifest.num_classes;
    let manifest = manifest.clone();

    // Compile errors must reach the caller: report readiness over a
    // one-shot channel before entering the serve loop.
    let (ready_tx, ready_rx) = bounded::<Result<()>>(1);
    let alive = Arc::new(AtomicBool::new(true));
    let alive2 = alive.clone();

    std::thread::Builder::new()
        .name("nuig-device".to_string())
        .spawn(move || {
            let _guard = AliveGuard(alive2);
            let setup = (|| -> Result<Device> { Device::new(&dir, &manifest, params) })();
            match setup {
                Ok(device) => {
                    let _ = ready_tx.send(Ok(()));
                    device.serve(rx_hi, rx_lo, &stats2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        })
        .context("spawning device thread")?;

    ready_rx
        .recv()
        .map_err(|_| anyhow!("device thread died during setup"))??;

    Ok(RuntimeHandle {
        tx_hi,
        tx_lo,
        stats,
        features,
        num_classes,
        resident: Arc::new(Mutex::new(HashSet::new())),
        alive,
    })
}

/// One request's resident endpoints: device buffers (referenced by
/// resident-slot executions) plus host copies (staged into gather
/// chunks; the `igchunk_m16` executable takes concatenated
/// `chunk × features` endpoint matrices, so per-request device buffers
/// cannot feed it directly — see `docs/ARCHITECTURE.md` §resident).
struct Resident {
    x_host: Vec<f32>,
    b_host: Vec<f32>,
    x_dev: xla::PjRtBuffer,
    b_dev: xla::PjRtBuffer,
}

/// Reused gather staging: one set of `chunk`-shaped host buffers the
/// device thread fills from the resident pool per chunk — zero
/// steady-state allocation on the gather hot path.
struct GatherStaging {
    xs: Vec<f32>,
    bs: Vec<f32>,
    alphas: Vec<f32>,
    weights: Vec<f32>,
    onehots: Vec<f32>,
}

/// Device-side state (NOT Send; lives only on the device thread).
struct Device {
    client: xla::PjRtClient,
    exes: Vec<xla::PjRtLoadedExecutable>,
    /// Parameters resident on-device: uploaded once, passed by reference
    /// to every execution (PERF: saves a ~116 KiB host copy per exec vs
    /// rebuilding a params literal each time).
    params: xla::PjRtBuffer,
    features: usize,
    num_classes: usize,
    /// Chunk width of the cross-request executable (`igchunk_m16`).
    chunk: usize,
    /// Resident request endpoints by slot.
    resident: HashMap<u64, Resident>,
    staging: GatherStaging,
}

impl Device {
    fn new(dir: &Path, manifest: &Manifest, params: Vec<f32>) -> Result<Device> {
        let client = xla::PjRtClient::cpu().map_err(into_anyhow).context("creating PJRT CPU client")?;
        let mut exes = Vec::with_capacity(ExeKind::ALL.len());
        for kind in ExeKind::ALL {
            let meta = manifest
                .executables
                .get(kind.manifest_name())
                .ok_or_else(|| anyhow!("manifest missing {}", kind.manifest_name()))?;
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(into_anyhow)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(into_anyhow)
                .with_context(|| format!("compiling {}", kind.manifest_name()))?;
            exes.push(exe);
        }
        let n = params.len();
        let params = client
            .buffer_from_host_buffer(&params, &[n], None)
            .map_err(into_anyhow)
            .context("uploading params buffer")?;
        let features = manifest.features;
        let num_classes = manifest.num_classes;
        let chunk = manifest
            .executables
            .get(ExeKind::IgChunkMulti16.manifest_name())
            .map(|m| m.chunk)
            .unwrap_or(BATCH16);
        Ok(Device {
            client,
            exes,
            params,
            features,
            num_classes,
            chunk,
            resident: HashMap::new(),
            staging: GatherStaging {
                xs: vec![0f32; chunk * features],
                bs: vec![0f32; chunk * features],
                alphas: vec![0f32; chunk],
                weights: vec![0f32; chunk],
                onehots: vec![0f32; chunk * num_classes],
            },
        })
    }

    fn serve(
        mut self,
        rx_hi: crate::exec::channel::Receiver<Job>,
        rx_lo: crate::exec::channel::Receiver<Job>,
        stats: &RuntimeStats,
    ) {
        // Two-level priority: drain hi (forward probes, resident-pool
        // admin) before lo (gradient chunks); park briefly on lo when
        // both are empty so a newly-arrived hi job is picked up within
        // ~500 µs.
        let mut hi_closed = false;
        let mut lo_closed = false;
        while !(hi_closed && lo_closed) {
            let job = if !hi_closed {
                match rx_hi.try_recv() {
                    Ok(Some(j)) => Some(j),
                    Ok(None) => None,
                    Err(_) => {
                        hi_closed = true;
                        None
                    }
                }
            } else {
                None
            };
            let job = match job {
                Some(j) => j,
                None => {
                    if lo_closed {
                        // Only hi remains: block on it.
                        match rx_hi.recv() {
                            Ok(j) => j,
                            Err(_) => break,
                        }
                    } else {
                        match rx_lo.recv_timeout(std::time::Duration::from_micros(500)) {
                            Ok(Some(j)) => j,
                            Ok(None) => continue, // timeout: re-check hi
                            Err(_) => {
                                lo_closed = true;
                                continue;
                            }
                        }
                    }
                }
            };
            self.dispatch(job, stats);
        }
    }

    fn dispatch(&mut self, job: Job, stats: &RuntimeStats) {
        // Receivers may have given up (cancelled request): ignore send errors.
        match job {
            Job::Execute { kind, args, reply } => {
                let t0 = Instant::now();
                let result = self.run(kind, &args);
                stats.exec_count[kind.index()].inc();
                stats.exec_latency[kind.index()].record(t0.elapsed().as_secs_f64());
                let _ = reply.send(result);
            }
            Job::ExecuteResident { kind, slot, args, reply } => {
                let t0 = Instant::now();
                let result = self.run_resident(kind, slot, &args);
                stats.exec_count[kind.index()].inc();
                stats.exec_latency[kind.index()].record(t0.elapsed().as_secs_f64());
                let _ = reply.send(result);
            }
            Job::Gather { lanes, reply } => {
                let t0 = Instant::now();
                let result = self.run_gather(&lanes);
                let k = ExeKind::IgChunkMulti16;
                stats.exec_count[k.index()].inc();
                stats.exec_latency[k.index()].record(t0.elapsed().as_secs_f64());
                let _ = reply.send(result);
            }
            Job::Register { slot, x, baseline, reply } => {
                stats.registrations.inc();
                let _ = reply.send(self.register(slot, x, baseline));
            }
            Job::Evict { slot } => {
                stats.evictions.inc();
                self.resident.remove(&slot);
            }
        }
    }

    fn register(&mut self, slot: u64, x: Vec<f32>, baseline: Vec<f32>) -> Result<()> {
        ensure!(
            !self.resident.contains_key(&slot),
            "resident slot {slot} already registered"
        );
        let f = self.features;
        ensure!(x.len() == f && baseline.len() == f, "endpoint width mismatch");
        let x_dev = self
            .client
            .buffer_from_host_buffer(&x, &[f], None)
            .map_err(into_anyhow)
            .context("uploading resident x")?;
        let b_dev = self
            .client
            .buffer_from_host_buffer(&baseline, &[f], None)
            .map_err(into_anyhow)
            .context("uploading resident baseline")?;
        self.resident.insert(slot, Resident { x_host: x, b_host: baseline, x_dev, b_dev });
        Ok(())
    }

    fn run(&self, kind: ExeKind, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        // Upload job args as device buffers; params are already resident.
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            bufs.push(
                self.client
                    .buffer_from_host_buffer(&a.data, &a.dims, None)
                    .map_err(into_anyhow)?,
            );
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len() + 1);
        refs.push(&self.params);
        refs.extend(bufs.iter());
        self.execute_refs(kind, refs)
    }

    /// Execute `kind` with `slot`'s resident endpoint buffers spliced in
    /// as the first two post-params args (the `igchunk_b*` arg order:
    /// params, x, baseline, alphas, weights, onehot).
    fn run_resident(&self, kind: ExeKind, slot: u64, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let res = self
            .resident
            .get(&slot)
            .ok_or_else(|| anyhow!("resident slot {slot} not registered"))?;
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            bufs.push(
                self.client
                    .buffer_from_host_buffer(&a.data, &a.dims, None)
                    .map_err(into_anyhow)?,
            );
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len() + 3);
        refs.push(&self.params);
        refs.push(&res.x_dev);
        refs.push(&res.b_dev);
        refs.extend(bufs.iter());
        self.execute_refs(kind, refs)
    }

    /// One gather chunk: stage per-lane endpoints from the resident host
    /// copies into the reused `chunk × features` buffers, zero-pad the
    /// scalar lanes, execute `igchunk_m16`, and return the per-lane
    /// partial rows (padding rows excluded).
    ///
    /// Stale endpoint rows from the previous chunk are left in place for
    /// padding lanes: their weight and one-hot are zero, so they
    /// contribute exactly nothing (the same padding contract the
    /// pre-gather feeder relied on) and their output rows are discarded.
    fn run_gather(&mut self, lanes: &[GatherLane]) -> Result<GatherOut> {
        let f = self.features;
        let c = self.num_classes;
        let chunk = self.chunk;
        ensure!(lanes.len() <= chunk, "gather chunk {} exceeds device width {chunk}", lanes.len());
        for (k, lane) in lanes.iter().enumerate() {
            let res = self
                .resident
                .get(&lane.slot)
                .ok_or_else(|| anyhow!("resident slot {} not registered", lane.slot))?;
            ensure!(lane.target < c, "lane target {} out of range", lane.target);
            self.staging.xs[k * f..(k + 1) * f].copy_from_slice(&res.x_host);
            self.staging.bs[k * f..(k + 1) * f].copy_from_slice(&res.b_host);
            self.staging.alphas[k] = lane.alpha;
            self.staging.weights[k] = lane.weight;
            let row = &mut self.staging.onehots[k * c..(k + 1) * c];
            row.fill(0.0);
            row[lane.target] = 1.0;
        }
        for k in lanes.len()..chunk {
            self.staging.alphas[k] = 0.0;
            self.staging.weights[k] = 0.0;
            self.staging.onehots[k * c..(k + 1) * c].fill(0.0);
        }

        let upload = |data: &[f32], dims: &[usize]| -> Result<xla::PjRtBuffer> {
            self.client.buffer_from_host_buffer(data, dims, None).map_err(into_anyhow)
        };
        let xs = upload(&self.staging.xs, &[chunk, f])?;
        let bs = upload(&self.staging.bs, &[chunk, f])?;
        let alphas = upload(&self.staging.alphas, &[chunk])?;
        let weights = upload(&self.staging.weights, &[chunk])?;
        let onehots = upload(&self.staging.onehots, &[chunk, c])?;
        let refs = vec![&self.params, &xs, &bs, &alphas, &weights, &onehots];
        let outs = self.execute_refs(ExeKind::IgChunkMulti16, refs)?;
        let partials = outs.into_iter().next().ok_or_else(|| anyhow!("empty gather output"))?;
        ensure!(partials.len() >= lanes.len() * f, "bad gather partial width");
        Ok(GatherOut { rows: partials[..lanes.len() * f].to_vec(), features: f })
    }

    fn execute_refs(&self, kind: ExeKind, refs: Vec<&xla::PjRtBuffer>) -> Result<Vec<Vec<f32>>> {
        let exe = &self.exes[kind.index()];
        let result = exe.execute_b(&refs).map_err(into_anyhow)?;
        let tuple = result[0][0].to_literal_sync().map_err(into_anyhow)?;
        let outs = tuple.to_tuple().map_err(into_anyhow)?;
        outs.into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(into_anyhow))
            .collect()
    }
}

fn into_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}

// Unit tests for the pure parts; execution paths are covered by the
// integration tests in rust/tests/ (they need real artifacts).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exe_kind_names_stable() {
        assert_eq!(ExeKind::Fwd16.manifest_name(), "fwd_b16");
        assert_eq!(ExeKind::IgChunkMulti16.manifest_name(), "igchunk_m16");
        // index() must be a bijection onto 0..5.
        let mut seen = [false; 5];
        for k in ExeKind::ALL {
            assert!(!seen[k.index()]);
            seen[k.index()] = true;
        }
    }

    #[test]
    fn arg_constructors() {
        let a = Arg::vec(vec![1.0, 2.0]);
        assert_eq!(a.dims, vec![2]);
        let m = Arg::mat(vec![0.0; 6], 2, 3);
        assert_eq!(m.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn arg_mat_checks_size() {
        Arg::mat(vec![0.0; 5], 2, 3);
    }

    #[test]
    fn stats_zeroed() {
        let s = RuntimeStats::new();
        assert_eq!(s.total_executions(), 0);
        assert_eq!(s.count(ExeKind::Fwd1), 0);
        assert_eq!(s.registrations.get(), 0);
        assert_eq!(s.evictions.get(), 0);
    }

    #[test]
    fn job_priority_classes() {
        let (tx, _rx) = bounded::<Result<Vec<Vec<f32>>>>(1);
        let probe = Job::Execute { kind: ExeKind::Fwd1, args: vec![], reply: tx.clone() };
        assert!(probe.is_priority());
        let grad = Job::Execute { kind: ExeKind::IgChunk16, args: vec![], reply: tx.clone() };
        assert!(!grad.is_priority());
        let res =
            Job::ExecuteResident { kind: ExeKind::IgChunk16, slot: 0, args: vec![], reply: tx };
        assert!(!res.is_priority());
        let (gtx, _grx) = bounded::<Result<GatherOut>>(1);
        assert!(!Job::Gather { lanes: vec![], reply: gtx }.is_priority());
        let (rtx, _rrx) = bounded::<Result<()>>(1);
        assert!(Job::Register { slot: 1, x: vec![], baseline: vec![], reply: rtx }.is_priority());
        assert!(Job::Evict { slot: 1 }.is_priority());
    }
}
