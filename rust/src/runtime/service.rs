//! The device thread: owns the PJRT client + executables, executes jobs
//! from a channel. See `runtime/mod.rs` for why this is a single thread.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::exec::channel::{bounded, Sender};
use crate::metrics::{Counter, Histogram};

use super::manifest::Manifest;

/// Which compiled executable a job targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExeKind {
    /// Batch-1 forward pass.
    Fwd1,
    /// Batch-16 forward pass.
    Fwd16,
    /// Single-request IG chunk, batch 1.
    IgChunk1,
    /// Single-request IG chunk, batch 16.
    IgChunk16,
    /// Cross-request IG chunk (per-lane endpoints/targets), batch 16.
    IgChunkMulti16,
}

impl ExeKind {
    /// The manifest key this executable is loaded under.
    pub fn manifest_name(&self) -> &'static str {
        match self {
            ExeKind::Fwd1 => "fwd_b1",
            ExeKind::Fwd16 => "fwd_b16",
            ExeKind::IgChunk1 => "igchunk_b1",
            ExeKind::IgChunk16 => "igchunk_b16",
            ExeKind::IgChunkMulti16 => "igchunk_m16",
        }
    }

    /// Every executable kind, in index order.
    pub const ALL: [ExeKind; 5] =
        [ExeKind::Fwd1, ExeKind::Fwd16, ExeKind::IgChunk1, ExeKind::IgChunk16, ExeKind::IgChunkMulti16];

    fn index(&self) -> usize {
        match self {
            ExeKind::Fwd1 => 0,
            ExeKind::Fwd16 => 1,
            ExeKind::IgChunk1 => 2,
            ExeKind::IgChunk16 => 3,
            ExeKind::IgChunkMulti16 => 4,
        }
    }
}

/// One argument: flat f32 data + dims to reshape to (rank 1 or 2).
#[derive(Debug, Clone)]
pub struct Arg {
    /// Flat f32 payload.
    pub data: Vec<f32>,
    /// Target shape (rank 1 or 2).
    pub dims: Vec<usize>,
}

impl Arg {
    /// A rank-1 argument.
    pub fn vec(data: Vec<f32>) -> Arg {
        let n = data.len();
        Arg { data, dims: vec![n] }
    }

    /// A rank-2 argument (`rows * cols` must match the payload length).
    pub fn mat(data: Vec<f32>, rows: usize, cols: usize) -> Arg {
        assert_eq!(data.len(), rows * cols, "matrix arg size mismatch");
        Arg { data, dims: vec![rows, cols] }
    }
}

struct Job {
    kind: ExeKind,
    /// Args EXCLUDING the leading params (the device thread prepends the
    /// resident parameter buffer).
    args: Vec<Arg>,
    reply: Sender<Result<Vec<Vec<f32>>>>,
}

impl ExeKind {
    /// Forward-only probes are latency-critical (they gate a request's
    /// schedule fan-out) and ~30x cheaper than gradient chunks, so they
    /// jump the device queue. PERF: without this, a sequential 5-boundary
    /// probe waits behind up to 5 in-flight ~30 ms gradient chunks.
    fn is_priority(&self) -> bool {
        matches!(self, ExeKind::Fwd1 | ExeKind::Fwd16)
    }
}

/// Cumulative per-executable execution statistics (shared, lock-free).
pub struct RuntimeStats {
    /// Executions per [`ExeKind`] (indexed by kind).
    pub exec_count: [Counter; 5],
    /// Execution latency per [`ExeKind`] (indexed by kind).
    pub exec_latency: [Histogram; 5],
    /// Time jobs spent queued before the device picked them up.
    pub queue_wait: Histogram,
}

impl RuntimeStats {
    fn new() -> Self {
        RuntimeStats {
            exec_count: std::array::from_fn(|_| Counter::new()),
            exec_latency: std::array::from_fn(|_| Histogram::new_latency()),
            queue_wait: Histogram::new_latency(),
        }
    }

    /// Executions of `kind` so far.
    pub fn count(&self, kind: ExeKind) -> u64 {
        self.exec_count[kind.index()].get()
    }

    /// Latency histogram for `kind`.
    pub fn latency(&self, kind: ExeKind) -> &Histogram {
        &self.exec_latency[kind.index()]
    }

    /// Executions across all kinds.
    pub fn total_executions(&self) -> u64 {
        self.exec_count.iter().map(|c| c.get()).sum()
    }
}

/// Clonable handle to the device thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx_hi: Sender<Job>,
    tx_lo: Sender<Job>,
    stats: Arc<RuntimeStats>,
    features: usize,
    num_classes: usize,
}

impl RuntimeHandle {
    /// Execute `kind` with `args` (params prepended device-side); returns
    /// the tuple outputs as flat f32 vectors. Forward probes take the
    /// priority queue (see `ExeKind::is_priority`).
    pub fn execute(&self, kind: ExeKind, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        let (rtx, rrx) = bounded(1);
        let tx = if kind.is_priority() { &self.tx_hi } else { &self.tx_lo };
        tx.send(Job { kind, args, reply: rtx })
            .map_err(|_| anyhow!("runtime device thread is down"))?;
        rrx.recv().map_err(|_| anyhow!("runtime device thread dropped the reply"))?
    }

    /// Shared execution statistics.
    pub fn stats(&self) -> Arc<RuntimeStats> {
        self.stats.clone()
    }

    /// Model input width F.
    pub fn features(&self) -> usize {
        self.features
    }

    /// Model class count.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }
}

/// Spawn the device thread: compile all executables, pin params, serve.
pub fn spawn(dir: &Path, manifest: &Manifest, params: Vec<f32>) -> Result<RuntimeHandle> {
    let (tx_hi, rx_hi) = bounded::<Job>(64);
    let (tx_lo, rx_lo) = bounded::<Job>(64);
    let stats = Arc::new(RuntimeStats::new());
    let stats2 = stats.clone();
    let dir = dir.to_path_buf();
    let features = manifest.features;
    let num_classes = manifest.num_classes;
    let manifest = manifest.clone();

    // Compile errors must reach the caller: report readiness over a
    // one-shot channel before entering the serve loop.
    let (ready_tx, ready_rx) = bounded::<Result<()>>(1);

    std::thread::Builder::new()
        .name("nuig-device".to_string())
        .spawn(move || {
            let setup = (|| -> Result<Device> { Device::new(&dir, &manifest, params) })();
            match setup {
                Ok(device) => {
                    let _ = ready_tx.send(Ok(()));
                    device.serve(rx_hi, rx_lo, &stats2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        })
        .context("spawning device thread")?;

    ready_rx
        .recv()
        .map_err(|_| anyhow!("device thread died during setup"))??;

    Ok(RuntimeHandle { tx_hi, tx_lo, stats, features, num_classes })
}

/// Device-side state (NOT Send; lives only on the device thread).
struct Device {
    client: xla::PjRtClient,
    exes: Vec<xla::PjRtLoadedExecutable>,
    /// Parameters resident on-device: uploaded once, passed by reference
    /// to every execution (PERF: saves a ~116 KiB host copy per exec vs
    /// rebuilding a params literal each time).
    params: xla::PjRtBuffer,
}

impl Device {
    fn new(dir: &Path, manifest: &Manifest, params: Vec<f32>) -> Result<Device> {
        let client = xla::PjRtClient::cpu().map_err(into_anyhow).context("creating PJRT CPU client")?;
        let mut exes = Vec::with_capacity(ExeKind::ALL.len());
        for kind in ExeKind::ALL {
            let meta = manifest
                .executables
                .get(kind.manifest_name())
                .ok_or_else(|| anyhow!("manifest missing {}", kind.manifest_name()))?;
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(into_anyhow)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(into_anyhow)
                .with_context(|| format!("compiling {}", kind.manifest_name()))?;
            exes.push(exe);
        }
        let n = params.len();
        let params = client
            .buffer_from_host_buffer(&params, &[n], None)
            .map_err(into_anyhow)
            .context("uploading params buffer")?;
        Ok(Device { client, exes, params })
    }

    fn serve(
        self,
        rx_hi: crate::exec::channel::Receiver<Job>,
        rx_lo: crate::exec::channel::Receiver<Job>,
        stats: &RuntimeStats,
    ) {
        // Two-level priority: drain hi (forward probes) before lo
        // (gradient chunks); park briefly on lo when both are empty so a
        // newly-arrived hi job is picked up within ~500 µs.
        let mut hi_closed = false;
        let mut lo_closed = false;
        while !(hi_closed && lo_closed) {
            let job = if !hi_closed {
                match rx_hi.try_recv() {
                    Ok(Some(j)) => Some(j),
                    Ok(None) => None,
                    Err(_) => {
                        hi_closed = true;
                        None
                    }
                }
            } else {
                None
            };
            let job = match job {
                Some(j) => j,
                None => {
                    if lo_closed {
                        // Only hi remains: block on it.
                        match rx_hi.recv() {
                            Ok(j) => j,
                            Err(_) => break,
                        }
                    } else {
                        match rx_lo.recv_timeout(std::time::Duration::from_micros(500)) {
                            Ok(Some(j)) => j,
                            Ok(None) => continue, // timeout: re-check hi
                            Err(_) => {
                                lo_closed = true;
                                continue;
                            }
                        }
                    }
                }
            };
            let t0 = Instant::now();
            let result = self.run(job.kind, &job.args);
            stats.exec_count[job.kind.index()].inc();
            stats.exec_latency[job.kind.index()].record(t0.elapsed().as_secs_f64());
            // Receiver may have given up (cancelled request): ignore.
            let _ = job.reply.send(result);
        }
    }

    fn run(&self, kind: ExeKind, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let exe = &self.exes[kind.index()];
        // Upload job args as device buffers; params are already resident.
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            bufs.push(
                self.client
                    .buffer_from_host_buffer(&a.data, &a.dims, None)
                    .map_err(into_anyhow)?,
            );
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len() + 1);
        refs.push(&self.params);
        refs.extend(bufs.iter());
        let result = exe.execute_b(&refs).map_err(into_anyhow)?;
        let tuple = result[0][0].to_literal_sync().map_err(into_anyhow)?;
        let outs = tuple.to_tuple().map_err(into_anyhow)?;
        outs.into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(into_anyhow))
            .collect()
    }
}

fn into_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}

// Unit tests for the pure parts; execution paths are covered by the
// integration tests in rust/tests/ (they need real artifacts).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exe_kind_names_stable() {
        assert_eq!(ExeKind::Fwd16.manifest_name(), "fwd_b16");
        assert_eq!(ExeKind::IgChunkMulti16.manifest_name(), "igchunk_m16");
        // index() must be a bijection onto 0..5.
        let mut seen = [false; 5];
        for k in ExeKind::ALL {
            assert!(!seen[k.index()]);
            seen[k.index()] = true;
        }
    }

    #[test]
    fn arg_constructors() {
        let a = Arg::vec(vec![1.0, 2.0]);
        assert_eq!(a.dims, vec![2]);
        let m = Arg::mat(vec![0.0; 6], 2, 3);
        assert_eq!(m.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn arg_mat_checks_size() {
        Arg::mat(vec![0.0; 5], 2, 3);
    }

    #[test]
    fn stats_zeroed() {
        let s = RuntimeStats::new();
        assert_eq!(s.total_executions(), 0);
        assert_eq!(s.count(ExeKind::Fwd1), 0);
    }
}
