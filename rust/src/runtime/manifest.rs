//! The AOT manifest: the contract between `python/compile/aot.py` and this
//! runtime. Everything the Rust side needs to know about the artifacts —
//! shapes, arg order, model dimensions, checksums — crosses here, so a
//! stale or mismatched artifact directory fails at load with a pointed
//! error instead of garbage numerics.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::data::synth;
use crate::jsonio::Json;

/// Manifest version this runtime understands (bump in lockstep with
/// `python/compile/aot.py::MANIFEST_VERSION`).
pub const SUPPORTED_VERSION: usize = 3;

/// One executable's metadata.
#[derive(Debug, Clone)]
pub struct ExeMeta {
    /// Manifest key (e.g. `fwd_b16`).
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: PathBuf,
    /// "fwd" | "igchunk" | "igchunk_multi"
    pub kind: String,
    /// Batch/chunk width K.
    pub chunk: usize,
    /// Arg shapes in call order (name, flat length).
    pub args: Vec<(String, usize)>,
    /// Output shapes in tuple order (name, flat length).
    pub outputs: Vec<(String, usize)>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version (must equal [`SUPPORTED_VERSION`]).
    pub version: usize,
    /// Model input width F.
    pub features: usize,
    /// Model class count.
    pub num_classes: usize,
    /// Flat parameter count (length of `params.bin` / 4).
    pub num_params: usize,
    /// SHA-256 of `params.bin` as written by the AOT side.
    pub params_sha256: String,
    /// Cross-language corpus checksum (mean pixel over 2 images/class).
    pub corpus_checksum: f64,
    /// Executable metadata by manifest key.
    pub executables: BTreeMap<String, ExeMeta>,
    /// JAX version used at build time (provenance).
    pub jax_version: String,
}

impl Manifest {
    /// Load and validate `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        Self::from_json(&j)
    }

    /// Parse and validate a manifest from its JSON tree.
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let version = j.get("version")?.as_usize()?;
        ensure!(
            version == SUPPORTED_VERSION,
            "manifest version {version} != supported {SUPPORTED_VERSION}; re-run `make artifacts`"
        );
        let model = j.get("model")?;
        let corpus = j.get("corpus")?;

        let mut executables = BTreeMap::new();
        for (name, meta) in j.get("executables")?.as_obj()? {
            let parse_io = |key: &str| -> Result<Vec<(String, usize)>> {
                meta.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|a| {
                        let nm = a.get("name")?.as_str()?.to_string();
                        let shape = a.get("shape")?.as_usize_vec()?;
                        ensure!(
                            a.get("dtype")?.as_str()? == "f32",
                            "only f32 artifacts supported"
                        );
                        Ok((nm, shape.iter().product()))
                    })
                    .collect()
            };
            executables.insert(
                name.clone(),
                ExeMeta {
                    name: name.clone(),
                    file: PathBuf::from(meta.get("file")?.as_str()?),
                    kind: meta.get("kind")?.as_str()?.to_string(),
                    chunk: meta.get("chunk")?.as_usize()?,
                    args: parse_io("args").with_context(|| format!("executable {name}"))?,
                    outputs: parse_io("outputs").with_context(|| format!("executable {name}"))?,
                },
            );
        }

        let m = Manifest {
            version,
            features: model.get("features")?.as_usize()?,
            num_classes: model.get("num_classes")?.as_usize()?,
            num_params: model.get("num_params")?.as_usize()?,
            params_sha256: model.get("params_sha256")?.as_str()?.to_string(),
            corpus_checksum: corpus.get("checksum_per_class_2")?.as_f64()?,
            executables,
            jax_version: j
                .get_opt("jax_version")
                .and_then(|v| v.as_str().ok().map(String::from))
                .unwrap_or_default(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.features == synth::F, "manifest features {} != {}", self.features, synth::F);
        ensure!(
            self.num_classes == synth::NUM_CLASSES,
            "manifest classes {} != {}",
            self.num_classes,
            synth::NUM_CLASSES
        );
        for required in ["fwd_b1", "fwd_b16", "igchunk_b1", "igchunk_b16", "igchunk_m16"] {
            ensure!(
                self.executables.contains_key(required),
                "manifest missing executable {required:?}; re-run `make artifacts`"
            );
        }
        // Spot-check the igchunk contract the runtime hard-codes.
        let ig = &self.executables["igchunk_b16"];
        ensure!(ig.chunk == 16, "igchunk_b16 chunk {} != 16", ig.chunk);
        ensure!(ig.args.len() == 6, "igchunk_b16 expects 6 args, manifest says {}", ig.args.len());
        ensure!(ig.outputs.len() == 2, "igchunk_b16 expects 2 outputs");
        ensure!(ig.outputs[0].1 == self.features, "igchunk partial width mismatch");
        Ok(())
    }

    /// Load and length-check `params.bin` (little-endian f32).
    pub fn load_params(&self, dir: &Path) -> Result<Vec<f32>> {
        let path = dir.join("params.bin");
        let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != 4 * self.num_params {
            bail!(
                "params.bin is {} bytes, expected {} ({} f32)",
                bytes.len(),
                4 * self.num_params,
                self.num_params
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Re-derive the corpus checksum locally and compare — catches any
    /// drift between the Python and Rust synthetic generators.
    pub fn verify_corpus(&self) -> Result<()> {
        let local = synth::corpus_checksum(2);
        ensure!(
            (local - self.corpus_checksum).abs() < 1e-9,
            "corpus checksum mismatch: python wrote {}, rust derives {local} — \
             the synthetic generators have drifted",
            self.corpus_checksum
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn minimal_manifest_json() -> String {
        let exe = |name: &str, kind: &str, chunk: usize| {
            format!(
                r#""{name}": {{"file": "{name}.hlo.txt", "kind": "{kind}", "chunk": {chunk},
                 "args": [{{"name": "params", "shape": [29678], "dtype": "f32"}},
                          {{"name": "x", "shape": [3072], "dtype": "f32"}},
                          {{"name": "baseline", "shape": [3072], "dtype": "f32"}},
                          {{"name": "alphas", "shape": [{chunk}], "dtype": "f32"}},
                          {{"name": "weights", "shape": [{chunk}], "dtype": "f32"}},
                          {{"name": "onehot", "shape": [8], "dtype": "f32"}}],
                 "outputs": [{{"name": "partial", "shape": [3072], "dtype": "f32"}},
                             {{"name": "probs", "shape": [{chunk}, 8], "dtype": "f32"}}]}}"#
            )
        };
        format!(
            r#"{{"version": 3,
                "model": {{"features": 3072, "num_classes": 8, "num_params": 29678,
                           "params_sha256": "ab"}},
                "corpus": {{"checksum_per_class_2": {}}},
                "executables": {{{}, {}, {}, {}, {}}},
                "jax_version": "0.8.2"}}"#,
            synth::corpus_checksum(2),
            exe("fwd_b1", "fwd", 1),
            exe("fwd_b16", "fwd", 16),
            exe("igchunk_b1", "igchunk", 1),
            exe("igchunk_b16", "igchunk", 16),
            exe("igchunk_m16", "igchunk_multi", 16),
        )
    }

    #[test]
    fn parses_minimal() {
        let j = jsonio::parse(&minimal_manifest_json()).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.features, 3072);
        assert_eq!(m.executables.len(), 5);
        assert_eq!(m.executables["igchunk_b16"].args[0].1, 29678);
        m.verify_corpus().unwrap();
    }

    #[test]
    fn rejects_wrong_version() {
        let s = minimal_manifest_json().replace("\"version\": 3", "\"version\": 99");
        let j = jsonio::parse(&s).unwrap();
        let err = Manifest::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_missing_executable() {
        let s = minimal_manifest_json().replace("igchunk_m16", "renamed_exe");
        let j = jsonio::parse(&s).unwrap();
        let err = Manifest::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("igchunk_m16"), "{err}");
    }

    #[test]
    fn rejects_wrong_features() {
        let s = minimal_manifest_json().replace("\"features\": 3072", "\"features\": 100");
        let j = jsonio::parse(&s).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn corpus_mismatch_detected() {
        let s = minimal_manifest_json();
        let j = jsonio::parse(&s).unwrap();
        let mut m = Manifest::from_json(&j).unwrap();
        m.corpus_checksum += 0.1;
        assert!(m.verify_corpus().is_err());
    }

    #[test]
    fn load_params_length_check() {
        let dir = std::env::temp_dir().join("nuig_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("params.bin"), [0u8; 12]).unwrap();
        let j = jsonio::parse(&minimal_manifest_json()).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        let err = m.load_params(&dir).unwrap_err().to_string();
        assert!(err.contains("12 bytes"), "{err}");
    }
}
