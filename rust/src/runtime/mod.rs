//! PJRT runtime: load the AOT artifacts and serve executions from
//! dedicated device threads.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), which is an
//! accurate model of the underlying device anyway: one accelerator, one
//! submission stream. The runtime therefore spawns ONE device thread per
//! **shard** that owns its client, compiled executables, resident
//! parameter literal, and resident request pool; everything else talks
//! to it through a channel of jobs. On CPU-PJRT this costs one channel
//! hop (~µs) per multi-millisecond execution and lets XLA's intra-op
//! thread pool own the cores.
//!
//! A default [`Runtime::load`] spawns one shard; [`Runtime::load_sharded`]
//! spawns several independent device threads (each compiles its own
//! executable set), and [`Runtime::sharded_backend`] wraps them as one
//! [`GatherExec`] surface the coordinator's feeder workers spread over —
//! registration broadcasts to every shard (any feeder may execute any
//! request's chunk), gather chunks route to the caller's shard.
//!
//! Loading path (see /opt/xla-example/README.md for the gotchas):
//! HLO **text** → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile`. The AOT side lowers with `return_tuple=True`, so
//! every executable returns a tuple literal that the device thread
//! unpacks into flat `f32` vectors.

mod manifest;
mod pjrt_model;
mod service;

pub use manifest::{ExeMeta, Manifest};
pub use pjrt_model::{PjrtModel, ProbeMode, PROBE_BATCH_CROSSOVER};
pub use service::{Arg, ExeKind, RuntimeHandle, RuntimeStats};

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::exec::gather::{GatherExec, GatherLane, GatherOut, ResidentPool, ShardHealth};
use crate::exec::sync::{self, Mutex};

/// A loaded runtime: manifest + one or more live device threads.
///
/// The artifact directory and params payload are retained after load:
/// they are the respawn recipe — [`Runtime::sharded_backend`] hands them
/// to the [`ShardedRuntime`] so a dead device shard can be re-spawned
/// and its resident tensors replayed without re-reading artifacts.
pub struct Runtime {
    /// The parsed AOT manifest the artifacts were loaded against.
    pub manifest: Manifest,
    handles: Vec<RuntimeHandle>,
    dir: PathBuf,
    params: Vec<f32>,
}

impl Runtime {
    /// Load manifest, params and all executables from `dir`; verify the
    /// cross-language corpus checksum.
    pub fn load_default<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        Self::load(dir, true)
    }

    /// Load with optional corpus verification (benches skip it to start
    /// faster; tests exercise both paths).
    pub fn load<P: AsRef<Path>>(dir: P, verify_corpus: bool) -> Result<Runtime> {
        Self::load_sharded(dir, verify_corpus, 1)
    }

    /// Load with `devices` independent device shards: each shard is its
    /// own device thread with its own PJRT client and compiled
    /// executables (the client is not `Send`, so sharding is the only
    /// way to open several submission streams). Artifacts are read once;
    /// compilation runs per shard.
    pub fn load_sharded<P: AsRef<Path>>(
        dir: P,
        verify_corpus: bool,
        devices: usize,
    ) -> Result<Runtime> {
        ensure!(devices >= 1, "devices must be >= 1");
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir).with_context(|| {
            format!(
                "loading AOT manifest from {} (run `make artifacts` first)",
                dir.display()
            )
        })?;
        if verify_corpus {
            manifest.verify_corpus()?;
        }
        // Read the params payload once; each shard's device thread takes
        // its own copy (it uploads and then owns a device buffer).
        let params = manifest.load_params(dir)?;
        let mut handles = Vec::with_capacity(devices);
        for shard in 0..devices {
            handles.push(
                service::spawn(dir, &manifest, params.clone())
                    .with_context(|| format!("spawning device shard {shard}"))?,
            );
        }
        Ok(Runtime { manifest, handles, dir: dir.to_path_buf(), params })
    }

    /// Handle for raw executions on the first shard (the engines and
    /// single-device tools use this directly).
    pub fn handle(&self) -> RuntimeHandle {
        self.handles[0].clone()
    }

    /// Live device shards.
    pub fn shards(&self) -> usize {
        self.handles.len()
    }

    /// An [`crate::ig::Model`] over this runtime's first shard (default
    /// probe mode).
    pub fn model(&self) -> PjrtModel {
        PjrtModel::new(self.handle(), self.manifest.features, self.manifest.num_classes)
    }

    /// Cumulative execution statistics of the first device shard.
    pub fn stats(&self) -> Arc<RuntimeStats> {
        self.handles[0].stats()
    }

    /// Per-shard execution statistics.
    pub fn shard_stats(&self) -> Vec<Arc<RuntimeStats>> {
        self.handles.iter().map(|h| h.stats()).collect()
    }

    /// A [`GatherExec`] backend over the first `devices` shards — what
    /// `Coordinator::start` drives. Fails if fewer shards are loaded
    /// than asked for (load with [`Runtime::load_sharded`]). The backend
    /// carries the respawn recipe (artifact dir, manifest, params) plus a
    /// host-copy [`ResidentPool`], so a dead shard can be re-spawned with
    /// every live registration replayed ([`GatherExec::respawn_shard`]).
    pub fn sharded_backend(&self, devices: usize) -> Result<ShardedRuntime> {
        ensure!(devices >= 1, "devices must be >= 1");
        ensure!(
            devices <= self.handles.len(),
            "runtime has {} device shard(s) but {devices} were requested; load with Runtime::load_sharded",
            self.handles.len()
        );
        Ok(ShardedRuntime {
            shards: self.handles[..devices]
                .iter()
                .map(|h| ShardSlot {
                    handle: Mutex::new(h.clone()),
                    draining: AtomicBool::new(false),
                })
                .collect(),
            pool: ResidentPool::new(),
            respawner: Respawner {
                dir: self.dir.clone(),
                manifest: self.manifest.clone(),
                params: self.params.clone(),
            },
            next_probe: AtomicUsize::new(0),
        })
    }
}

/// The recipe for bringing up a fresh device shard: everything
/// `service::spawn` needs, retained from load time.
struct Respawner {
    dir: PathBuf,
    manifest: Manifest,
    params: Vec<f32>,
}

/// One shard's mutable lifecycle state: the (swappable) device-thread
/// handle plus the administrative drain fence. The handle mutex is held
/// only to clone the handle (or, rarely, across a respawn swap) — never
/// across a device execution.
struct ShardSlot {
    handle: Mutex<RuntimeHandle>,
    draining: AtomicBool,
}

impl ShardSlot {
    fn handle(&self) -> RuntimeHandle {
        sync::lock(&self.handle).clone()
    }
}

/// A [`GatherExec`] over several device shards: registration broadcasts
/// to every live shard (a chunk may execute anywhere), gather chunks
/// route to the caller's shard, probes round-robin over live shards.
///
/// Implements the full elastic lifecycle (`docs/ARCHITECTURE.md` §"Shard
/// lifecycle"): [`GatherExec::shard_health`] reports per-shard
/// live/draining/dead state (a dead device thread is detected through
/// [`RuntimeHandle::is_alive`]), [`GatherExec::drain_shard`] fences a
/// shard from new gather chunks so the coordinator's feeder failover
/// migrates them to siblings, and [`GatherExec::respawn_shard`] spawns a
/// fresh device thread and replays every live resident registration into
/// it from the host-copy pool — no stranded slots
/// (`docs/INVARIANTS.md` §I8).
pub struct ShardedRuntime {
    shards: Vec<ShardSlot>,
    /// Host-copy replay source: registration lands here first, so a
    /// respawn can re-upload every live request's endpoints even though
    /// the dead device thread took its own copies with it.
    pool: ResidentPool,
    respawner: Respawner,
    next_probe: AtomicUsize,
}

impl GatherExec for ShardedRuntime {
    fn features(&self) -> usize {
        self.shards[0].handle().features()
    }

    fn num_classes(&self) -> usize {
        self.shards[0].handle().num_classes()
    }

    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        // Round-robin probes across shards so stage 1 does not serialize
        // on shard 0 while gradient chunks spread; dead shards are
        // skipped (draining ones still probe — the drain fence covers
        // gather chunks only).
        let n = self.shards.len();
        let k = self.next_probe.fetch_add(1, Ordering::Relaxed) % n;
        for off in 0..n {
            let handle = self.shards[(k + off) % n].handle();
            if handle.is_alive() {
                return handle.forward(imgs, rows);
            }
        }
        bail!("no live device shard to serve the probe")
    }

    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        // Host copy first: it is the replay source, and ordering it
        // before the broadcast means a concurrent respawn either sees
        // the slot in its pool snapshot or blocks the broadcast on the
        // handle lock until the fresh handle is in place — no window
        // where the slot can strand.
        self.pool.register(slot, x, baseline)?;
        for (k, shard) in self.shards.iter().enumerate() {
            let handle = shard.handle();
            if let Err(e) = handle.register_request(slot, x, baseline) {
                if !handle.is_alive() {
                    // Dead shard: skipped now, replayed at respawn.
                    continue;
                }
                if e.to_string().contains("already registered") {
                    // A concurrent respawn replayed this slot between our
                    // pool insert and this broadcast — the slot IS
                    // resident, which is the goal. (Genuine duplicates
                    // are caught by the pool insert above, before any
                    // broadcast.)
                    continue;
                }
                // Roll back the shards that already admitted the slot so
                // a failed registration leaves no orphan residents.
                for done in &self.shards[..k] {
                    done.handle().evict_request(slot);
                }
                self.pool.evict(slot);
                return Err(e);
            }
        }
        Ok(())
    }

    fn evict_request(&self, slot: u64) {
        self.pool.evict(slot);
        for shard in &self.shards {
            shard.handle().evict_request(slot);
        }
    }

    fn resident_len(&self) -> usize {
        // The host-copy pool is the authoritative gauge: broadcast may
        // legitimately skip dead shards, so per-shard counts can lag.
        self.pool.len()
    }

    fn shards(&self) -> usize {
        self.shards.len()
    }

    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        let idx = shard % self.shards.len();
        let slot = &self.shards[idx];
        if slot.draining.load(Ordering::SeqCst) {
            bail!("shard {idx} is draining");
        }
        slot.handle().eval_gather(0, lanes)
    }

    fn shard_health(&self, shard: usize) -> ShardHealth {
        let idx = shard % self.shards.len();
        let slot = &self.shards[idx];
        if !slot.handle().is_alive() {
            ShardHealth::Dead
        } else if slot.draining.load(Ordering::SeqCst) {
            ShardHealth::Draining
        } else {
            ShardHealth::Live
        }
    }

    fn drain_shard(&self, shard: usize) {
        let idx = shard % self.shards.len();
        self.shards[idx].draining.store(true, Ordering::SeqCst);
    }

    fn respawn_shard(&self, shard: usize) -> Result<()> {
        let idx = shard % self.shards.len();
        let slot = &self.shards[idx];
        // Hold the handle lock across the whole respawn: concurrent
        // respawners serialize (no double spawn), and a concurrent
        // registration broadcast blocks here until the fresh handle is
        // in place (see register_request's ordering argument).
        let mut handle = sync::lock(&slot.handle);
        if handle.is_alive() {
            // Nothing to respawn; treat as an un-drain.
            slot.draining.store(false, Ordering::SeqCst);
            return Ok(());
        }
        let rs = &self.respawner;
        let fresh = service::spawn(&rs.dir, &rs.manifest, rs.params.clone())
            .with_context(|| format!("respawning device shard {idx}"))?;
        let replayed = self.pool.snapshot_sorted();
        for (slot_id, entry) in &replayed {
            fresh
                .register_request(*slot_id, &entry.0, &entry.1)
                .with_context(|| format!("replaying resident slot {slot_id} into shard {idx}"))?;
        }
        // A request that settled mid-replay evicted its pool entry but
        // may already have been replayed; sweep those out so the fresh
        // shard holds exactly the live set.
        for (slot_id, _) in &replayed {
            if self.pool.entry(*slot_id).is_none() {
                fresh.evict_request(*slot_id);
            }
        }
        *handle = fresh;
        slot.draining.store(false, Ordering::SeqCst);
        Ok(())
    }
}

impl ShardedRuntime {
    /// Whether `shard`'s device thread is still serving (liveness probe
    /// for admin surfaces; [`GatherExec::shard_health`] folds this into
    /// the lifecycle state).
    pub fn shard_alive(&self, shard: usize) -> bool {
        self.shards[shard % self.shards.len()].handle().is_alive()
    }
}
