//! PJRT runtime: load the AOT artifacts and serve executions from a
//! dedicated device thread.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), which is an
//! accurate model of the underlying device anyway: one accelerator, one
//! submission stream. The runtime therefore spawns ONE device thread that
//! owns the client, the compiled executables, and the resident parameter
//! literal; everything else talks to it through a channel of [`Job`]s.
//! On CPU-PJRT this costs one channel hop (~µs) per multi-millisecond
//! execution and lets XLA's intra-op thread pool own the cores.
//!
//! Loading path (see /opt/xla-example/README.md for the gotchas):
//! HLO **text** → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile`. The AOT side lowers with `return_tuple=True`, so
//! every executable returns a tuple literal that the device thread
//! unpacks into flat `f32` vectors.

mod manifest;
mod pjrt_model;
mod service;

pub use manifest::{ExeMeta, Manifest};
pub use pjrt_model::{PjrtModel, ProbeMode, PROBE_BATCH_CROSSOVER};
pub use service::{Arg, ExeKind, RuntimeHandle, RuntimeStats};

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

/// A loaded runtime: manifest + live device thread.
pub struct Runtime {
    /// The parsed AOT manifest the artifacts were loaded against.
    pub manifest: Manifest,
    handle: RuntimeHandle,
}

impl Runtime {
    /// Load manifest, params and all executables from `dir`; verify the
    /// cross-language corpus checksum.
    pub fn load_default<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        Self::load(dir, true)
    }

    /// Load with optional corpus verification (benches skip it to start
    /// faster; tests exercise both paths).
    pub fn load<P: AsRef<Path>>(dir: P, verify_corpus: bool) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir).with_context(|| {
            format!(
                "loading AOT manifest from {} (run `make artifacts` first)",
                dir.display()
            )
        })?;
        if verify_corpus {
            manifest.verify_corpus()?;
        }
        let params = manifest.load_params(dir)?;
        let handle = service::spawn(dir, &manifest, params)?;
        Ok(Runtime { manifest, handle })
    }

    /// Handle for raw executions (the coordinator uses this directly).
    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    /// An [`crate::ig::Model`] over this runtime (default probe mode).
    pub fn model(&self) -> PjrtModel {
        PjrtModel::new(self.handle.clone(), self.manifest.features, self.manifest.num_classes)
    }

    /// Cumulative execution statistics from the device thread.
    pub fn stats(&self) -> Arc<RuntimeStats> {
        self.handle.stats()
    }
}
