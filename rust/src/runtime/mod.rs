//! PJRT runtime: load the AOT artifacts and serve executions from
//! dedicated device threads.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), which is an
//! accurate model of the underlying device anyway: one accelerator, one
//! submission stream. The runtime therefore spawns ONE device thread per
//! **shard** that owns its client, compiled executables, resident
//! parameter literal, and resident request pool; everything else talks
//! to it through a channel of jobs. On CPU-PJRT this costs one channel
//! hop (~µs) per multi-millisecond execution and lets XLA's intra-op
//! thread pool own the cores.
//!
//! A default [`Runtime::load`] spawns one shard; [`Runtime::load_sharded`]
//! spawns several independent device threads (each compiles its own
//! executable set), and [`Runtime::sharded_backend`] wraps them as one
//! [`GatherExec`] surface the coordinator's feeder workers spread over —
//! registration broadcasts to every shard (any feeder may execute any
//! request's chunk), gather chunks route to the caller's shard.
//!
//! Loading path (see /opt/xla-example/README.md for the gotchas):
//! HLO **text** → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile`. The AOT side lowers with `return_tuple=True`, so
//! every executable returns a tuple literal that the device thread
//! unpacks into flat `f32` vectors.

mod manifest;
mod pjrt_model;
mod service;

pub use manifest::{ExeMeta, Manifest};
pub use pjrt_model::{PjrtModel, ProbeMode, PROBE_BATCH_CROSSOVER};
pub use service::{Arg, ExeKind, RuntimeHandle, RuntimeStats};

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::exec::gather::{GatherExec, GatherLane, GatherOut};

/// A loaded runtime: manifest + one or more live device threads.
pub struct Runtime {
    /// The parsed AOT manifest the artifacts were loaded against.
    pub manifest: Manifest,
    handles: Vec<RuntimeHandle>,
}

impl Runtime {
    /// Load manifest, params and all executables from `dir`; verify the
    /// cross-language corpus checksum.
    pub fn load_default<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        Self::load(dir, true)
    }

    /// Load with optional corpus verification (benches skip it to start
    /// faster; tests exercise both paths).
    pub fn load<P: AsRef<Path>>(dir: P, verify_corpus: bool) -> Result<Runtime> {
        Self::load_sharded(dir, verify_corpus, 1)
    }

    /// Load with `devices` independent device shards: each shard is its
    /// own device thread with its own PJRT client and compiled
    /// executables (the client is not `Send`, so sharding is the only
    /// way to open several submission streams). Artifacts are read once;
    /// compilation runs per shard.
    pub fn load_sharded<P: AsRef<Path>>(
        dir: P,
        verify_corpus: bool,
        devices: usize,
    ) -> Result<Runtime> {
        ensure!(devices >= 1, "devices must be >= 1");
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir).with_context(|| {
            format!(
                "loading AOT manifest from {} (run `make artifacts` first)",
                dir.display()
            )
        })?;
        if verify_corpus {
            manifest.verify_corpus()?;
        }
        // Read the params payload once; each shard's device thread takes
        // its own copy (it uploads and then owns a device buffer).
        let params = manifest.load_params(dir)?;
        let mut handles = Vec::with_capacity(devices);
        for shard in 0..devices {
            handles.push(
                service::spawn(dir, &manifest, params.clone())
                    .with_context(|| format!("spawning device shard {shard}"))?,
            );
        }
        Ok(Runtime { manifest, handles })
    }

    /// Handle for raw executions on the first shard (the engines and
    /// single-device tools use this directly).
    pub fn handle(&self) -> RuntimeHandle {
        self.handles[0].clone()
    }

    /// Live device shards.
    pub fn shards(&self) -> usize {
        self.handles.len()
    }

    /// An [`crate::ig::Model`] over this runtime's first shard (default
    /// probe mode).
    pub fn model(&self) -> PjrtModel {
        PjrtModel::new(self.handle(), self.manifest.features, self.manifest.num_classes)
    }

    /// Cumulative execution statistics of the first device shard.
    pub fn stats(&self) -> Arc<RuntimeStats> {
        self.handles[0].stats()
    }

    /// Per-shard execution statistics.
    pub fn shard_stats(&self) -> Vec<Arc<RuntimeStats>> {
        self.handles.iter().map(|h| h.stats()).collect()
    }

    /// A [`GatherExec`] backend over the first `devices` shards — what
    /// `Coordinator::start` drives. Fails if fewer shards are loaded
    /// than asked for (load with [`Runtime::load_sharded`]).
    pub fn sharded_backend(&self, devices: usize) -> Result<ShardedRuntime> {
        ensure!(devices >= 1, "devices must be >= 1");
        ensure!(
            devices <= self.handles.len(),
            "runtime has {} device shard(s) but {devices} were requested; load with Runtime::load_sharded",
            self.handles.len()
        );
        Ok(ShardedRuntime {
            shards: self.handles[..devices].to_vec(),
            next_probe: AtomicUsize::new(0),
        })
    }
}

/// A [`GatherExec`] over several device shards: registration broadcasts
/// to every shard (a chunk may execute anywhere), gather chunks route to
/// the caller's shard, probes round-robin.
pub struct ShardedRuntime {
    shards: Vec<RuntimeHandle>,
    next_probe: AtomicUsize,
}

impl GatherExec for ShardedRuntime {
    fn features(&self) -> usize {
        self.shards[0].features()
    }

    fn num_classes(&self) -> usize {
        self.shards[0].num_classes()
    }

    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        // Round-robin probes across shards so stage 1 does not serialize
        // on shard 0 while gradient chunks spread.
        let k = self.next_probe.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.shards[k].forward(imgs, rows)
    }

    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        for (k, shard) in self.shards.iter().enumerate() {
            if let Err(e) = shard.register_request(slot, x, baseline) {
                // Roll back the shards that already admitted the slot so
                // a failed registration leaves no orphan residents.
                for done in &self.shards[..k] {
                    done.evict_request(slot);
                }
                return Err(e);
            }
        }
        Ok(())
    }

    fn evict_request(&self, slot: u64) {
        for shard in &self.shards {
            shard.evict_request(slot);
        }
    }

    fn resident_len(&self) -> usize {
        // Registration is broadcast, so any shard's count is the pool
        // gauge; use the first.
        self.shards[0].resident_len()
    }

    fn shards(&self) -> usize {
        self.shards.len()
    }

    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        self.shards[shard % self.shards.len()].eval_gather(0, lanes)
    }
}
