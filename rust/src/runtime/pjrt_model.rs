//! [`crate::ig::Model`] implementation over the PJRT runtime: chunking,
//! padding, and f64 accumulation around the raw executables.

use anyhow::{ensure, Result};

use crate::exec::batch::{BatchExec, BatchOut, BatchPlan};
use crate::exec::gather::GatherExec;
use crate::ig::model::{eval_points, IgPointsOut, Model};

use super::service::{Arg, ExeKind, RuntimeHandle};

/// How stage-1 probes (and `probs` generally) hit the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeMode {
    /// Cost-based (default): sequential `fwd_b1` below the crossover
    /// batch size, padded `fwd_b16` above it. PERF: on CPU-PJRT a padded
    /// lane costs real compute (~0.75 ms), so a 5-boundary probe is ~2x
    /// cheaper as five batch-1 calls (5 x ~1.0 ms) than as one padded
    /// batch-16 call (~12 ms). See docs/EXPERIMENTS.md §Perf.
    Auto,
    /// Always pack into `fwd_b16` (padding unused lanes).
    Batched,
    /// One `fwd_b1` call per image — the paper's literal protocol ("we
    /// run the inference pass through the network n_int + 1 times"),
    /// kept for the Fig. 6b overhead-scaling reproduction.
    Sequential,
}

/// Batch size at/above which padded `fwd_b16` beats sequential `fwd_b1`
/// (measured crossover: 16 x ~0.75ms/lane batched vs ~1.0ms/call).
pub const PROBE_BATCH_CROSSOVER: usize = 12;

/// The serving-path model: MiniInception via AOT executables.
pub struct PjrtModel {
    handle: RuntimeHandle,
    features: usize,
    num_classes: usize,
    /// How `probs` batches onto the device (see [`ProbeMode`]).
    pub probe_mode: ProbeMode,
    /// Chunk width of the batched executables (16, from the manifest).
    pub chunk: usize,
}

impl PjrtModel {
    /// Wrap a runtime handle with the model dimensions (default probe mode).
    pub fn new(handle: RuntimeHandle, features: usize, num_classes: usize) -> PjrtModel {
        PjrtModel { handle, features, num_classes, probe_mode: ProbeMode::Auto, chunk: 16 }
    }

    /// Builder: override the probe batching mode.
    pub fn with_probe_mode(mut self, mode: ProbeMode) -> PjrtModel {
        self.probe_mode = mode;
        self
    }

    /// Upload a request's endpoints to the device once; point streams
    /// evaluated through [`crate::ig::model::eval_points_resident`] with
    /// this slot then skip the per-chunk `x`/baseline upload (the
    /// resident-tensor path — `O(chunk)` host bytes per device chunk).
    /// Pair with [`PjrtModel::evict_request`] when the request settles.
    pub fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        self.handle.register_request(slot, x, baseline)
    }

    /// Release a slot registered with [`PjrtModel::register_request`].
    pub fn evict_request(&self, slot: u64) {
        self.handle.evict_request(slot);
    }

    fn probs_batched(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>> {
        let mut out = Vec::with_capacity(imgs.len());
        for group in imgs.chunks(self.chunk) {
            let mut flat = vec![0f32; self.chunk * self.features];
            for (k, img) in group.iter().enumerate() {
                flat[k * self.features..(k + 1) * self.features].copy_from_slice(img);
            }
            let outs = self
                .handle
                .execute(ExeKind::Fwd16, vec![Arg::mat(flat, self.chunk, self.features)])?;
            let probs = &outs[0];
            for k in 0..group.len() {
                out.push(
                    probs[k * self.num_classes..(k + 1) * self.num_classes]
                        .iter()
                        .map(|&v| v as f64)
                        .collect(),
                );
            }
        }
        Ok(out)
    }

    fn probs_sequential(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>> {
        imgs.iter()
            .map(|img| {
                let outs = self
                    .handle
                    .execute(ExeKind::Fwd1, vec![Arg::mat(img.to_vec(), 1, self.features)])?;
                Ok(outs[0].iter().map(|&v| v as f64).collect())
            })
            .collect()
    }
}

impl Model for PjrtModel {
    fn features(&self) -> usize {
        self.features
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn probs(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>> {
        for img in imgs {
            ensure!(img.len() == self.features, "image width {} != {}", img.len(), self.features);
        }
        match self.probe_mode {
            ProbeMode::Auto => {
                if imgs.len() < PROBE_BATCH_CROSSOVER {
                    self.probs_sequential(imgs)
                } else {
                    self.probs_batched(imgs)
                }
            }
            ProbeMode::Batched => self.probs_batched(imgs),
            ProbeMode::Sequential => self.probs_sequential(imgs),
        }
    }

    fn ig_points(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> Result<IgPointsOut> {
        // The canonical chunked order, sequentially (the batched backend's
        // execution chunks are multiples of the device width, so the
        // device-call sequence is unchanged from the pre-batch path).
        eval_points(self, x, baseline, alphas, weights, target, &BatchExec::Sequential)
    }

    /// The device batch kernel: the chunk's point stream packed into
    /// `igchunk_b16` calls, ragged tails padded with zero-weight lanes
    /// (exactly no contribution; validated by the kernel tests on both
    /// sides), f64 accumulation across device chunks in stream order.
    ///
    /// With `plan.slot` set (endpoints registered via
    /// [`PjrtModel::register_request`]) the per-device-chunk payload is
    /// only alphas/weights/onehot — the resident `x`/baseline device
    /// buffers are passed by reference, so host bytes per chunk drop
    /// from `O(features)` to `O(chunk)`. The device-side arithmetic is
    /// identical either way (same executable, same buffers' contents),
    /// so attributions are bit-identical across the two paths
    /// (artifact-gated test in `tests/runtime_artifacts.rs`).
    fn eval_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchOut> {
        ensure!(
            plan.x.len() == self.features && plan.baseline.len() == self.features,
            "endpoint width mismatch"
        );
        ensure!(plan.alphas.len() == plan.weights.len(), "alpha/weight length mismatch");
        ensure!(plan.target < self.num_classes, "target {} out of range", plan.target);

        let mut onehot = vec![0f32; self.num_classes];
        onehot[plan.target] = 1.0;

        let mut partial = vec![0f64; self.features];
        let mut target_probs = Vec::with_capacity(plan.len());

        for (a_chunk, w_chunk) in plan.alphas.chunks(self.chunk).zip(plan.weights.chunks(self.chunk))
        {
            let n = a_chunk.len();
            let mut a = vec![0f32; self.chunk];
            let mut w = vec![0f32; self.chunk];
            a[..n].copy_from_slice(a_chunk);
            w[..n].copy_from_slice(w_chunk);

            let outs = match plan.slot {
                Some(slot) => self.handle.execute_resident(
                    ExeKind::IgChunk16,
                    slot,
                    vec![Arg::vec(a), Arg::vec(w), Arg::vec(onehot.clone())],
                )?,
                None => self.handle.execute(
                    ExeKind::IgChunk16,
                    vec![
                        Arg::vec(plan.x.to_vec()),
                        Arg::vec(plan.baseline.to_vec()),
                        Arg::vec(a),
                        Arg::vec(w),
                        Arg::vec(onehot.clone()),
                    ],
                )?,
            };
            let chunk_partial = &outs[0];
            let probs = &outs[1];
            ensure!(chunk_partial.len() == self.features, "bad partial width");
            for (acc, &v) in partial.iter_mut().zip(chunk_partial) {
                *acc += v as f64;
            }
            for k in 0..n {
                target_probs.push(probs[k * self.num_classes + plan.target] as f64);
            }
        }
        Ok(BatchOut { partial, target_probs })
    }
}

// Execution-path tests live in rust/tests/runtime_artifacts.rs (need real
// artifacts); here we only cover pure helpers via the public contract.
#[cfg(test)]
mod tests {
    #[test]
    fn probe_mode_is_copy_eq() {
        use super::ProbeMode;
        let m = ProbeMode::Batched;
        let n = m;
        assert_eq!(m, n);
        assert_ne!(ProbeMode::Batched, ProbeMode::Sequential);
    }
}
