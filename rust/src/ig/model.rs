//! The model abstraction the IG engines run against.
//!
//! Two implementations exist:
//!
//! * `runtime::PjrtModel` — the real thing: AOT-compiled MiniInception
//!   executables on the PJRT CPU client (serving path).
//! * [`AnalyticModel`] — a closed-form softmax-linear classifier in pure
//!   Rust, with *exact* gradients. It exists so the engine, coordinator,
//!   and allocator can be tested and benched without artifacts, and so
//!   convergence claims can be checked against analytically-known
//!   integrals (logits are exactly linear in α along a black-baseline
//!   path — the same positive-homogeneity the zero-bias MiniInception
//!   has, so the path behaviour matches the real model family).
//!
//! # Batched evaluation
//!
//! The stage-2 hot path goes through [`eval_points`]: the fused point
//! stream is sharded into fixed-size chunks
//! ([`exec::batch`](crate::exec::batch)), each chunk evaluated via
//! [`Model::eval_batch`], and the chunk partials reduced **in chunk
//! order** — so attributions are bit-identical at any worker count (the
//! determinism contract the schedule-cache goldens and the Python parity
//! suite rely on). Models with a native batch kernel ([`AnalyticModel`],
//! `runtime::PjrtModel`) override `eval_batch`; everything else (test
//! doubles, ablation models) rides the default shim over
//! [`Model::ig_points`].
//!
//! All f32 inner loops — interpolation, logit dots, gradient
//! accumulation — run through the fixed-width lane kernels in
//! [`exec::simd`](crate::exec::simd); the logit dot's lane-major
//! reduction order is the canonical one every backend (scalar
//! reference, portable, AVX2/NEON) computes bit-identically
//! (docs/INVARIANTS.md §I13).

use anyhow::{ensure, Result};

use crate::exec::batch::{self, BatchExec, BatchOut, BatchPlan, ScratchArena};
use crate::exec::gather::{GatherExec, GatherLane, GatherOut, ResidentPool};
use crate::exec::simd;

/// A differentiable classifier the IG engines can drive.
///
/// Implementations must be thread-safe (`Sync`): the coordinator calls
/// them from worker threads, and [`eval_points`] may shard a request's
/// chunks across the pool.
pub trait Model: Sync {
    /// Flat input width F the model consumes.
    fn features(&self) -> usize;
    /// Number of output classes.
    fn num_classes(&self) -> usize;

    /// Class probabilities for a batch of flat images.
    fn probs(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>>;

    /// The IG inner loop over one request's points: compute
    /// `Σ_k w_k · ∂p_target/∂x |_{α_k} ⊙ (x − x')` plus the target-class
    /// probability at every point.
    ///
    /// Implementations chunk internally to their executable width (zero
    /// weight ⇒ padding lane ⇒ exactly no contribution). The engines do
    /// not call this directly anymore — they go through [`eval_points`],
    /// which shards onto [`Model::eval_batch`]; this method remains the
    /// required building block the default `eval_batch` shim rides on
    /// (and the convenient whole-stream entry for tests and tools).
    fn ig_points(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> Result<IgPointsOut>;

    /// Evaluate one contiguous chunk of the fused point stream into a
    /// chunk-local partial (the batched backend's unit of work).
    ///
    /// The default shim delegates to [`Model::ig_points`], so existing
    /// implementations — the engine tests' `Recorder`, the batching
    /// ablation's batch-1 model — participate in the chunked backend
    /// unchanged. Backends with a native batch kernel override it.
    fn eval_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchOut> {
        let out = self.ig_points(plan.x, plan.baseline, plan.alphas, plan.weights, plan.target)?;
        Ok(BatchOut { partial: out.partial, target_probs: out.target_probs })
    }
}

/// Output of [`Model::ig_points`].
#[derive(Debug, Clone)]
pub struct IgPointsOut {
    /// (F,) partial attribution, f64-accumulated.
    pub partial: Vec<f64>,
    /// Target-class probability at each requested point.
    pub target_probs: Vec<f64>,
}

/// Evaluate a fused point stream through the batched execution backend —
/// THE stage-2 entry point every engine uses.
///
/// The stream is sharded into `exec.chunk()`-sized chunks
/// ([`batch::chunk_spans`]), each chunk evaluated via
/// [`Model::eval_batch`] (inline, or fanned out across the pool under
/// [`BatchExec::Parallel`]), and the chunk partials reduced in chunk
/// order. For a fixed chunk size the result is **bit-identical at any
/// worker count** — see the `exec::batch` module doc for the full
/// determinism contract. A chunk that panics on the pool fails the
/// stream with `Err` after its siblings settle; the pool and concurrent
/// requests survive.
pub fn eval_points(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    alphas: &[f32],
    weights: &[f32],
    target: usize,
    exec: &BatchExec,
) -> Result<IgPointsOut> {
    eval_points_at(model, x, baseline, alphas, weights, target, exec, None)
}

/// [`eval_points`] over endpoints already **resident** with the executing
/// backend: identical chunking/reduction semantics, but each chunk's
/// [`BatchPlan`] carries `slot`, so backends with a resident-tensor path
/// (e.g. `runtime::PjrtModel`) pass the registered device buffers by
/// reference instead of re-uploading `x`/`baseline` per chunk — the host
/// bytes moved per chunk drop from `O(chunk × features)` to `O(chunk)`.
/// The caller still provides the endpoint slices (they size validation
/// and serve backends without residency unchanged).
#[allow(clippy::too_many_arguments)]
pub fn eval_points_resident(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    alphas: &[f32],
    weights: &[f32],
    target: usize,
    exec: &BatchExec,
    slot: u64,
) -> Result<IgPointsOut> {
    eval_points_at(model, x, baseline, alphas, weights, target, exec, Some(slot))
}

#[allow(clippy::too_many_arguments)]
fn eval_points_at(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    alphas: &[f32],
    weights: &[f32],
    target: usize,
    exec: &BatchExec,
    slot: Option<u64>,
) -> Result<IgPointsOut> {
    ensure!(
        x.len() == model.features() && baseline.len() == model.features(),
        "bad endpoint widths"
    );
    ensure!(alphas.len() == weights.len(), "alpha/weight length mismatch");
    ensure!(target < model.num_classes(), "target {target} out of range");
    let out = batch::run_chunks(exec, alphas.len(), model.features(), |start, len| {
        model.eval_batch(&BatchPlan {
            x,
            baseline,
            alphas: &alphas[start..start + len],
            weights: &weights[start..start + len],
            target,
            slot,
        })
    })?;
    Ok(IgPointsOut { partial: out.partial, target_probs: out.target_probs })
}

/// Closed-form test model: `p = softmax(gain · W · x / F)` with fixed
/// pseudo-random per-class weight vectors.
///
/// Gradient (exact): `∂p_t/∂x_i = p_t (W_{t,i} − Σ_c p_c W_{c,i}) · gain / F`.
pub struct AnalyticModel {
    features: usize,
    classes: usize,
    /// (classes × features) row-major weights.
    w: Vec<f32>,
    gain: f64,
}

impl AnalyticModel {
    /// Deterministic weights from `seed`; `gain` tunes softmax saturation
    /// along the path (≈12 mimics the calibrated MiniInception).
    pub fn new(features: usize, classes: usize, seed: u64, gain: f64) -> AnalyticModel {
        let mut w = Vec::with_capacity(features * classes);
        for c in 0..classes {
            for i in 0..features {
                let z = crate::data::synth::mix64(
                    seed ^ (c as u64).wrapping_mul(0x9E3779B97F4A7C15)
                        ^ (i as u64).wrapping_mul(0xBF58476D1CE4E5B9),
                );
                // Uniform in [-1, 1).
                w.push(((z >> 40) as f32 / 8388608.0) - 1.0);
            }
        }
        AnalyticModel { features, classes, w, gain }
    }

    /// Standard test instance matching the corpus dimensions.
    pub fn standard() -> AnalyticModel {
        AnalyticModel::new(crate::data::synth::F, crate::data::synth::NUM_CLASSES, 0xA11CE, 12.0)
    }

    fn logits(&self, x: &[f32]) -> Vec<f64> {
        let f = self.features;
        (0..self.classes)
            .map(|c| {
                // Lane-major canonical dot (docs/INVARIANTS.md §I13):
                // every caller — scalar reference, batched kernel, any
                // dispatch backend — computes this exact addend order.
                let dot = simd::dot_f32(&self.w[c * f..(c + 1) * f], x);
                self.gain * dot / f as f64
            })
            .collect()
    }

    fn softmax(logits: &[f64]) -> Vec<f64> {
        // nuig:allow(float-reduce): max is order-independent (single NaN-free reduction)
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let s: f64 = e.iter().sum();
        e.iter().map(|v| v / s).collect()
    }

    /// Exact gradient of p_target w.r.t. x at the given point.
    ///
    /// `wavg_i = Σ_c p_c W_{c,i}` accumulates class-major through the
    /// lane-blocked [`simd::accum_scaled`]: per feature the addend order
    /// over classes is the sequential class order (each class adds once,
    /// in order, starting from 0.0), identical to the per-feature sum it
    /// replaces — elementwise per `i`, so lane width cannot change bits.
    pub fn grad(&self, x: &[f32], target: usize) -> Vec<f64> {
        let p = Self::softmax(&self.logits(x));
        let f = self.features;
        let scale = self.gain / f as f64;
        let mut wavg = vec![0f64; f];
        for (c, &pc) in p.iter().enumerate() {
            simd::accum_scaled(&mut wavg, pc, &self.w[c * f..(c + 1) * f]);
        }
        let trow = &self.w[target * f..(target + 1) * f];
        wavg.iter()
            .zip(trow)
            .map(|(&avg, &wt)| p[target] * (wt as f64 - avg) * scale)
            .collect()
    }

    /// The pre-batch scalar reference kernel: one point at a time, a
    /// fresh scratch image and gradient `Vec` per point, one global f64
    /// accumulator — exactly what `ig_points` dispatched before the
    /// batched backend existed.
    ///
    /// Kept public on purpose: it is the oracle the batched kernel's
    /// property tests compare against (bit-identical within a single
    /// chunk, ≤ f64-reassociation distance across chunks) and the
    /// `fig_hotpath` bench's sequential baseline.
    ///
    /// Its dot products go through [`logits`](Self::logits) →
    /// [`simd::dot_f32`], so the reference itself computes the
    /// canonical lane-major reduction order — the anchor every
    /// backend's bits are pinned to (docs/INVARIANTS.md §I13).
    pub fn ig_points_scalar(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> Result<IgPointsOut> {
        ensure!(x.len() == self.features && baseline.len() == self.features, "bad endpoint widths");
        ensure!(alphas.len() == weights.len(), "alpha/weight length mismatch");
        ensure!(target < self.classes, "target {target} out of range");
        let f = self.features;
        let mut partial = vec![0f64; f];
        let mut target_probs = Vec::with_capacity(alphas.len());
        let mut point = vec![0f32; f];
        for (&a, &wgt) in alphas.iter().zip(weights) {
            for i in 0..f {
                point[i] = baseline[i] + a * (x[i] - baseline[i]);
            }
            let p = Self::softmax(&self.logits(&point));
            target_probs.push(p[target]);
            if wgt != 0.0 {
                let g = self.grad(&point, target);
                for i in 0..f {
                    partial[i] += wgt as f64 * g[i] * (x[i] - baseline[i]) as f64;
                }
            }
        }
        Ok(IgPointsOut { partial, target_probs })
    }

    /// Weight row of class `c` — the `(F,)` slice the logit dot runs
    /// over. Exposed so `fig_hotpath` can clock the lane kernels on
    /// the model's real operands.
    pub fn class_row(&self, c: usize) -> &[f32] {
        let f = self.features;
        &self.w[c * f..(c + 1) * f]
    }
}

impl Model for AnalyticModel {
    fn features(&self) -> usize {
        self.features
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn probs(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>> {
        imgs.iter()
            .map(|img| {
                ensure!(img.len() == self.features, "bad image width {}", img.len());
                Ok(Self::softmax(&self.logits(img)))
            })
            .collect()
    }

    fn ig_points(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> Result<IgPointsOut> {
        // The canonical chunked order, sequentially: bit-identical to any
        // parallel evaluation of the same stream.
        eval_points(self, x, baseline, alphas, weights, target, &BatchExec::Sequential)
    }

    /// The batched kernel: planar [`PointBatch`](batch::PointBatch) fill
    /// (interpolation fused into the write), per-worker scratch arena for
    /// logits/softmax/gradient intermediates, and width-[`simd::LANES`]
    /// lane kernels ([`simd::dot_f32`] / [`simd::accum_scaled`] /
    /// [`simd::accum_grad`]) for every f32 inner loop, with f64
    /// accumulation — and zero per-point allocations.
    ///
    /// Arithmetic is the scalar reference kernel's, in the same per-point
    /// order and the same lane-major dot-reduction order, so a
    /// single-chunk stream reproduces
    /// [`AnalyticModel::ig_points_scalar`] to the bit — on every dispatch
    /// backend (docs/INVARIANTS.md §I13).
    fn eval_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchOut> {
        let f = self.features;
        let c = self.classes;
        ensure!(plan.x.len() == f && plan.baseline.len() == f, "bad endpoint widths");
        ensure!(plan.alphas.len() == plan.weights.len(), "alpha/weight length mismatch");
        ensure!(plan.target < c, "target {} out of range", plan.target);

        let n = plan.len();
        let scale = self.gain / f as f64;
        let mut partial = vec![0f64; f];
        let mut target_probs = Vec::with_capacity(n);
        ScratchArena::with(|arena| {
            // One planar fill for the whole chunk: x′ + α(x − x′) goes
            // straight into the reused buffer, no per-point image Vec.
            arena.batch.fill(plan.x, plan.baseline, plan.alphas);
            arena.logits.resize(c, 0.0);
            arena.probs.resize(c, 0.0);
            arena.wavg.resize(f, 0.0);

            for (k, &wgt) in plan.weights.iter().enumerate() {
                let row = arena.batch.row(k);

                // Logits: the canonical lane-major dot, class by class —
                // the exact reduction order the scalar kernel computes.
                for cc in 0..c {
                    let dot = simd::dot_f32(&self.w[cc * f..(cc + 1) * f], row);
                    arena.logits[cc] = self.gain * dot / f as f64;
                }

                // Softmax in f64, into the reused probs slot.
                // nuig:allow(float-reduce): max is order-independent (single NaN-free reduction)
                let mx = arena.logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut sum = 0f64;
                for cc in 0..c {
                    let e = (arena.logits[cc] - mx).exp();
                    arena.probs[cc] = e;
                    sum += e;
                }
                for p in arena.probs.iter_mut() {
                    *p /= sum;
                }
                target_probs.push(arena.probs[plan.target]);

                if wgt != 0.0 {
                    // wavg_i = Σ_c p_c W_{c,i}, accumulated class-major in
                    // lane blocks; per feature the addend order over
                    // classes matches the scalar kernel's sum exactly.
                    for v in arena.wavg.iter_mut() {
                        *v = 0.0;
                    }
                    for cc in 0..c {
                        let wrow = &self.w[cc * f..(cc + 1) * f];
                        simd::accum_scaled(&mut arena.wavg, arena.probs[cc], wrow);
                    }
                    // Gradient × (x − x′) fused into the accumulate: the
                    // scalar kernel's `w · g_i · (x_i − x′_i)` expression,
                    // without materializing g.
                    let trow = &self.w[plan.target * f..(plan.target + 1) * f];
                    simd::accum_grad(
                        &mut partial,
                        wgt as f64,
                        arena.probs[plan.target],
                        scale,
                        trow,
                        &arena.wavg,
                        plan.x,
                        plan.baseline,
                    );
                }
            }
        });
        Ok(BatchOut { partial, target_probs })
    }
}

/// Serving-path execution backend over the closed-form
/// [`AnalyticModel`]: implements [`GatherExec`] with a host-side
/// [`ResidentPool`], so the whole coordinator — gather-indexed chunks,
/// resident registration/eviction, sharded feeders — is testable and
/// benchable without artifacts (`tests/sharded_feeder.rs`,
/// `benches/fig_serving.rs`).
///
/// A lane's output row mirrors the device kernel's per-lane semantics
/// exactly: `row_k = w_k · ∂p_{t_k}/∂x|_{α_k} ⊙ (x_k − x′_k)` computed in
/// f64, cast to f32 — a pure function of the lane alone, never of its
/// chunk neighbours or the executing shard (the gather determinism
/// contract; see `exec::gather`).
pub struct AnalyticExec {
    model: AnalyticModel,
    pool: ResidentPool,
    shards: usize,
}

impl AnalyticExec {
    /// A single-shard backend over `model`.
    pub fn new(model: AnalyticModel) -> AnalyticExec {
        AnalyticExec::with_shards(model, 1)
    }

    /// A backend advertising `shards` submission streams. All shards
    /// evaluate on the same in-process model (there is no per-shard state
    /// to diverge), so this only spreads the coordinator's feeders — the
    /// analytic stand-in for a multi-device runtime.
    pub fn with_shards(model: AnalyticModel, shards: usize) -> AnalyticExec {
        assert!(shards >= 1, "shards must be >= 1");
        AnalyticExec { model, pool: ResidentPool::new(), shards }
    }

    /// The wrapped model (engine-side parity checks in tests/benches).
    pub fn model(&self) -> &AnalyticModel {
        &self.model
    }
}

impl GatherExec for AnalyticExec {
    fn features(&self) -> usize {
        self.model.features()
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        let f = self.model.features();
        ensure!(imgs.len() == rows * f, "probe batch size mismatch");
        let mut out = Vec::with_capacity(rows * self.model.num_classes());
        for r in 0..rows {
            let probs = self.model.probs(&[&imgs[r * f..(r + 1) * f]])?;
            out.extend(probs[0].iter().map(|&v| v as f32));
        }
        Ok(out)
    }

    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        let f = self.model.features();
        ensure!(x.len() == f && baseline.len() == f, "endpoint width mismatch");
        self.pool.register(slot, x, baseline)
    }

    fn evict_request(&self, slot: u64) {
        self.pool.evict(slot);
    }

    fn resident_len(&self) -> usize {
        self.pool.len()
    }

    fn shards(&self) -> usize {
        self.shards
    }

    fn eval_gather(&self, _shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        let f = self.model.features();
        let c = self.model.num_classes();
        let mut rows = vec![0f32; lanes.len() * f];
        let mut point = vec![0f32; f];
        for (k, lane) in lanes.iter().enumerate() {
            ensure!(lane.target < c, "lane target {} out of range", lane.target);
            // Grab the endpoints as a shared entry — the pool lock is
            // released before the gradient runs, so concurrent shards'
            // gather work never serializes on the pool.
            let entry = self
                .pool
                .entry(lane.slot)
                .ok_or_else(|| anyhow::anyhow!("resident slot {} not registered", lane.slot))?;
            let (x, b) = (&entry.0, &entry.1);
            for i in 0..f {
                point[i] = b[i] + lane.alpha * (x[i] - b[i]);
            }
            if lane.weight != 0.0 {
                let g = self.model.grad(&point, lane.target);
                let row = &mut rows[k * f..(k + 1) * f];
                let w64 = lane.weight as f64;
                for i in 0..f {
                    row[i] = (w64 * g[i] * (x[i] - b[i]) as f64) as f32;
                }
            }
        }
        Ok(GatherOut { rows, features: f })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ThreadPool;
    use crate::testutil::{self, TestRng};
    use std::sync::Arc;

    fn tiny() -> AnalyticModel {
        AnalyticModel::new(8, 3, 42, 6.0)
    }

    #[test]
    fn probs_normalized() {
        let m = tiny();
        let x = vec![0.5f32; 8];
        let p = &m.probs(&[&x]).unwrap()[0];
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn zero_input_uniform_probs() {
        let m = tiny();
        let p = &m.probs(&[&vec![0f32; 8]]).unwrap()[0];
        for &v in p {
            assert!((v - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        let m = tiny();
        let x: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let g = m.grad(&x, 1);
        let eps = 1e-4f32;
        for i in 0..8 {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let pp = m.probs(&[&xp]).unwrap()[0][1];
            let pm = m.probs(&[&xm]).unwrap()[0][1];
            let fd = (pp - pm) / (2.0 * eps as f64);
            // f32 inputs + central difference: ~1e-4-scale agreement.
            assert!((g[i] - fd).abs() < 2e-4, "feature {i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn ig_points_zero_weights_no_contribution() {
        let m = tiny();
        let x = vec![0.7f32; 8];
        let b = vec![0f32; 8];
        let out = m.ig_points(&x, &b, &[0.5, 0.9], &[0.0, 0.0], 0).unwrap();
        assert!(out.partial.iter().all(|&v| v == 0.0));
        assert_eq!(out.target_probs.len(), 2);
    }

    #[test]
    fn ig_points_weight_linearity() {
        let m = tiny();
        let x = vec![0.7f32; 8];
        let b = vec![0f32; 8];
        let o1 = m.ig_points(&x, &b, &[0.5], &[0.25], 0).unwrap();
        let o2 = m.ig_points(&x, &b, &[0.5], &[0.5], 0).unwrap();
        for i in 0..8 {
            assert!((o2.partial[i] - 2.0 * o1.partial[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn saturation_along_path() {
        // gain high enough that p(target) saturates before alpha = 1.
        let m = AnalyticModel::new(64, 4, 7, 40.0);
        let x: Vec<f32> = (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect();
        let p1 = m.probs(&[&x]).unwrap()[0].clone();
        let target = p1
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let b = vec![0f32; 64];
        let out = m
            .ig_points(&x, &b, &[0.0, 0.25, 0.5, 0.75, 1.0], &[0.0; 5], target)
            .unwrap();
        let c = &out.target_probs;
        let total = c[4] - c[0];
        assert!(total > 0.1, "path must climb: {c:?}");
        assert!((c[2] - c[0]) / total > 0.5, "early concentration expected: {c:?}");
    }

    #[test]
    fn deterministic_weights() {
        let a = AnalyticModel::new(8, 3, 42, 6.0);
        let b = AnalyticModel::new(8, 3, 42, 6.0);
        assert_eq!(a.w, b.w);
        let c = AnalyticModel::new(8, 3, 43, 6.0);
        assert_ne!(a.w, c.w);
    }

    #[test]
    fn validation_errors() {
        let m = tiny();
        assert!(m.probs(&[&vec![0f32; 4]]).is_err());
        let x = vec![0f32; 8];
        assert!(m.ig_points(&x, &x, &[0.5], &[0.5, 0.5], 0).is_err());
        assert!(m.ig_points(&x, &x, &[0.5], &[0.5], 9).is_err());
        assert!(m.ig_points(&x, &vec![0f32; 4], &[0.5], &[0.5], 0).is_err());
        assert!(m.ig_points_scalar(&x, &x, &[0.5], &[0.5, 0.5], 0).is_err());
        assert!(m.ig_points_scalar(&x, &x, &[0.5], &[0.5], 9).is_err());
    }

    // ---- Batched-kernel properties ------------------------------------

    fn rand_stream(rng: &mut TestRng, n: usize) -> (Vec<f32>, Vec<f32>) {
        let alphas = rng.vec_f32(n, 0.0, 1.0);
        let mut weights = rng.vec_f32(n, -0.1, 0.3);
        // Sprinkle exact zeros: forward-only points must stay free.
        for k in 0..n {
            if rng.bool() && k % 5 == 0 {
                weights[k] = 0.0;
            }
        }
        (alphas, weights)
    }

    #[test]
    fn batched_kernel_matches_scalar_bitwise_within_one_chunk() {
        // A single chunk accumulates per point in the scalar order, so
        // the batched kernel must reproduce the scalar reference to the
        // bit (0 ULP) for any stream that fits one chunk.
        let m = AnalyticModel::new(48, 5, 9, 20.0);
        testutil::prop(20, 4141, |rng| {
            let x = rng.vec_f32(48, 0.0, 1.0);
            let b = rng.vec_f32(48, 0.0, 0.5);
            let n = rng.range(0, batch::DEFAULT_CHUNK + 1);
            let (alphas, weights) = rand_stream(rng, n);
            let target = rng.range(0, 5);
            let scalar = m.ig_points_scalar(&x, &b, &alphas, &weights, target).unwrap();
            let batched = m.ig_points(&x, &b, &alphas, &weights, target).unwrap();
            assert_eq!(batched.target_probs, scalar.target_probs);
            for i in 0..48 {
                assert_eq!(
                    batched.partial[i].to_bits(),
                    scalar.partial[i].to_bits(),
                    "feature {i}: {} vs {}",
                    batched.partial[i],
                    scalar.partial[i]
                );
            }
        });
    }

    #[test]
    fn batched_kernel_matches_scalar_across_chunks_to_reassociation() {
        // Across chunk boundaries the f64 sum re-associates; agreement
        // stays at round-off scale.
        let m = AnalyticModel::new(32, 4, 11, 30.0);
        let mut rng = TestRng::new(77);
        let x = rng.vec_f32(32, 0.0, 1.0);
        let b = vec![0f32; 32];
        let n = 3 * batch::DEFAULT_CHUNK + 17;
        let (alphas, weights) = rand_stream(&mut rng, n);
        let scalar = m.ig_points_scalar(&x, &b, &alphas, &weights, 1).unwrap();
        let batched = m.ig_points(&x, &b, &alphas, &weights, 1).unwrap();
        assert_eq!(batched.target_probs, scalar.target_probs);
        testutil::assert_allclose(&batched.partial, &scalar.partial, 1e-11, 1e-14);
    }

    #[test]
    fn parallel_eval_points_bit_identical_at_any_worker_count() {
        // The determinism contract: same chunk size ⇒ same bits, whether
        // the chunks run inline or on 1/2/4/8 workers.
        let m = AnalyticModel::new(40, 4, 5, 25.0);
        let mut rng = TestRng::new(2024);
        let x = rng.vec_f32(40, 0.0, 1.0);
        let b = rng.vec_f32(40, 0.0, 0.3);
        let n = 5 * batch::DEFAULT_CHUNK + 3;
        let (alphas, weights) = rand_stream(&mut rng, n);
        let seq = eval_points(&m, &x, &b, &alphas, &weights, 2, &BatchExec::Sequential).unwrap();
        for workers in [1usize, 2, 4, 8] {
            let pool = Arc::new(ThreadPool::new(workers));
            let par =
                eval_points(&m, &x, &b, &alphas, &weights, 2, &BatchExec::parallel(pool)).unwrap();
            assert_eq!(par.target_probs, seq.target_probs, "workers={workers}");
            for i in 0..40 {
                assert_eq!(
                    par.partial[i].to_bits(),
                    seq.partial[i].to_bits(),
                    "workers={workers} feature {i}"
                );
            }
        }
    }

    #[test]
    fn lane_tail_widths_bitwise_across_workers() {
        // The masked-scalar-tail property (I13): at feature counts
        // W−1 / W / W+1 and primes, the lane-blocked batched kernel is
        // bitwise-equal to the scalar reference within one chunk on
        // whatever dot backend is dispatched, and parallel evaluation
        // at workers {1,2,4,8} is bitwise-equal to sequential.
        for f in [simd::LANES - 1, simd::LANES, simd::LANES + 1, 13, 31, 37] {
            let m = AnalyticModel::new(f, 5, 17, 18.0);
            let mut rng = TestRng::new(900 + f as u64);
            let x = rng.vec_f32(f, 0.0, 1.0);
            let b = rng.vec_f32(f, 0.0, 0.5);
            let n = batch::DEFAULT_CHUNK;
            let (alphas, weights) = rand_stream(&mut rng, n);
            let scalar = m.ig_points_scalar(&x, &b, &alphas, &weights, 3).unwrap();
            let batched = m.ig_points(&x, &b, &alphas, &weights, 3).unwrap();
            assert_eq!(batched.target_probs, scalar.target_probs, "F={f}");
            for i in 0..f {
                assert_eq!(
                    batched.partial[i].to_bits(),
                    scalar.partial[i].to_bits(),
                    "backend {} F={f} feature {i}",
                    simd::backend()
                );
            }

            let long = 4 * batch::DEFAULT_CHUNK + 5;
            let (la, lw) = rand_stream(&mut rng, long);
            let seq = eval_points(&m, &x, &b, &la, &lw, 3, &BatchExec::Sequential).unwrap();
            for workers in [1usize, 2, 4, 8] {
                let pool = Arc::new(ThreadPool::new(workers));
                let par = eval_points(&m, &x, &b, &la, &lw, 3, &BatchExec::parallel(pool)).unwrap();
                assert_eq!(par.target_probs, seq.target_probs, "F={f} workers={workers}");
                for i in 0..f {
                    assert_eq!(
                        par.partial[i].to_bits(),
                        seq.partial[i].to_bits(),
                        "F={f} workers={workers} feature {i}"
                    );
                }
            }
        }
    }

    // ---- AnalyticExec (gather backend) properties ---------------------

    #[test]
    fn gather_rows_match_scalar_kernel_contributions() {
        // One lane's row summed over features must equal the scalar
        // kernel's partial for that single point (cast through f32, the
        // device row dtype).
        let m = AnalyticModel::new(16, 3, 5, 10.0);
        let exec = AnalyticExec::new(AnalyticModel::new(16, 3, 5, 10.0));
        let mut rng = TestRng::new(99);
        let x = rng.vec_f32(16, 0.0, 1.0);
        let b = rng.vec_f32(16, 0.0, 0.5);
        exec.register_request(1, &x, &b).unwrap();
        let lanes = [
            GatherLane { slot: 1, alpha: 0.25, weight: 0.5, target: 0 },
            GatherLane { slot: 1, alpha: 0.75, weight: 0.0, target: 2 },
        ];
        let out = exec.eval_gather(0, &lanes).unwrap();
        assert_eq!(out.lanes(), 2);
        let scalar = m.ig_points_scalar(&x, &b, &[0.25], &[0.5], 0).unwrap();
        for i in 0..16 {
            assert_eq!(out.row(0)[i], scalar.partial[i] as f32, "feature {i}");
        }
        assert!(out.row(1).iter().all(|&v| v == 0.0), "zero-weight lane contributes nothing");
    }

    #[test]
    fn gather_rows_are_pure_per_lane() {
        // The gather determinism contract: a lane's row never depends on
        // its chunk neighbours or on the executing shard.
        let exec = AnalyticExec::with_shards(AnalyticModel::new(12, 4, 3, 8.0), 4);
        assert_eq!(exec.shards(), 4);
        let mut rng = TestRng::new(7);
        let zeros = vec![0f32; 12];
        for slot in 0..3u64 {
            let x = rng.vec_f32(12, 0.0, 1.0);
            exec.register_request(slot, &x, &zeros).unwrap();
        }
        let lane = GatherLane { slot: 1, alpha: 0.5, weight: 0.25, target: 2 };
        let alone = exec.eval_gather(0, &[lane]).unwrap();
        let crowded = exec
            .eval_gather(3, &[
                GatherLane { slot: 0, alpha: 0.1, weight: 0.9, target: 0 },
                lane,
                GatherLane { slot: 2, alpha: 0.9, weight: 0.1, target: 3 },
            ])
            .unwrap();
        assert_eq!(alone.row(0), crowded.row(1), "row must be a pure function of the lane");
        assert_eq!(exec.resident_len(), 3);
        exec.evict_request(1);
        assert_eq!(exec.resident_len(), 2);
        let err = exec.eval_gather(0, &[lane]).unwrap_err().to_string();
        assert!(err.contains("not registered"), "{err}");
    }

    #[test]
    fn gather_forward_matches_model_probs() {
        let exec = AnalyticExec::new(AnalyticModel::new(8, 3, 42, 6.0));
        let mut imgs = vec![0f32; 2 * 8];
        for (i, v) in imgs.iter_mut().enumerate() {
            *v = (i as f32) / 16.0;
        }
        let out = exec.forward(&imgs, 2).unwrap();
        assert_eq!(out.len(), 2 * 3);
        let direct = exec.model().probs(&[&imgs[..8], &imgs[8..]]).unwrap();
        for r in 0..2 {
            for c in 0..3 {
                assert_eq!(out[r * 3 + c], direct[r][c] as f32);
            }
        }
        assert!(exec.forward(&imgs, 3).is_err(), "row/payload mismatch must fail");
    }

    #[test]
    fn eval_batch_default_shim_delegates_to_ig_points() {
        // A Model that only implements ig_points still serves eval_batch.
        struct Shim(AnalyticModel);
        impl Model for Shim {
            fn features(&self) -> usize {
                self.0.features()
            }
            fn num_classes(&self) -> usize {
                self.0.num_classes()
            }
            fn probs(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>> {
                self.0.probs(imgs)
            }
            fn ig_points(
                &self,
                x: &[f32],
                baseline: &[f32],
                alphas: &[f32],
                weights: &[f32],
                target: usize,
            ) -> Result<IgPointsOut> {
                self.0.ig_points_scalar(x, baseline, alphas, weights, target)
            }
        }
        let m = Shim(tiny());
        let x = vec![0.7f32; 8];
        let b = vec![0f32; 8];
        let plan = BatchPlan {
            x: &x,
            baseline: &b,
            alphas: &[0.25, 0.75],
            weights: &[0.5, 0.5],
            target: 1,
            slot: None,
        };
        let shimmed = m.eval_batch(&plan).unwrap();
        let direct = m.0.ig_points_scalar(&x, &b, &[0.25, 0.75], &[0.5, 0.5], 1).unwrap();
        assert_eq!(shimmed.partial, direct.partial);
        assert_eq!(shimmed.target_probs, direct.target_probs);
    }
}
