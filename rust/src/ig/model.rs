//! The model abstraction the IG engines run against.
//!
//! Two implementations exist:
//!
//! * `runtime::PjrtModel` — the real thing: AOT-compiled MiniInception
//!   executables on the PJRT CPU client (serving path).
//! * [`AnalyticModel`] — a closed-form softmax-linear classifier in pure
//!   Rust, with *exact* gradients. It exists so the engine, coordinator,
//!   and allocator can be tested and benched without artifacts, and so
//!   convergence claims can be checked against analytically-known
//!   integrals (logits are exactly linear in α along a black-baseline
//!   path — the same positive-homogeneity the zero-bias MiniInception
//!   has, so the path behaviour matches the real model family).

use anyhow::{ensure, Result};

/// A differentiable classifier the IG engines can drive.
///
/// Implementations must be thread-safe (`Sync`): the coordinator calls
/// them from worker threads.
pub trait Model: Sync {
    /// Flat input width F the model consumes.
    fn features(&self) -> usize;
    /// Number of output classes.
    fn num_classes(&self) -> usize;

    /// Class probabilities for a batch of flat images.
    fn probs(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>>;

    /// The IG inner loop over one request's points: compute
    /// `Σ_k w_k · ∂p_target/∂x |_{α_k} ⊙ (x − x')` plus the target-class
    /// probability at every point.
    ///
    /// Implementations chunk internally to their executable width (zero
    /// weight ⇒ padding lane ⇒ exactly no contribution).
    fn ig_points(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> Result<IgPointsOut>;
}

/// Output of [`Model::ig_points`].
#[derive(Debug, Clone)]
pub struct IgPointsOut {
    /// (F,) partial attribution, f64-accumulated.
    pub partial: Vec<f64>,
    /// Target-class probability at each requested point.
    pub target_probs: Vec<f64>,
}

/// Closed-form test model: `p = softmax(gain · W · x / F)` with fixed
/// pseudo-random per-class weight vectors.
///
/// Gradient (exact): `∂p_t/∂x_i = p_t (W_{t,i} − Σ_c p_c W_{c,i}) · gain / F`.
pub struct AnalyticModel {
    features: usize,
    classes: usize,
    /// (classes × features) row-major weights.
    w: Vec<f32>,
    gain: f64,
}

impl AnalyticModel {
    /// Deterministic weights from `seed`; `gain` tunes softmax saturation
    /// along the path (≈12 mimics the calibrated MiniInception).
    pub fn new(features: usize, classes: usize, seed: u64, gain: f64) -> AnalyticModel {
        let mut w = Vec::with_capacity(features * classes);
        for c in 0..classes {
            for i in 0..features {
                let z = crate::data::synth::mix64(
                    seed ^ (c as u64).wrapping_mul(0x9E3779B97F4A7C15)
                        ^ (i as u64).wrapping_mul(0xBF58476D1CE4E5B9),
                );
                // Uniform in [-1, 1).
                w.push(((z >> 40) as f32 / 8388608.0) - 1.0);
            }
        }
        AnalyticModel { features, classes, w, gain }
    }

    /// Standard test instance matching the corpus dimensions.
    pub fn standard() -> AnalyticModel {
        AnalyticModel::new(crate::data::synth::F, crate::data::synth::NUM_CLASSES, 0xA11CE, 12.0)
    }

    fn logits(&self, x: &[f32]) -> Vec<f64> {
        let f = self.features;
        (0..self.classes)
            .map(|c| {
                let row = &self.w[c * f..(c + 1) * f];
                let dot: f64 = row.iter().zip(x).map(|(&w, &v)| w as f64 * v as f64).sum();
                self.gain * dot / f as f64
            })
            .collect()
    }

    fn softmax(logits: &[f64]) -> Vec<f64> {
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
        let s: f64 = e.iter().sum();
        e.iter().map(|v| v / s).collect()
    }

    /// Exact gradient of p_target w.r.t. x at the given point.
    pub fn grad(&self, x: &[f32], target: usize) -> Vec<f64> {
        let p = Self::softmax(&self.logits(x));
        let f = self.features;
        let scale = self.gain / f as f64;
        (0..f)
            .map(|i| {
                let wt = self.w[target * f + i] as f64;
                let wavg: f64 =
                    (0..self.classes).map(|c| p[c] * self.w[c * f + i] as f64).sum();
                p[target] * (wt - wavg) * scale
            })
            .collect()
    }
}

impl Model for AnalyticModel {
    fn features(&self) -> usize {
        self.features
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn probs(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f64>>> {
        imgs.iter()
            .map(|img| {
                ensure!(img.len() == self.features, "bad image width {}", img.len());
                Ok(Self::softmax(&self.logits(img)))
            })
            .collect()
    }

    fn ig_points(
        &self,
        x: &[f32],
        baseline: &[f32],
        alphas: &[f32],
        weights: &[f32],
        target: usize,
    ) -> Result<IgPointsOut> {
        ensure!(x.len() == self.features && baseline.len() == self.features, "bad endpoint widths");
        ensure!(alphas.len() == weights.len(), "alpha/weight length mismatch");
        ensure!(target < self.classes, "target {target} out of range");
        let f = self.features;
        let mut partial = vec![0f64; f];
        let mut target_probs = Vec::with_capacity(alphas.len());
        let mut point = vec![0f32; f];
        for (&a, &wgt) in alphas.iter().zip(weights) {
            for i in 0..f {
                point[i] = baseline[i] + a * (x[i] - baseline[i]);
            }
            let p = Self::softmax(&self.logits(&point));
            target_probs.push(p[target]);
            if wgt != 0.0 {
                let g = self.grad(&point, target);
                for i in 0..f {
                    partial[i] += wgt as f64 * g[i] * (x[i] - baseline[i]) as f64;
                }
            }
        }
        Ok(IgPointsOut { partial, target_probs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> AnalyticModel {
        AnalyticModel::new(8, 3, 42, 6.0)
    }

    #[test]
    fn probs_normalized() {
        let m = tiny();
        let x = vec![0.5f32; 8];
        let p = &m.probs(&[&x]).unwrap()[0];
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn zero_input_uniform_probs() {
        let m = tiny();
        let p = &m.probs(&[&vec![0f32; 8]]).unwrap()[0];
        for &v in p {
            assert!((v - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        let m = tiny();
        let x: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let g = m.grad(&x, 1);
        let eps = 1e-4f32;
        for i in 0..8 {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let pp = m.probs(&[&xp]).unwrap()[0][1];
            let pm = m.probs(&[&xm]).unwrap()[0][1];
            let fd = (pp - pm) / (2.0 * eps as f64);
            // f32 inputs + central difference: ~1e-4-scale agreement.
            assert!((g[i] - fd).abs() < 2e-4, "feature {i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn ig_points_zero_weights_no_contribution() {
        let m = tiny();
        let x = vec![0.7f32; 8];
        let b = vec![0f32; 8];
        let out = m.ig_points(&x, &b, &[0.5, 0.9], &[0.0, 0.0], 0).unwrap();
        assert!(out.partial.iter().all(|&v| v == 0.0));
        assert_eq!(out.target_probs.len(), 2);
    }

    #[test]
    fn ig_points_weight_linearity() {
        let m = tiny();
        let x = vec![0.7f32; 8];
        let b = vec![0f32; 8];
        let o1 = m.ig_points(&x, &b, &[0.5], &[0.25], 0).unwrap();
        let o2 = m.ig_points(&x, &b, &[0.5], &[0.5], 0).unwrap();
        for i in 0..8 {
            assert!((o2.partial[i] - 2.0 * o1.partial[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn saturation_along_path() {
        // gain high enough that p(target) saturates before alpha = 1.
        let m = AnalyticModel::new(64, 4, 7, 40.0);
        let x: Vec<f32> = (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect();
        let p1 = m.probs(&[&x]).unwrap()[0].clone();
        let target = p1
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let b = vec![0f32; 64];
        let out = m
            .ig_points(&x, &b, &[0.0, 0.25, 0.5, 0.75, 1.0], &[0.0; 5], target)
            .unwrap();
        let c = &out.target_probs;
        let total = c[4] - c[0];
        assert!(total > 0.1, "path must climb: {c:?}");
        assert!((c[2] - c[0]) / total > 0.5, "early concentration expected: {c:?}");
    }

    #[test]
    fn deterministic_weights() {
        let a = AnalyticModel::new(8, 3, 42, 6.0);
        let b = AnalyticModel::new(8, 3, 42, 6.0);
        assert_eq!(a.w, b.w);
        let c = AnalyticModel::new(8, 3, 43, 6.0);
        assert_ne!(a.w, c.w);
    }

    #[test]
    fn validation_errors() {
        let m = tiny();
        assert!(m.probs(&[&vec![0f32; 4]]).is_err());
        let x = vec![0f32; 8];
        assert!(m.ig_points(&x, &x, &[0.5], &[0.5, 0.5], 0).is_err());
        assert!(m.ig_points(&x, &x, &[0.5], &[0.5], 9).is_err());
        assert!(m.ig_points(&x, &vec![0f32; 4], &[0.5], &[0.5], 0).is_err());
    }
}
