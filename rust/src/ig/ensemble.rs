//! Ensemble attribution methods from the literature the paper cites in
//! §I — all of which run baseline IG *multiple times* in their pipeline
//! and therefore "stand to gain significant performance benefits from an
//! IG implementation optimized for low-latency":
//!
//! * [`multi_baseline`] — average attributions over several baselines
//!   (Sturmfels et al. [8]);
//! * [`noise_tunnel`] — SmoothGrad-style averaging over noisy copies of
//!   the input (Smilkov et al. [16], Captum's NoiseTunnel [15]).
//!
//! Both are scheme-agnostic: pass a uniform or non-uniform `IgOptions`
//! and the inner IG runs inherit it — `benches/ablation_allocator` and
//! the `reproduce_paper` example show the speedup composing.

use anyhow::{ensure, Result};

use crate::data::synth;
use crate::metrics::StageBreakdown;

use super::attribution::Attribution;
use super::baselines::BaselineKind;
use super::engine::{self, IgOptions};
use super::model::Model;

/// Result of an ensemble run: the averaged attribution plus the per-run
/// bookkeeping (total steps across members, worst member delta).
#[derive(Debug, Clone)]
pub struct EnsembleAttribution {
    /// The averaged attribution with summed step accounting.
    pub attribution: Attribution,
    /// Number of inner IG runs.
    pub members: usize,
    /// Max completeness residual across members (each member satisfies
    /// its own completeness equation; the mean does not have one).
    pub worst_member_delta: f64,
}

/// IG averaged over a set of baselines. Target is pinned from the
/// prediction on `x` so every member explains the same class.
pub fn multi_baseline(
    model: &dyn Model,
    x: &[f32],
    baselines: &[BaselineKind],
    opts: &IgOptions,
) -> Result<EnsembleAttribution> {
    ensure!(!baselines.is_empty(), "need at least one baseline");
    let probs = model.probs(&[x])?;
    let target = engine::argmax(&probs[0]);

    let mut acc = vec![0f64; x.len()];
    let mut steps = 0;
    let mut probe_passes = 0;
    let mut worst = 0f64;
    let mut gap_acc = 0f64;
    let mut breakdown = StageBreakdown::default();
    for kind in baselines {
        let baseline = kind.build(x.len());
        let a = engine::explain_with_target(model, x, &baseline, target, opts)?;
        for (s, v) in acc.iter_mut().zip(&a.values) {
            *s += v / baselines.len() as f64;
        }
        steps += a.steps;
        probe_passes += a.probe_passes;
        worst = worst.max(a.delta);
        gap_acc += a.endpoint_gap / baselines.len() as f64;
        breakdown.probe += a.breakdown.probe;
        breakdown.schedule += a.breakdown.schedule;
        breakdown.execute += a.breakdown.execute;
        breakdown.reduce += a.breakdown.reduce;
    }
    // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
    let sum: f64 = acc.iter().sum();
    let delta = (sum - gap_acc).abs();
    Ok(EnsembleAttribution {
        attribution: Attribution {
            delta,
            endpoint_gap: gap_acc,
            values: acc,
            target,
            steps,
            probe_passes,
            rounds: 1,
            residuals: vec![delta],
            breakdown,
        },
        members: baselines.len(),
        worst_member_delta: worst,
    })
}

/// SmoothGrad-style noise tunnel: average IG attributions over `n_samples`
/// noisy copies of the input (`x + sigma * U(-0.5, 0.5)` per feature,
/// seeded and counter-based for reproducibility).
pub fn noise_tunnel(
    model: &dyn Model,
    x: &[f32],
    n_samples: usize,
    sigma: f32,
    seed: u64,
    opts: &IgOptions,
) -> Result<EnsembleAttribution> {
    ensure!(n_samples >= 1, "need at least one sample");
    ensure!(sigma >= 0.0, "sigma must be non-negative");
    let probs = model.probs(&[x])?;
    let target = engine::argmax(&probs[0]);
    let baseline = vec![0f32; x.len()];

    let mut acc = vec![0f64; x.len()];
    let mut steps = 0;
    let mut probe_passes = 0;
    let mut worst = 0f64;
    let mut gap_acc = 0f64;
    let mut breakdown = StageBreakdown::default();
    for s in 0..n_samples {
        let noisy: Vec<f32> = x
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let u = synth::draw_u01(seed ^ (s as u64) << 32, i as u64) - 0.5;
                (v + sigma * u).clamp(0.0, 1.0)
            })
            .collect();
        let a = engine::explain_with_target(model, &noisy, &baseline, target, opts)?;
        for (dst, v) in acc.iter_mut().zip(&a.values) {
            *dst += v / n_samples as f64;
        }
        steps += a.steps;
        probe_passes += a.probe_passes;
        worst = worst.max(a.delta);
        gap_acc += a.endpoint_gap / n_samples as f64;
        breakdown.probe += a.breakdown.probe;
        breakdown.execute += a.breakdown.execute;
    }
    // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
    let sum: f64 = acc.iter().sum();
    let delta = (sum - gap_acc).abs();
    Ok(EnsembleAttribution {
        attribution: Attribution {
            delta,
            endpoint_gap: gap_acc,
            values: acc,
            target,
            steps,
            probe_passes,
            rounds: 1,
            residuals: vec![delta],
            breakdown,
        },
        members: n_samples,
        worst_member_delta: worst,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;
    use crate::ig::Scheme;

    fn model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 80.0)
    }

    fn input() -> Vec<f32> {
        (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect()
    }

    #[test]
    fn multi_baseline_averages() {
        let m = model();
        let x = input();
        let opts = IgOptions { m: 32, ..Default::default() };
        let ens = multi_baseline(&m, &x, &BaselineKind::standard_set(1), &opts).unwrap();
        assert_eq!(ens.members, 3);
        // 3 members, nonuniform default; fused schedules cost m + 1 each.
        assert_eq!(ens.attribution.steps, 3 * (32 + 1));
        assert!(ens.worst_member_delta >= ens.attribution.delta * 0.0); // defined
        assert!(ens.attribution.values.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn single_black_baseline_reduces_to_plain_ig() {
        let m = model();
        let x = input();
        let opts = IgOptions { m: 32, scheme: Scheme::Uniform, ..Default::default() };
        let ens = multi_baseline(&m, &x, &[BaselineKind::Black], &opts).unwrap();
        let plain = engine::explain(&m, &x, None, &opts).unwrap();
        crate::testutil::assert_allclose(&ens.attribution.values, &plain.values, 1e-9, 1e-12);
        assert!((ens.attribution.delta - plain.delta).abs() < 1e-12);
    }

    #[test]
    fn noise_tunnel_zero_sigma_equals_plain() {
        let m = model();
        let x = input();
        let opts = IgOptions { m: 24, scheme: Scheme::Uniform, ..Default::default() };
        let nt = noise_tunnel(&m, &x, 3, 0.0, 42, &opts).unwrap();
        let plain = engine::explain(&m, &x, None, &opts).unwrap();
        crate::testutil::assert_allclose(&nt.attribution.values, &plain.values, 1e-9, 1e-12);
    }

    #[test]
    fn noise_tunnel_deterministic() {
        let m = model();
        let x = input();
        let opts = IgOptions { m: 16, ..Default::default() };
        let a = noise_tunnel(&m, &x, 2, 0.1, 7, &opts).unwrap();
        let b = noise_tunnel(&m, &x, 2, 0.1, 7, &opts).unwrap();
        assert_eq!(a.attribution.values, b.attribution.values);
        let c = noise_tunnel(&m, &x, 2, 0.1, 8, &opts).unwrap();
        assert_ne!(a.attribution.values, c.attribution.values);
    }

    #[test]
    fn noise_tunnel_smooths() {
        // Averaging over noisy copies must not blow up the attribution
        // scale and must stay correlated with the clean attribution.
        let m = model();
        let x = input();
        let opts = IgOptions { m: 24, ..Default::default() };
        let nt = noise_tunnel(&m, &x, 4, 0.05, 1, &opts).unwrap();
        let plain = engine::explain(&m, &x, None, &opts).unwrap();
        assert!(nt.attribution.cosine_similarity(&plain) > 0.9);
    }

    #[test]
    fn ensemble_speedup_composes_with_nonuniform() {
        // The §I claim: pipelines that call IG repeatedly inherit the
        // scheme's step savings — equal member count, fewer total steps
        // at comparable convergence.
        let m = model();
        let x = input();
        let uni = IgOptions { m: 64, scheme: Scheme::Uniform, ..Default::default() };
        let non = IgOptions { m: 24, scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() };
        let set = BaselineKind::standard_set(1);
        let e_uni = multi_baseline(&m, &x, &set, &uni).unwrap();
        let e_non = multi_baseline(&m, &x, &set, &non).unwrap();
        assert!(e_non.attribution.steps * 2 < e_uni.attribution.steps);
        assert!(e_non.worst_member_delta < 2.0 * e_uni.worst_member_delta + 1e-3);
        assert!(e_non.attribution.cosine_similarity(&e_uni.attribution) > 0.98);
    }

    #[test]
    fn validation() {
        let m = model();
        let x = input();
        let opts = IgOptions::default();
        assert!(multi_baseline(&m, &x, &[], &opts).is_err());
        assert!(noise_tunnel(&m, &x, 0, 0.1, 1, &opts).is_err());
        assert!(noise_tunnel(&m, &x, 1, -0.5, 1, &opts).is_err());
    }
}
