//! Alpha/weight schedules: where on the IG path to evaluate gradients and
//! with what quadrature weight.
//!
//! A [`Schedule`] is the fully-resolved plan for stage 2: a list of
//! `(alpha, weight)` points whose weighted gradient sum approximates
//! Eq. 1's integral. The uniform baseline is one grid over [0,1]; the
//! paper's non-uniform schedule is the concatenation of per-interval
//! uniform grids, each scaled by its interval width.
//!
//! # Fusion
//!
//! The raw concatenation is *not* what the engines dispatch: every
//! interior probe boundary alpha appears in two adjacent interval grids,
//! and the Left/Right Riemann rules carry a structurally zero-weight
//! endpoint. Both buy a full forward+backward pass for nothing. The
//! [`Schedule::fused`] pass merges coincident-alpha points by summing
//! their quadrature weights and prunes zero-weight points, so the fused
//! point list is exactly the set of model evaluations: for a trapezoid
//! non-uniform schedule over `n_int` intervals, the `m + n_int` raw points
//! (`Σ(m_i + 1)`) fuse down to exactly `m + 1` — the same model-eval count
//! as the uniform baseline at equal `m`. All public constructors
//! ([`Schedule::uniform`], [`Schedule::nonuniform`]) return fused
//! schedules; [`Schedule::nonuniform_unfused`] exposes the raw
//! concatenation for equivalence testing and step-accounting audits.
//!
//! # Nested refinement
//!
//! [`Schedule::refine`] produces the next-level fused schedule by
//! bisecting every consecutive-alpha gap: the refined point set is a
//! *strict superset* of the current one (every alpha is carried over
//! bit-identically), which is what makes anytime IG possible — gradients
//! already evaluated at level `k` are reused at level `k + 1`, never
//! recomputed. For an endpoint-inclusive rule (trapezoid, eq2) every
//! carried point's quadrature weight is *exactly halved* by refinement
//! ([`Schedule::REFINE_CARRY`]), so a partial weighted gradient sum
//! carries across rounds as `sum / 2` plus the novel midpoints'
//! contributions ([`Schedule::novel_vs`]). Refining
//! `nonuniform(bounds, alloc)` is pointwise identical to building
//! `nonuniform(bounds, 2 * alloc)` directly — doubling every interval's
//! grid — so the refined schedule is itself a legal stage-2 schedule.
//!
//! # Cross-request caching
//!
//! The [`cache`] submodule amortizes stage 1 across requests: a bounded,
//! sharded LRU keyed by `(target class, baseline id, quantized probe
//! signature, m, rule, allocation)` stores *canonical* fused schedules
//! together with their lazily-extended refine ladders, and a probe memo
//! lets deadline-tier serving skip stage 1 entirely on warm traffic. See
//! [`cache::ScheduleCache`].

pub mod cache;

use anyhow::{ensure, Result};

use super::riemann::Rule;

/// Coincidence tolerance for fusing alphas. Interval builders pin shared
/// boundaries to bit-identical f64 values, so this only absorbs residue
/// from callers composing their own sub-interval grids; it is far below
/// any legal grid spacing (>= 1/(m * n_int) >> 1e-12).
const FUSE_EPS: f64 = 1e-12;

/// One gradient-evaluation point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// Interpolation constant in [0, 1].
    pub alpha: f64,
    /// Quadrature weight (absorbs rule weight x interval width).
    pub weight: f64,
}

/// A resolved evaluation plan.
///
/// Invariant for fused schedules (everything the public constructors
/// return): alphas strictly increasing, no zero-weight points, hence
/// `len()` is exactly the number of model evaluations stage 2 costs.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// The evaluation points, in alpha order.
    pub points: Vec<Point>,
    /// Grid-interval count(s) this schedule was built from, for reporting.
    pub m_total: usize,
}

impl Schedule {
    /// The baseline: a uniform grid of `m` intervals over the full path,
    /// fused (`m + 1` points for trapezoid/eq2, `m` for left/right whose
    /// zero-weight endpoint is pruned).
    pub fn uniform(m: usize, rule: Rule) -> Result<Schedule> {
        Ok(Self::interval(0.0, 1.0, m, rule)?.fused())
    }

    /// A uniform grid of `m` intervals over `[lo, hi]`, weights scaled by
    /// the interval width so concatenated subpath schedules integrate the
    /// full path (additivity of Eq. 1 over subpaths).
    ///
    /// Raw (unfused): zero-weight rule endpoints are kept so the grid
    /// always has `m + 1` points. The shared-boundary alphas are pinned to
    /// exactly `lo`/`hi` so adjacent interval grids fuse by equality.
    pub fn interval(lo: f64, hi: f64, m: usize, rule: Rule) -> Result<Schedule> {
        ensure!(m >= 1, "need m >= 1 intervals, got {m}");
        ensure!((0.0..=1.0).contains(&lo) && (0.0..=1.0).contains(&hi) && lo < hi,
                "bad interval [{lo}, {hi}]");
        let w = rule.weights(m + 1)?;
        let width = hi - lo;
        let points = (0..=m)
            .map(|k| Point {
                // Endpoints pinned exactly: `lo + width` need not round
                // back to `hi`, and fusion relies on coincidence.
                alpha: if k == 0 {
                    lo
                } else if k == m {
                    hi
                } else {
                    lo + width * (k as f64 / m as f64)
                },
                weight: w[k] * width,
            })
            .collect();
        Ok(Schedule { points, m_total: m })
    }

    /// The paper's stage-2 schedule: per-interval uniform grids over the
    /// equal-width probe intervals, with `alloc[i]` grid intervals each —
    /// fused, so shared interval boundaries cost one model evaluation and
    /// `len() == m + 1` for the trapezoid rule.
    pub fn nonuniform(bounds: &[f64], alloc: &[usize], rule: Rule) -> Result<Schedule> {
        Ok(Self::nonuniform_unfused(bounds, alloc, rule)?.fused())
    }

    /// The raw per-interval concatenation, with interior boundary alphas
    /// duplicated (`len() == Σ(m_i + 1) == m + n_int`). Kept public for
    /// fused-vs-unfused equivalence tests and cost audits; engines must
    /// dispatch the fused form.
    pub fn nonuniform_unfused(bounds: &[f64], alloc: &[usize], rule: Rule) -> Result<Schedule> {
        ensure!(bounds.len() >= 2, "need at least one interval");
        ensure!(alloc.len() == bounds.len() - 1, "alloc/bounds mismatch");
        let mut points = Vec::new();
        let mut m_total = 0;
        for (i, &m_i) in alloc.iter().enumerate() {
            let part = Self::interval(bounds[i], bounds[i + 1], m_i, rule)?;
            points.extend(part.points);
            m_total += m_i;
        }
        Ok(Schedule { points, m_total })
    }

    /// Fuse the schedule: merge runs of coincident alphas by summing their
    /// quadrature weights, then prune zero-weight points. Preserves total
    /// quadrature mass exactly (weight addition is the only arithmetic)
    /// and leaves strictly increasing alphas, so `len()` afterwards equals
    /// the number of model evaluations the schedule costs. Idempotent.
    pub fn fused(mut self) -> Schedule {
        let mut fused: Vec<Point> = Vec::with_capacity(self.points.len());
        for p in self.points.drain(..) {
            match fused.last_mut() {
                Some(last) if (p.alpha - last.alpha).abs() <= FUSE_EPS => {
                    last.weight += p.weight;
                }
                _ => fused.push(p),
            }
        }
        fused.retain(|p| p.weight != 0.0);
        Schedule { points: fused, m_total: self.m_total }
    }

    /// Whether the fused invariants hold: strictly increasing alphas and
    /// no zero-weight points.
    pub fn is_fused(&self) -> bool {
        self.points.windows(2).all(|w| w[0].alpha < w[1].alpha)
            && self.points.iter().all(|p| p.weight != 0.0)
    }

    /// The exact factor every carried point's weight shrinks by under
    /// [`Schedule::refine`]. Bisecting every gap halves the grid spacing,
    /// and for endpoint-inclusive rules each old point's weight is linear
    /// in its local spacing, so all carried weights are multiplied by
    /// exactly 0.5 — a power-of-two scale, lossless in floating point.
    /// An incremental accumulator therefore carries its partial weighted
    /// gradient sum across a refinement round as `partial * REFINE_CARRY`
    /// plus the novel midpoints' weighted contributions.
    pub const REFINE_CARRY: f64 = 0.5;

    /// Nested refinement: the next-level fused schedule, produced by
    /// bisecting every consecutive-alpha gap.
    ///
    /// Contract (property-tested below; the anytime engine and the
    /// coordinator's refinement rounds rely on every clause):
    ///
    /// * every current alpha reappears **bit-identically** (strict
    ///   superset — a refined schedule never re-evaluates a point);
    /// * every carried point's weight is exactly `weight * REFINE_CARRY`;
    /// * each novel midpoint `(αⱼ + αⱼ₊₁) / 2` gets weight `gap / 2`,
    ///   its interior weight at the refined spacing;
    /// * `m_total` doubles, and for a schedule built by
    ///   [`Schedule::nonuniform`] the result is pointwise the schedule
    ///   built with a doubled allocation.
    ///
    /// Requires a fused, endpoint-inclusive schedule (first alpha 0, last
    /// alpha 1 — i.e. built with [`Rule::Trapezoid`] or [`Rule::Eq2`]):
    /// Left/Right prune a zero-weight endpoint at build, so the region
    /// beyond their last kept point has no gap to bisect and the carry
    /// identity breaks; refining them is rejected.
    pub fn refine(&self) -> Result<Schedule> {
        ensure!(self.len() >= 2, "cannot refine a schedule with < 2 points");
        ensure!(self.is_fused(), "refine requires a fused schedule");
        ensure!(
            self.points[0].alpha == 0.0 && (self.points[self.len() - 1].alpha - 1.0).abs() <= FUSE_EPS,
            "refine requires an endpoint-inclusive schedule (trapezoid/eq2); \
             left/right rules prune an endpoint and cannot be refined in place"
        );
        let mut points = Vec::with_capacity(2 * self.len() - 1);
        for w in self.points.windows(2) {
            let gap = w[1].alpha - w[0].alpha;
            points.push(Point { alpha: w[0].alpha, weight: w[0].weight * Self::REFINE_CARRY });
            points.push(Point { alpha: w[0].alpha + gap * 0.5, weight: gap * 0.5 });
        }
        let last = self.points[self.len() - 1];
        points.push(Point { alpha: last.alpha, weight: last.weight * Self::REFINE_CARRY });
        Ok(Schedule { points, m_total: self.m_total * 2 })
    }

    /// The points of `self` whose alpha does not occur in `coarser`
    /// (coincidence within the fuse tolerance) — exactly the gradient
    /// evaluations a refinement round must pay, with their *refined*
    /// weights. Both schedules must be fused (alphas sorted); this is a
    /// linear merge-walk.
    pub fn novel_vs(&self, coarser: &Schedule) -> Vec<Point> {
        let mut out = Vec::with_capacity(self.len().saturating_sub(coarser.len()));
        let mut i = 0;
        for p in &self.points {
            while i < coarser.points.len() && coarser.points[i].alpha < p.alpha - FUSE_EPS {
                i += 1;
            }
            let carried =
                i < coarser.points.len() && (coarser.points[i].alpha - p.alpha).abs() <= FUSE_EPS;
            if !carried {
                out.push(*p);
            }
        }
        out
    }

    /// Equal-width probe boundaries for `n_int` intervals: 0, 1/n, .., 1.
    pub fn probe_boundaries(n_int: usize) -> Vec<f64> {
        (0..=n_int).map(|i| i as f64 / n_int as f64).collect()
    }

    /// Point count — for a fused schedule, exactly the model-eval cost.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Whether the schedule has no points.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Total quadrature mass — the path-length covered. 1.0 for exact
    /// rules over the full path ((m+1)/m for Eq2-built schedules).
    /// Invariant under [`Schedule::fused`].
    pub fn total_weight(&self) -> f64 {
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        self.points.iter().map(|p| p.weight).sum()
    }

    /// Split into `(alphas, weights)` f32 vectors for the executables.
    pub fn to_f32(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.points.iter().map(|p| p.alpha as f32).collect(),
            self.points.iter().map(|p| p.weight as f32).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::allocator::Allocation;
    use crate::ig::model::AnalyticModel;
    use crate::testutil;

    #[test]
    fn uniform_grid_points() {
        let s = Schedule::uniform(4, Rule::Trapezoid).unwrap();
        assert_eq!(s.len(), 5);
        let alphas: Vec<f64> = s.points.iter().map(|p| p.alpha).collect();
        assert_eq!(alphas, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
        assert!((s.total_weight() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_left_right_prune_zero_endpoint() {
        // The weight-0 endpoint must not buy a model evaluation.
        let l = Schedule::uniform(4, Rule::Left).unwrap();
        assert_eq!(l.len(), 4);
        assert_eq!(l.points.last().unwrap().alpha, 0.75);
        let r = Schedule::uniform(4, Rule::Right).unwrap();
        assert_eq!(r.len(), 4);
        assert_eq!(r.points[0].alpha, 0.25);
        for s in [l, r] {
            assert!(s.is_fused());
            assert!((s.total_weight() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn interval_scales_weights() {
        let s = Schedule::interval(0.25, 0.5, 2, Rule::Trapezoid).unwrap();
        assert_eq!(s.points[0].alpha, 0.25);
        assert_eq!(s.points[2].alpha, 0.5);
        assert!((s.total_weight() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn interval_pins_endpoint_alphas_exactly() {
        // Fusion relies on adjacent grids sharing bit-identical boundary
        // alphas even for non-dyadic bounds.
        for n_int in [3usize, 5, 7] {
            let bounds = Schedule::probe_boundaries(n_int);
            for i in 0..n_int {
                let s = Schedule::interval(bounds[i], bounds[i + 1], 3, Rule::Trapezoid).unwrap();
                assert_eq!(s.points[0].alpha, bounds[i]);
                assert_eq!(s.points[3].alpha, bounds[i + 1]);
            }
        }
    }

    #[test]
    fn nonuniform_fuses_boundaries() {
        let bounds = Schedule::probe_boundaries(4);
        let s = Schedule::nonuniform(&bounds, &[8, 4, 2, 2], Rule::Trapezoid).unwrap();
        assert_eq!(s.m_total, 16);
        assert_eq!(s.len(), 16 + 1); // fused: one eval per grid point
        assert!(s.is_fused());
        assert!((s.total_weight() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nonuniform_unfused_keeps_duplicates() {
        let bounds = Schedule::probe_boundaries(4);
        let s = Schedule::nonuniform_unfused(&bounds, &[8, 4, 2, 2], Rule::Trapezoid).unwrap();
        assert_eq!(s.len(), 8 + 4 + 2 + 2 + 4); // sum(m_i + 1) = m + n_int
        assert!(!s.is_fused());
        // Monotone (non-strict: boundary alphas duplicated).
        let alphas: Vec<f64> = s.points.iter().map(|p| p.alpha).collect();
        assert!(alphas.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn fusion_preserves_quadrature_mass() {
        let bounds = Schedule::probe_boundaries(5);
        for rule in [Rule::Left, Rule::Right, Rule::Trapezoid, Rule::Eq2] {
            let raw = Schedule::nonuniform_unfused(&bounds, &[3, 1, 4, 2, 5], rule).unwrap();
            let fused = raw.clone().fused();
            assert!((raw.total_weight() - fused.total_weight()).abs() < 1e-12, "{rule}");
            assert!(fused.is_fused(), "{rule}");
        }
    }

    #[test]
    fn fused_is_idempotent() {
        let bounds = Schedule::probe_boundaries(4);
        let s = Schedule::nonuniform(&bounds, &[4, 4, 4, 4], Rule::Trapezoid).unwrap();
        assert_eq!(s.clone().fused(), s);
    }

    #[test]
    fn fused_left_right_nonuniform_have_m_points() {
        // Each interval's zero-weight endpoint either fuses into the next
        // interval's first point or (at alpha=1 for Left / alpha=0 for
        // Right) is pruned: exactly m evaluations remain.
        let bounds = Schedule::probe_boundaries(4);
        for rule in [Rule::Left, Rule::Right] {
            let s = Schedule::nonuniform(&bounds, &[8, 4, 2, 2], rule).unwrap();
            assert_eq!(s.len(), 16, "{rule}");
            assert!(s.is_fused());
            assert!((s.total_weight() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn nonuniform_single_interval_is_uniform() {
        let s1 = Schedule::nonuniform(&[0.0, 1.0], &[16], Rule::Trapezoid).unwrap();
        let s2 = Schedule::uniform(16, Rule::Trapezoid).unwrap();
        assert_eq!(s1, s2);
    }

    #[test]
    fn probe_boundaries_shape() {
        assert_eq!(Schedule::probe_boundaries(4), vec![0.0, 0.25, 0.5, 0.75, 1.0]);
        assert_eq!(Schedule::probe_boundaries(1), vec![0.0, 1.0]);
    }

    #[test]
    fn rejects_bad_intervals() {
        assert!(Schedule::interval(0.5, 0.5, 2, Rule::Trapezoid).is_err());
        assert!(Schedule::interval(0.5, 0.2, 2, Rule::Trapezoid).is_err());
        assert!(Schedule::interval(0.0, 1.5, 2, Rule::Trapezoid).is_err());
        assert!(Schedule::uniform(0, Rule::Trapezoid).is_err());
        assert!(Schedule::nonuniform(&[0.0, 0.5, 1.0], &[2], Rule::Trapezoid).is_err());
    }

    #[test]
    fn to_f32_parallel_arrays() {
        let s = Schedule::uniform(2, Rule::Left).unwrap();
        let (a, w) = s.to_f32();
        // Zero-weight alpha=1 endpoint pruned at build.
        assert_eq!(a, vec![0.0, 0.5]);
        assert_eq!(w, vec![0.5, 0.5]);
    }

    #[test]
    fn property_nonuniform_fused_invariants() {
        // The tentpole invariants: strictly increasing alphas, unit
        // quadrature mass, and exactly m + 1 evaluations for trapezoid.
        testutil::prop(100, 21, |rng| {
            let n_int = rng.range(1, 9);
            let m = rng.range(n_int, 200);
            let deltas: Vec<f64> = (0..n_int).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let alloc = Allocation::Sqrt.allocate(m, &deltas).unwrap();
            let bounds = Schedule::probe_boundaries(n_int);
            let s = Schedule::nonuniform(&bounds, &alloc, Rule::Trapezoid).unwrap();
            assert_eq!(s.m_total, m);
            assert_eq!(s.len(), m + 1, "trapezoid fused len must be m + 1");
            assert!(s.is_fused());
            assert!(s.points.windows(2).all(|w| w[0].alpha < w[1].alpha));
            assert!((s.total_weight() - 1.0).abs() < 1e-9);
            assert!(s.points.iter().all(|p| (0.0..=1.0).contains(&p.alpha)));
            assert!(s.points.first().unwrap().alpha == 0.0);
            assert!((s.points.last().unwrap().alpha - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn property_fused_matches_unfused_quadrature() {
        // Fused and unfused schedules integrate the same f64 quadrature
        // on the analytic model to 1e-12 per value: merging coincident
        // points only re-associates the weight sum.
        let model = AnalyticModel::new(64, 4, 7, 300.0);
        testutil::prop(20, 4242, |rng| {
            let x = rng.vec_f32(64, 0.0, 1.0);
            let n_int = rng.range(2, 8);
            let m = rng.range(n_int, 65);
            let deltas: Vec<f64> = (0..n_int).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let alloc = Allocation::Sqrt.allocate(m, &deltas).unwrap();
            let bounds = Schedule::probe_boundaries(n_int);
            let raw = Schedule::nonuniform_unfused(&bounds, &alloc, Rule::Trapezoid).unwrap();
            let fused = raw.clone().fused();

            let quad = |s: &Schedule| -> Vec<f64> {
                let mut acc = vec![0f64; 64];
                for p in &s.points {
                    let point: Vec<f32> =
                        x.iter().map(|&v| p.alpha as f32 * v).collect();
                    let g = model.grad(&point, 0);
                    for (a, (&gi, &xi)) in acc.iter_mut().zip(g.iter().zip(&x)) {
                        *a += p.weight * gi * xi as f64;
                    }
                }
                acc
            };
            testutil::assert_allclose(&quad(&raw), &quad(&fused), 0.0, 1e-12);
        });
    }

    #[test]
    fn refine_carries_old_points_verbatim_at_half_weight() {
        let bounds = Schedule::probe_boundaries(4);
        let s = Schedule::nonuniform(&bounds, &[8, 4, 2, 2], Rule::Trapezoid).unwrap();
        let r = s.refine().unwrap();
        assert_eq!(r.len(), 2 * s.len() - 1);
        assert_eq!(r.m_total, 2 * s.m_total);
        assert!(r.is_fused());
        for (j, p) in s.points.iter().enumerate() {
            // Bit-identical alphas, exactly halved weights (both exact:
            // the incremental accumulator's carry identity depends on it).
            assert_eq!(r.points[2 * j].alpha, p.alpha);
            assert_eq!(r.points[2 * j].weight, p.weight * Schedule::REFINE_CARRY);
        }
    }

    #[test]
    fn refine_equals_doubled_allocation() {
        // refine(nonuniform(bounds, alloc)) == nonuniform(bounds, 2*alloc):
        // the refined schedule is itself a legal stage-2 schedule.
        for rule in [Rule::Trapezoid, Rule::Eq2] {
            let bounds = Schedule::probe_boundaries(4);
            let alloc = [8usize, 4, 2, 2];
            let doubled: Vec<usize> = alloc.iter().map(|&a| 2 * a).collect();
            let r = Schedule::nonuniform(&bounds, &alloc, rule).unwrap().refine().unwrap();
            let d = Schedule::nonuniform(&bounds, &doubled, rule).unwrap();
            assert_eq!(r.len(), d.len(), "{rule}");
            assert_eq!(r.m_total, d.m_total);
            for (a, b) in r.points.iter().zip(&d.points) {
                assert!((a.alpha - b.alpha).abs() < 1e-12, "{rule}");
                assert!((a.weight - b.weight).abs() < 1e-12, "{rule}");
            }
        }
    }

    #[test]
    fn refine_preserves_trapezoid_mass() {
        let s = Schedule::uniform(8, Rule::Trapezoid).unwrap();
        let r = s.refine().unwrap();
        assert!((r.total_weight() - 1.0).abs() < 1e-12);
        let u16 = Schedule::uniform(16, Rule::Trapezoid).unwrap();
        assert_eq!(r.len(), u16.len());
        for (a, b) in r.points.iter().zip(&u16.points) {
            assert!((a.alpha - b.alpha).abs() < 1e-12);
            assert!((a.weight - b.weight).abs() < 1e-12);
        }
    }

    #[test]
    fn refine_rejects_endpoint_pruned_and_unfused() {
        // Left/Right prune an endpoint: the carry identity breaks.
        assert!(Schedule::uniform(8, Rule::Left).unwrap().refine().is_err());
        assert!(Schedule::uniform(8, Rule::Right).unwrap().refine().is_err());
        // Unfused schedules (duplicate boundary alphas) are rejected too.
        let bounds = Schedule::probe_boundaries(2);
        let raw = Schedule::nonuniform_unfused(&bounds, &[2, 2], Rule::Trapezoid).unwrap();
        assert!(raw.refine().is_err());
    }

    #[test]
    fn novel_vs_returns_exactly_the_midpoints() {
        let s = Schedule::uniform(4, Rule::Trapezoid).unwrap();
        let r = s.refine().unwrap();
        let novel = r.novel_vs(&s);
        assert_eq!(novel.len(), s.len() - 1);
        let alphas: Vec<f64> = novel.iter().map(|p| p.alpha).collect();
        assert_eq!(alphas, vec![0.125, 0.375, 0.625, 0.875]);
        assert!(novel.iter().all(|p| (p.weight - 0.125).abs() < 1e-12));
    }

    #[test]
    fn property_zero_reevaluated_alphas_across_rounds() {
        // The anytime reuse guarantee: across any number of refinement
        // rounds, no alpha is ever evaluated twice — the union of per-round
        // novel sets plus the initial schedule IS the final schedule.
        testutil::prop(30, 77, |rng| {
            let n_int = rng.range(1, 6);
            let m = rng.range(n_int, 33);
            let deltas: Vec<f64> = (0..n_int).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let alloc = Allocation::Sqrt.allocate(m, &deltas).unwrap();
            let bounds = Schedule::probe_boundaries(n_int);
            let mut sched = Schedule::nonuniform(&bounds, &alloc, Rule::Trapezoid).unwrap();
            let mut evaluated: Vec<f64> = sched.points.iter().map(|p| p.alpha).collect();
            let mut evals = sched.len();
            for _ in 0..3 {
                let refined = sched.refine().unwrap();
                let novel = refined.novel_vs(&sched);
                assert_eq!(novel.len(), refined.len() - sched.len());
                for p in &novel {
                    assert!(
                        evaluated.iter().all(|&a| (a - p.alpha).abs() > FUSE_EPS),
                        "alpha {} re-evaluated",
                        p.alpha
                    );
                    evaluated.push(p.alpha);
                }
                evals += novel.len();
                sched = refined;
            }
            assert_eq!(evals, sched.len(), "total evals must equal the final schedule length");
            assert_eq!(evaluated.len(), sched.len());
        });
    }

    #[test]
    fn property_equal_deltas_reduce_to_uniform() {
        // With equal interval deltas the fused non-uniform schedule IS the
        // uniform schedule (pointwise) whenever n_int divides m.
        testutil::prop(50, 22, |rng| {
            let n_int = rng.range(1, 6);
            let m = n_int * rng.range(1, 20);
            let alloc = Allocation::Sqrt.allocate(m, &vec![0.5; n_int]).unwrap();
            assert!(alloc.iter().all(|&a| a == m / n_int));
            let s = Schedule::nonuniform(&Schedule::probe_boundaries(n_int), &alloc, Rule::Trapezoid).unwrap();
            let u = Schedule::uniform(m, Rule::Trapezoid).unwrap();
            assert_eq!(s.len(), u.len());
            for (a, b) in s.points.iter().zip(&u.points) {
                assert!((a.alpha - b.alpha).abs() < 1e-12);
                assert!((a.weight - b.weight).abs() < 1e-12);
            }
        });
    }
}
