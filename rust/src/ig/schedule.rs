//! Alpha/weight schedules: where on the IG path to evaluate gradients and
//! with what quadrature weight.
//!
//! A [`Schedule`] is the fully-resolved plan for stage 2: a list of
//! `(alpha, weight)` points whose weighted gradient sum approximates
//! Eq. 1's integral. The uniform baseline is one grid over [0,1]; the
//! paper's non-uniform schedule is the concatenation of per-interval
//! uniform grids, each scaled by its interval width.

use anyhow::{ensure, Result};

use super::riemann::Rule;

/// One gradient-evaluation point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// Interpolation constant in [0, 1].
    pub alpha: f64,
    /// Quadrature weight (absorbs rule weight x interval width).
    pub weight: f64,
}

/// A resolved evaluation plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub points: Vec<Point>,
    /// Grid-interval count(s) this schedule was built from, for reporting.
    pub m_total: usize,
}

impl Schedule {
    /// The baseline: a uniform grid of `m` intervals (`m+1` points) over
    /// the full path.
    pub fn uniform(m: usize, rule: Rule) -> Result<Schedule> {
        Self::interval(0.0, 1.0, m, rule)
    }

    /// A uniform grid of `m` intervals over `[lo, hi]`, weights scaled by
    /// the interval width so concatenated subpath schedules integrate the
    /// full path (additivity of Eq. 1 over subpaths).
    pub fn interval(lo: f64, hi: f64, m: usize, rule: Rule) -> Result<Schedule> {
        ensure!(m >= 1, "need m >= 1 intervals, got {m}");
        ensure!((0.0..=1.0).contains(&lo) && (0.0..=1.0).contains(&hi) && lo < hi,
                "bad interval [{lo}, {hi}]");
        let w = rule.weights(m + 1)?;
        let width = hi - lo;
        let points = (0..=m)
            .map(|k| Point {
                alpha: lo + width * (k as f64 / m as f64),
                weight: w[k] * width,
            })
            .collect();
        Ok(Schedule { points, m_total: m })
    }

    /// The paper's stage-2 schedule: per-interval uniform grids over the
    /// equal-width probe intervals, with `alloc[i]` grid intervals each.
    pub fn nonuniform(bounds: &[f64], alloc: &[usize], rule: Rule) -> Result<Schedule> {
        ensure!(bounds.len() >= 2, "need at least one interval");
        ensure!(alloc.len() == bounds.len() - 1, "alloc/bounds mismatch");
        let mut points = Vec::new();
        let mut m_total = 0;
        for (i, &m_i) in alloc.iter().enumerate() {
            let part = Self::interval(bounds[i], bounds[i + 1], m_i, rule)?;
            points.extend(part.points);
            m_total += m_i;
        }
        Ok(Schedule { points, m_total })
    }

    /// Equal-width probe boundaries for `n_int` intervals: 0, 1/n, .., 1.
    pub fn probe_boundaries(n_int: usize) -> Vec<f64> {
        (0..=n_int).map(|i| i as f64 / n_int as f64).collect()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Total quadrature mass — the path-length covered. 1.0 for exact
    /// rules over the full path ((m+1)/m for Eq2-built schedules).
    pub fn total_weight(&self) -> f64 {
        self.points.iter().map(|p| p.weight).sum()
    }

    /// Split into `(alphas, weights)` f32 vectors for the executables.
    pub fn to_f32(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.points.iter().map(|p| p.alpha as f32).collect(),
            self.points.iter().map(|p| p.weight as f32).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::allocator::Allocation;
    use crate::testutil;

    #[test]
    fn uniform_grid_points() {
        let s = Schedule::uniform(4, Rule::Trapezoid).unwrap();
        assert_eq!(s.len(), 5);
        let alphas: Vec<f64> = s.points.iter().map(|p| p.alpha).collect();
        assert_eq!(alphas, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
        assert!((s.total_weight() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn interval_scales_weights() {
        let s = Schedule::interval(0.25, 0.5, 2, Rule::Trapezoid).unwrap();
        assert_eq!(s.points[0].alpha, 0.25);
        assert_eq!(s.points[2].alpha, 0.5);
        assert!((s.total_weight() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn nonuniform_covers_path() {
        let bounds = Schedule::probe_boundaries(4);
        let s = Schedule::nonuniform(&bounds, &[8, 4, 2, 2], Rule::Trapezoid).unwrap();
        assert_eq!(s.m_total, 16);
        assert_eq!(s.len(), 8 + 4 + 2 + 2 + 4); // sum(m_i + 1)
        assert!((s.total_weight() - 1.0).abs() < 1e-12);
        // Monotone within each interval, intervals ordered.
        let alphas: Vec<f64> = s.points.iter().map(|p| p.alpha).collect();
        let mut sorted = alphas.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(alphas, sorted);
    }

    #[test]
    fn nonuniform_single_interval_is_uniform() {
        let s1 = Schedule::nonuniform(&[0.0, 1.0], &[16], Rule::Trapezoid).unwrap();
        let s2 = Schedule::uniform(16, Rule::Trapezoid).unwrap();
        assert_eq!(s1, s2);
    }

    #[test]
    fn probe_boundaries_shape() {
        assert_eq!(Schedule::probe_boundaries(4), vec![0.0, 0.25, 0.5, 0.75, 1.0]);
        assert_eq!(Schedule::probe_boundaries(1), vec![0.0, 1.0]);
    }

    #[test]
    fn rejects_bad_intervals() {
        assert!(Schedule::interval(0.5, 0.5, 2, Rule::Trapezoid).is_err());
        assert!(Schedule::interval(0.5, 0.2, 2, Rule::Trapezoid).is_err());
        assert!(Schedule::interval(0.0, 1.5, 2, Rule::Trapezoid).is_err());
        assert!(Schedule::uniform(0, Rule::Trapezoid).is_err());
        assert!(Schedule::nonuniform(&[0.0, 0.5, 1.0], &[2], Rule::Trapezoid).is_err());
    }

    #[test]
    fn to_f32_parallel_arrays() {
        let s = Schedule::uniform(2, Rule::Left).unwrap();
        let (a, w) = s.to_f32();
        assert_eq!(a, vec![0.0, 0.5, 1.0]);
        assert_eq!(w, vec![0.5, 0.5, 0.0]);
    }

    #[test]
    fn property_nonuniform_mass_and_bounds() {
        testutil::prop(100, 21, |rng| {
            let n_int = rng.range(1, 9);
            let m = rng.range(n_int, 200);
            let deltas: Vec<f64> = (0..n_int).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let alloc = Allocation::Sqrt.allocate(m, &deltas).unwrap();
            let bounds = Schedule::probe_boundaries(n_int);
            let s = Schedule::nonuniform(&bounds, &alloc, Rule::Trapezoid).unwrap();
            assert_eq!(s.m_total, m);
            assert!((s.total_weight() - 1.0).abs() < 1e-9);
            assert!(s.points.iter().all(|p| (0.0..=1.0).contains(&p.alpha)));
            assert!(s.points.first().unwrap().alpha == 0.0);
            assert!((s.points.last().unwrap().alpha - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn property_equal_deltas_reduce_to_uniform_mass() {
        // With equal interval deltas the non-uniform schedule's quadrature
        // mass distribution matches a uniform schedule of the same m
        // (pointwise equality only when n_int divides m).
        testutil::prop(50, 22, |rng| {
            let n_int = rng.range(1, 6);
            let m = n_int * rng.range(1, 20);
            let alloc = Allocation::Sqrt.allocate(m, &vec![0.5; n_int]).unwrap();
            assert!(alloc.iter().all(|&a| a == m / n_int));
            let s = Schedule::nonuniform(&Schedule::probe_boundaries(n_int), &alloc, Rule::Trapezoid).unwrap();
            assert!((s.total_weight() - 1.0).abs() < 1e-9);
        });
    }
}
