//! The completeness residual δ (Eq. 3), the iso-convergence search, and
//! the anytime refinement gate.
//!
//! The paper's protocol (Fig. 5b): fix a threshold δ_th, walk a step-count
//! grid upward, report the first m whose δ ≤ δ_th. The grid here matches
//! the ~1.5x-spaced grid used for all figure benches.
//!
//! Two drivers build on it:
//!
//! * [`ConvergencePolicy`] — the paper's protocol verbatim: re-run at each
//!   grid m from scratch (each probe costs the full schedule);
//! * [`AnytimePolicy`] — the gate for the *anytime* engine
//!   ([`crate::ig::explain_anytime`]): refine the schedule in place
//!   (doubling m, reusing every already-evaluated gradient) until δ meets
//!   the target or the next doubling would blow the `max_m` budget, so
//!   the total gradient cost is the *final* schedule's length, not the
//!   sum over rounds.

use anyhow::{ensure, Result};

/// δ = |Σφ − (f(x) − f(x'))|.
pub fn delta(attr_sum: f64, endpoint_gap: f64) -> f64 {
    (attr_sum - endpoint_gap).abs()
}

/// The step-count search grid (≈1.5x spacing, the paper's working range).
pub fn default_grid() -> Vec<usize> {
    vec![8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512]
}

/// Iso-convergence search policy.
#[derive(Debug, Clone)]
pub struct ConvergencePolicy {
    /// Target completeness residual.
    pub delta_th: f64,
    /// Step-count grid to walk (ascending).
    pub grid: Vec<usize>,
}

impl ConvergencePolicy {
    /// Policy over the default ~1.5x-spaced grid.
    pub fn new(delta_th: f64) -> Self {
        ConvergencePolicy { delta_th, grid: default_grid() }
    }

    /// Policy over a custom ascending step grid.
    pub fn with_grid(delta_th: f64, grid: Vec<usize>) -> Result<Self> {
        ensure!(!grid.is_empty(), "empty step grid");
        ensure!(grid.windows(2).all(|w| w[0] < w[1]), "grid must be ascending");
        Ok(ConvergencePolicy { delta_th, grid })
    }

    /// Walk the grid until `run(m)` yields δ ≤ δ_th.
    ///
    /// Returns `(m, delta, converged)`; if nothing on the grid converges,
    /// returns the last grid point with `converged = false` (the paper's
    /// figures simply extend the axis; we surface the failure).
    pub fn search<E, F: FnMut(usize) -> Result<f64, E>>(
        &self,
        mut run: F,
    ) -> Result<(usize, f64, bool), E> {
        let mut last = (self.grid[0], f64::INFINITY);
        for &m in &self.grid {
            let d = run(m)?;
            if d <= self.delta_th {
                return Ok((m, d, true));
            }
            last = (m, d);
        }
        Ok((last.0, last.1, false))
    }
}

/// Convergence gate for anytime refinement: stop once the completeness
/// residual meets `delta_target`, or once doubling the schedule again
/// would exceed the `max_m` interval budget (the unconverged best-so-far
/// attribution is still delivered — that is the "anytime" contract).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnytimePolicy {
    /// Stop refining once δ ≤ this.
    pub delta_target: f64,
    /// Hard cap on grid intervals m: a refinement round never starts if
    /// it would push `m_total` past this.
    pub max_m: usize,
}

impl AnytimePolicy {
    /// Upper end of [`default_grid`] — the default refinement budget.
    pub const DEFAULT_MAX_M: usize = 512;

    /// Gate with the default 512-interval budget.
    pub fn new(delta_target: f64) -> Self {
        AnytimePolicy { delta_target, max_m: Self::DEFAULT_MAX_M }
    }

    /// Gate with an explicit interval budget.
    pub fn with_max_m(delta_target: f64, max_m: usize) -> Result<Self> {
        ensure!(max_m >= 1, "max_m must be >= 1");
        ensure!(delta_target.is_finite() && delta_target >= 0.0, "delta_target must be finite and >= 0");
        Ok(AnytimePolicy { delta_target, max_m })
    }

    /// Has the residual met the target?
    pub fn converged(&self, delta: f64) -> bool {
        delta <= self.delta_target
    }

    /// May a schedule currently at `m` intervals refine once more within
    /// the budget?
    pub fn can_refine(&self, m: usize) -> bool {
        m.saturating_mul(2) <= self.max_m
    }

    /// The per-round gate: refine only while unconverged and in budget.
    pub fn should_refine(&self, delta: f64, m: usize) -> bool {
        !self.converged(delta) && self.can_refine(m)
    }
}

/// Derive δ_th values from a measured uniform-baseline δ-vs-m curve, at
/// the paper's relative positions. The paper uses absolute thresholds
/// (0.005–0.02) tuned to InceptionV3's δ scale; our model has its own
/// scale, so thresholds are taken as the baseline's δ at m ∈ {16, 32, 64,
/// 128} — preserving the "tight to loose" sweep shape (see DESIGN.md §4).
pub fn thresholds_from_baseline(curve: &[(usize, f64)], at_m: &[usize]) -> Vec<f64> {
    at_m.iter()
        .filter_map(|m| {
            curve
                .iter()
                .find(|(cm, _)| cm == m)
                .map(|(_, d)| *d)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_abs() {
        assert!((delta(0.9, 1.0) - delta(1.1, 1.0)).abs() < 1e-12);
        assert_eq!(delta(1.0, 1.0), 0.0);
    }

    #[test]
    fn grid_ascending() {
        let g = default_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(g[0], 8);
        assert_eq!(*g.last().unwrap(), 512);
    }

    #[test]
    fn search_finds_first_converged() {
        let pol = ConvergencePolicy::with_grid(0.1, vec![2, 4, 8, 16]).unwrap();
        // δ(m) = 1/m: converges at m = 16? 1/16 = 0.0625 <= 0.1; m=8 -> 0.125 > 0.1
        let (m, d, ok) = pol.search(|m| Ok::<f64, ()>(1.0 / m as f64)).unwrap();
        assert!(ok);
        assert_eq!(m, 16);
        assert!((d - 0.0625).abs() < 1e-12);
    }

    #[test]
    fn search_reports_non_convergence() {
        let pol = ConvergencePolicy::with_grid(1e-9, vec![2, 4]).unwrap();
        let (m, d, ok) = pol.search(|m| Ok::<f64, ()>(1.0 / m as f64)).unwrap();
        assert!(!ok);
        assert_eq!(m, 4);
        assert_eq!(d, 0.25);
    }

    #[test]
    fn search_propagates_errors() {
        let pol = ConvergencePolicy::new(0.1);
        let r = pol.search(|_| Err::<f64, &str>("boom"));
        assert_eq!(r.unwrap_err(), "boom");
    }

    #[test]
    fn with_grid_validates() {
        assert!(ConvergencePolicy::with_grid(0.1, vec![]).is_err());
        assert!(ConvergencePolicy::with_grid(0.1, vec![4, 4]).is_err());
        assert!(ConvergencePolicy::with_grid(0.1, vec![8, 4]).is_err());
    }

    #[test]
    fn anytime_gate_logic() {
        let p = AnytimePolicy::with_max_m(0.01, 64).unwrap();
        assert!(p.converged(0.01));
        assert!(!p.converged(0.011));
        assert!(p.can_refine(32));
        assert!(!p.can_refine(33));
        assert!(p.should_refine(0.5, 16));
        assert!(!p.should_refine(0.005, 16), "converged: no more rounds");
        assert!(!p.should_refine(0.5, 64), "budget: no more rounds");
    }

    #[test]
    fn anytime_policy_validates() {
        assert!(AnytimePolicy::with_max_m(0.01, 0).is_err());
        assert!(AnytimePolicy::with_max_m(-1.0, 8).is_err());
        assert!(AnytimePolicy::with_max_m(f64::NAN, 8).is_err());
        assert_eq!(AnytimePolicy::new(0.1).max_m, AnytimePolicy::DEFAULT_MAX_M);
    }

    #[test]
    fn thresholds_from_curve() {
        let curve = vec![(16, 0.08), (32, 0.04), (64, 0.02)];
        assert_eq!(thresholds_from_baseline(&curve, &[16, 64]), vec![0.08, 0.02]);
        assert_eq!(thresholds_from_baseline(&curve, &[99]), Vec::<f64>::new());
    }
}
