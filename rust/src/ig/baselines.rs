//! Baseline construction for path attribution.
//!
//! A baseline x′ encodes "missingness" (§II): the paper uses black; the
//! literature ([8] Sturmfels et al.) also uses white, gray, and random
//! noise, and averages attributions over several baselines. This module
//! builds them deterministically so every run is reproducible.

use anyhow::{bail, Result};

use crate::data::synth;

/// Baseline families from the IG literature.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BaselineKind {
    /// All-zeros image (the paper's default).
    Black,
    /// All-ones image.
    White,
    /// Constant mid-gray (0.5).
    Gray,
    /// Uniform noise in [0,1), seeded (counter-based, reproducible).
    Noise { seed: u64 },
}

impl std::fmt::Display for BaselineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BaselineKind::Black => write!(f, "black"),
            BaselineKind::White => write!(f, "white"),
            BaselineKind::Gray => write!(f, "gray"),
            BaselineKind::Noise { seed } => write!(f, "noise:{seed}"),
        }
    }
}

impl BaselineKind {
    /// Parse `black|white|gray|noise:<seed>`.
    pub fn parse(s: &str) -> Result<BaselineKind> {
        Ok(match s {
            "black" => BaselineKind::Black,
            "white" => BaselineKind::White,
            "gray" => BaselineKind::Gray,
            _ => {
                if let Some(seed) = s.strip_prefix("noise:") {
                    BaselineKind::Noise { seed: seed.parse()? }
                } else {
                    bail!("unknown baseline {s:?} (black|white|gray|noise:<seed>)")
                }
            }
        })
    }

    /// Materialize an `n`-feature baseline image.
    pub fn build(&self, n: usize) -> Vec<f32> {
        match self {
            BaselineKind::Black => vec![0.0; n],
            BaselineKind::White => vec![1.0; n],
            BaselineKind::Gray => vec![0.5; n],
            BaselineKind::Noise { seed } => {
                (0..n).map(|i| synth::draw_u01(*seed, i as u64)).collect()
            }
        }
    }

    /// The multi-baseline set used by [`super::ensemble::multi_baseline`]:
    /// black + white + `n_noise` seeded noise baselines.
    pub fn standard_set(n_noise: usize) -> Vec<BaselineKind> {
        let mut set = vec![BaselineKind::Black, BaselineKind::White];
        set.extend((0..n_noise).map(|i| BaselineKind::Noise { seed: 0xBA5E + i as u64 }));
        set
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_baselines() {
        assert!(BaselineKind::Black.build(8).iter().all(|&v| v == 0.0));
        assert!(BaselineKind::White.build(8).iter().all(|&v| v == 1.0));
        assert!(BaselineKind::Gray.build(8).iter().all(|&v| v == 0.5));
    }

    #[test]
    fn noise_deterministic_and_in_range() {
        let a = BaselineKind::Noise { seed: 1 }.build(256);
        let b = BaselineKind::Noise { seed: 1 }.build(256);
        let c = BaselineKind::Noise { seed: 2 }.build(256);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn parse_roundtrip() {
        for k in [
            BaselineKind::Black,
            BaselineKind::White,
            BaselineKind::Gray,
            BaselineKind::Noise { seed: 7 },
        ] {
            assert_eq!(BaselineKind::parse(&k.to_string()).unwrap(), k);
        }
        assert!(BaselineKind::parse("plaid").is_err());
        assert!(BaselineKind::parse("noise:x").is_err());
    }

    #[test]
    fn standard_set_composition() {
        let set = BaselineKind::standard_set(3);
        assert_eq!(set.len(), 5);
        assert_eq!(set[0], BaselineKind::Black);
        assert_eq!(set[1], BaselineKind::White);
        assert!(matches!(set[2], BaselineKind::Noise { .. }));
        // Distinct noise seeds.
        assert_ne!(set[2].build(16), set[3].build(16));
    }
}
