//! The probe-schedule cache: amortize stage 1 across requests.
//!
//! The paper prices stage 1 at 0.2–3.2 % of an explanation (worse at
//! small m), and the serving path pays it per request — `n_int + 1`
//! forward passes plus allocation + grid building + fusion, all to
//! produce a schedule that is *almost always the same* for traffic that
//! explains the same class against the same baseline (Fig. 3: the path
//! information profile is a property of the class's saturation shape far
//! more than of the individual input). This module makes that reuse
//! explicit:
//!
//! * [`ProbeSignature`] — the probe's normalized interval deltas,
//!   quantized to a `1/64` grid ([`SIGNATURE_QUANT`]). Two probes whose
//!   deltas agree to the quantization step produce the same signature and
//!   therefore share one cached schedule. The quantization is mirrored
//!   bit-for-bit by `python/compile/igref.py::quantize_signature` and
//!   pinned by parity tests on both sides.
//! * [`CacheKey`] — `(target class, baseline id, signature, m, rule,
//!   allocation)`: everything the fused schedule depends on. The cached
//!   schedule is **canonical**: built from the *dequantized* signature,
//!   not from whichever request populated the entry, so cache content is
//!   deterministic and hit/miss is invisible in the served numbers.
//! * [`CachedSchedule`] — the canonical fused schedule plus its lazily
//!   extended refine ladder (`level(k)` = `refine` applied `k` times),
//!   so anytime rounds reuse schedule construction too.
//! * [`ScheduleCache`] — a bounded, sharded LRU over those entries, plus
//!   a probe *memo* (most recent signature + endpoint gap per
//!   `(target, baseline, n_int)`) that lets deadline-tier admission skip
//!   stage 1 entirely on warm traffic — zero probe passes.
//!
//! The memo trade is explicit: a warm request reuses the class-level
//! signature and endpoint gap instead of probing its own input, so its
//! reported completeness residual δ is computed against the memoized gap
//! (an estimate). Tight-latency tiers accept that — their round budget is
//! a hard cap, not a convergence search; quality tiers keep probing. See
//! `docs/TUNING.md` for the tier guidance and `benches/fig_warmcache.rs`
//! for the measured stage-1 collapse.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::ig::allocator::Allocation;
use crate::ig::riemann::Rule;
use crate::metrics::CacheCounters;

use super::Schedule;

/// Quantization resolution for probe signatures: normalized interval
/// deltas are snapped to multiples of `1/SIGNATURE_QUANT`. At 64 the
/// allocation derived from a dequantized signature differs from the
/// exact-delta allocation by at most ±1 step per interval — below the
/// schedule's own discretization error. Mirrored by
/// `python/compile/igref.py::SIGNATURE_QUANT`.
pub const SIGNATURE_QUANT: f64 = 64.0;

/// FNV-1a 64 offset basis (the id of an empty baseline).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64 prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Stable identity for a baseline image: FNV-1a 64 over the f32
/// little-endian bytes. Deterministic across runs and mirrored by
/// `python/compile/igref.py::baseline_id` (parity-tested goldens).
pub fn baseline_id(baseline: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for v in baseline {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// A probe's normalized interval deltas, quantized to the
/// [`SIGNATURE_QUANT`] grid — the cache-key component that makes
/// near-identical probes collide onto one schedule.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProbeSignature {
    /// One quantized level per probe interval (`round(delta * 64)`,
    /// clamped to u8).
    levels: Vec<u8>,
}

impl ProbeSignature {
    /// Quantize normalized interval deltas. Uses `floor(d * Q + 0.5)`
    /// (round-half-up) so the Rust and Python sides are bit-identical.
    pub fn quantize(deltas: &[f64]) -> ProbeSignature {
        let levels = deltas
            .iter()
            .map(|d| {
                let q = (d.abs() * SIGNATURE_QUANT + 0.5).floor();
                if q >= 255.0 {
                    255
                } else {
                    q as u8
                }
            })
            .collect();
        ProbeSignature { levels }
    }

    /// Number of probe intervals this signature covers.
    pub fn n_int(&self) -> usize {
        self.levels.len()
    }

    /// The raw quantized levels (for diagnostics and parity tests).
    pub fn levels(&self) -> &[u8] {
        &self.levels
    }

    /// Reconstruct normalized deltas from the quantized levels
    /// (renormalized so they sum to 1; an all-zero signature falls back
    /// to an even split, matching the probe's flat-path fallback). The
    /// canonical cached schedule is built from these, so cache content
    /// does not depend on which request populated an entry.
    pub fn dequantize(&self) -> Vec<f64> {
        let n = self.levels.len();
        let sum: u32 = self.levels.iter().map(|&q| q as u32).sum();
        if sum == 0 {
            vec![1.0 / n as f64; n]
        } else {
            self.levels.iter().map(|&q| q as f64 / sum as f64).collect()
        }
    }
}

/// Everything a fused non-uniform schedule depends on: the cache key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Explained class (schedules are class-conditional: the probe reads
    /// p(target) along the path).
    pub target: usize,
    /// [`baseline_id`] of the path's start point.
    pub baseline_id: u64,
    /// Quantized probe signature (also fixes `n_int` via its length).
    pub signature: ProbeSignature,
    /// Total grid intervals m of the base (round-0) schedule.
    pub m: usize,
    /// Quadrature rule.
    pub rule: Rule,
    /// Stage-1 step-allocation policy.
    pub allocation: Allocation,
}

impl CacheKey {
    /// Build the canonical fused schedule this key denotes: equal-width
    /// probe boundaries for `signature.n_int()` intervals, the allocation
    /// applied to the *dequantized* signature, fused. Deterministic given
    /// the key alone — the property the Rust↔Python parity test pins.
    pub fn canonical_schedule(&self) -> Result<Schedule> {
        ensure!(self.signature.n_int() >= 1, "empty probe signature");
        let bounds = Schedule::probe_boundaries(self.signature.n_int());
        let deltas = self.signature.dequantize();
        let alloc = self.allocation.allocate(self.m, &deltas)?;
        Schedule::nonuniform(&bounds, &alloc, self.rule)
    }
}

/// A cached canonical schedule plus its lazily extended refine ladder.
///
/// `level(0)` is the base schedule; `level(k)` is [`Schedule::refine`]
/// applied `k` times, memoized — so anytime refinement rounds served
/// from the cache also skip schedule construction, and every consumer of
/// the same entry shares one `Arc<Schedule>` per level.
pub struct CachedSchedule {
    levels: Mutex<Vec<Arc<Schedule>>>,
}

impl CachedSchedule {
    /// Wrap a base (round-0) schedule.
    pub fn new(base: Schedule) -> CachedSchedule {
        CachedSchedule { levels: Mutex::new(vec![Arc::new(base)]) }
    }

    /// The base (round-0) schedule.
    pub fn base(&self) -> Arc<Schedule> {
        self.levels.lock().unwrap()[0].clone()
    }

    /// The `k`-times-refined schedule, extending the ladder on demand.
    /// Errors only if the base is not refinable (endpoint-pruned rules).
    pub fn level(&self, k: usize) -> Result<Arc<Schedule>> {
        let mut levels = self.levels.lock().unwrap();
        while levels.len() <= k {
            let next = levels.last().expect("ladder is never empty").refine()?;
            levels.push(Arc::new(next));
        }
        Ok(levels[k].clone())
    }

    /// Ladder depth materialized so far (≥ 1).
    pub fn ladder_len(&self) -> usize {
        self.levels.lock().unwrap().len()
    }
}

/// The most recent probe observation for a `(target, baseline, n_int)`
/// stream: what deadline-tier admission reuses to skip stage 1.
#[derive(Debug, Clone)]
pub struct ProbeMemo {
    /// Quantized signature of the last cold probe.
    pub signature: ProbeSignature,
    /// Endpoint gap `f(x) − f(x′)` observed by that probe. Warm requests
    /// report δ against this class-level estimate instead of their own
    /// (unprobed) gap — the documented tight-tier quality trade.
    pub gap: f64,
}

struct Entry {
    val: Arc<CachedSchedule>,
    last_used: u64,
}

/// Memo map: `(target, baseline id, n_int)` → most recent probe memo,
/// stamped with an LRU tick.
type MemoMap = HashMap<(usize, u64, usize), (ProbeMemo, u64)>;

/// Bounded, sharded LRU of canonical schedules plus the probe memo.
///
/// Sharding bounds lock contention: the shard index is the key hash
/// modulo the shard count, and each shard enforces `ceil(capacity /
/// shards)` entries with least-recently-used eviction (a linear min-scan — shards
/// stay small, and eviction is off the hot hit path). All counter
/// traffic lands in a shared [`CacheCounters`] so the coordinator can
/// export hit/miss/evict rates without touching the shards.
pub struct ScheduleCache {
    shards: Vec<Mutex<HashMap<CacheKey, Entry>>>,
    per_shard: usize,
    memos: Mutex<MemoMap>,
    memo_cap: usize,
    tick: AtomicU64,
    counters: Arc<CacheCounters>,
}

impl ScheduleCache {
    /// A bounded cache over `shards` shards (both args clamped to ≥ 1;
    /// shards are clamped to `capacity`).
    ///
    /// Exact bound: each shard holds at most `ceil(capacity / shards)`
    /// entries, so the total can reach `shards * ceil(capacity / shards)`
    /// — equal to `capacity` when `shards` divides it, up to
    /// `capacity + shards - 1` otherwise. Size memory off that ceiling
    /// (or pick `capacity` a multiple of `shards`, as the defaults do).
    pub fn new(capacity: usize, shards: usize) -> ScheduleCache {
        Self::with_counters(capacity, shards, Arc::new(CacheCounters::default()))
    }

    /// Like [`ScheduleCache::new`] but sharing externally owned counters
    /// (the coordinator passes the ones it exports from its stats).
    pub fn with_counters(
        capacity: usize,
        shards: usize,
        counters: Arc<CacheCounters>,
    ) -> ScheduleCache {
        let capacity = capacity.max(1);
        let shards = shards.clamp(1, capacity);
        ScheduleCache {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            per_shard: capacity.div_ceil(shards),
            memos: Mutex::new(HashMap::new()),
            memo_cap: 2 * capacity,
            tick: AtomicU64::new(0),
            counters,
        }
    }

    /// The shared hit/miss/evict/insert counters.
    pub fn counters(&self) -> &CacheCounters {
        &self.counters
    }

    fn shard_of(&self, key: &CacheKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    /// Pure lookup (refreshes recency; counts a hit or a miss).
    pub fn get(&self, key: &CacheKey) -> Option<Arc<CachedSchedule>> {
        let mut shard = self.shards[self.shard_of(key)].lock().unwrap();
        match shard.get_mut(key) {
            Some(e) => {
                e.last_used = self.next_tick();
                self.counters.hits.inc();
                Some(e.val.clone())
            }
            None => {
                self.counters.misses.inc();
                None
            }
        }
    }

    /// Lookup, building and inserting the canonical schedule on a miss
    /// (the cold-traffic populate path). The build runs outside the
    /// shard lock; a racing populator's entry wins, so all callers of
    /// one key share a single [`CachedSchedule`].
    pub fn get_or_build(&self, key: &CacheKey) -> Result<Arc<CachedSchedule>> {
        let idx = self.shard_of(key);
        {
            let mut shard = self.shards[idx].lock().unwrap();
            if let Some(e) = shard.get_mut(key) {
                e.last_used = self.next_tick();
                self.counters.hits.inc();
                return Ok(e.val.clone());
            }
        }
        self.counters.misses.inc();
        let built = Arc::new(CachedSchedule::new(key.canonical_schedule()?));
        let mut shard = self.shards[idx].lock().unwrap();
        if let Some(e) = shard.get_mut(key) {
            // A racing builder inserted first: reuse its entry.
            e.last_used = self.next_tick();
            return Ok(e.val.clone());
        }
        if shard.len() >= self.per_shard {
            let victim = shard.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone());
            if let Some(victim) = victim {
                shard.remove(&victim);
                self.counters.evictions.inc();
            }
        }
        self.counters.insertions.inc();
        shard.insert(key.clone(), Entry { val: built.clone(), last_used: self.next_tick() });
        Ok(built)
    }

    /// Entries currently cached, across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether no schedule is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The most recent probe memo for `(target, baseline, n_int)`, if
    /// any cold probe has populated it — the warm-admission lookup.
    pub fn memo(&self, target: usize, baseline_id: u64, n_int: usize) -> Option<ProbeMemo> {
        self.memos.lock().unwrap().get(&(target, baseline_id, n_int)).map(|(m, _)| m.clone())
    }

    /// Record a cold probe's observation so subsequent requests for the
    /// same `(target, baseline, n_int)` can skip stage 1. Bounded at
    /// `2 × capacity` memos with oldest-entry eviction.
    pub fn memo_put(&self, target: usize, baseline_id: u64, memo: ProbeMemo) {
        let mut memos = self.memos.lock().unwrap();
        let key = (target, baseline_id, memo.signature.n_int());
        let tick = self.next_tick();
        memos.insert(key, (memo, tick));
        if memos.len() > self.memo_cap {
            let victim = memos.iter().min_by_key(|(_, (_, t))| *t).map(|(k, _)| *k);
            if let Some(victim) = victim {
                memos.remove(&victim);
            }
        }
    }

    /// Probe memos currently held.
    pub fn memo_len(&self) -> usize {
        self.memos.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(target: usize, deltas: &[f64], m: usize) -> CacheKey {
        CacheKey {
            target,
            baseline_id: baseline_id(&[0.0; 4]),
            signature: ProbeSignature::quantize(deltas),
            m,
            rule: Rule::Trapezoid,
            allocation: Allocation::Sqrt,
        }
    }

    #[test]
    fn quantization_parity_goldens() {
        // Pinned on the Python side by tests/test_cache_parity.py — any
        // drift breaks cross-language cache-key agreement.
        let sig = ProbeSignature::quantize(&[0.625, 0.25, 0.0625, 0.0625]);
        assert_eq!(sig.levels(), &[40, 16, 4, 4]);
        assert_eq!(ProbeSignature::quantize(&[0.7, 0.2, 0.08, 0.02]).levels(), &[45, 13, 5, 1]);
        assert_eq!(ProbeSignature::quantize(&[1.0]).levels(), &[64]);
        // Out-of-range inputs clamp instead of wrapping.
        assert_eq!(ProbeSignature::quantize(&[5.0]).levels(), &[255]);
    }

    #[test]
    fn baseline_id_parity_goldens() {
        // Pinned on the Python side by tests/test_cache_parity.py.
        assert_eq!(baseline_id(&[]), 0xcbf29ce484222325);
        assert_eq!(baseline_id(&[0.0; 4]), 0x88201fb960ff6465);
        assert_eq!(baseline_id(&[0.0, 0.25, 0.5, 1.0]), 0xd831ed359a404d8b);
        assert_eq!(baseline_id(&[0.5; 64]), 0xed65da9ccebf6d25);
    }

    #[test]
    fn dequantize_renormalizes_exactly() {
        let sig = ProbeSignature::quantize(&[0.7, 0.2, 0.08, 0.02]);
        // Levels [45, 13, 5, 1] sum to 64: dyadic, exact in f64.
        assert_eq!(sig.dequantize(), vec![0.703125, 0.203125, 0.078125, 0.015625]);
        let flat = ProbeSignature { levels: vec![0, 0, 0] };
        assert_eq!(flat.dequantize(), vec![1.0 / 3.0; 3]);
    }

    #[test]
    fn quantization_collapses_near_identical_probes() {
        let a = ProbeSignature::quantize(&[0.7001, 0.1999, 0.08, 0.02]);
        let b = ProbeSignature::quantize(&[0.6999, 0.2001, 0.08, 0.02]);
        assert_eq!(a, b, "probes within the quantization step must share a key");
    }

    #[test]
    fn canonical_schedule_is_fused_and_deterministic() {
        let k = key(0, &[0.7, 0.2, 0.08, 0.02], 32);
        let s = k.canonical_schedule().unwrap();
        assert!(s.is_fused());
        assert_eq!(s.len(), 32 + 1, "trapezoid fused len is m + 1");
        assert_eq!(s.m_total, 32);
        // Identical to building directly from the dequantized deltas.
        let bounds = Schedule::probe_boundaries(4);
        let alloc = Allocation::Sqrt.allocate(32, &k.signature.dequantize()).unwrap();
        let direct = Schedule::nonuniform(&bounds, &alloc, Rule::Trapezoid).unwrap();
        assert_eq!(s, direct);
    }

    #[test]
    fn get_or_build_counts_miss_then_hit_and_shares_the_entry() {
        let cache = ScheduleCache::new(8, 2);
        let k = key(1, &[0.6, 0.25, 0.1, 0.05], 16);
        let a = cache.get_or_build(&k).unwrap();
        assert_eq!(cache.counters().misses.get(), 1);
        assert_eq!(cache.counters().insertions.get(), 1);
        let b = cache.get_or_build(&k).unwrap();
        assert_eq!(cache.counters().hits.get(), 1);
        assert!(Arc::ptr_eq(&a, &b), "one canonical entry per key");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn get_reports_miss_without_building() {
        let cache = ScheduleCache::new(4, 1);
        assert!(cache.get(&key(0, &[1.0], 8)).is_none());
        assert_eq!(cache.counters().misses.get(), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn lru_evicts_the_stale_entry() {
        let cache = ScheduleCache::new(2, 1);
        let k1 = key(1, &[0.9, 0.1], 8);
        let k2 = key(2, &[0.9, 0.1], 8);
        let k3 = key(3, &[0.9, 0.1], 8);
        cache.get_or_build(&k1).unwrap();
        cache.get_or_build(&k2).unwrap();
        // Touch k1 so k2 is the LRU victim.
        assert!(cache.get(&k1).is_some());
        cache.get_or_build(&k3).unwrap();
        assert_eq!(cache.counters().evictions.get(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&k1).is_some(), "recently used entry survives");
        assert!(cache.get(&k2).is_none(), "LRU entry evicted");
    }

    #[test]
    fn sharded_capacity_is_bounded() {
        let cache = ScheduleCache::new(8, 4);
        for t in 0..50 {
            cache.get_or_build(&key(t, &[0.5, 0.3, 0.15, 0.05], 16)).unwrap();
        }
        assert!(cache.len() <= 8, "total entries {} exceed capacity", cache.len());
        assert!(cache.counters().evictions.get() >= 42);
    }

    #[test]
    fn refine_ladder_levels_match_direct_refinement() {
        let cache = ScheduleCache::new(4, 1);
        let k = key(0, &[0.7, 0.2, 0.08, 0.02], 16);
        let cached = cache.get_or_build(&k).unwrap();
        let base = cached.base();
        let l2 = cached.level(2).unwrap();
        assert_eq!(l2.m_total, 4 * base.m_total);
        let direct = base.refine().unwrap().refine().unwrap();
        assert_eq!(*l2, direct);
        assert_eq!(cached.ladder_len(), 3);
        // Re-requesting a level reuses the memoized Arc.
        assert!(Arc::ptr_eq(&l2, &cached.level(2).unwrap()));
    }

    #[test]
    fn memo_roundtrip_and_bound() {
        let cache = ScheduleCache::new(2, 1); // memo_cap = 4
        let sig = ProbeSignature::quantize(&[0.8, 0.1, 0.05, 0.05]);
        cache.memo_put(3, 42, ProbeMemo { signature: sig.clone(), gap: 0.87 });
        let m = cache.memo(3, 42, 4).expect("memo present");
        assert_eq!(m.signature, sig);
        assert!((m.gap - 0.87).abs() < 1e-12);
        assert!(cache.memo(3, 42, 8).is_none(), "n_int is part of the memo key");
        assert!(cache.memo(4, 42, 4).is_none());
        // Overwrite is an update, not a second entry.
        cache.memo_put(3, 42, ProbeMemo { signature: sig.clone(), gap: 0.5 });
        assert_eq!(cache.memo_len(), 1);
        assert!((cache.memo(3, 42, 4).unwrap().gap - 0.5).abs() < 1e-12);
        // Bound: oldest memo evicted past 2 x capacity.
        for t in 0..10 {
            cache.memo_put(t, 7, ProbeMemo { signature: sig.clone(), gap: 0.0 });
        }
        assert!(cache.memo_len() <= 4);
    }

    #[test]
    fn concurrent_populate_converges_to_one_entry() {
        let cache = Arc::new(ScheduleCache::new(8, 2));
        let k = key(0, &[0.6, 0.25, 0.1, 0.05], 32);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let cache = cache.clone();
                let k = k.clone();
                std::thread::spawn(move || cache.get_or_build(&k).unwrap())
            })
            .collect();
        let arcs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for a in &arcs[1..] {
            assert!(Arc::ptr_eq(&arcs[0], a), "racing populators must share one entry");
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.counters().insertions.get(), 1);
    }

    #[test]
    fn left_rule_key_builds_but_cannot_ladder() {
        let k = CacheKey { rule: Rule::Left, ..key(0, &[0.7, 0.3], 8) };
        let cached = CachedSchedule::new(k.canonical_schedule().unwrap());
        assert!(cached.level(0).is_ok());
        assert!(cached.level(1).is_err(), "endpoint-pruned rules cannot refine");
    }
}
