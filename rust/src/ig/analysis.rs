//! Path-information analysis behind the paper's Fig. 3: how the target
//! probability, its path-derivative (≈ gradient magnitude), and the
//! contribution to convergence distribute along the IG path.

use anyhow::{ensure, Result};

use crate::exec::batch::BatchExec;

use super::model::{eval_points, Model};
use super::schedule::Schedule;
use super::riemann::Rule;

/// Fig. 3 statistics for one input.
#[derive(Debug, Clone)]
pub struct PathInfo {
    /// Sampled alphas (uniform dense grid).
    pub alphas: Vec<f64>,
    /// p(target) at each alpha — Fig. 3(b).
    pub probs: Vec<f64>,
    /// |dp/dα| (central finite differences) — the path-derivative whose
    /// magnitude tracks gradient magnitude along the path, Fig. 3(c).
    pub dprob: Vec<f64>,
    /// Per-interval share of Σ|dp/dα| for `n_int` equal intervals.
    pub interval_share: Vec<f64>,
    /// The class whose probability path was sampled.
    pub target: usize,
}

/// Sample the path at `samples+1` uniform points and compute Fig. 3's
/// series. Runs the batched backend with zero weights — forward-only cost.
pub fn path_info(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    samples: usize,
    n_int: usize,
) -> Result<PathInfo> {
    ensure!(samples >= 2, "need >= 2 samples");
    ensure!(n_int >= 1 && samples % n_int == 0, "n_int must divide samples");
    let sched = Schedule::uniform(samples, Rule::Trapezoid)?;
    let (alphas_f32, _) = sched.to_f32();
    let zeros = vec![0f32; alphas_f32.len()];
    let out = eval_points(model, x, baseline, &alphas_f32, &zeros, target, &BatchExec::Sequential)?;

    let alphas: Vec<f64> = sched.points.iter().map(|p| p.alpha).collect();
    let probs = out.target_probs;
    let h = 1.0 / samples as f64;
    let n = probs.len();
    let dprob: Vec<f64> = (0..n)
        .map(|i| {
            if i == 0 {
                (probs[1] - probs[0]) / h
            } else if i == n - 1 {
                (probs[n - 1] - probs[n - 2]) / h
            } else {
                (probs[i + 1] - probs[i - 1]) / (2.0 * h)
            }
            .abs()
        })
        .collect();

    // Per-interval share of the derivative mass, computed as trapezoidal
    // segment masses so the shares partition exactly (sum to 1).
    let per = samples / n_int;
    let seg_mass = |k: usize| (dprob[k] + dprob[k + 1]) / 2.0;
    // nuig:allow(float-reduce): sequential in-order range iteration — fixed order
    let total: f64 = (0..samples).map(seg_mass).sum();
    let interval_share: Vec<f64> = (0..n_int)
        .map(|i| {
            // nuig:allow(float-reduce): sequential in-order range iteration — fixed order
            let s: f64 = (i * per..(i + 1) * per).map(seg_mass).sum();
            if total > 0.0 {
                s / total
            } else {
                1.0 / n_int as f64
            }
        })
        .collect();

    Ok(PathInfo { alphas, probs, dprob, interval_share, target })
}

impl PathInfo {
    /// The alpha by which `q` of the total probability change has happened
    /// (Fig. 3's ">90 % of final value by α = 0.25"-style statistic).
    pub fn alpha_at_change_fraction(&self, q: f64) -> f64 {
        let total = self.probs.last().unwrap() - self.probs[0];
        if total.abs() < 1e-12 {
            return 1.0;
        }
        for (i, &p) in self.probs.iter().enumerate() {
            if (p - self.probs[0]) / total >= q {
                return self.alphas[i];
            }
        }
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;

    fn setup() -> (AnalyticModel, Vec<f32>, usize) {
        // High gain so the softmax saturates early along the path, like
        // the calibrated MiniInception does (Fig. 3b shape).
        let m = AnalyticModel::new(64, 4, 7, 150.0);
        let x: Vec<f32> = (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect();
        let p = m.probs(&[&x]).unwrap();
        let t = crate::ig::engine::argmax(&p[0]);
        (m, x, t)
    }

    #[test]
    fn shapes() {
        let (m, x, t) = setup();
        let info = path_info(&m, &x, &vec![0f32; 64], t, 32, 4).unwrap();
        assert_eq!(info.alphas.len(), 33);
        assert_eq!(info.probs.len(), 33);
        assert_eq!(info.dprob.len(), 33);
        assert_eq!(info.interval_share.len(), 4);
    }

    #[test]
    fn interval_share_sums_to_one() {
        let (m, x, t) = setup();
        let info = path_info(&m, &x, &vec![0f32; 64], t, 32, 8).unwrap();
        let s: f64 = info.interval_share.iter().sum();
        assert!((s - 1.0).abs() < 1e-9, "shares partition exactly: sum={s}");
    }

    #[test]
    fn probs_monotone_for_dominant_target() {
        let (m, x, t) = setup();
        let info = path_info(&m, &x, &vec![0f32; 64], t, 16, 4).unwrap();
        assert!(info.probs.last().unwrap() > &info.probs[0]);
    }

    #[test]
    fn change_concentrated_early() {
        // The saturating model puts most derivative mass early — the
        // paper's core observation.
        let (m, x, t) = setup();
        let info = path_info(&m, &x, &vec![0f32; 64], t, 32, 4).unwrap();
        assert!(
            info.interval_share[0] > info.interval_share[3],
            "{:?}",
            info.interval_share
        );
        let a90 = info.alpha_at_change_fraction(0.9);
        assert!(a90 < 0.9, "90% change by alpha={a90}");
    }

    #[test]
    fn validation() {
        let (m, x, t) = setup();
        assert!(path_info(&m, &x, &vec![0f32; 64], t, 1, 1).is_err());
        assert!(path_info(&m, &x, &vec![0f32; 64], t, 10, 3).is_err());
    }

    #[test]
    fn flat_path_even_shares() {
        let (m, x, t) = setup();
        // x as its own baseline -> constant path -> even share fallback.
        let info = path_info(&m, &x, &x, t, 16, 4).unwrap();
        for s in &info.interval_share {
            assert!((s - 0.25).abs() < 1e-9);
        }
        assert_eq!(info.alpha_at_change_fraction(0.9), 1.0);
    }
}
