//! Adaptive iso-convergence driver: "give me an explanation with δ ≤ δ_th"
//! — the deployment interface the paper's evaluation protocol implies
//! (step counts are chosen by convergence threshold, §II).
//!
//! Walks the step grid upward, *reusing stage 1* across rounds for the
//! non-uniform scheme (the probe depends only on (x, baseline, n_int),
//! not on m), so refinement pays no repeated probe cost.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::metrics::StageBreakdown;

use super::attribution::Attribution;
use super::convergence::{delta as delta_fn, ConvergencePolicy};
use super::engine::{argmax, IgOptions};
use super::model::Model;
use super::probe::Probe;
use super::schedule::Schedule;
use super::Scheme;

/// Result of an adaptive run.
#[derive(Debug, Clone)]
pub struct AdaptiveResult {
    pub attribution: Attribution,
    /// Step counts attempted, in order (last one produced `attribution`).
    pub rounds: Vec<usize>,
    /// Whether the threshold was met (false ⇒ grid exhausted; the best
    /// attempt is still returned).
    pub converged: bool,
    /// Total gradient evaluations across all rounds (the real cost:
    /// schedules are fused, so each round's count is exactly its
    /// model-eval count — `m + 1` for trapezoid schedules, uniform or
    /// non-uniform alike).
    pub total_steps: usize,
}

/// Explain to a convergence threshold.
pub fn explain_to_threshold(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
    policy: &ConvergencePolicy,
) -> Result<AdaptiveResult> {
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    ensure!(x.len() == model.features(), "image width mismatch");

    // ---- Stage 1 once: probe (also yields the target + endpoint gap). --
    let t0 = Instant::now();
    let n_int = match opts.scheme {
        Scheme::NonUniform { n_int } => n_int,
        Scheme::Uniform => 1,
    };
    let bounds = Schedule::probe_boundaries(n_int);
    let boundary_imgs: Vec<Vec<f32>> = bounds
        .iter()
        .map(|&a| {
            (0..x.len()).map(|i| baseline[i] + a as f32 * (x[i] - baseline[i])).collect()
        })
        .collect();
    let refs: Vec<&[f32]> = boundary_imgs.iter().map(|v| v.as_slice()).collect();
    let probs = model.probs(&refs)?;
    let target = argmax(&probs[probs.len() - 1]);
    let probe = Probe::new(bounds.clone(), probs.iter().map(|p| p[target]).collect())?;
    let gap = probe.endpoint_gap();
    let deltas = probe.interval_deltas();
    let t_probe = t0.elapsed();

    // ---- Refinement rounds: rebuild stage-2 schedule per m. -------------
    let mut rounds = Vec::new();
    let mut total_steps = 0usize;
    let mut best: Option<Attribution> = None;
    let mut converged = false;

    for &m in &policy.grid {
        if m < n_int {
            continue;
        }
        let t1 = Instant::now();
        // Both constructors return fused schedules: `schedule.len()` below
        // is the true per-round model-eval count.
        let schedule = match opts.scheme {
            Scheme::Uniform => Schedule::uniform(m, opts.rule)?,
            Scheme::NonUniform { .. } => {
                let alloc = opts.allocation.allocate(m, &deltas)?;
                Schedule::nonuniform(&bounds, &alloc, opts.rule)?
            }
        };
        let (alphas, weights) = schedule.to_f32();
        let t_sched = t1.elapsed();

        let t2 = Instant::now();
        let out = model.ig_points(x, baseline, &alphas, &weights, target)?;
        let t_exec = t2.elapsed();

        let sum: f64 = out.partial.iter().sum();
        let d = delta_fn(sum, gap);
        rounds.push(m);
        total_steps += schedule.len();

        let attr = Attribution {
            delta: d,
            endpoint_gap: gap,
            values: out.partial,
            target,
            steps: schedule.len(),
            // This driver really runs bounds.len() forward passes for
            // target + gap, for BOTH schemes (2 for uniform): report them,
            // so steps + probe_passes is the true eval count of this path.
            probe_passes: bounds.len(),
            breakdown: StageBreakdown {
                probe: t_probe,
                schedule: t_sched,
                execute: t_exec,
                reduce: Default::default(),
            },
        };
        let better = best.as_ref().map(|b| attr.delta < b.delta).unwrap_or(true);
        if better {
            best = Some(attr);
        }
        if d <= policy.delta_th {
            converged = true;
            break;
        }
    }

    Ok(AdaptiveResult {
        attribution: best.expect("grid has at least one feasible m"),
        rounds,
        converged,
        total_steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;

    fn model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 300.0)
    }

    fn input() -> Vec<f32> {
        (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect()
    }

    #[test]
    fn converges_and_stops() {
        let m = model();
        let x = input();
        // Find the delta at m=128 first, then demand it adaptively.
        let ref_attr = crate::ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 128, ..Default::default() },
        )
        .unwrap();
        let policy = ConvergencePolicy::new(ref_attr.delta * 1.01);
        let res = explain_to_threshold(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, ..Default::default() }, &policy).unwrap();
        assert!(res.converged);
        assert!(res.attribution.delta <= policy.delta_th);
        assert!(*res.rounds.last().unwrap() <= 128);
        // Uniform via this driver still probes the two path endpoints.
        assert_eq!(res.attribution.probe_passes, 2);
        // Rounds walk the grid in order.
        assert!(res.rounds.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn nonuniform_converges_in_fewer_rounds() {
        let m = model();
        let x = input();
        let ref_attr = crate::ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 96, ..Default::default() },
        )
        .unwrap();
        let policy = ConvergencePolicy::new(ref_attr.delta);
        let uni = explain_to_threshold(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, ..Default::default() }, &policy).unwrap();
        let non = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert!(uni.converged && non.converged);
        assert!(
            non.total_steps < uni.total_steps,
            "nonuniform total {} !< uniform total {}",
            non.total_steps,
            uni.total_steps
        );
    }

    #[test]
    fn unreachable_threshold_reports_best_attempt() {
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16]).unwrap();
        let res = explain_to_threshold(&m, &x, None, &IgOptions::default(), &policy).unwrap();
        assert!(!res.converged);
        assert_eq!(res.rounds, vec![8, 16]);
        assert!(res.attribution.delta > 1e-15);
    }

    #[test]
    fn grid_entries_below_n_int_skipped() {
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![2, 4, 8]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert_eq!(res.rounds, vec![4, 8]);
    }

    #[test]
    fn probe_time_charged_once() {
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16, 32]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        // Probe passes reported once (5), not per round.
        assert_eq!(res.attribution.probe_passes, 5);
    }
}
