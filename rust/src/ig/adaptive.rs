//! Adaptive iso-convergence driver: "give me an explanation with δ ≤ δ_th"
//! — the deployment interface the paper's evaluation protocol implies
//! (step counts are chosen by convergence threshold, §II).
//!
//! Built on the anytime machinery (`engine::refine_loop`): stage 1 runs
//! *once* (the probe depends only on `(x, baseline, n_int)`, not on m),
//! and refinement rounds double the schedule **reusing every gradient
//! already evaluated** — each round pays only the novel midpoints, so the
//! total gradient cost is the final schedule's length, not the sum over
//! rounds the old fixed-m grid walk paid. The policy's grid is read as a
//! `[start, budget]` pair: rounds double m from the starting level (the
//! first feasible entry, raised to ≥ 4 steps per probe interval so the
//! sqrt allocation keeps a non-uniform shape, clamped to the budget) and
//! interior grid entries are not visited.
//!
//! The Left/Right Riemann rules prune a zero-weight endpoint at schedule
//! build, which breaks the refinement carry identity (see
//! [`Schedule::refine`](crate::ig::schedule::Schedule::refine)); for
//! those rules the driver falls back to the
//! paper's literal protocol — rebuild and re-evaluate at each grid entry.

use anyhow::{bail, ensure, Result};

use crate::exec::batch::BatchExec;
use crate::metrics::{StageBreakdown, StageTimer};

use super::attribution::Attribution;
use super::convergence::{delta as delta_fn, ConvergencePolicy};
use super::engine::{self, IgOptions};
use super::model::{eval_points, Model};
use super::Scheme;

/// Result of an adaptive run.
#[derive(Debug, Clone)]
pub struct AdaptiveResult {
    /// The delivered attribution (the most-refined round; its `rounds` /
    /// `residuals` fields carry the per-round trajectory).
    pub attribution: Attribution,
    /// Step counts attempted, in order (last one produced `attribution`).
    pub rounds: Vec<usize>,
    /// Whether the threshold was met (false ⇒ budget exhausted; the most
    /// refined attempt is still returned — the anytime contract).
    pub converged: bool,
    /// Total gradient evaluations across all rounds. For endpoint-
    /// inclusive rules (trapezoid/eq2) refinement reuses every earlier
    /// gradient, so this equals the *final* schedule's length (`m + 1`);
    /// for Left/Right it is the sum over rebuilt grid attempts.
    pub total_steps: usize,
}

/// Explain to a convergence threshold.
///
/// For endpoint-inclusive rules the policy's grid is interpreted as a
/// `[start, budget]` pair (see the module doc): rounds run at
/// `m0, 2·m0, 4·m0, ...` up to the last grid entry, reusing every
/// evaluated gradient, and interior grid entries are not visited. For
/// Left/Right rules the grid is walked literally, entry by entry.
pub fn explain_to_threshold(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
    policy: &ConvergencePolicy,
) -> Result<AdaptiveResult> {
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    ensure!(x.len() == model.features(), "image width mismatch");
    let n_int = match opts.scheme {
        Scheme::NonUniform { n_int } => n_int,
        Scheme::Uniform => 1,
    };

    // ---- Stage 1 once: probe (also yields the target + endpoint gap). --
    let mut timer = StageTimer::start();
    let probed = engine::probe_path(model, x, baseline, n_int, None)?;
    let t_probe = timer.lap();

    // Round plan from the grid, read as a [start, budget] pair: nested
    // refinement doubles m between rounds, so interior grid entries are
    // not visited (the ~1.5x paper grid is the protocol of the
    // from-scratch search, not of incremental refinement). The first
    // feasible entry sets the starting level, raised to at least 4 steps
    // per probe interval — coarser starts quantize the sqrt allocation
    // to an even split (largest-remainder with a 1-step floor) and
    // doubling would freeze that uniform shape forever — but clamped to
    // the last entry, which acts as the refinement budget.
    let Some(first_feasible) = policy.grid.iter().copied().find(|&m| m >= n_int) else {
        bail!("no step-grid entry is >= n_int ({n_int})");
    };
    let cap = *policy.grid.last().expect("grid is validated non-empty");
    let m0 = first_feasible.max(4 * n_int).min(cap);

    if !opts.rule.keeps_endpoints() {
        return walk_grid(model, x, baseline, opts, policy, &probed, t_probe, n_int);
    }

    // ---- Incremental rounds: refine in place, pay only novel points. ----
    let initial = engine::initial_schedule(opts, m0, &probed)?;
    let run = engine::refine_loop(
        model,
        x,
        baseline,
        probed.target,
        probed.gap,
        initial,
        |s, _| s.refine(),
        |delta, m| delta > policy.delta_th && m * 2 <= cap,
        &BatchExec::Sequential,
    )?;

    let delta = *run.residuals.last().expect("at least one round");
    let converged = delta <= policy.delta_th;
    let rounds: Vec<usize> = (0..run.residuals.len()).map(|r| m0 << r).collect();
    let attribution = Attribution {
        delta,
        endpoint_gap: probed.gap,
        values: run.partial,
        target: probed.target,
        steps: run.evals,
        // This driver really runs bounds.len() forward passes for target +
        // gap, for BOTH schemes (2 for uniform): report them, so
        // steps + probe_passes is the true eval count of this path.
        probe_passes: probed.bounds.len(),
        rounds: run.residuals.len(),
        residuals: run.residuals,
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: run.t_sched,
            execute: run.t_exec,
            reduce: Default::default(),
        },
    };
    Ok(AdaptiveResult { attribution, rounds, converged, total_steps: run.evals })
}

/// The paper's literal protocol for non-refinable rules (Left/Right):
/// rebuild the schedule at each grid entry and re-evaluate from scratch,
/// reusing only the stage-1 probe. Returns the best attempt by δ.
#[allow(clippy::too_many_arguments)]
fn walk_grid(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    opts: &IgOptions,
    policy: &ConvergencePolicy,
    probed: &engine::ProbedPath,
    t_probe: std::time::Duration,
    n_int: usize,
) -> Result<AdaptiveResult> {
    let mut rounds = Vec::new();
    let mut total_steps = 0usize;
    let mut best: Option<Attribution> = None;
    let mut converged = false;

    for &m in &policy.grid {
        if m < n_int {
            continue;
        }
        let mut timer = StageTimer::start();
        let schedule = engine::initial_schedule(opts, m, probed)?;
        let (alphas, weights) = schedule.to_f32();
        let t_sched = timer.lap();

        let out =
            eval_points(model, x, baseline, &alphas, &weights, probed.target, &BatchExec::Sequential)?;
        let t_exec = timer.lap();

        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let sum: f64 = out.partial.iter().sum();
        let d = delta_fn(sum, probed.gap);
        rounds.push(m);
        total_steps += schedule.len();

        let attr = Attribution {
            delta: d,
            endpoint_gap: probed.gap,
            values: out.partial,
            target: probed.target,
            steps: schedule.len(),
            probe_passes: probed.bounds.len(),
            rounds: 1,
            residuals: vec![d],
            breakdown: StageBreakdown {
                probe: t_probe,
                schedule: t_sched,
                execute: t_exec,
                reduce: Default::default(),
            },
        };
        let better = best.as_ref().map(|b| attr.delta < b.delta).unwrap_or(true);
        if better {
            best = Some(attr);
        }
        if d <= policy.delta_th {
            converged = true;
            break;
        }
    }

    Ok(AdaptiveResult {
        attribution: best.expect("grid has at least one feasible m"),
        rounds,
        converged,
        total_steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;
    use crate::ig::Rule;

    fn model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 300.0)
    }

    fn input() -> Vec<f32> {
        (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect()
    }

    #[test]
    fn converges_and_stops() {
        let m = model();
        let x = input();
        // Find the delta at m=128 first, then demand it adaptively.
        let ref_attr = crate::ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 128, ..Default::default() },
        )
        .unwrap();
        let policy = ConvergencePolicy::new(ref_attr.delta * 1.01);
        let res = explain_to_threshold(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, ..Default::default() }, &policy).unwrap();
        assert!(res.converged);
        assert!(res.attribution.delta <= policy.delta_th);
        assert!(*res.rounds.last().unwrap() <= 128);
        // Uniform via this driver still probes the two path endpoints.
        assert_eq!(res.attribution.probe_passes, 2);
        // Rounds walk upward (doubling refinement levels).
        assert!(res.rounds.windows(2).all(|w| w[0] < w[1]));
        // Reuse: the total cost is the final schedule, not the round sum.
        assert_eq!(res.total_steps, res.rounds.last().unwrap() + 1);
        assert_eq!(res.attribution.steps, res.total_steps);
    }

    #[test]
    fn nonuniform_converges_in_fewer_rounds() {
        let m = model();
        let x = input();
        let ref_attr = crate::ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 96, ..Default::default() },
        )
        .unwrap();
        let policy = ConvergencePolicy::new(ref_attr.delta);
        let uni = explain_to_threshold(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, ..Default::default() }, &policy).unwrap();
        let non = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert!(uni.converged && non.converged);
        assert!(
            non.total_steps < uni.total_steps,
            "nonuniform total {} !< uniform total {}",
            non.total_steps,
            uni.total_steps
        );
    }

    #[test]
    fn unreachable_threshold_reports_best_attempt() {
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16]).unwrap();
        let res = explain_to_threshold(&m, &x, None, &IgOptions::default(), &policy).unwrap();
        assert!(!res.converged);
        // n_int = 4 starts at the first entry with allocation resolution
        // (>= 4 * n_int = 16), which is also the cap: a single round.
        assert_eq!(res.rounds, vec![16]);
        assert!(res.attribution.delta > 1e-15);
        assert_eq!(res.total_steps, 16 + 1);
    }

    #[test]
    fn m0_applies_allocation_resolution_floor_clamped_to_budget() {
        let m = model();
        let x = input();
        // Grid with room: starts at 4 * n_int = 16, not at the first
        // feasible entry 8, so the sqrt allocation isn't quantized even.
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16, 32]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert_eq!(res.rounds, vec![16, 32]);
        // Sparse grid: the floor must NOT jump to a huge entry — it is
        // clamped between the first feasible entry and the budget, so a
        // [8, 512] grid still starts at 16 and doubles from there.
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 512]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert_eq!(res.rounds, vec![16, 32, 64, 128, 256, 512]);
        assert_eq!(res.total_steps, 512 + 1);
    }

    #[test]
    fn grid_entries_below_n_int_skipped() {
        // Entries below n_int are infeasible; with the resolution floor
        // (4 * n_int = 16) clamped to the grid's cap, the single round
        // runs at the cap.
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![2, 4, 8]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert_eq!(res.rounds, vec![8]);
        assert_eq!(res.total_steps, 8 + 1);
    }

    #[test]
    fn probe_time_charged_once() {
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16, 32]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() },
            &policy,
        )
        .unwrap();
        // Probe passes reported once (5), not per round.
        assert_eq!(res.attribution.probe_passes, 5);
    }

    #[test]
    fn residual_trajectory_reported_per_round() {
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16, 32, 64]).unwrap();
        let res = explain_to_threshold(&m, &x, None, &IgOptions::default(), &policy).unwrap();
        // Default opts are nonuniform n_int = 4: rounds start at 16.
        assert_eq!(res.rounds, vec![16, 32, 64]);
        assert_eq!(res.attribution.rounds, 3);
        assert_eq!(res.attribution.residuals.len(), 3);
        assert_eq!(*res.attribution.residuals.last().unwrap(), res.attribution.delta);
        assert!(
            res.attribution.residuals.last().unwrap() < res.attribution.residuals.first().unwrap(),
            "refinement must tighten the residual: {:?}",
            res.attribution.residuals
        );
    }

    #[test]
    fn incremental_matches_direct_final_round() {
        // The reused-gradient accumulator must equal a from-scratch run of
        // the final round's schedule (engine parity at 1e-9).
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16, 32]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, ..Default::default() },
            &policy,
        )
        .unwrap();
        let direct = crate::ig::explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 32, ..Default::default() },
        )
        .unwrap();
        crate::testutil::assert_allclose(&res.attribution.values, &direct.values, 0.0, 1e-9);
    }

    #[test]
    fn left_rule_falls_back_to_grid_walk() {
        // Left/Right cannot refine in place: the driver rebuilds per grid
        // entry and total_steps is the (honest) sum over attempts.
        let m = model();
        let x = input();
        let policy = ConvergencePolicy::with_grid(1e-15, vec![8, 16]).unwrap();
        let res = explain_to_threshold(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, rule: Rule::Left, ..Default::default() },
            &policy,
        )
        .unwrap();
        assert_eq!(res.rounds, vec![8, 16]);
        // Left-rule fused schedules have m points each (endpoint pruned).
        assert_eq!(res.total_steps, 8 + 16);
        assert!(!res.converged);
    }
}
