//! The two IG engines: baseline uniform interpolation (Eq. 2) and the
//! paper's two-stage non-uniform interpolation.
//!
//! Both are thin orchestrations over [`Model`]: build a fused [`Schedule`]
//! (coincident boundary points merged, zero-weight points pruned — see
//! `schedule.rs`), evaluate it via `Model::ig_points` (which chunks to the
//! executable width), and account for completeness. `Attribution.steps`
//! is exactly `schedule.len()`, the true number of gradient (fwd+bwd)
//! model evaluations; forward-only passes are counted in `probe_passes`.
//! Stage timing is recorded so the overhead figures (Fig. 6b) come from
//! real measurements.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::metrics::StageBreakdown;

use super::allocator::Allocation;
use super::attribution::Attribution;
use super::convergence;
use super::model::Model;
use super::probe::Probe;
use super::riemann::Rule;
use super::schedule::Schedule;
use super::Scheme;

/// Per-explanation options.
#[derive(Debug, Clone, Copy)]
pub struct IgOptions {
    pub scheme: Scheme,
    /// Total interpolation steps m (stage-2 budget).
    pub m: usize,
    pub rule: Rule,
    pub allocation: Allocation,
}

impl Default for IgOptions {
    fn default() -> Self {
        IgOptions {
            scheme: Scheme::NonUniform { n_int: 4 },
            m: 64,
            rule: Rule::Trapezoid,
            allocation: Allocation::Sqrt,
        }
    }
}

/// Explain `x` against `baseline` (black if `None`), targeting the model's
/// predicted class.
pub fn explain(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
) -> Result<Attribution> {
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    let probs = model.probs(&[x])?;
    let target = argmax(&probs[0]);
    explain_with_target(model, x, baseline, target, opts)
}

/// Explain with a pinned target class.
pub fn explain_with_target(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    ensure!(x.len() == model.features(), "image width {} != model features {}", x.len(), model.features());
    ensure!(baseline.len() == x.len(), "baseline width mismatch");
    ensure!(target < model.num_classes(), "target {target} out of range");
    ensure!(opts.m >= 1, "m must be >= 1");

    match opts.scheme {
        Scheme::Uniform => uniform_ig(model, x, baseline, target, opts),
        Scheme::NonUniform { n_int } => nonuniform_ig(model, x, baseline, target, n_int, opts),
    }
}

fn uniform_ig(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    let t0 = Instant::now();
    let schedule = Schedule::uniform(opts.m, opts.rule)?;
    let (alphas, weights) = schedule.to_f32();
    let t_sched = t0.elapsed();

    let t1 = Instant::now();
    let out = model.ig_points(x, baseline, &alphas, &weights, target)?;
    let t_exec = t1.elapsed();

    // Endpoint gap: read off the schedule's own endpoint probabilities
    // when the fused grid still includes the path endpoints (trapezoid,
    // eq2); the Left/Right rules prune a zero-weight endpoint at build,
    // so the missing endpoint is evaluated directly — a forward-only
    // pass, counted in `probe_passes` and timed under `breakdown.probe`
    // (it is probe-shaped work, and Fig. 6b reads overheads off probe).
    let t2 = Instant::now();
    let first = schedule.points.first().expect("fused schedule is non-empty");
    let last = schedule.points.last().expect("fused schedule is non-empty");
    let mut probe_passes = 0;
    let p_at_0 = if first.alpha == 0.0 {
        out.target_probs[0]
    } else {
        probe_passes += 1;
        model.probs(&[baseline])?[0][target]
    };
    let p_at_1 = if (last.alpha - 1.0).abs() < 1e-12 {
        out.target_probs[out.target_probs.len() - 1]
    } else {
        probe_passes += 1;
        model.probs(&[x])?[0][target]
    };
    let gap = p_at_1 - p_at_0;
    let t_probe = t2.elapsed();

    let t3 = Instant::now();
    let sum: f64 = out.partial.iter().sum();
    let t_reduce = t3.elapsed();

    Ok(Attribution {
        delta: convergence::delta(sum, gap),
        endpoint_gap: gap,
        values: out.partial,
        target,
        steps: schedule.len(),
        probe_passes,
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: t_sched,
            execute: t_exec,
            reduce: t_reduce,
        },
    })
}

fn nonuniform_ig(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    n_int: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    ensure!(n_int >= 1, "n_int must be >= 1");
    ensure!(opts.m >= n_int, "m ({}) must be >= n_int ({n_int})", opts.m);

    // ---- Stage 1: probe boundary probabilities (forward-only). ----------
    let t0 = Instant::now();
    let bounds = Schedule::probe_boundaries(n_int);
    let f = x.len();
    let boundary_imgs: Vec<Vec<f32>> = bounds
        .iter()
        .map(|&a| {
            (0..f)
                .map(|i| baseline[i] + a as f32 * (x[i] - baseline[i]))
                .collect()
        })
        .collect();
    let refs: Vec<&[f32]> = boundary_imgs.iter().map(|v| v.as_slice()).collect();
    let probe_probs = model.probs(&refs)?;
    let probe = Probe::new(bounds.clone(), probe_probs.iter().map(|p| p[target]).collect())?;
    let t_probe = t0.elapsed();

    // ---- Allocate + build the fused composite schedule. ------------------
    let t1 = Instant::now();
    let deltas = probe.interval_deltas();
    let alloc = opts.allocation.allocate(opts.m, &deltas)?;
    let schedule = Schedule::nonuniform(&bounds, &alloc, opts.rule)?;
    let (alphas, weights) = schedule.to_f32();
    let t_sched = t1.elapsed();

    // ---- Stage 2: one fused point stream (m + 1 evals for trapezoid). ---
    let t2 = Instant::now();
    let out = model.ig_points(x, baseline, &alphas, &weights, target)?;
    let t_exec = t2.elapsed();

    let t3 = Instant::now();
    let gap = probe.endpoint_gap();
    let sum: f64 = out.partial.iter().sum();
    let t_reduce = t3.elapsed();

    Ok(Attribution {
        delta: convergence::delta(sum, gap),
        endpoint_gap: gap,
        values: out.partial,
        target,
        steps: schedule.len(),
        probe_passes: bounds.len(),
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: t_sched,
            execute: t_exec,
            reduce: t_reduce,
        },
    })
}

/// Index of the largest non-NaN element (0 if empty or all-NaN).
///
/// Total-order comparison: a misbehaving backend can emit NaN logits, and
/// the previous `partial_cmp(..).unwrap()` aborted the whole process on
/// them. NaN entries are skipped so one poisoned lane cannot hijack the
/// target class either.
pub fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .filter(|(_, x)| !x.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;

    fn model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 40.0)
    }

    /// High-gain variant: the softmax saturates early along the path, the
    /// regime where the paper's non-uniform allocation pays off (the
    /// gain-40 model's path is near-linear, so its probe deltas are flat
    /// and the sqrt allocation legitimately degenerates to even).
    fn saturating_model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 300.0)
    }

    fn input() -> Vec<f32> {
        (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect()
    }

    fn run(m: usize, scheme: Scheme) -> Attribution {
        let opts = IgOptions { scheme, m, ..Default::default() };
        explain(&model(), &input(), None, &opts).unwrap()
    }

    #[test]
    fn uniform_step_accounting() {
        let a = run(16, Scheme::Uniform);
        assert_eq!(a.steps, 17);
        assert_eq!(a.probe_passes, 0);
    }

    #[test]
    fn nonuniform_step_accounting() {
        // Fused semantics: interval-boundary evaluations are shared, so a
        // trapezoid non-uniform schedule costs exactly m + 1 model evals —
        // not the m + n_int the unfused concatenation used to dispatch.
        let a = run(16, Scheme::NonUniform { n_int: 4 });
        assert_eq!(a.steps, 16 + 1);
        assert_eq!(a.probe_passes, 5);
        assert!(a.breakdown.probe.as_nanos() > 0);
    }

    #[test]
    fn left_rule_uniform_prunes_endpoint_and_keeps_gap() {
        // The weight-0 alpha=1 point is pruned (m evals, not m + 1); the
        // endpoint gap is recovered by one direct forward pass.
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 16, rule: Rule::Left, ..Default::default() };
        let a = explain(&m, &x, None, &opts).unwrap();
        assert_eq!(a.steps, 16);
        assert_eq!(a.probe_passes, 1);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    #[test]
    fn right_rule_uniform_prunes_endpoint_and_keeps_gap() {
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 16, rule: Rule::Right, ..Default::default() };
        let a = explain(&m, &x, None, &opts).unwrap();
        assert_eq!(a.steps, 16);
        assert_eq!(a.probe_passes, 1);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    #[test]
    fn completeness_improves_with_m() {
        let d8 = run(8, Scheme::Uniform).delta;
        let d64 = run(64, Scheme::Uniform).delta;
        let d256 = run(256, Scheme::Uniform).delta;
        assert!(d8 > d64, "{d8} !> {d64}");
        assert!(d64 > d256, "{d64} !> {d256}");
    }

    #[test]
    fn nonuniform_beats_uniform_at_iso_steps() {
        // The paper's headline effect. Needs the saturating model: with a
        // near-linear path the probe deltas are flat, the allocation is
        // even, and the fused non-uniform schedule IS the uniform one.
        let m = saturating_model();
        let x = input();
        let steps = 24;
        let du = explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: steps, ..Default::default() })
            .unwrap()
            .delta;
        let dn = explain(&m, &x, None, &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: steps, ..Default::default() })
            .unwrap()
            .delta;
        assert!(dn < du, "nonuniform {dn} !< uniform {du}");
    }

    #[test]
    fn flat_probe_degenerates_to_uniform_schedule() {
        // The gain-40 path is near-linear: the probe deltas are flat, the
        // sqrt allocation degenerates to an even split, and the fused
        // non-uniform schedule IS the uniform grid — the attributions
        // must match to f64 round-off. (Step counts being equal is true
        // by construction post-fusion; the values check is the real one.)
        let u = run(24, Scheme::Uniform);
        let n = run(24, Scheme::NonUniform { n_int: 4 });
        crate::testutil::assert_allclose(&u.values, &n.values, 1e-9, 1e-12);
    }

    #[test]
    fn engines_agree_at_high_m() {
        let u = run(512, Scheme::Uniform);
        let n = run(512, Scheme::NonUniform { n_int: 4 });
        assert!(u.cosine_similarity(&n) > 0.9999, "{}", u.cosine_similarity(&n));
        assert!((u.sum() - n.sum()).abs() < 1e-3);
    }

    #[test]
    fn nonuniform_n1_equals_uniform() {
        let u = run(32, Scheme::Uniform);
        let n = run(32, Scheme::NonUniform { n_int: 1 });
        crate::testutil::assert_allclose(&u.values, &n.values, 1e-9, 1e-12);
    }

    #[test]
    fn fused_matches_unfused_attribution() {
        // Drive `ig_points` with the raw (duplicated-boundary) schedule
        // and with its fused form: same attribution to 1e-9 through the
        // full f32 pipeline, at n_int - 1 fewer model evaluations.
        let model = saturating_model();
        let x = input();
        let baseline = vec![0f32; 64];
        let target = argmax(&model.probs(&[&x]).unwrap()[0]);

        let n_int = 4;
        let bounds = Schedule::probe_boundaries(n_int);
        let imgs: Vec<Vec<f32>> = bounds
            .iter()
            .map(|&a| x.iter().map(|&v| a as f32 * v).collect())
            .collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let probs = model.probs(&refs).unwrap();
        let probe = Probe::new(bounds.clone(), probs.iter().map(|p| p[target]).collect()).unwrap();
        let alloc = Allocation::Sqrt.allocate(24, &probe.interval_deltas()).unwrap();

        let raw = Schedule::nonuniform_unfused(&bounds, &alloc, Rule::Trapezoid).unwrap();
        let fused = raw.clone().fused();
        assert_eq!(raw.len(), 24 + n_int);
        assert_eq!(fused.len(), 24 + 1);

        let (ra, rw) = raw.to_f32();
        let (fa, fw) = fused.to_f32();
        let out_raw = model.ig_points(&x, &baseline, &ra, &rw, target).unwrap();
        let out_fused = model.ig_points(&x, &baseline, &fa, &fw, target).unwrap();
        crate::testutil::assert_allclose(&out_raw.partial, &out_fused.partial, 0.0, 1e-9);
    }

    #[test]
    fn identical_endpoints_zero() {
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 8, ..Default::default() };
        let a = explain_with_target(&m, &x, &x, 0, &opts).unwrap();
        assert!(a.delta < 1e-9);
        assert!(a.values.iter().all(|&v| v.abs() < 1e-9));
    }

    #[test]
    fn explicit_target_respected() {
        let m = model();
        let x = input();
        let b = vec![0f32; 64];
        let opts = IgOptions::default();
        let a = explain_with_target(&m, &x, &b, 2, &opts).unwrap();
        assert_eq!(a.target, 2);
    }

    #[test]
    fn validation_errors() {
        let m = model();
        let x = input();
        let opts = IgOptions::default();
        assert!(explain_with_target(&m, &x[..10], &x, 0, &opts).is_err());
        assert!(explain_with_target(&m, &x, &x[..10], 0, &opts).is_err());
        assert!(explain_with_target(&m, &x, &x, 99, &opts).is_err());
        let bad = IgOptions { m: 2, scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() };
        assert!(explain_with_target(&m, &x, &vec![0f32; 64], 0, &bad).is_err());
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // Regression: a NaN from a misbehaving backend used to abort via
        // partial_cmp().unwrap(). NaNs are skipped, not elected.
        assert_eq!(argmax(&[0.1, f64::NAN, 0.5]), 2);
        assert_eq!(argmax(&[f64::NAN, 0.3, 0.1]), 1);
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), 0);
        assert_eq!(argmax(&[f64::NEG_INFINITY, f64::NAN, -1.0]), 2);
    }

    #[test]
    fn endpoint_gap_matches_direct_eval() {
        let m = model();
        let x = input();
        let a = run(32, Scheme::Uniform);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    #[test]
    fn property_delta_scale_free_invariants() {
        crate::testutil::prop(10, 31, |rng| {
            let m = rng.range(8, 64);
            let a = run(m, Scheme::NonUniform { n_int: 4 });
            assert!(a.delta >= 0.0);
            assert!(a.relative_delta() >= 0.0);
            assert_eq!(a.values.len(), 64);
            assert_eq!(a.steps, m + 1, "steps must be the true eval count");
        });
    }
}
