//! The IG engines: baseline uniform interpolation (Eq. 2), the paper's
//! two-stage non-uniform interpolation, and the *anytime* variant.
//!
//! The fixed-m engines are thin orchestrations over [`Model`]: build a
//! fused [`Schedule`] (coincident boundary points merged, zero-weight
//! points pruned — see `schedule.rs`), evaluate it through the batched
//! execution backend (`model::eval_points`: fixed-size chunks, per-chunk
//! `Model::eval_batch`, deterministic ordered reduction — the `*_exec`
//! engine variants shard those chunks across the `exec::ThreadPool`
//! bit-identically), and account
//! for completeness. `Attribution.steps` is exactly `schedule.len()`, the
//! true number of gradient (fwd+bwd) model evaluations; forward-only
//! passes are counted in `probe_passes`. Stage timing is recorded so the
//! overhead figures (Fig. 6b) come from real measurements.
//!
//! [`explain_anytime`] replaces the fixed step count with a convergence
//! target: evaluate a small initial schedule, then repeatedly
//! [`Schedule::refine`] it — each round pays **only the novel midpoints**
//! (the carried points' weights halve exactly, so the partial quadrature
//! sum carries across rounds as `partial * REFINE_CARRY` plus the novel
//! contributions) — until the completeness residual δ meets the
//! [`AnytimePolicy`] target. Total gradient cost is the *final*
//! schedule's length, not the sum over rounds: iso-convergence without
//! ever re-evaluating an alpha.

use std::time::Duration;

use anyhow::{ensure, Result};

use crate::exec::batch::{BatchExec, PointBatch};
use crate::metrics::{StageBreakdown, StageTimer};

use super::allocator::Allocation;
use super::attribution::Attribution;
use super::convergence::{self, AnytimePolicy};
use super::model::{eval_points, Model};
use super::probe::Probe;
use super::riemann::Rule;
use super::schedule::cache::{baseline_id, CacheKey, ProbeMemo, ProbeSignature, ScheduleCache};
use super::schedule::Schedule;
use super::Scheme;

/// Per-explanation options.
#[derive(Debug, Clone, Copy)]
pub struct IgOptions {
    /// Interpolation scheme (uniform baseline vs the paper's non-uniform).
    pub scheme: Scheme,
    /// Total interpolation steps m (stage-2 budget; the *initial* level
    /// for the anytime engine, which doubles it per refinement round).
    pub m: usize,
    /// Quadrature rule for the grids.
    pub rule: Rule,
    /// Stage-1 step-allocation policy across probe intervals.
    pub allocation: Allocation,
}

impl Default for IgOptions {
    fn default() -> Self {
        IgOptions {
            scheme: Scheme::NonUniform { n_int: 4 },
            m: 64,
            rule: Rule::Trapezoid,
            allocation: Allocation::Sqrt,
        }
    }
}

/// Explain `x` against `baseline` (black if `None`), targeting the model's
/// predicted class. Sequential execution; see [`explain_exec`] for
/// intra-request parallelism.
pub fn explain(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
) -> Result<Attribution> {
    explain_exec(model, x, baseline, None, opts, &BatchExec::Sequential)
}

/// Explain under an explicit execution policy: `target` pinned or argmax
/// at the input endpoint, stage 2 dispatched through the batched backend
/// (`exec` decides inline vs pool-parallel chunk execution; attributions
/// are bit-identical either way — see `exec::batch`).
pub fn explain_exec(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    target: Option<usize>,
    opts: &IgOptions,
    exec: &BatchExec,
) -> Result<Attribution> {
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    let target = match target {
        Some(t) => t,
        None => argmax(&model.probs(&[x])?[0]),
    };
    explain_with_target_exec(model, x, baseline, target, opts, exec)
}

/// Explain with a pinned target class (sequential execution).
pub fn explain_with_target(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    explain_with_target_exec(model, x, baseline, target, opts, &BatchExec::Sequential)
}

/// Explain with a pinned target class under an explicit execution policy.
pub fn explain_with_target_exec(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
    exec: &BatchExec,
) -> Result<Attribution> {
    ensure!(x.len() == model.features(), "image width {} != model features {}", x.len(), model.features());
    ensure!(baseline.len() == x.len(), "baseline width mismatch");
    ensure!(target < model.num_classes(), "target {target} out of range");
    ensure!(opts.m >= 1, "m must be >= 1");

    match opts.scheme {
        Scheme::Uniform => uniform_ig(model, x, baseline, target, opts, exec),
        Scheme::NonUniform { n_int } => nonuniform_ig(model, x, baseline, target, n_int, opts, exec),
    }
}

/// Coincidence tolerance for recognizing the path endpoints on a fused
/// schedule. Symmetric by construction: a `0.0 + ε` first point must be
/// treated exactly like a `1.0 − ε` last point, or an ε-perturbed
/// schedule double-pays a probe pass at one end only.
const ENDPOINT_EPS: f64 = 1e-12;

/// Whether `alpha` is (within tolerance) the path endpoint `endpoint`.
fn at_endpoint(alpha: f64, endpoint: f64) -> bool {
    (alpha - endpoint).abs() < ENDPOINT_EPS
}

fn uniform_ig(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
    exec: &BatchExec,
) -> Result<Attribution> {
    let mut timer = StageTimer::start();
    let schedule = Schedule::uniform(opts.m, opts.rule)?;
    let (alphas, weights) = schedule.to_f32();
    let t_sched = timer.lap();

    let out = eval_points(model, x, baseline, &alphas, &weights, target, exec)?;
    let t_exec = timer.lap();

    // Endpoint gap: read off the schedule's own endpoint probabilities
    // when the fused grid still includes the path endpoints (trapezoid,
    // eq2); the Left/Right rules prune a zero-weight endpoint at build,
    // so the missing endpoint is evaluated directly — a forward-only
    // pass, counted in `probe_passes` and timed under `breakdown.probe`
    // (it is probe-shaped work, and Fig. 6b reads overheads off probe).
    // Both ends use the same `at_endpoint` tolerance: the old exact
    // `alpha == 0.0` check at the left end meant a `0.0 + ε` first point
    // double-paid a probe pass the right end would have absorbed.
    let first = schedule.points.first().expect("fused schedule is non-empty");
    let last = schedule.points.last().expect("fused schedule is non-empty");
    let mut probe_passes = 0;
    let p_at_0 = if at_endpoint(first.alpha, 0.0) {
        out.target_probs[0]
    } else {
        probe_passes += 1;
        model.probs(&[baseline])?[0][target]
    };
    let p_at_1 = if at_endpoint(last.alpha, 1.0) {
        out.target_probs[out.target_probs.len() - 1]
    } else {
        probe_passes += 1;
        model.probs(&[x])?[0][target]
    };
    let gap = p_at_1 - p_at_0;
    let t_probe = timer.lap();

    // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
    let sum: f64 = out.partial.iter().sum();
    let t_reduce = timer.lap();

    let delta = convergence::delta(sum, gap);
    Ok(Attribution {
        delta,
        endpoint_gap: gap,
        values: out.partial,
        target,
        steps: schedule.len(),
        probe_passes,
        rounds: 1,
        residuals: vec![delta],
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: t_sched,
            execute: t_exec,
            reduce: t_reduce,
        },
    })
}

/// Materialize the probe-boundary images for `bounds` as one planar
/// [`PointBatch`] (fused interpolation write, no per-boundary `Vec`) and
/// return the batch; callers borrow rows for `Model::probs`.
fn probe_batch(x: &[f32], baseline: &[f32], bounds: &[f64]) -> PointBatch {
    let alphas_f32: Vec<f32> = bounds.iter().map(|&b| b as f32).collect();
    let mut batch = PointBatch::new();
    batch.fill(x, baseline, &alphas_f32);
    batch
}

fn nonuniform_ig(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    n_int: usize,
    opts: &IgOptions,
    exec: &BatchExec,
) -> Result<Attribution> {
    ensure!(n_int >= 1, "n_int must be >= 1");
    ensure!(opts.m >= n_int, "m ({}) must be >= n_int ({n_int})", opts.m);

    // ---- Stage 1: probe boundary probabilities (forward-only). ----------
    let mut timer = StageTimer::start();
    let bounds = Schedule::probe_boundaries(n_int);
    let batch = probe_batch(x, baseline, &bounds);
    let refs: Vec<&[f32]> = (0..batch.rows()).map(|k| batch.row(k)).collect();
    let probe_probs = model.probs(&refs)?;
    let probe = Probe::new(bounds.clone(), probe_probs.iter().map(|p| p[target]).collect())?;
    let t_probe = timer.lap();

    // ---- Allocate + build the fused composite schedule. ------------------
    let deltas = probe.interval_deltas();
    let alloc = opts.allocation.allocate(opts.m, &deltas)?;
    let schedule = Schedule::nonuniform(&bounds, &alloc, opts.rule)?;
    let (alphas, weights) = schedule.to_f32();
    let t_sched = timer.lap();

    // ---- Stage 2: one fused point stream (m + 1 evals for trapezoid). ---
    let out = eval_points(model, x, baseline, &alphas, &weights, target, exec)?;
    let t_exec = timer.lap();

    let gap = probe.endpoint_gap();
    // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
    let sum: f64 = out.partial.iter().sum();
    let t_reduce = timer.lap();

    let delta = convergence::delta(sum, gap);
    Ok(Attribution {
        delta,
        endpoint_gap: gap,
        values: out.partial,
        target,
        steps: schedule.len(),
        probe_passes: bounds.len(),
        rounds: 1,
        residuals: vec![delta],
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: t_sched,
            execute: t_exec,
            reduce: t_reduce,
        },
    })
}

// ---------------------------------------------------------------------------
// Anytime engine: incremental refinement with convergence-gated early exit.
// ---------------------------------------------------------------------------

/// Stage-1 boundary probe shared by the anytime engine, the adaptive
/// driver, and the cache-backed engine: probe the `n_int + 1` equal-width
/// boundaries once (forward only), pick the target (pinned, or argmax at
/// the input endpoint), and read the endpoint gap + normalized interval
/// deltas off the probe.
pub struct ProbedPath {
    /// Probe boundary alphas (0, 1/n, .., 1).
    pub bounds: Vec<f64>,
    /// Explained class.
    pub target: usize,
    /// f(x) − f(x′) at the target class.
    pub gap: f64,
    /// Normalized |Δp| per interval.
    pub deltas: Vec<f64>,
}

/// Run stage 1: `n_int + 1` forward-only boundary passes. `pin` fixes the
/// explained class; `None` picks argmax at the input endpoint.
pub fn probe_path(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    n_int: usize,
    pin: Option<usize>,
) -> Result<ProbedPath> {
    let bounds = Schedule::probe_boundaries(n_int);
    let batch = probe_batch(x, baseline, &bounds);
    let refs: Vec<&[f32]> = (0..batch.rows()).map(|k| batch.row(k)).collect();
    let probs = model.probs(&refs)?;
    let target = pin.unwrap_or_else(|| argmax(&probs[probs.len() - 1]));
    let probe = Probe::new(bounds.clone(), probs.iter().map(|p| p[target]).collect())?;
    Ok(ProbedPath { bounds, target, gap: probe.endpoint_gap(), deltas: probe.interval_deltas() })
}

/// Build the round-0 schedule for `opts.scheme` at `m` grid intervals
/// from a completed stage-1 probe. Shared by the anytime engine and the
/// adaptive driver so their initial rounds are constructed identically.
pub(crate) fn initial_schedule(opts: &IgOptions, m: usize, probed: &ProbedPath) -> Result<Schedule> {
    match opts.scheme {
        Scheme::Uniform => Schedule::uniform(m, opts.rule),
        Scheme::NonUniform { .. } => {
            let alloc = opts.allocation.allocate(m, &probed.deltas)?;
            Schedule::nonuniform(&probed.bounds, &alloc, opts.rule)
        }
    }
}

/// Bookkeeping from one incremental refinement run.
pub(crate) struct RefineRun {
    /// f64 attribution accumulator at the final level.
    pub partial: Vec<f64>,
    /// Total gradient evaluations — equals the final schedule's length
    /// (nothing is ever re-evaluated).
    pub evals: usize,
    /// δ after each round (initial schedule + each refinement).
    pub residuals: Vec<f64>,
    /// The final (most refined) schedule.
    pub schedule: Schedule,
    /// Cumulative schedule-construction time across rounds.
    pub t_sched: Duration,
    /// Cumulative device-execution time across rounds.
    pub t_exec: Duration,
}

/// The incremental refinement driver: evaluate `initial` fully, then while
/// `should_refine(latest_delta, m_total)` holds, advance to the schedule
/// `next_level(&current, level)` produces (the `level`-times-refined one;
/// direct callers pass `|s, _| s.refine()`, the cache-backed engine reads
/// its memoized ladder) and evaluate **only the novel midpoints**,
/// carrying the accumulator as `partial * REFINE_CARRY + novel_partial`
/// (exact: every carried weight halves — see [`Schedule::refine`]).
///
/// There is exactly ONE copy of this round arithmetic: the uncached and
/// cached engines differ only in where the next schedule comes from, so
/// hit/miss can never change served numbers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn refine_loop(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    gap: f64,
    initial: Schedule,
    mut next_level: impl FnMut(&Schedule, usize) -> Result<Schedule>,
    mut should_refine: impl FnMut(f64, usize) -> bool,
    exec: &BatchExec,
) -> Result<RefineRun> {
    let mut t_sched = Duration::ZERO;
    let mut t_exec = Duration::ZERO;

    let mut timer = StageTimer::start();
    let mut schedule = initial;
    let (alphas, weights) = schedule.to_f32();
    t_sched += timer.lap();

    let out = eval_points(model, x, baseline, &alphas, &weights, target, exec)?;
    t_exec += timer.lap();

    let mut partial = out.partial;
    let mut evals = schedule.len();
    // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
    let mut residuals = vec![convergence::delta(partial.iter().sum(), gap)];
    let mut level = 0usize;

    while should_refine(*residuals.last().expect("non-empty"), schedule.m_total) {
        // Discard the between-round accumulation time so the sched/exec
        // split matches what each lap actually covers.
        timer.lap();
        level += 1;
        let refined = next_level(&schedule, level)?;
        let novel = refined.novel_vs(&schedule);
        let novel_alphas: Vec<f32> = novel.iter().map(|p| p.alpha as f32).collect();
        let novel_weights: Vec<f32> = novel.iter().map(|p| p.weight as f32).collect();
        t_sched += timer.lap();

        let novel_out =
            eval_points(model, x, baseline, &novel_alphas, &novel_weights, target, exec)?;
        t_exec += timer.lap();

        for (acc, nv) in partial.iter_mut().zip(&novel_out.partial) {
            *acc = *acc * Schedule::REFINE_CARRY + nv;
        }
        evals += novel.len();
        schedule = refined;
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        residuals.push(convergence::delta(partial.iter().sum(), gap));
    }
    debug_assert_eq!(evals, schedule.len(), "reuse invariant: evals == final schedule length");

    Ok(RefineRun { partial, evals, residuals, schedule, t_sched, t_exec })
}

/// Anytime IG: explain to a completeness target instead of a fixed step
/// count, reusing every evaluated gradient across refinement rounds.
///
/// Starts from `opts.m` grid intervals (the coarse level), then doubles
/// the schedule via nested refinement — paying only the novel midpoints
/// each round — until δ ≤ `policy.delta_target` or the `policy.max_m`
/// budget is reached. The returned [`Attribution`] reports the rounds and
/// the full residual trajectory; `steps` is the true total gradient cost,
/// which equals the final schedule's length.
///
/// Requires an endpoint-inclusive rule (trapezoid/eq2): Left/Right prune
/// an endpoint and cannot be refined in place.
///
/// Pick `opts.m >= 4 * n_int` for the non-uniform scheme: refinement
/// doubles the initial allocation verbatim, and a coarser start
/// quantizes the sqrt allocation to an even split (largest-remainder
/// with a 1-step floor), freezing the schedule into the uniform shape.
/// The adaptive driver applies this rule automatically.
pub fn explain_anytime(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
    policy: &AnytimePolicy,
) -> Result<Attribution> {
    explain_anytime_exec(model, x, baseline, opts, policy, &BatchExec::Sequential)
}

/// [`explain_anytime`] under an explicit execution policy: every round's
/// point stream (initial schedule and each round's novel midpoints) is
/// dispatched through the batched backend.
pub fn explain_anytime_exec(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
    policy: &AnytimePolicy,
    exec: &BatchExec,
) -> Result<Attribution> {
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    ensure!(x.len() == model.features(), "image width {} != model features {}", x.len(), model.features());
    ensure!(baseline.len() == x.len(), "baseline width mismatch");
    ensure!(opts.m >= 1, "m must be >= 1");
    ensure!(
        opts.rule.keeps_endpoints(),
        "anytime refinement requires an endpoint-inclusive rule (trapezoid/eq2), got {}",
        opts.rule
    );
    ensure!(
        opts.m <= policy.max_m,
        "initial m ({}) exceeds the anytime budget max_m ({})",
        opts.m,
        policy.max_m
    );
    let n_int = match opts.scheme {
        Scheme::NonUniform { n_int } => {
            ensure!(n_int >= 1, "n_int must be >= 1");
            ensure!(opts.m >= n_int, "m ({}) must be >= n_int ({n_int})", opts.m);
            n_int
        }
        Scheme::Uniform => 1,
    };

    // Stage 1 once: the probe serves every round (it depends only on
    // (x, baseline, n_int), not on the refinement level).
    let mut timer = StageTimer::start();
    let probed = probe_path(model, x, baseline, n_int, None)?;
    let t_probe = timer.lap();

    let initial = initial_schedule(opts, opts.m, &probed)?;

    let run = refine_loop(
        model,
        x,
        baseline,
        probed.target,
        probed.gap,
        initial,
        |s, _| s.refine(),
        |delta, m| policy.should_refine(delta, m),
        exec,
    )?;

    let delta = *run.residuals.last().expect("at least one round");
    // Reuse invariant: the total gradient bill IS the final schedule.
    debug_assert_eq!(run.evals, run.schedule.len());
    Ok(Attribution {
        delta,
        endpoint_gap: probed.gap,
        values: run.partial,
        target: probed.target,
        steps: run.evals,
        probe_passes: probed.bounds.len(),
        rounds: run.residuals.len(),
        residuals: run.residuals,
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: run.t_sched,
            execute: run.t_exec,
            reduce: Default::default(),
        },
    })
}

/// Cache-backed anytime IG: the engine-level mirror of the coordinator's
/// deadline-aware admission path (`benches/fig_warmcache.rs` drives it).
///
/// * **Warm** (`target` pinned and `cache` holds a probe memo for
///   `(target, baseline, n_int)`): stage 1 is skipped entirely — zero
///   probe passes. The canonical cached schedule and its refine ladder
///   serve the request, and δ is computed against the memoized endpoint
///   gap — a class-level estimate, the documented tight-tier trade (see
///   `docs/TUNING.md` §Latency tiers).
/// * **Cold** (no memo, or `target` not pinned): stage 1 runs as in
///   [`explain_anytime`], then populates the probe memo and the schedule
///   cache so subsequent requests for the same class/baseline are warm.
///
/// With a cache in play the served schedule is always the *canonical*
/// one (built from the quantized probe signature), so results do not
/// depend on whether a given request hit or missed. The uniform scheme
/// has nothing to cache (its schedule is a pure function of `m` and the
/// rule) and delegates to [`explain_anytime`].
pub fn explain_anytime_cached(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    target: Option<usize>,
    opts: &IgOptions,
    policy: &AnytimePolicy,
    cache: &ScheduleCache,
) -> Result<Attribution> {
    explain_anytime_cached_exec(model, x, baseline, target, opts, policy, cache, &BatchExec::Sequential)
}

/// [`explain_anytime_cached`] under an explicit execution policy.
#[allow(clippy::too_many_arguments)]
pub fn explain_anytime_cached_exec(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    target: Option<usize>,
    opts: &IgOptions,
    policy: &AnytimePolicy,
    cache: &ScheduleCache,
    exec: &BatchExec,
) -> Result<Attribution> {
    let n_int = match opts.scheme {
        Scheme::NonUniform { n_int } => n_int,
        Scheme::Uniform => return explain_anytime_exec(model, x, baseline, opts, policy, exec),
    };
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    ensure!(x.len() == model.features(), "image width {} != model features {}", x.len(), model.features());
    ensure!(baseline.len() == x.len(), "baseline width mismatch");
    ensure!(n_int >= 1, "n_int must be >= 1");
    ensure!(opts.m >= n_int, "m ({}) must be >= n_int ({n_int})", opts.m);
    ensure!(
        opts.rule.keeps_endpoints(),
        "anytime refinement requires an endpoint-inclusive rule (trapezoid/eq2), got {}",
        opts.rule
    );
    ensure!(
        opts.m <= policy.max_m,
        "initial m ({}) exceeds the anytime budget max_m ({})",
        opts.m,
        policy.max_m
    );
    if let Some(t) = target {
        ensure!(t < model.num_classes(), "target {t} out of range");
    }

    let bid = baseline_id(baseline);
    let warm = target.and_then(|t| cache.memo(t, bid, n_int).map(|memo| (t, memo)));
    let signature;
    let (target, gap, probe_passes, t_probe) = match warm {
        Some((t, memo)) => {
            signature = memo.signature;
            (t, memo.gap, 0, Duration::ZERO)
        }
        None => {
            let mut timer = StageTimer::start();
            let probed = probe_path(model, x, baseline, n_int, target)?;
            signature = ProbeSignature::quantize(&probed.deltas);
            let memo = ProbeMemo { signature: signature.clone(), gap: probed.gap };
            cache.memo_put(probed.target, bid, memo);
            (probed.target, probed.gap, probed.bounds.len(), timer.lap())
        }
    };

    let key = CacheKey {
        target,
        baseline_id: bid,
        signature,
        m: opts.m,
        rule: opts.rule,
        allocation: opts.allocation,
    };

    // Round 0 from the cached canonical schedule; refinement rounds read
    // the memoized ladder (`cached.level(k)`) through the SAME
    // `refine_loop` the uncached engine uses — one copy of the round
    // arithmetic, so hit/miss can never change served numbers.
    let mut timer = StageTimer::start();
    let cached = cache.get_or_build(&key)?;
    let initial = (*cached.base()).clone();
    let t_lookup = timer.lap();

    let run = refine_loop(
        model,
        x,
        baseline,
        target,
        gap,
        initial,
        |_, level| cached.level(level).map(|s| (*s).clone()),
        |delta, m| policy.should_refine(delta, m),
        exec,
    )?;

    let delta = *run.residuals.last().expect("at least one round");
    Ok(Attribution {
        delta,
        endpoint_gap: gap,
        values: run.partial,
        target,
        steps: run.evals,
        probe_passes,
        rounds: run.residuals.len(),
        residuals: run.residuals,
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: t_lookup + run.t_sched,
            execute: run.t_exec,
            reduce: Default::default(),
        },
    })
}

/// Index of the largest non-NaN element (0 if empty or all-NaN).
///
/// Total-order comparison: a misbehaving backend can emit NaN logits, and
/// the previous `partial_cmp(..).unwrap()` aborted the whole process on
/// them. NaN entries are skipped so one poisoned lane cannot hijack the
/// target class either.
pub fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .filter(|(_, x)| !x.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;

    fn model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 40.0)
    }

    /// High-gain variant: the softmax saturates early along the path, the
    /// regime where the paper's non-uniform allocation pays off (the
    /// gain-40 model's path is near-linear, so its probe deltas are flat
    /// and the sqrt allocation legitimately degenerates to even).
    fn saturating_model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 300.0)
    }

    fn input() -> Vec<f32> {
        (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect()
    }

    fn run(m: usize, scheme: Scheme) -> Attribution {
        let opts = IgOptions { scheme, m, ..Default::default() };
        explain(&model(), &input(), None, &opts).unwrap()
    }

    #[test]
    fn uniform_step_accounting() {
        let a = run(16, Scheme::Uniform);
        assert_eq!(a.steps, 17);
        assert_eq!(a.probe_passes, 0);
    }

    #[test]
    fn nonuniform_step_accounting() {
        // Fused semantics: interval-boundary evaluations are shared, so a
        // trapezoid non-uniform schedule costs exactly m + 1 model evals —
        // not the m + n_int the unfused concatenation used to dispatch.
        let a = run(16, Scheme::NonUniform { n_int: 4 });
        assert_eq!(a.steps, 16 + 1);
        assert_eq!(a.probe_passes, 5);
        assert!(a.breakdown.probe.as_nanos() > 0);
    }

    #[test]
    fn left_rule_uniform_prunes_endpoint_and_keeps_gap() {
        // The weight-0 alpha=1 point is pruned (m evals, not m + 1); the
        // endpoint gap is recovered by one direct forward pass.
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 16, rule: Rule::Left, ..Default::default() };
        let a = explain(&m, &x, None, &opts).unwrap();
        assert_eq!(a.steps, 16);
        assert_eq!(a.probe_passes, 1);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    #[test]
    fn right_rule_uniform_prunes_endpoint_and_keeps_gap() {
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 16, rule: Rule::Right, ..Default::default() };
        let a = explain(&m, &x, None, &opts).unwrap();
        assert_eq!(a.steps, 16);
        assert_eq!(a.probe_passes, 1);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    #[test]
    fn completeness_improves_with_m() {
        let d8 = run(8, Scheme::Uniform).delta;
        let d64 = run(64, Scheme::Uniform).delta;
        let d256 = run(256, Scheme::Uniform).delta;
        assert!(d8 > d64, "{d8} !> {d64}");
        assert!(d64 > d256, "{d64} !> {d256}");
    }

    #[test]
    fn nonuniform_beats_uniform_at_iso_steps() {
        // The paper's headline effect. Needs the saturating model: with a
        // near-linear path the probe deltas are flat, the allocation is
        // even, and the fused non-uniform schedule IS the uniform one.
        let m = saturating_model();
        let x = input();
        let steps = 24;
        let du = explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: steps, ..Default::default() })
            .unwrap()
            .delta;
        let dn = explain(&m, &x, None, &IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: steps, ..Default::default() })
            .unwrap()
            .delta;
        assert!(dn < du, "nonuniform {dn} !< uniform {du}");
    }

    #[test]
    fn flat_probe_degenerates_to_uniform_schedule() {
        // The gain-40 path is near-linear: the probe deltas are flat, the
        // sqrt allocation degenerates to an even split, and the fused
        // non-uniform schedule IS the uniform grid — the attributions
        // must match to f64 round-off. (Step counts being equal is true
        // by construction post-fusion; the values check is the real one.)
        let u = run(24, Scheme::Uniform);
        let n = run(24, Scheme::NonUniform { n_int: 4 });
        crate::testutil::assert_allclose(&u.values, &n.values, 1e-9, 1e-12);
    }

    #[test]
    fn engines_agree_at_high_m() {
        let u = run(512, Scheme::Uniform);
        let n = run(512, Scheme::NonUniform { n_int: 4 });
        assert!(u.cosine_similarity(&n) > 0.9999, "{}", u.cosine_similarity(&n));
        assert!((u.sum() - n.sum()).abs() < 1e-3);
    }

    #[test]
    fn nonuniform_n1_equals_uniform() {
        let u = run(32, Scheme::Uniform);
        let n = run(32, Scheme::NonUniform { n_int: 1 });
        crate::testutil::assert_allclose(&u.values, &n.values, 1e-9, 1e-12);
    }

    #[test]
    fn fused_matches_unfused_attribution() {
        // Drive `ig_points` with the raw (duplicated-boundary) schedule
        // and with its fused form: same attribution to 1e-9 through the
        // full f32 pipeline, at n_int - 1 fewer model evaluations.
        let model = saturating_model();
        let x = input();
        let baseline = vec![0f32; 64];
        let target = argmax(&model.probs(&[&x]).unwrap()[0]);

        let n_int = 4;
        let bounds = Schedule::probe_boundaries(n_int);
        let imgs: Vec<Vec<f32>> = bounds
            .iter()
            .map(|&a| x.iter().map(|&v| a as f32 * v).collect())
            .collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let probs = model.probs(&refs).unwrap();
        let probe = Probe::new(bounds.clone(), probs.iter().map(|p| p[target]).collect()).unwrap();
        let alloc = Allocation::Sqrt.allocate(24, &probe.interval_deltas()).unwrap();

        let raw = Schedule::nonuniform_unfused(&bounds, &alloc, Rule::Trapezoid).unwrap();
        let fused = raw.clone().fused();
        assert_eq!(raw.len(), 24 + n_int);
        assert_eq!(fused.len(), 24 + 1);

        let (ra, rw) = raw.to_f32();
        let (fa, fw) = fused.to_f32();
        let out_raw = model.ig_points(&x, &baseline, &ra, &rw, target).unwrap();
        let out_fused = model.ig_points(&x, &baseline, &fa, &fw, target).unwrap();
        crate::testutil::assert_allclose(&out_raw.partial, &out_fused.partial, 0.0, 1e-9);
    }

    #[test]
    fn identical_endpoints_zero() {
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 8, ..Default::default() };
        let a = explain_with_target(&m, &x, &x, 0, &opts).unwrap();
        assert!(a.delta < 1e-9);
        assert!(a.values.iter().all(|&v| v.abs() < 1e-9));
    }

    #[test]
    fn explicit_target_respected() {
        let m = model();
        let x = input();
        let b = vec![0f32; 64];
        let opts = IgOptions::default();
        let a = explain_with_target(&m, &x, &b, 2, &opts).unwrap();
        assert_eq!(a.target, 2);
    }

    #[test]
    fn validation_errors() {
        let m = model();
        let x = input();
        let opts = IgOptions::default();
        assert!(explain_with_target(&m, &x[..10], &x, 0, &opts).is_err());
        assert!(explain_with_target(&m, &x, &x[..10], 0, &opts).is_err());
        assert!(explain_with_target(&m, &x, &x, 99, &opts).is_err());
        let bad = IgOptions { m: 2, scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() };
        assert!(explain_with_target(&m, &x, &vec![0f32; 64], 0, &bad).is_err());
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // Regression: a NaN from a misbehaving backend used to abort via
        // partial_cmp().unwrap(). NaNs are skipped, not elected.
        assert_eq!(argmax(&[0.1, f64::NAN, 0.5]), 2);
        assert_eq!(argmax(&[f64::NAN, 0.3, 0.1]), 1);
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), 0);
        assert_eq!(argmax(&[f64::NEG_INFINITY, f64::NAN, -1.0]), 2);
    }

    #[test]
    fn endpoint_gap_matches_direct_eval() {
        let m = model();
        let x = input();
        let a = run(32, Scheme::Uniform);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    /// Model wrapper recording every alpha handed to `ig_points` — used to
    /// prove the anytime engine never re-evaluates a gradient point.
    struct Recorder<'a> {
        inner: &'a AnalyticModel,
        alphas: std::sync::Mutex<Vec<f32>>,
    }

    impl Model for Recorder<'_> {
        fn features(&self) -> usize {
            self.inner.features()
        }
        fn num_classes(&self) -> usize {
            self.inner.num_classes()
        }
        fn probs(&self, imgs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f64>>> {
            self.inner.probs(imgs)
        }
        fn ig_points(
            &self,
            x: &[f32],
            baseline: &[f32],
            alphas: &[f32],
            weights: &[f32],
            target: usize,
        ) -> anyhow::Result<crate::ig::model::IgPointsOut> {
            self.alphas.lock().unwrap().extend_from_slice(alphas);
            self.inner.ig_points(x, baseline, alphas, weights, target)
        }
    }

    #[test]
    fn anytime_matches_direct_at_final_level() {
        // Reuse loses nothing: the incrementally-accumulated attribution
        // equals a direct single-shot evaluation of the final (doubled-
        // allocation) schedule to 1e-9 through the f32 pipeline.
        let m = saturating_model();
        let x = input();
        let baseline = vec![0f32; 64];
        // delta_target 0 is unreachable: refines 8 -> 16 -> 32 -> 64.
        let policy = AnytimePolicy::with_max_m(0.0, 64).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 8, ..Default::default() };
        let a = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        assert_eq!(a.rounds, 4);
        assert_eq!(a.residuals.len(), 4);
        assert_eq!(a.steps, 64 + 1, "total evals must be the final schedule length");
        assert_eq!(a.probe_passes, 5);

        // Direct evaluation of the same final schedule: the initial
        // allocation at m0 = 8, doubled three times.
        let probed = probe_path(&m, &x, &baseline, 4, None).unwrap();
        assert_eq!(probed.target, a.target);
        let alloc0 = Allocation::Sqrt.allocate(8, &probed.deltas).unwrap();
        let alloc_final: Vec<usize> = alloc0.iter().map(|&v| v * 8).collect();
        let final_sched =
            Schedule::nonuniform(&probed.bounds, &alloc_final, Rule::Trapezoid).unwrap();
        let (fa, fw) = final_sched.to_f32();
        let direct = m.ig_points(&x, &baseline, &fa, &fw, probed.target).unwrap();
        crate::testutil::assert_allclose(&a.values, &direct.partial, 0.0, 1e-9);
    }

    #[test]
    fn anytime_uniform_matches_direct_uniform() {
        let m = saturating_model();
        let x = input();
        let policy = AnytimePolicy::with_max_m(0.0, 32).unwrap();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 8, ..Default::default() };
        let a = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        assert_eq!(a.steps, 32 + 1);
        let direct =
            explain(&m, &x, None, &IgOptions { scheme: Scheme::Uniform, m: 32, ..Default::default() })
                .unwrap();
        crate::testutil::assert_allclose(&a.values, &direct.values, 0.0, 1e-9);
        assert!((a.delta - direct.delta).abs() < 1e-9);
    }

    #[test]
    fn anytime_converges_early_and_reports_trajectory() {
        let m = saturating_model();
        let x = input();
        // Target: the residual the uniform baseline reaches at m = 128 —
        // the iso-convergence question the anytime engine answers cheaply.
        let target = explain(
            &m,
            &x,
            None,
            &IgOptions { scheme: Scheme::Uniform, m: 128, ..Default::default() },
        )
        .unwrap()
        .delta;
        let policy = AnytimePolicy::with_max_m(target, 512).unwrap();
        // m0 = 16 gives the sqrt allocation resolution (4 steps/interval);
        // a coarser start would quantize it to an even (uniform) split.
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
        let a = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        assert!(a.delta <= target, "{} !<= {target}", a.delta);
        assert!(a.rounds >= 2, "a coarse start should need refinement");
        assert!(a.steps < 128 + 1, "early exit must beat the uniform baseline's cost");
        assert_eq!(a.residuals.len(), a.rounds);
        assert_eq!(*a.residuals.last().unwrap(), a.delta);
        assert!(a.residuals.last().unwrap() < a.residuals.first().unwrap());
    }

    #[test]
    fn anytime_budget_cap_reports_best_effort() {
        let m = saturating_model();
        let x = input();
        let policy = AnytimePolicy::with_max_m(0.0, 32).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 8, ..Default::default() };
        let a = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        assert_eq!(a.rounds, 3); // 8 -> 16 -> 32, then the budget gate stops it
        assert_eq!(a.steps, 32 + 1);
        assert!(a.delta > 0.0);
    }

    #[test]
    fn anytime_never_reevaluates_an_alpha() {
        // The acceptance property: across all refinement rounds, every
        // gradient alpha is evaluated exactly once.
        let inner = saturating_model();
        let x = input();
        crate::testutil::prop(10, 91, |rng| {
            let m0 = rng.range(4, 17);
            let rec = Recorder { inner: &inner, alphas: std::sync::Mutex::new(Vec::new()) };
            let policy = AnytimePolicy::with_max_m(0.0, m0 * 8).unwrap();
            let opts =
                IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: m0, ..Default::default() };
            let a = explain_anytime(&rec, &x, None, &opts, &policy).unwrap();
            let mut seen = rec.alphas.into_inner().unwrap();
            assert_eq!(seen.len(), a.steps, "every dispatched alpha is accounted in steps");
            seen.sort_by(|p, q| p.partial_cmp(q).unwrap());
            assert!(
                seen.windows(2).all(|w| w[0] < w[1]),
                "duplicate alpha dispatched: reuse violated"
            );
        });
    }

    #[test]
    fn anytime_validation_errors() {
        let m = model();
        let x = input();
        let policy = AnytimePolicy::new(0.01);
        let left = IgOptions { rule: Rule::Left, scheme: Scheme::Uniform, m: 8, ..Default::default() };
        assert!(explain_anytime(&m, &x, None, &left, &policy).is_err());
        let over = IgOptions { m: 1024, ..Default::default() };
        assert!(explain_anytime(&m, &x, None, &over, &policy).is_err());
        let tight = AnytimePolicy::with_max_m(0.01, 4).unwrap();
        let a = explain_anytime(&m, &x, None, &IgOptions { m: 4, ..Default::default() }, &tight)
            .unwrap();
        assert_eq!(a.rounds, 1, "m0 == max_m: no refinement possible");
    }

    #[test]
    fn cached_cold_then_warm_skips_the_probe() {
        let m = saturating_model();
        let x = input();
        let cache = ScheduleCache::new(16, 2);
        let policy = AnytimePolicy::with_max_m(0.0, 32).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
        let target = argmax(&m.probs(&[&x]).unwrap()[0]);

        let cold =
            explain_anytime_cached(&m, &x, None, Some(target), &opts, &policy, &cache).unwrap();
        assert_eq!(cold.probe_passes, 5, "cold request pays the probe");
        let warm =
            explain_anytime_cached(&m, &x, None, Some(target), &opts, &policy, &cache).unwrap();
        assert_eq!(warm.probe_passes, 0, "warm request skips stage 1 entirely");
        // Same input, canonical schedule, memoized gap: bit-identical.
        assert_eq!(warm.values, cold.values);
        assert_eq!(warm.delta, cold.delta);
        assert_eq!(warm.steps, cold.steps);
        assert!(cache.counters().hits.get() >= 1, "warm round 0 must hit the schedule cache");
    }

    #[test]
    fn cached_unpinned_cold_populates_the_memo() {
        let m = saturating_model();
        let x = input();
        let cache = ScheduleCache::new(16, 2);
        let policy = AnytimePolicy::with_max_m(0.0, 16).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
        let a = explain_anytime_cached(&m, &x, None, None, &opts, &policy, &cache).unwrap();
        assert_eq!(a.probe_passes, 5, "no pinned target: the cold path must probe");
        assert_eq!(cache.memo_len(), 1);
        // A pinned follow-up for the same class rides the memo.
        let warm =
            explain_anytime_cached(&m, &x, None, Some(a.target), &opts, &policy, &cache).unwrap();
        assert_eq!(warm.probe_passes, 0);
        assert_eq!(warm.steps, 17);
    }

    #[test]
    fn cached_matches_uncached_to_quantization_tolerance() {
        // The canonical (quantized-signature) schedule differs from the
        // exact-delta schedule by at most ±1 step per interval, so the
        // attribution agrees closely without being bit-identical.
        let m = saturating_model();
        let x = input();
        let cache = ScheduleCache::new(16, 2);
        let policy = AnytimePolicy::with_max_m(0.0, 64).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
        let cached = explain_anytime_cached(&m, &x, None, None, &opts, &policy, &cache).unwrap();
        let direct = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        assert_eq!(cached.target, direct.target);
        assert_eq!(cached.steps, direct.steps, "equal m: equal fused eval count");
        assert_eq!(cached.rounds, direct.rounds, "budget-gated: equal refinement depth");
        assert!(cached.cosine_similarity(&direct) > 0.999, "{}", cached.cosine_similarity(&direct));
        assert!((cached.sum() - direct.sum()).abs() < 1e-3);
    }

    #[test]
    fn cached_warm_serves_new_inputs_of_the_same_class() {
        // The amortization claim: a DIFFERENT input of the same class
        // rides the memo — zero probe passes — and only delta leans on
        // the class-level memoized gap; the weighted gradient sum is the
        // true one for the new input.
        let m = saturating_model();
        let x = input();
        let x2: Vec<f32> = x.iter().map(|v| v * 0.9 + 0.05).collect();
        let cache = ScheduleCache::new(16, 2);
        let policy = AnytimePolicy::with_max_m(0.0, 16).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
        let target = argmax(&m.probs(&[&x]).unwrap()[0]);
        explain_anytime_cached(&m, &x, None, Some(target), &opts, &policy, &cache).unwrap();
        let warm =
            explain_anytime_cached(&m, &x2, None, Some(target), &opts, &policy, &cache).unwrap();
        assert_eq!(warm.probe_passes, 0);
        assert_eq!(warm.steps, 17);
        let black = vec![0f32; 64];
        let direct = explain_with_target(&m, &x2, &black, target, &opts).unwrap();
        assert!(warm.cosine_similarity(&direct) > 0.99, "{}", warm.cosine_similarity(&direct));
    }

    #[test]
    fn cached_uniform_delegates_to_explain_anytime() {
        let m = saturating_model();
        let x = input();
        let cache = ScheduleCache::new(16, 2);
        let policy = AnytimePolicy::with_max_m(0.0, 16).unwrap();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 8, ..Default::default() };
        let a = explain_anytime_cached(&m, &x, None, None, &opts, &policy, &cache).unwrap();
        let b = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        assert_eq!(a.values, b.values);
        assert!(cache.is_empty(), "the uniform scheme has nothing to cache");
    }

    #[test]
    fn cached_validation_errors() {
        let m = model();
        let x = input();
        let cache = ScheduleCache::new(16, 2);
        let policy = AnytimePolicy::new(0.01);
        let left = IgOptions {
            rule: Rule::Left,
            scheme: Scheme::NonUniform { n_int: 4 },
            m: 8,
            ..Default::default()
        };
        assert!(explain_anytime_cached(&m, &x, None, None, &left, &policy, &cache).is_err());
        let opts = IgOptions::default();
        assert!(explain_anytime_cached(&m, &x, None, Some(99), &opts, &policy, &cache).is_err());
        let over = IgOptions { m: 1024, ..Default::default() };
        assert!(explain_anytime_cached(&m, &x, None, None, &over, &policy, &cache).is_err());
    }

    #[test]
    fn endpoint_detection_is_symmetric() {
        // The satellite bugfix: both path ends share one tolerance, so an
        // ε-perturbed endpoint is recognized on the left exactly like on
        // the right (the old code compared `alpha == 0.0` exactly).
        assert!(at_endpoint(0.0, 0.0));
        assert!(at_endpoint(1e-13, 0.0));
        assert!(at_endpoint(-1e-13, 0.0));
        assert!(at_endpoint(1.0, 1.0));
        assert!(at_endpoint(1.0 - 1e-13, 1.0));
        assert!(!at_endpoint(1e-9, 0.0));
        assert!(!at_endpoint(1.0 - 1e-9, 1.0));
        assert!(!at_endpoint(0.5, 0.0));
    }

    #[test]
    fn parallel_engines_bit_identical_to_sequential() {
        // The engine-level face of the determinism contract: the same
        // request through `explain_exec` on a pool reproduces the
        // sequential attribution to the bit, for both schemes.
        use crate::exec::ThreadPool;
        let m = saturating_model();
        let x = input();
        let pool = std::sync::Arc::new(ThreadPool::new(4));
        for scheme in [Scheme::Uniform, Scheme::NonUniform { n_int: 4 }] {
            let opts = IgOptions { scheme, m: 96, ..Default::default() };
            let seq = explain(&m, &x, None, &opts).unwrap();
            let par =
                explain_exec(&m, &x, None, None, &opts, &BatchExec::parallel(pool.clone())).unwrap();
            assert_eq!(par.target, seq.target);
            assert_eq!(par.steps, seq.steps);
            assert_eq!(par.values, seq.values, "{scheme}: parallel must be bit-identical");
            assert_eq!(par.delta, seq.delta);
        }
        // Anytime: every refinement round's stream is dispatched in
        // parallel; the carried accumulator must still match exactly.
        let policy = AnytimePolicy::with_max_m(0.0, 64).unwrap();
        let opts = IgOptions { scheme: Scheme::NonUniform { n_int: 4 }, m: 16, ..Default::default() };
        let seq = explain_anytime(&m, &x, None, &opts, &policy).unwrap();
        let par =
            explain_anytime_exec(&m, &x, None, &opts, &policy, &BatchExec::parallel(pool)).unwrap();
        assert_eq!(par.values, seq.values);
        assert_eq!(par.rounds, seq.rounds);
        assert_eq!(par.residuals, seq.residuals);
    }

    /// Model whose `eval_batch` panics on any chunk containing an alpha
    /// above `poison_from` — the poisoned-chunk fault injection.
    struct PoisonModel<'a> {
        inner: &'a AnalyticModel,
        poison_from: f32,
    }

    impl Model for PoisonModel<'_> {
        fn features(&self) -> usize {
            self.inner.features()
        }
        fn num_classes(&self) -> usize {
            self.inner.num_classes()
        }
        fn probs(&self, imgs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f64>>> {
            self.inner.probs(imgs)
        }
        fn ig_points(
            &self,
            x: &[f32],
            baseline: &[f32],
            alphas: &[f32],
            weights: &[f32],
            target: usize,
        ) -> anyhow::Result<crate::ig::model::IgPointsOut> {
            assert!(
                alphas.iter().all(|&a| a < self.poison_from),
                "poisoned chunk: alpha >= {}",
                self.poison_from
            );
            self.inner.ig_points(x, baseline, alphas, weights, target)
        }
    }

    #[test]
    fn poisoned_chunk_fails_request_pool_and_siblings_survive() {
        // One request hits a panicking chunk mid-stream: it must come
        // back as Err (not a process abort), and both the pool and a
        // sibling request running on the same pool must be unaffected.
        use crate::exec::ThreadPool;
        let inner = saturating_model();
        let x = input();
        let pool = std::sync::Arc::new(ThreadPool::new(4));
        let exec = BatchExec::parallel_with_chunk(pool.clone(), 16);
        let opts = IgOptions { scheme: Scheme::Uniform, m: 128, ..Default::default() };

        let poisoned = PoisonModel { inner: &inner, poison_from: 0.5 };
        let err = explain_exec(&poisoned, &x, None, Some(0), &opts, &exec).unwrap_err();
        assert!(err.to_string().contains("poisoned chunk"), "{err}");

        // Sibling request on the same pool, healthy model: still served,
        // and still bit-identical to the sequential path.
        let ok = explain_exec(&inner, &x, None, None, &opts, &exec).unwrap();
        let seq = explain(&inner, &x, None, &opts).unwrap();
        assert_eq!(ok.values, seq.values);
    }

    #[test]
    fn property_delta_scale_free_invariants() {
        crate::testutil::prop(10, 31, |rng| {
            let m = rng.range(8, 64);
            let a = run(m, Scheme::NonUniform { n_int: 4 });
            assert!(a.delta >= 0.0);
            assert!(a.relative_delta() >= 0.0);
            assert_eq!(a.values.len(), 64);
            assert_eq!(a.steps, m + 1, "steps must be the true eval count");
        });
    }
}
