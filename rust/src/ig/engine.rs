//! The two IG engines: baseline uniform interpolation (Eq. 2) and the
//! paper's two-stage non-uniform interpolation.
//!
//! Both are thin orchestrations over [`Model`]: build a [`Schedule`],
//! evaluate it via `Model::ig_points` (which chunks to the executable
//! width), and account for completeness. Stage timing is recorded so the
//! overhead figures (Fig. 6b) come from real measurements.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::metrics::StageBreakdown;

use super::allocator::Allocation;
use super::attribution::Attribution;
use super::convergence;
use super::model::Model;
use super::probe::Probe;
use super::riemann::Rule;
use super::schedule::Schedule;
use super::Scheme;

/// Per-explanation options.
#[derive(Debug, Clone, Copy)]
pub struct IgOptions {
    pub scheme: Scheme,
    /// Total interpolation steps m (stage-2 budget).
    pub m: usize,
    pub rule: Rule,
    pub allocation: Allocation,
}

impl Default for IgOptions {
    fn default() -> Self {
        IgOptions {
            scheme: Scheme::NonUniform { n_int: 4 },
            m: 64,
            rule: Rule::Trapezoid,
            allocation: Allocation::Sqrt,
        }
    }
}

/// Explain `x` against `baseline` (black if `None`), targeting the model's
/// predicted class.
pub fn explain(
    model: &dyn Model,
    x: &[f32],
    baseline: Option<&[f32]>,
    opts: &IgOptions,
) -> Result<Attribution> {
    let black;
    let baseline = match baseline {
        Some(b) => b,
        None => {
            black = vec![0f32; model.features()];
            &black
        }
    };
    let probs = model.probs(&[x])?;
    let target = argmax(&probs[0]);
    explain_with_target(model, x, baseline, target, opts)
}

/// Explain with a pinned target class.
pub fn explain_with_target(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    ensure!(x.len() == model.features(), "image width {} != model features {}", x.len(), model.features());
    ensure!(baseline.len() == x.len(), "baseline width mismatch");
    ensure!(target < model.num_classes(), "target {target} out of range");
    ensure!(opts.m >= 1, "m must be >= 1");

    match opts.scheme {
        Scheme::Uniform => uniform_ig(model, x, baseline, target, opts),
        Scheme::NonUniform { n_int } => nonuniform_ig(model, x, baseline, target, n_int, opts),
    }
}

fn uniform_ig(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    let t0 = Instant::now();
    let schedule = Schedule::uniform(opts.m, opts.rule)?;
    let (alphas, weights) = schedule.to_f32();
    let t_sched = t0.elapsed();

    let t1 = Instant::now();
    let out = model.ig_points(x, baseline, &alphas, &weights, target)?;
    let t_exec = t1.elapsed();

    // Endpoint gap read off the schedule's own endpoint probabilities
    // (α=0 is the first point, α=1 the last — both grids include them).
    let t2 = Instant::now();
    let gap = out.target_probs[out.target_probs.len() - 1] - out.target_probs[0];
    let sum: f64 = out.partial.iter().sum();
    let t_reduce = t2.elapsed();

    Ok(Attribution {
        delta: convergence::delta(sum, gap),
        endpoint_gap: gap,
        values: out.partial,
        target,
        steps: schedule.len(),
        probe_passes: 0,
        breakdown: StageBreakdown {
            probe: Default::default(),
            schedule: t_sched,
            execute: t_exec,
            reduce: t_reduce,
        },
    })
}

fn nonuniform_ig(
    model: &dyn Model,
    x: &[f32],
    baseline: &[f32],
    target: usize,
    n_int: usize,
    opts: &IgOptions,
) -> Result<Attribution> {
    ensure!(n_int >= 1, "n_int must be >= 1");
    ensure!(opts.m >= n_int, "m ({}) must be >= n_int ({n_int})", opts.m);

    // ---- Stage 1: probe boundary probabilities (forward-only). ----------
    let t0 = Instant::now();
    let bounds = Schedule::probe_boundaries(n_int);
    let f = x.len();
    let boundary_imgs: Vec<Vec<f32>> = bounds
        .iter()
        .map(|&a| {
            (0..f)
                .map(|i| baseline[i] + a as f32 * (x[i] - baseline[i]))
                .collect()
        })
        .collect();
    let refs: Vec<&[f32]> = boundary_imgs.iter().map(|v| v.as_slice()).collect();
    let probe_probs = model.probs(&refs)?;
    let probe = Probe::new(bounds.clone(), probe_probs.iter().map(|p| p[target]).collect())?;
    let t_probe = t0.elapsed();

    // ---- Allocate + build the composite schedule. ------------------------
    let t1 = Instant::now();
    let deltas = probe.interval_deltas();
    let alloc = opts.allocation.allocate(opts.m, &deltas)?;
    let schedule = Schedule::nonuniform(&bounds, &alloc, opts.rule)?;
    let (alphas, weights) = schedule.to_f32();
    let t_sched = t1.elapsed();

    // ---- Stage 2: uniform IG inside each interval (one point stream). ---
    let t2 = Instant::now();
    let out = model.ig_points(x, baseline, &alphas, &weights, target)?;
    let t_exec = t2.elapsed();

    let t3 = Instant::now();
    let gap = probe.endpoint_gap();
    let sum: f64 = out.partial.iter().sum();
    let t_reduce = t3.elapsed();

    Ok(Attribution {
        delta: convergence::delta(sum, gap),
        endpoint_gap: gap,
        values: out.partial,
        target,
        steps: schedule.len(),
        probe_passes: bounds.len(),
        breakdown: StageBreakdown {
            probe: t_probe,
            schedule: t_sched,
            execute: t_exec,
            reduce: t_reduce,
        },
    })
}

/// Index of the largest element.
pub fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ig::model::AnalyticModel;

    fn model() -> AnalyticModel {
        AnalyticModel::new(64, 4, 7, 40.0)
    }

    fn input() -> Vec<f32> {
        (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect()
    }

    fn run(m: usize, scheme: Scheme) -> Attribution {
        let opts = IgOptions { scheme, m, ..Default::default() };
        explain(&model(), &input(), None, &opts).unwrap()
    }

    #[test]
    fn uniform_step_accounting() {
        let a = run(16, Scheme::Uniform);
        assert_eq!(a.steps, 17);
        assert_eq!(a.probe_passes, 0);
    }

    #[test]
    fn nonuniform_step_accounting() {
        let a = run(16, Scheme::NonUniform { n_int: 4 });
        assert_eq!(a.steps, 16 + 4); // Σ(m_i + 1) = m + n_int
        assert_eq!(a.probe_passes, 5);
        assert!(a.breakdown.probe.as_nanos() > 0);
    }

    #[test]
    fn completeness_improves_with_m() {
        let d8 = run(8, Scheme::Uniform).delta;
        let d64 = run(64, Scheme::Uniform).delta;
        let d256 = run(256, Scheme::Uniform).delta;
        assert!(d8 > d64, "{d8} !> {d64}");
        assert!(d64 > d256, "{d64} !> {d256}");
    }

    #[test]
    fn nonuniform_beats_uniform_at_iso_steps() {
        // The paper's headline effect, on the analytic model.
        let m = 24;
        let du = run(m, Scheme::Uniform).delta;
        let dn = run(m, Scheme::NonUniform { n_int: 4 }).delta;
        assert!(dn < du, "nonuniform {dn} !< uniform {du}");
    }

    #[test]
    fn engines_agree_at_high_m() {
        let u = run(512, Scheme::Uniform);
        let n = run(512, Scheme::NonUniform { n_int: 4 });
        assert!(u.cosine_similarity(&n) > 0.9999, "{}", u.cosine_similarity(&n));
        assert!((u.sum() - n.sum()).abs() < 1e-3);
    }

    #[test]
    fn nonuniform_n1_equals_uniform() {
        let u = run(32, Scheme::Uniform);
        let n = run(32, Scheme::NonUniform { n_int: 1 });
        crate::testutil::assert_allclose(&u.values, &n.values, 1e-9, 1e-12);
    }

    #[test]
    fn identical_endpoints_zero() {
        let m = model();
        let x = input();
        let opts = IgOptions { scheme: Scheme::Uniform, m: 8, ..Default::default() };
        let a = explain_with_target(&m, &x, &x, 0, &opts).unwrap();
        assert!(a.delta < 1e-9);
        assert!(a.values.iter().all(|&v| v.abs() < 1e-9));
    }

    #[test]
    fn explicit_target_respected() {
        let m = model();
        let x = input();
        let b = vec![0f32; 64];
        let opts = IgOptions::default();
        let a = explain_with_target(&m, &x, &b, 2, &opts).unwrap();
        assert_eq!(a.target, 2);
    }

    #[test]
    fn validation_errors() {
        let m = model();
        let x = input();
        let opts = IgOptions::default();
        assert!(explain_with_target(&m, &x[..10], &x, 0, &opts).is_err());
        assert!(explain_with_target(&m, &x, &x[..10], 0, &opts).is_err());
        assert!(explain_with_target(&m, &x, &x, 99, &opts).is_err());
        let bad = IgOptions { m: 2, scheme: Scheme::NonUniform { n_int: 4 }, ..Default::default() };
        assert!(explain_with_target(&m, &x, &vec![0f32; 64], 0, &bad).is_err());
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn endpoint_gap_matches_direct_eval() {
        let m = model();
        let x = input();
        let a = run(32, Scheme::Uniform);
        let p = m.probs(&[&x, &vec![0f32; 64]]).unwrap();
        let gap = p[0][a.target] - p[1][a.target];
        assert!((a.endpoint_gap - gap).abs() < 1e-9);
    }

    #[test]
    fn property_delta_scale_free_invariants() {
        crate::testutil::prop(10, 31, |rng| {
            let m = rng.range(8, 64);
            let a = run(m, Scheme::NonUniform { n_int: 4 });
            assert!(a.delta >= 0.0);
            assert!(a.relative_delta() >= 0.0);
            assert_eq!(a.values.len(), 64);
        });
    }
}
