//! Quadrature rules over the unit interval.
//!
//! The paper's Eq. 2 is the `Eq2` rule verbatim (all m+1 points at weight
//! 1/m — note it over-counts: weights sum to (m+1)/m, one source of the
//! baseline's completeness residual). `Trapezoid` is what Captum ships and
//! what both engines here default to; `Left`/`Right` exist for the
//! Riemann-rule ablation bench.

use anyhow::{bail, Result};

/// Quadrature rule for a uniform grid.
///
/// `Hash` is derived because the rule is part of the probe-schedule
/// cache key ([`crate::ig::schedule::cache::CacheKey`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// Left Riemann sum: points 0..m-1, weight 1/m.
    Left,
    /// Right Riemann sum: points 1..m, weight 1/m.
    Right,
    /// Trapezoid: half-weight endpoints (default; 2nd-order accurate).
    Trapezoid,
    /// The paper's literal Eq. 2: all m+1 points at weight 1/m.
    Eq2,
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Rule::Left => "left",
            Rule::Right => "right",
            Rule::Trapezoid => "trapezoid",
            Rule::Eq2 => "eq2",
        };
        write!(f, "{s}")
    }
}

impl Rule {
    /// Parse `left|right|trapezoid|eq2` (CLI syntax).
    pub fn parse(s: &str) -> Result<Rule> {
        Ok(match s {
            "left" => Rule::Left,
            "right" => Rule::Right,
            "trapezoid" => Rule::Trapezoid,
            "eq2" => Rule::Eq2,
            _ => bail!("unknown rule {s:?} (left|right|trapezoid|eq2)"),
        })
    }

    /// Whether this rule keeps both grid endpoints at nonzero weight.
    /// Left/Right structurally zero one endpoint (pruned at schedule
    /// build), so their fused grids are not endpoint-inclusive — which is
    /// what nested refinement ([`crate::ig::schedule::Schedule::refine`])
    /// and therefore the anytime engine require.
    pub fn keeps_endpoints(&self) -> bool {
        matches!(self, Rule::Trapezoid | Rule::Eq2)
    }

    /// Weights for a grid of `n_points = m + 1` uniform points covering a
    /// unit interval. All rules except `Eq2` sum to exactly 1.
    pub fn weights(&self, n_points: usize) -> Result<Vec<f64>> {
        if n_points < 2 {
            bail!("need at least 2 grid points, got {n_points}");
        }
        let m = (n_points - 1) as f64;
        let mut w = vec![0.0; n_points];
        match self {
            Rule::Left => {
                for wi in w.iter_mut().take(n_points - 1) {
                    *wi = 1.0 / m;
                }
            }
            Rule::Right => {
                for wi in w.iter_mut().skip(1) {
                    *wi = 1.0 / m;
                }
            }
            Rule::Trapezoid => {
                for wi in w.iter_mut() {
                    *wi = 1.0 / m;
                }
                w[0] = 0.5 / m;
                w[n_points - 1] = 0.5 / m;
            }
            Rule::Eq2 => {
                for wi in w.iter_mut() {
                    *wi = 1.0 / m;
                }
            }
        }
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    #[test]
    fn weights_sum_to_one_except_eq2() {
        for n in [2usize, 3, 9, 65] {
            for rule in [Rule::Left, Rule::Right, Rule::Trapezoid] {
                let s: f64 = rule.weights(n).unwrap().iter().sum();
                assert!((s - 1.0).abs() < 1e-12, "{rule} n={n} sum={s}");
            }
            let s: f64 = Rule::Eq2.weights(n).unwrap().iter().sum();
            let expect = n as f64 / (n as f64 - 1.0);
            assert!((s - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn left_right_structure() {
        let l = Rule::Left.weights(5).unwrap();
        assert_eq!(l[4], 0.0);
        assert_eq!(l[0], 0.25);
        let r = Rule::Right.weights(5).unwrap();
        assert_eq!(r[0], 0.0);
        assert_eq!(r[4], 0.25);
    }

    #[test]
    fn trapezoid_endpoints() {
        let w = Rule::Trapezoid.weights(5).unwrap();
        assert_eq!(w[0], 0.125);
        assert_eq!(w[4], 0.125);
        assert_eq!(w[2], 0.25);
    }

    #[test]
    fn rejects_tiny_grids() {
        assert!(Rule::Trapezoid.weights(1).is_err());
        assert!(Rule::Trapezoid.weights(0).is_err());
    }

    #[test]
    fn parse_roundtrip() {
        for rule in [Rule::Left, Rule::Right, Rule::Trapezoid, Rule::Eq2] {
            assert_eq!(Rule::parse(&rule.to_string()).unwrap(), rule);
        }
        assert!(Rule::parse("simpson").is_err());
    }

    #[test]
    fn trapezoid_integrates_linear_exactly() {
        // ∫0..1 (a + b t) dt = a + b/2, trapezoid is exact for degree 1.
        testutil::prop(50, 99, |rng| {
            let a = rng.range_f64(-2.0, 2.0);
            let b = rng.range_f64(-2.0, 2.0);
            let n = rng.range(2, 40);
            let w = Rule::Trapezoid.weights(n).unwrap();
            let m = (n - 1) as f64;
            let quad: f64 = w
                .iter()
                .enumerate()
                .map(|(k, wk)| wk * (a + b * k as f64 / m))
                .sum();
            let exact = a + b / 2.0;
            assert!((quad - exact).abs() < 1e-10, "{quad} vs {exact}");
        });
    }

    #[test]
    fn left_right_bracket_monotone_integrand() {
        // For increasing f, left sum underestimates, right overestimates.
        let n = 33;
        let f = |t: f64| t * t;
        let exact = 1.0 / 3.0;
        let m = (n - 1) as f64;
        let sum_with = |rule: Rule| -> f64 {
            rule.weights(n)
                .unwrap()
                .iter()
                .enumerate()
                .map(|(k, w)| w * f(k as f64 / m))
                .sum()
        };
        assert!(sum_with(Rule::Left) < exact);
        assert!(sum_with(Rule::Right) > exact);
        assert!((sum_with(Rule::Trapezoid) - exact).abs() < 1e-3);
    }
}
