//! Integrated Gradients core: the paper's algorithm, engine-agnostic.
//!
//! The module layout mirrors the algorithm's anatomy:
//!
//! * [`riemann`] — quadrature rules over the unit interval (Eq. 2's
//!   discretization and its better-behaved variants);
//! * [`schedule`] — alpha/weight schedules: uniform grids, per-interval
//!   grids, their *fused* concatenation into the paper's non-uniform
//!   schedule (coincident boundary points merged, zero-weight points
//!   pruned — `len()` is exactly the model-eval count), and *nested
//!   refinement* (`Schedule::refine`: the next level is a strict superset
//!   of the current points, enabling gradient reuse across rounds); its
//!   [`schedule::cache`] submodule amortizes stage 1 *across requests*
//!   (quantized-signature keyed LRU of canonical schedules + refine
//!   ladders, plus the probe memo behind deadline-tier admission);
//! * [`allocator`] — stage 1's step distribution (`m_int ∝ √|Δf|`, with
//!   the linear variant kept as the paper's ablation);
//! * [`probe`] — stage 1's boundary probing and interval-delta math;
//! * [`convergence`] — the completeness residual δ (Eq. 3), the
//!   iso-convergence search protocol (Fig. 5b), and the anytime gate
//!   (`AnytimePolicy`);
//! * [`model`] — the [`Model`] abstraction the engine runs against (the
//!   PJRT-backed model at serving time, a closed-form analytic model in
//!   tests and coordinator benches) and [`eval_points`], the batched
//!   stage-2 entry: fixed-size chunks through `Model::eval_batch` with a
//!   deterministic ordered reduction, optionally sharded across the
//!   `exec::ThreadPool` ([`crate::exec::BatchExec`]) — bit-identical at
//!   any worker count;
//! * [`engine`] — the engines: baseline uniform IG, the paper's
//!   two-stage non-uniform IG, and the anytime engine
//!   (`explain_anytime`: incremental refinement with convergence-gated
//!   early exit);
//! * [`attribution`] — result type with completeness accounting;
//! * [`analysis`] — path-information statistics behind Fig. 3.

pub mod adaptive;
pub mod allocator;
pub mod analysis;
pub mod attribution;
pub mod baselines;
pub mod convergence;
pub mod engine;
pub mod ensemble;
pub mod model;
pub mod probe;
pub mod riemann;
pub mod schedule;

pub use adaptive::{explain_to_threshold, AdaptiveResult};
pub use allocator::Allocation;
pub use attribution::Attribution;
pub use baselines::BaselineKind;
pub use convergence::{AnytimePolicy, ConvergencePolicy};
pub use engine::{
    explain, explain_anytime, explain_anytime_cached, explain_anytime_cached_exec,
    explain_anytime_exec, explain_exec, IgOptions,
};
pub use model::{eval_points, eval_points_resident, AnalyticExec, AnalyticModel, Model};
pub use riemann::Rule;
pub use schedule::cache::{CacheKey, ProbeSignature, ScheduleCache};

/// Interpolation scheme selector: the baseline vs the paper's contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Baseline IG: one uniform grid over the whole path (Eq. 2).
    Uniform,
    /// The paper's two-stage non-uniform interpolation with `n_int`
    /// equal-width probe intervals.
    NonUniform { n_int: usize },
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scheme::Uniform => write!(f, "uniform"),
            Scheme::NonUniform { n_int } => write!(f, "nonuniform(n_int={n_int})"),
        }
    }
}

impl Scheme {
    /// Parse `uniform` or `nonuniform:<n_int>` (CLI syntax).
    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        if s == "uniform" {
            return Ok(Scheme::Uniform);
        }
        if let Some(n) = s.strip_prefix("nonuniform:") {
            let n_int: usize = n.parse()?;
            anyhow::ensure!(n_int >= 1, "n_int must be >= 1");
            return Ok(Scheme::NonUniform { n_int });
        }
        anyhow::bail!("unknown scheme {s:?} (expected `uniform` or `nonuniform:<n_int>`)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_display_roundtrip() {
        assert_eq!(Scheme::Uniform.to_string(), "uniform");
        assert_eq!(Scheme::NonUniform { n_int: 4 }.to_string(), "nonuniform(n_int=4)");
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("uniform").unwrap(), Scheme::Uniform);
        assert_eq!(Scheme::parse("nonuniform:8").unwrap(), Scheme::NonUniform { n_int: 8 });
        assert!(Scheme::parse("nonuniform:0").is_err());
        assert!(Scheme::parse("simpson").is_err());
    }
}
