//! Attribution result type with completeness accounting.

use crate::metrics::StageBreakdown;

/// The output of an explanation: per-feature relevance scores plus the
/// bookkeeping the paper's evaluation protocol needs (steps consumed,
/// probe passes, completeness residual, stage timing).
#[derive(Debug, Clone)]
pub struct Attribution {
    /// Per-feature scores φ_i (f64 accumulation over f32 chunk partials).
    pub values: Vec<f64>,
    /// Explained class (argmax of f(x) unless the caller pinned one).
    pub target: usize,
    /// Gradient evaluations consumed — exactly the fused schedule's point
    /// count, i.e. the true number of fwd+bwd model passes (`m + 1` for
    /// trapezoid schedules, uniform or non-uniform; `m` for left/right).
    pub steps: usize,
    /// Forward-only passes this explanation performed beyond the gradient
    /// points (target selection by the caller excluded): the stage-1
    /// probe (`n_int + 1`) for the non-uniform scheme; for the direct
    /// uniform engine, the endpoint evaluation(s) recovering the
    /// completeness gap (0 when the fused grid includes both endpoints,
    /// 1 for left/right whose pruned endpoint is evaluated directly);
    /// paths that obtain target + gap from a boundary probe (coordinator
    /// router, adaptive driver) report that probe's passes — 2 for
    /// uniform. `steps + probe_passes` is the true model-eval count of
    /// whichever path produced this attribution.
    pub probe_passes: usize,
    /// Completeness residual δ = |Σφ − (f(x) − f(x'))|   (Eq. 3).
    pub delta: f64,
    /// The endpoint gap f(x) − f(x') itself.
    pub endpoint_gap: f64,
    /// Refinement rounds that produced this attribution: 1 for the
    /// fixed-m engines; the anytime engine / adaptive driver report one
    /// entry per schedule level evaluated (initial + each doubling).
    pub rounds: usize,
    /// δ after each round, in order — the residual trajectory. The last
    /// entry equals [`Attribution::delta`]; fixed-m paths report the
    /// single final residual.
    pub residuals: Vec<f64>,
    /// Wall-clock decomposition (probe/schedule/execute/reduce).
    pub breakdown: StageBreakdown,
}

impl Attribution {
    /// Σφ — should approach `endpoint_gap` as m grows (completeness).
    pub fn sum(&self) -> f64 {
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        self.values.iter().sum()
    }

    /// δ normalized by |gap| — scale-free convergence measure.
    pub fn relative_delta(&self) -> f64 {
        if self.endpoint_gap.abs() < 1e-12 {
            return self.delta;
        }
        self.delta / self.endpoint_gap.abs()
    }

    /// Indices of the `k` largest |φ| features (top attributed features).
    pub fn top_features(&self, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.values.len()).collect();
        idx.sort_by(|&a, &b| {
            self.values[b]
                .abs()
                .partial_cmp(&self.values[a].abs())
                .unwrap()
        });
        idx.truncate(k);
        idx
    }

    /// Cosine similarity against another attribution (used to check the
    /// uniform and non-uniform engines converge to the same explanation).
    pub fn cosine_similarity(&self, other: &Attribution) -> f64 {
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let dot: f64 = self.values.iter().zip(&other.values).map(|(a, b)| a * b).sum();
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let na: f64 = self.values.iter().map(|v| v * v).sum::<f64>().sqrt();
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let nb: f64 = other.values.iter().map(|v| v * v).sum::<f64>().sqrt();
        if na == 0.0 || nb == 0.0 {
            return 0.0;
        }
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(values: Vec<f64>, gap: f64) -> Attribution {
        let sum: f64 = values.iter().sum();
        let delta = (sum - gap).abs();
        Attribution {
            values,
            target: 0,
            steps: 10,
            probe_passes: 0,
            delta,
            endpoint_gap: gap,
            rounds: 1,
            residuals: vec![delta],
            breakdown: StageBreakdown::default(),
        }
    }

    #[test]
    fn sum_and_delta() {
        let a = mk(vec![0.2, 0.3, 0.1], 0.65);
        assert!((a.sum() - 0.6).abs() < 1e-12);
        assert!((a.delta - 0.05).abs() < 1e-12);
        assert!((a.relative_delta() - 0.05 / 0.65).abs() < 1e-12);
    }

    #[test]
    fn residual_trajectory_ends_at_delta() {
        let a = mk(vec![0.2, 0.3], 0.6);
        assert_eq!(a.rounds, 1);
        assert_eq!(a.residuals.len(), a.rounds);
        assert_eq!(*a.residuals.last().unwrap(), a.delta);
    }

    #[test]
    fn relative_delta_zero_gap() {
        let a = mk(vec![0.0, 0.0], 0.0);
        assert_eq!(a.relative_delta(), a.delta);
    }

    #[test]
    fn top_features_by_magnitude() {
        let a = mk(vec![0.1, -0.9, 0.5, -0.2], 0.0);
        assert_eq!(a.top_features(2), vec![1, 2]);
        assert_eq!(a.top_features(10).len(), 4);
    }

    #[test]
    fn cosine() {
        let a = mk(vec![1.0, 0.0], 1.0);
        let b = mk(vec![2.0, 0.0], 2.0);
        let c = mk(vec![0.0, 1.0], 1.0);
        assert!((a.cosine_similarity(&b) - 1.0).abs() < 1e-12);
        assert!(a.cosine_similarity(&c).abs() < 1e-12);
        let z = mk(vec![0.0, 0.0], 0.0);
        assert_eq!(a.cosine_similarity(&z), 0.0);
    }
}
