//! Stage 1: probe the interval boundaries and turn boundary probabilities
//! into normalized interval deltas — the paper's information-content
//! metric ("change in classification probability along the IG path").

use anyhow::{ensure, Result};

/// Result of probing `n_int + 1` boundary points.
#[derive(Debug, Clone)]
pub struct Probe {
    /// Boundary alphas: 0, 1/n, ..., 1.
    pub boundaries: Vec<f64>,
    /// Target-class probability at each boundary.
    pub probs: Vec<f64>,
}

impl Probe {
    /// Build from matching boundary/probability vectors (>= 2 boundaries).
    pub fn new(boundaries: Vec<f64>, probs: Vec<f64>) -> Result<Probe> {
        ensure!(boundaries.len() == probs.len(), "boundary/prob length mismatch");
        ensure!(boundaries.len() >= 2, "need at least 2 boundaries");
        Ok(Probe { boundaries, probs })
    }

    /// Number of probe intervals (boundaries − 1).
    pub fn n_int(&self) -> usize {
        self.boundaries.len() - 1
    }

    /// Normalized |Δp| per interval (sums to 1; all-zero change falls back
    /// to an even distribution, matching the Python reference).
    pub fn interval_deltas(&self) -> Vec<f64> {
        let n = self.n_int();
        let raw: Vec<f64> = (0..n).map(|i| (self.probs[i + 1] - self.probs[i]).abs()).collect();
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let total: f64 = raw.iter().sum();
        if total > 0.0 {
            raw.iter().map(|d| d / total).collect()
        } else {
            vec![1.0 / n as f64; n]
        }
    }

    /// This probe's quantized signature — the cache-key component that
    /// lets near-identical probes share one cached schedule (see
    /// [`crate::ig::schedule::cache`]).
    pub fn signature(&self) -> crate::ig::schedule::cache::ProbeSignature {
        crate::ig::schedule::cache::ProbeSignature::quantize(&self.interval_deltas())
    }

    /// Endpoint probability gap `f(x) - f(x')` — the completeness target
    /// of Eq. 3, read off the probe for free (boundary 0 is the baseline,
    /// boundary n is the input).
    pub fn endpoint_gap(&self) -> f64 {
        self.probs[self.probs.len() - 1] - self.probs[0]
    }

    /// Fraction of the total probability change that happens in the
    /// lowest-alpha `frac` of the path (Fig. 3's concentration statistic).
    pub fn change_concentration(&self, frac: f64) -> f64 {
        let total: f64 = self
            .interval_deltas()
            .iter()
            // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
            .sum();
        if total == 0.0 {
            return 0.0;
        }
        let deltas = self.interval_deltas();
        let mut acc = 0.0;
        for (i, d) in deltas.iter().enumerate() {
            let hi = self.boundaries[i + 1];
            if hi <= frac + 1e-12 {
                acc += d;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn saturating_probe() -> Probe {
        // Shape from the real model: sharp rise then saturation.
        Probe::new(
            vec![0.0, 0.25, 0.5, 0.75, 1.0],
            vec![0.125, 0.82, 0.95, 0.98, 0.99],
        )
        .unwrap()
    }

    #[test]
    fn deltas_normalized() {
        let p = saturating_probe();
        let d = p.interval_deltas();
        assert_eq!(d.len(), 4);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d[0] > 0.75, "first interval should dominate: {d:?}");
    }

    #[test]
    fn deltas_use_abs() {
        let p = Probe::new(vec![0.0, 0.5, 1.0], vec![0.5, 0.9, 0.6]).unwrap();
        let d = p.interval_deltas();
        assert!((d[0] - 0.4 / 0.7).abs() < 1e-12);
        assert!((d[1] - 0.3 / 0.7).abs() < 1e-12);
    }

    #[test]
    fn flat_path_even_fallback() {
        let p = Probe::new(vec![0.0, 0.5, 1.0], vec![0.3, 0.3, 0.3]).unwrap();
        assert_eq!(p.interval_deltas(), vec![0.5, 0.5]);
    }

    #[test]
    fn endpoint_gap() {
        assert!((saturating_probe().endpoint_gap() - (0.99 - 0.125)).abs() < 1e-12);
    }

    #[test]
    fn concentration() {
        let p = saturating_probe();
        let c = p.change_concentration(0.25);
        assert!(c > 0.7, "{c}");
        assert!((p.change_concentration(1.0) - 1.0).abs() < 1e-12);
        assert_eq!(p.change_concentration(0.1), 0.0);
    }

    #[test]
    fn validation() {
        assert!(Probe::new(vec![0.0], vec![0.1]).is_err());
        assert!(Probe::new(vec![0.0, 1.0], vec![0.1]).is_err());
    }

    #[test]
    fn signature_quantizes_normalized_deltas() {
        let p = saturating_probe();
        let sig = p.signature();
        assert_eq!(sig.n_int(), 4);
        // Levels are round(delta * 64) of the normalized deltas.
        let expect: Vec<u8> = p
            .interval_deltas()
            .iter()
            .map(|d| (d * 64.0 + 0.5).floor() as u8)
            .collect();
        assert_eq!(sig.levels(), &expect[..]);
    }
}
