//! Stage-1 step allocation: distribute the total step budget `m` across
//! probe intervals.
//!
//! The paper's rule is `m_int ∝ √|Δf(x_int)|` — the square root
//! deliberately attenuates the bias toward high-change intervals because
//! the linear rule (`m_int ∝ |Δf|`, kept here as [`Allocation::Linear`]
//! for the ablation bench) "allotted negligible discretization steps to
//! regions with small change" (§III). [`Allocation::Even`] ignores the
//! probe entirely (a second ablation: how much of the win is the probe?).
//!
//! Rounding uses largest-remainder so counts sum to exactly `m`, with a
//! floor of 1 step per interval (a zero-step interval has no grid).

use anyhow::{bail, Result};

/// Step-allocation policy across probe intervals.
///
/// `Hash` is derived because the policy is part of the probe-schedule
/// cache key ([`crate::ig::schedule::cache::CacheKey`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Allocation {
    /// The paper's rule: proportional to sqrt(|delta|).
    Sqrt,
    /// Ablation: proportional to |delta| (starves low-change intervals).
    Linear,
    /// Ablation: equal split regardless of the probe.
    Even,
}

impl std::fmt::Display for Allocation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Allocation::Sqrt => "sqrt",
            Allocation::Linear => "linear",
            Allocation::Even => "even",
        };
        write!(f, "{s}")
    }
}

impl Allocation {
    /// Parse `sqrt|linear|even` (CLI syntax).
    pub fn parse(s: &str) -> Result<Allocation> {
        Ok(match s {
            "sqrt" => Allocation::Sqrt,
            "linear" => Allocation::Linear,
            "even" => Allocation::Even,
            _ => bail!("unknown allocation {s:?} (sqrt|linear|even)"),
        })
    }

    /// Distribute `m_total` steps over `deltas.len()` intervals.
    ///
    /// `deltas` are the normalized per-interval probability changes from
    /// stage 1 (non-negative; all-zero falls back to an even split).
    /// Returns per-interval step counts summing to exactly `m_total`,
    /// each >= 1. Mirrors `python/compile/igref.py::_allocate`.
    pub fn allocate(&self, m_total: usize, deltas: &[f64]) -> Result<Vec<usize>> {
        let n = deltas.len();
        if n == 0 {
            bail!("no intervals to allocate over");
        }
        if m_total < n {
            bail!("m_total={m_total} < n_int={n}: every interval needs >= 1 step");
        }
        let scores: Vec<f64> = match self {
            Allocation::Sqrt => deltas.iter().map(|d| d.abs().sqrt()).collect(),
            Allocation::Linear => deltas.iter().map(|d| d.abs()).collect(),
            Allocation::Even => vec![1.0; n],
        };
        Ok(largest_remainder(m_total, &scores))
    }
}

/// Largest-remainder apportionment with a 1-step floor per interval.
/// Mirrors the Python reference: reserve 1 per interval, split the rest
/// proportionally, floor, then hand surplus to the largest fractional
/// remainders (ties broken toward the earlier interval).
fn largest_remainder(m_total: usize, scores: &[f64]) -> Vec<usize> {
    let n = scores.len();
    // nuig:allow(float-reduce): sequential in-order slice iteration — fixed order
    let total: f64 = scores.iter().sum();
    let scores: Vec<f64> = if total <= 0.0 { vec![1.0; n] } else { scores.to_vec() };
    // nuig:allow(float-reduce): sequential in-order slice iteration — fixed order
    let total: f64 = scores.iter().sum();

    let rest = (m_total - n) as f64;
    let raw: Vec<f64> = scores.iter().map(|s| rest * s / total).collect();
    let mut base: Vec<usize> = raw.iter().map(|r| r.floor() as usize).collect();
    let short = (m_total - n) - base.iter().sum::<usize>();

    // Order by fractional remainder desc, index asc — matches Python's
    // sorted(..., key=lambda i: (raw[i]-base[i], -i), reverse=True).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let fa = raw[a] - base[a] as f64;
        let fb = raw[b] - base[b] as f64;
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &i in order.iter().take(short) {
        base[i] += 1;
    }
    base.iter().map(|b| 1 + b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    #[test]
    fn sums_to_total() {
        let alloc = Allocation::Sqrt.allocate(64, &[0.7, 0.2, 0.08, 0.02]).unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 64);
    }

    #[test]
    fn min_one_even_when_starved() {
        let alloc = Allocation::Sqrt.allocate(8, &[1.0, 0.0, 0.0, 0.0]).unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 8);
        assert!(alloc.iter().all(|&a| a >= 1), "{alloc:?}");
    }

    #[test]
    fn monotone_in_delta() {
        let alloc = Allocation::Sqrt.allocate(100, &[0.5, 0.3, 0.15, 0.05]).unwrap();
        let mut sorted = alloc.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(alloc, sorted);
    }

    #[test]
    fn equal_deltas_equal_split() {
        assert_eq!(Allocation::Sqrt.allocate(40, &[0.25; 4]).unwrap(), vec![10; 4]);
        assert_eq!(Allocation::Even.allocate(40, &[0.9, 0.1, 0.0, 0.0]).unwrap(), vec![10; 4]);
    }

    #[test]
    fn sqrt_attenuates_bias_vs_linear() {
        // The paper's §III justification, as an executable fact.
        let deltas = [0.9, 0.05, 0.03, 0.02];
        let lin = Allocation::Linear.allocate(64, &deltas).unwrap();
        let sq = Allocation::Sqrt.allocate(64, &deltas).unwrap();
        assert!(sq.iter().min() > lin.iter().min(), "sqrt {sq:?} vs linear {lin:?}");
        assert!(sq.iter().max() < lin.iter().max());
    }

    #[test]
    fn zero_deltas_fall_back_even() {
        assert_eq!(Allocation::Sqrt.allocate(12, &[0.0, 0.0, 0.0]).unwrap(), vec![4, 4, 4]);
        assert_eq!(Allocation::Linear.allocate(12, &[0.0; 3]).unwrap(), vec![4, 4, 4]);
    }

    #[test]
    fn rejects_m_below_n() {
        assert!(Allocation::Sqrt.allocate(3, &[0.5, 0.3, 0.1, 0.1]).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Allocation::Sqrt.allocate(10, &[]).is_err());
    }

    #[test]
    fn parse_roundtrip() {
        for a in [Allocation::Sqrt, Allocation::Linear, Allocation::Even] {
            assert_eq!(Allocation::parse(&a.to_string()).unwrap(), a);
        }
        assert!(Allocation::parse("cubic").is_err());
    }

    #[test]
    fn matches_python_reference_cases() {
        // Values cross-checked against python igref.sqrt_allocate.
        assert_eq!(
            Allocation::Sqrt.allocate(64, &[0.6, 0.25, 0.1, 0.05]).unwrap().iter().sum::<usize>(),
            64
        );
        // Remainder distribution: ties break toward the earlier interval,
        // matching Python's sorted(key=(frac, -i), reverse=True).
        let alloc = Allocation::Sqrt.allocate(10, &[0.5, 0.5, 0.0]).unwrap();
        assert_eq!(alloc, vec![5, 4, 1]);
    }

    #[test]
    fn property_sum_and_floor() {
        testutil::prop(200, 7, |rng| {
            let n = rng.range(1, 9);
            let m = rng.range(n, 513);
            let deltas: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 1.0)).collect();
            for alloc_kind in [Allocation::Sqrt, Allocation::Linear, Allocation::Even] {
                let alloc = alloc_kind.allocate(m, &deltas).unwrap();
                assert_eq!(alloc.iter().sum::<usize>(), m, "{alloc_kind} {alloc:?}");
                assert!(alloc.iter().all(|&a| a >= 1));
                assert_eq!(alloc.len(), n);
            }
        });
    }

    #[test]
    fn property_scale_invariance() {
        // Allocation depends only on the *relative* deltas.
        testutil::prop(100, 8, |rng| {
            let n = rng.range(2, 8);
            let m = rng.range(n, 257);
            let deltas: Vec<f64> = (0..n).map(|_| rng.range_f64(0.01, 1.0)).collect();
            let scaled: Vec<f64> = deltas.iter().map(|d| d * 7.3).collect();
            assert_eq!(
                Allocation::Sqrt.allocate(m, &deltas).unwrap(),
                Allocation::Sqrt.allocate(m, &scaled).unwrap()
            );
        });
    }
}
