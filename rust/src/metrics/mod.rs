//! Serving metrics: counters, latency histograms, percentiles, EWMA.
//!
//! The paper's evaluation protocol (PyTorch benchmark profiler: warm-up,
//! multi-run averaging) is mirrored by `crate::bench`; this module is the
//! *online* side — what the coordinator reports while serving. Everything
//! is lock-cheap (atomics or a short Mutex) and allocation-free on the hot
//! path once constructed.

mod histogram;
mod summary;

pub use histogram::Histogram;
pub use summary::Summary;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter {
    n: AtomicU64,
}

impl Counter {
    /// A counter at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `k`.
    pub fn add(&self, k: u64) {
        self.n.fetch_add(k, Ordering::Relaxed);
    }

    /// Current count.
    pub fn get(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }
}

/// Peak gauge: remembers the maximum value ever observed (lock-free
/// compare-and-swap). Used for high-water telemetry on the overload
/// gauges the admission shed decision reads (peak resident-pool
/// occupancy, peak lane-queue depth) — "how close did we get to the
/// mark" is the number `docs/TUNING.md` says to tune the marks from.
#[derive(Default)]
pub struct Watermark {
    max: AtomicU64,
}

impl Watermark {
    /// A watermark at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one gauge reading into the peak.
    pub fn observe(&self, v: u64) {
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// The largest value observed so far (0 before any observation).
    pub fn get(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }
}

/// Exponentially-weighted moving average (thread-safe, short critical
/// section). Used for queue-depth and batch-occupancy gauges.
pub struct Ewma {
    alpha: f64,
    state: Mutex<Option<f64>>,
}

impl Ewma {
    /// `alpha` in (0, 1]; larger tracks faster.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1]");
        Ewma { alpha, state: Mutex::new(None) }
    }

    /// Fold one observation into the average.
    pub fn observe(&self, v: f64) {
        let mut s = self.state.lock().unwrap();
        *s = Some(match *s {
            None => v,
            Some(prev) => prev + self.alpha * (v - prev),
        });
    }

    /// Current average (`None` before the first observation).
    pub fn get(&self) -> Option<f64> {
        *self.state.lock().unwrap()
    }
}

/// Hit/miss/evict/insert counters for the probe-schedule cache
/// ([`crate::ig::schedule::cache::ScheduleCache`]). Shared by `Arc`
/// between the cache and [`crate::coordinator::CoordinatorStats`] so the
/// serving layer reports cache effectiveness without reaching into the
/// cache's shards.
#[derive(Default)]
pub struct CacheCounters {
    /// Lookups served from the cache (warm traffic).
    pub hits: Counter,
    /// Lookups that found nothing (cold traffic; a build + insert follows).
    pub misses: Counter,
    /// Entries displaced by the per-shard LRU bound.
    pub evictions: Counter,
    /// Entries built and inserted (one per cold miss that populated).
    pub insertions: Counter,
}

impl CacheCounters {
    /// `hits / (hits + misses)`, or 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get() as f64;
        let m = self.misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// Dispatch-path counters for the tiered work-stealing lane scheduler
/// ([`crate::coordinator::LaneScheduler`]). Shared by `Arc` between the
/// scheduler and [`crate::coordinator::CoordinatorStats`] so serving
/// telemetry reports steal pressure without reaching into the queue.
#[derive(Default)]
pub struct StealCounters {
    /// Chunks assembled fresh from the shared priority buckets.
    pub bucket_pops: Counter,
    /// Chunks served LIFO from the popping feeder's own staged deque.
    pub local_pops: Counter,
    /// Chunks stolen FIFO from a sibling feeder's staged deque.
    pub steals: Counter,
    /// Waits entered by a feeder that found every source empty.
    pub parks: Counter,
    /// Parked-feeder wakeups (bucket activation or close).
    pub wakes: Counter,
}

impl StealCounters {
    /// Total chunks dispatched through any path.
    pub fn chunks(&self) -> u64 {
        self.bucket_pops.get() + self.local_pops.get() + self.steals.get()
    }

    /// `steals / chunks` — the fraction of dispatched chunks a feeder
    /// took from a sibling's deque; 0 before any dispatch.
    pub fn steal_rate(&self) -> f64 {
        let total = self.chunks();
        if total == 0 {
            0.0
        } else {
            self.steals.get() as f64 / total as f64
        }
    }
}

/// RAII timer recording elapsed time into a [`Histogram`] on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> Timer<'a> {
    /// Start timing; the elapsed time is recorded into `hist` on drop.
    pub fn new(hist: &'a Histogram) -> Self {
        Timer { hist, start: Instant::now() }
    }

    /// Elapsed so far, without stopping.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record_duration(self.start.elapsed());
    }
}

/// Lap timer for filling a [`StageBreakdown`]: one wall-clock read per
/// stage boundary, owned here so the deterministic kernels in `ig::`
/// carry no time source of their own (the `wallclock-kernel` lint in
/// tools/nuig-analyze keeps them that way).
pub struct StageTimer {
    last: Instant,
}

impl StageTimer {
    /// Start timing at the current instant.
    pub fn start() -> StageTimer {
        StageTimer { last: Instant::now() }
    }

    /// Time since construction or the previous lap; resets the origin,
    /// so consecutive laps partition the elapsed time into stages.
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now.saturating_duration_since(self.last);
        self.last = now;
        d
    }
}

/// Fixed-stage latency breakdown for one request: probe / schedule /
/// execute / reduce — the decomposition Fig. 6(b)'s overhead analysis
/// needs (stage-1 time as a fraction of total).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StageBreakdown {
    /// Stage-1 probing (forward-only boundary evaluations).
    pub probe: Duration,
    /// Schedule construction (allocation + grid building + fusion).
    pub schedule: Duration,
    /// Device execution of the gradient points.
    pub execute: Duration,
    /// Final reduction/accumulation.
    pub reduce: Duration,
}

impl StageBreakdown {
    /// Sum of all four stages.
    pub fn total(&self) -> Duration {
        self.probe + self.schedule + self.execute + self.reduce
    }

    /// Stage-1 (probe + schedule) share of total, in [0, 1].
    pub fn stage1_fraction(&self) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            return 0.0;
        }
        (self.probe + self.schedule).as_secs_f64() / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn counter_threads() {
        let c = std::sync::Arc::new(Counter::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn watermark_tracks_peak() {
        let w = Watermark::new();
        assert_eq!(w.get(), 0);
        w.observe(4);
        w.observe(2);
        assert_eq!(w.get(), 4, "lower readings never move the peak");
        w.observe(9);
        assert_eq!(w.get(), 9);
    }

    #[test]
    fn watermark_threads() {
        let w = std::sync::Arc::new(Watermark::new());
        let hs: Vec<_> = (0..8)
            .map(|t| {
                let w = w.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        w.observe(t * 1000 + i);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(w.get(), 7999);
    }

    #[test]
    fn ewma_converges() {
        let e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.observe(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.observe(20.0);
        }
        assert!((e.get().unwrap() - 20.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_bad_alpha() {
        Ewma::new(0.0);
    }

    #[test]
    fn timer_records() {
        let h = Histogram::new_latency();
        {
            let _t = Timer::new(&h);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(h.count(), 1);
        assert!(h.mean() >= 0.005);
    }

    #[test]
    fn stage_breakdown_fraction() {
        let b = StageBreakdown {
            probe: Duration::from_millis(2),
            schedule: Duration::from_millis(1),
            execute: Duration::from_millis(90),
            reduce: Duration::from_millis(7),
        };
        assert_eq!(b.total(), Duration::from_millis(100));
        assert!((b.stage1_fraction() - 0.03).abs() < 1e-9);
    }

    #[test]
    fn stage_breakdown_zero_total() {
        assert_eq!(StageBreakdown::default().stage1_fraction(), 0.0);
    }

    #[test]
    fn cache_counters_hit_rate() {
        let c = CacheCounters::default();
        assert_eq!(c.hit_rate(), 0.0, "no lookups yet");
        c.misses.inc();
        assert_eq!(c.hit_rate(), 0.0);
        c.hits.inc();
        c.hits.inc();
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn steal_counters_rate() {
        let c = StealCounters::default();
        assert_eq!(c.steal_rate(), 0.0, "no dispatches yet");
        c.bucket_pops.inc();
        c.local_pops.inc();
        c.local_pops.inc();
        assert_eq!(c.steal_rate(), 0.0);
        c.steals.inc();
        assert_eq!(c.chunks(), 4);
        assert!((c.steal_rate() - 0.25).abs() < 1e-12);
    }
}
