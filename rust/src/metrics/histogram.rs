//! Log-bucketed histogram for latencies (seconds) and other positive values.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free histogram over exponentially-spaced buckets.
///
/// Default layout covers 1 µs .. ~68 s with 8 buckets per octave —
/// ~1.09x relative bucket width, i.e. ≤ ~9 % quantile error, plenty for
/// serving percentiles. Values below/above range clamp to the edge
/// buckets. Also tracks exact count/sum/min/max for an exact mean.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    /// lower bound of bucket 0
    base: f64,
    /// buckets per doubling
    per_octave: usize,
    count: AtomicU64,
    /// sum in nanos-like fixed point (1e-9 of unit)
    sum_fp: AtomicU64,
    min_fp: AtomicU64,
    max_fp: AtomicU64,
}

const FP: f64 = 1e9; // fixed-point scale for sums (ns when unit is seconds)

impl Histogram {
    /// Latency histogram: unit = seconds, 1 µs .. ~68 s.
    pub fn new_latency() -> Self {
        Self::new(1e-6, 8, 8 * 26)
    }

    /// General histogram: `base` = smallest resolvable value,
    /// `per_octave` buckets per doubling, `n_buckets` total.
    pub fn new(base: f64, per_octave: usize, n_buckets: usize) -> Self {
        assert!(base > 0.0 && per_octave >= 1 && n_buckets >= 2);
        Histogram {
            buckets: (0..n_buckets).map(|_| AtomicU64::new(0)).collect(),
            base,
            per_octave,
            count: AtomicU64::new(0),
            sum_fp: AtomicU64::new(0),
            min_fp: AtomicU64::new(u64::MAX),
            max_fp: AtomicU64::new(0),
        }
    }

    fn bucket_index(&self, v: f64) -> usize {
        if v <= self.base {
            return 0;
        }
        let idx = ((v / self.base).log2() * self.per_octave as f64).floor() as isize;
        idx.clamp(0, self.buckets.len() as isize - 1) as usize
    }

    /// Lower edge of bucket `i` (used when reporting quantiles).
    fn bucket_value(&self, i: usize) -> f64 {
        self.base * 2f64.powf(i as f64 / self.per_octave as f64)
    }

    /// Record one observation (non-finite and negative values ignored).
    pub fn record(&self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return; // defensive: never let a NaN poison percentiles
        }
        self.buckets[self.bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let fp = (v * FP) as u64;
        self.sum_fp.fetch_add(fp, Ordering::Relaxed);
        self.min_fp.fetch_min(fp, Ordering::Relaxed);
        self.max_fp.fetch_max(fp, Ordering::Relaxed);
    }

    /// Record a duration in seconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean of recorded observations (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_fp.load(Ordering::Relaxed) as f64 / FP / n as f64
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> f64 {
        let v = self.min_fp.load(Ordering::Relaxed);
        if v == u64::MAX {
            0.0
        } else {
            v as f64 / FP
        }
    }

    /// Exact maximum (0 when empty).
    pub fn max(&self) -> f64 {
        self.max_fp.load(Ordering::Relaxed) as f64 / FP
    }

    /// Approximate quantile `q` in [0,1] (bucket lower-edge estimate;
    /// min/max exact at the extremes).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max();
        }
        let rank = (q * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return self.bucket_value(i).min(self.max()).max(self.min());
            }
        }
        self.max()
    }

    /// p50/p95/p99 convenience tuple.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }

    /// Human-readable one-liner for logs.
    pub fn format_ms(&self) -> String {
        let (p50, p95, p99) = self.percentiles();
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count(),
            self.mean() * 1e3,
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3,
            self.max() * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new_latency();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn mean_exact() {
        let h = Histogram::new_latency();
        for v in [0.001, 0.002, 0.003] {
            h.record(v);
        }
        assert!((h.mean() - 0.002).abs() < 1e-9);
        assert!((h.min() - 0.001).abs() < 1e-9);
        assert!((h.max() - 0.003).abs() < 1e-9);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let h = Histogram::new_latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms..1s uniform
        }
        let p50 = h.quantile(0.5);
        assert!((p50 - 0.5).abs() / 0.5 < 0.15, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((p99 - 0.99).abs() / 0.99 < 0.15, "p99={p99}");
    }

    #[test]
    fn rejects_nan_and_negative() {
        let h = Histogram::new_latency();
        h.record(f64::NAN);
        h.record(-1.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn clamps_out_of_range() {
        let h = Histogram::new_latency();
        h.record(1e-12);
        h.record(1e6);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) < 1e-6 + 1e-9);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new_latency());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 1..=500 {
                        h.record(i as f64 * 1e-5);
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 2000);
    }

    #[test]
    fn format_ms_contains_fields() {
        let h = Histogram::new_latency();
        h.record(0.01);
        let s = h.format_ms();
        assert!(s.contains("n=1") && s.contains("p99="), "{s}");
    }
}
