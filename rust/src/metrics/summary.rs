//! Exact small-sample summary statistics (for bench reporting, where we
//! keep every observation; the serving path uses `Histogram` instead).

/// Exact summary over a stored sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    /// An empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from an existing sample vector.
    pub fn from_values(values: Vec<f64>) -> Self {
        let mut s = Summary { values, sorted: false };
        s.sort();
        s
    }

    /// Record one observation (non-finite values ignored).
    pub fn record(&mut self, v: f64) {
        if v.is_finite() {
            self.values.push(v);
            self.sorted = false;
        }
    }

    fn sort(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Number of recorded observations.
    pub fn count(&self) -> usize {
        self.values.len()
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Exact minimum (0 when empty).
    pub fn min(&mut self) -> f64 {
        self.sort();
        self.values.first().copied().unwrap_or(0.0)
    }

    /// Exact maximum (0 when empty).
    pub fn max(&mut self) -> f64 {
        self.sort();
        self.values.last().copied().unwrap_or(0.0)
    }

    /// Exact quantile with linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        self.sort();
        let n = self.values.len();
        if n == 0 {
            return 0.0;
        }
        if n == 1 {
            return self.values[0];
        }
        let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    /// Exact median (interpolated for even counts).
    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }

    /// Relative spread: stddev / mean (coefficient of variation).
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.stddev() / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = Summary::from_values(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn median_interpolates() {
        let mut s = Summary::from_values(vec![1.0, 2.0, 3.0, 10.0]);
        assert_eq!(s.median(), 2.5);
        let mut s = Summary::from_values(vec![1.0, 2.0, 3.0]);
        assert_eq!(s.median(), 2.0);
    }

    #[test]
    fn quantile_edges() {
        let mut s = Summary::from_values(vec![5.0, 1.0, 3.0]);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 5.0);
    }

    #[test]
    fn empty_and_singleton() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.median(), 0.0);
        s.record(7.0);
        assert_eq!(s.median(), 7.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn ignores_non_finite() {
        let mut s = Summary::new();
        s.record(f64::NAN);
        s.record(f64::INFINITY);
        s.record(2.0);
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn unsorted_input_handled() {
        let mut s = Summary::new();
        for v in [9.0, 1.0, 5.0, 3.0, 7.0] {
            s.record(v);
        }
        assert_eq!(s.median(), 5.0);
        assert!((s.cv() - s.stddev() / 5.0).abs() < 1e-12);
    }
}
