//! Colormaps: scalar in [0,1] → RGB. Piecewise-linear ramps, no lookup
//! tables — precision is irrelevant at 8 bits/channel.

/// A colormap maps t ∈ [0,1] (clamped) to RGB in [0,1]^3.
#[derive(Clone, Copy)]
pub struct Colormap {
    /// Control points (t, r, g, b), strictly increasing t, covering [0,1].
    stops: &'static [(f32, f32, f32, f32)],
}

impl Colormap {
    /// Evaluate at `t` (clamped to [0, 1]) as float RGB.
    pub fn eval(&self, t: f32) -> [f32; 3] {
        let t = t.clamp(0.0, 1.0);
        let stops = self.stops;
        // Find the segment containing t.
        let mut i = 0;
        while i + 2 < stops.len() && stops[i + 1].0 < t {
            i += 1;
        }
        let (t0, r0, g0, b0) = stops[i];
        let (t1, r1, g1, b1) = stops[i + 1];
        let f = if t1 > t0 { (t - t0) / (t1 - t0) } else { 0.0 };
        let f = f.clamp(0.0, 1.0);
        [r0 + f * (r1 - r0), g0 + f * (g1 - g0), b0 + f * (b1 - b0)]
    }

    /// Evaluate at `t` as 8-bit RGB.
    pub fn eval_u8(&self, t: f32) -> [u8; 3] {
        let [r, g, b] = self.eval(t);
        [(r * 255.0).round() as u8, (g * 255.0).round() as u8, (b * 255.0).round() as u8]
    }
}

/// Inferno-like sequential map (black → purple → orange → yellow) for
/// attribution magnitude.
pub fn inferno_like() -> Colormap {
    Colormap {
        stops: &[
            (0.00, 0.00, 0.00, 0.02),
            (0.25, 0.26, 0.04, 0.41),
            (0.50, 0.73, 0.22, 0.33),
            (0.75, 0.98, 0.55, 0.04),
            (1.00, 0.99, 0.99, 0.75),
        ],
    }
}

/// Diverging red-white-blue map for signed attributions (negative = blue,
/// positive = red), centered at t = 0.5.
pub fn diverging_rb() -> Colormap {
    Colormap {
        stops: &[
            (0.00, 0.02, 0.19, 0.60),
            (0.50, 0.97, 0.97, 0.97),
            (1.00, 0.70, 0.02, 0.15),
        ],
    }
}

/// Plain grayscale.
pub fn grayscale() -> Colormap {
    Colormap { stops: &[(0.0, 0.0, 0.0, 0.0), (1.0, 1.0, 1.0, 1.0)] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let cm = grayscale();
        assert_eq!(cm.eval(0.0), [0.0, 0.0, 0.0]);
        assert_eq!(cm.eval(1.0), [1.0, 1.0, 1.0]);
        assert_eq!(cm.eval_u8(0.5), [128, 128, 128]);
    }

    #[test]
    fn clamps_out_of_range() {
        let cm = inferno_like();
        assert_eq!(cm.eval(-3.0), cm.eval(0.0));
        assert_eq!(cm.eval(9.0), cm.eval(1.0));
    }

    #[test]
    fn monotone_brightness_sequential() {
        let cm = inferno_like();
        let lum = |t: f32| {
            let [r, g, b] = cm.eval(t);
            0.2126 * r + 0.7152 * g + 0.0722 * b
        };
        let mut prev = -1.0f32;
        for i in 0..=20 {
            let l = lum(i as f32 / 20.0);
            assert!(l >= prev - 1e-4, "brightness dipped at {i}");
            prev = l;
        }
    }

    #[test]
    fn diverging_center_is_near_white() {
        let [r, g, b] = diverging_rb().eval(0.5);
        assert!(r > 0.9 && g > 0.9 && b > 0.9);
    }

    #[test]
    fn continuous_at_stops() {
        let cm = inferno_like();
        for &(t, ..) in cm.stops {
            let lo = cm.eval((t - 1e-4).max(0.0));
            let hi = cm.eval((t + 1e-4).min(1.0));
            for k in 0..3 {
                assert!((lo[k] - hi[k]).abs() < 0.02);
            }
        }
    }
}
