//! Attribution visualization: colormaps, heatmap rendering, ASCII output.
//!
//! Reproduces the paper's Fig. 1(c)-style heatmaps: per-pixel attribution
//! magnitude over the input image, rendered either as a PPM file or as a
//! terminal ASCII block map (for the quickstart example).

mod colormap;
mod heatmap;

pub use colormap::{diverging_rb, grayscale, inferno_like, Colormap};
pub use heatmap::{ascii_heatmap, pixel_attributions, render_heatmap, render_overlay, HeatmapOptions};
