//! Attribution → heatmap rendering (PPM and ASCII).

use anyhow::{bail, Result};

use crate::data::ppm::Ppm;
use crate::data::synth::{C, F, H, W};

use super::colormap::{inferno_like, Colormap};

/// Rendering options for [`render_heatmap`] / [`render_overlay`].
pub struct HeatmapOptions {
    /// Upscale factor (nearest neighbour) for viewability of 32x32 maps.
    pub scale: usize,
    /// Percentile (0..1] used as the normalization ceiling; attribution
    /// magnitude above it saturates. The IG literature uses 0.99 to stop
    /// single-pixel outliers from washing the map out.
    pub clip_percentile: f64,
    /// Colormap for the normalized magnitudes.
    pub colormap: Colormap,
}

impl Default for HeatmapOptions {
    fn default() -> Self {
        HeatmapOptions { scale: 8, clip_percentile: 0.99, colormap: inferno_like() }
    }
}

/// Collapse a flat (F,) per-feature attribution into per-pixel magnitude
/// (sum of |channel| contributions), the standard IG visualization.
pub fn pixel_attributions(attr: &[f64]) -> Result<Vec<f64>> {
    if attr.len() != F {
        bail!("expected {F} attribution values, got {}", attr.len());
    }
    let mut px = vec![0f64; H * W];
    for pix in 0..H * W {
        let mut s = 0f64;
        for ch in 0..C {
            s += attr[pix * C + ch].abs();
        }
        px[pix] = s;
    }
    Ok(px)
}

fn normalize(px: &[f64], clip_percentile: f64) -> Vec<f32> {
    let mut sorted: Vec<f64> = px.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((clip_percentile.clamp(0.0, 1.0)) * (sorted.len() - 1) as f64).round() as usize;
    let ceil = sorted[idx].max(1e-12);
    px.iter().map(|&v| (v / ceil).min(1.0) as f32).collect()
}

/// Render the attribution heatmap alone.
pub fn render_heatmap(attr: &[f64], opts: &HeatmapOptions) -> Result<Ppm> {
    let px = pixel_attributions(attr)?;
    let norm = normalize(&px, opts.clip_percentile);
    let s = opts.scale.max(1);
    let mut img = Ppm::new(W * s, H * s);
    for y in 0..H {
        for x in 0..W {
            let rgb = opts.colormap.eval_u8(norm[y * W + x]);
            for dy in 0..s {
                for dx in 0..s {
                    img.set(x * s + dx, y * s + dy, rgb);
                }
            }
        }
    }
    Ok(img)
}

/// Render the input image with the heatmap alpha-blended on top
/// (the paper's Fig. 1(c) presentation).
pub fn render_overlay(image: &[f32], attr: &[f64], opts: &HeatmapOptions) -> Result<Ppm> {
    if image.len() != F {
        bail!("expected {F} image values, got {}", image.len());
    }
    let px = pixel_attributions(attr)?;
    let norm = normalize(&px, opts.clip_percentile);
    let s = opts.scale.max(1);
    let mut img = Ppm::new(W * s, H * s);
    for y in 0..H {
        for x in 0..W {
            let t = norm[y * W + x];
            let heat = opts.colormap.eval(t);
            // Blend weight grows with attribution so unexplained regions
            // show the (dimmed) input.
            let a = 0.25 + 0.75 * t;
            let mut rgb = [0u8; 3];
            for ch in 0..3 {
                let base = image[(y * W + x) * C + ch] * 0.6;
                let v = base * (1.0 - a) + heat[ch] * a;
                rgb[ch] = (v.clamp(0.0, 1.0) * 255.0).round() as u8;
            }
            for dy in 0..s {
                for dx in 0..s {
                    img.set(x * s + dx, y * s + dy, rgb);
                }
            }
        }
    }
    Ok(img)
}

/// Terminal heatmap: rows of density glyphs, one char per pixel column
/// pair (2 pixels per char vertically via half-block aesthetics avoided —
/// plain 5-level density keeps it dependency- and locale-safe).
pub fn ascii_heatmap(attr: &[f64]) -> Result<String> {
    const GLYPHS: [char; 6] = [' ', '.', ':', '+', '#', '@'];
    let px = pixel_attributions(attr)?;
    let norm = normalize(&px, 0.99);
    let mut out = String::with_capacity((W + 1) * H);
    for y in 0..H {
        for x in 0..W {
            let lvl = (norm[y * W + x] * (GLYPHS.len() - 1) as f32).round() as usize;
            out.push(GLYPHS[lvl.min(GLYPHS.len() - 1)]);
        }
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_attr() -> Vec<f64> {
        // Strong attribution in a 4x4 block at (8..12, 8..12).
        let mut a = vec![0.0f64; F];
        for y in 8..12 {
            for x in 8..12 {
                for ch in 0..C {
                    a[(y * W + x) * C + ch] = 1.0;
                }
            }
        }
        a
    }

    #[test]
    fn pixel_attributions_sums_channels() {
        let px = pixel_attributions(&fake_attr()).unwrap();
        assert_eq!(px[9 * W + 9], 3.0);
        assert_eq!(px[0], 0.0);
    }

    #[test]
    fn pixel_attributions_uses_abs() {
        let mut a = vec![0.0f64; F];
        a[0] = -2.0;
        a[1] = 1.0;
        let px = pixel_attributions(&a).unwrap();
        assert_eq!(px[0], 3.0);
    }

    #[test]
    fn rejects_wrong_len() {
        assert!(pixel_attributions(&[0.0; 5]).is_err());
    }

    #[test]
    fn heatmap_hot_where_attribution() {
        let img = render_heatmap(&fake_attr(), &HeatmapOptions { scale: 1, ..Default::default() }).unwrap();
        let hot = img.get(9, 9);
        let cold = img.get(0, 0);
        let lum = |p: [u8; 3]| p[0] as u32 + p[1] as u32 + p[2] as u32;
        assert!(lum(hot) > lum(cold) + 100, "{hot:?} vs {cold:?}");
    }

    #[test]
    fn heatmap_scales() {
        let img = render_heatmap(&fake_attr(), &HeatmapOptions { scale: 4, ..Default::default() }).unwrap();
        assert_eq!(img.width, 128);
        assert_eq!(img.height, 128);
        assert_eq!(img.get(36, 36), img.get(37, 37)); // nearest-neighbour block
    }

    #[test]
    fn overlay_shape_and_blend() {
        let image = vec![0.5f32; F];
        let img = render_overlay(&image, &fake_attr(), &HeatmapOptions { scale: 1, ..Default::default() }).unwrap();
        assert_eq!(img.width, W);
        // Cold region shows dimmed input, not pure black.
        let cold = img.get(0, 0);
        assert!(cold[0] > 10);
    }

    #[test]
    fn ascii_dimensions_and_hotspot() {
        let s = ascii_heatmap(&fake_attr()).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), H);
        assert!(lines.iter().all(|l| l.chars().count() == W));
        assert_eq!(lines[9].chars().nth(9), Some('@'));
        assert_eq!(lines[0].chars().next(), Some(' '));
    }

    #[test]
    fn constant_attr_does_not_div_by_zero() {
        let a = vec![0.0f64; F];
        let s = ascii_heatmap(&a).unwrap();
        assert!(s.chars().all(|c| c == ' ' || c == '\n'));
    }
}
