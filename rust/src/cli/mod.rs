//! Minimal CLI argument parser (no `clap` offline).
//!
//! Supports the subcommand + flags shape the `nuig` binary uses:
//!
//! ```text
//! nuig <subcommand> [--flag] [--key value] [--key=value] [positional...]
//! ```
//!
//! Typed accessors consume recognized keys; [`Args::finish`] errors on
//! anything left over, so typos fail loudly instead of being ignored.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    /// The subcommand (first non-flag token), if any.
    pub command: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut tokens = tokens.into_iter().peekable();
        let mut command = None;
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positionals = Vec::new();

        while let Some(tok) = tokens.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest are positionals.
                    positionals.extend(tokens.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    if k.is_empty() {
                        bail!("empty option name in {tok:?}");
                    }
                    options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if the next token isn't another option;
                    // otherwise a boolean flag.
                    match tokens.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = tokens.next().unwrap();
                            options.insert(body.to_string(), v);
                        }
                        _ => flags.push(body.to_string()),
                    }
                }
            } else if command.is_none() && positionals.is_empty() {
                command = Some(tok);
            } else {
                positionals.push(tok);
            }
        }
        Ok(Args { command, options, flags, positionals })
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Consume a string option.
    pub fn opt_str(&mut self, key: &str) -> Option<String> {
        self.options.remove(key)
    }

    /// Consume a required string option.
    pub fn req_str(&mut self, key: &str) -> Result<String> {
        self.opt_str(key).ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// Consume a typed option with a default.
    pub fn opt<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.remove(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("invalid value for --{key}: {v:?} ({e})")),
        }
    }

    /// Consume a comma-separated list option (empty → default).
    pub fn opt_list<T: std::str::FromStr>(&mut self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.options.remove(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|e| anyhow!("invalid element {s:?} for --{key}: {e}"))
                })
                .collect(),
        }
    }

    /// Consume a boolean flag (present or not).
    pub fn flag(&mut self, key: &str) -> bool {
        if let Some(pos) = self.flags.iter().position(|f| f == key) {
            self.flags.remove(pos);
            true
        } else {
            false
        }
    }

    /// Remaining positionals (consumes).
    pub fn take_positionals(&mut self) -> Vec<String> {
        std::mem::take(&mut self.positionals)
    }

    /// Error if any unconsumed option/flag remains (positionals included).
    pub fn finish(self) -> Result<()> {
        let mut leftovers: Vec<String> = self.options.keys().map(|k| format!("--{k}")).collect();
        leftovers.extend(self.flags.iter().map(|f| format!("--{f}")));
        leftovers.extend(self.positionals.iter().cloned());
        if leftovers.is_empty() {
            Ok(())
        } else {
            bail!("unrecognized arguments: {}", leftovers.join(" "));
        }
    }
}

/// Parse helper for `k1=v1,k2=v2` option payloads.
pub fn parse_kv_list(s: &str) -> Result<BTreeMap<String, String>> {
    let mut m = BTreeMap::new();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let (k, v) = part
            .split_once('=')
            .with_context(|| format!("expected key=value, got {part:?}"))?;
        m.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let mut a = parse(&["serve", "--workers", "4", "--m=128", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.opt("workers", 1usize).unwrap(), 4);
        assert_eq!(a.opt("m", 0usize).unwrap(), 128);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_applied() {
        let mut a = parse(&["explain"]);
        assert_eq!(a.opt("m", 64usize).unwrap(), 64);
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn invalid_typed_value() {
        let mut a = parse(&["x", "--m", "abc"]);
        assert!(a.opt("m", 0usize).is_err());
    }

    #[test]
    fn list_option() {
        let mut a = parse(&["x", "--grid", "8,16, 32"]);
        assert_eq!(a.opt_list("grid", &[1usize]).unwrap(), vec![8, 16, 32]);
        let mut b = parse(&["x"]);
        assert_eq!(b.opt_list("grid", &[1usize, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn finish_rejects_leftovers() {
        let a = parse(&["x", "--unknown", "1"]);
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--unknown"), "{err}");
    }

    #[test]
    fn flag_followed_by_option() {
        let mut a = parse(&["x", "--fast", "--m", "8"]);
        assert!(a.flag("fast"));
        assert_eq!(a.opt("m", 0usize).unwrap(), 8);
    }

    #[test]
    fn double_dash_terminator() {
        let mut a = parse(&["x", "--", "--not-an-option"]);
        assert_eq!(a.take_positionals(), vec!["--not-an-option"]);
    }

    #[test]
    fn positionals() {
        let mut a = parse(&["render", "out.ppm", "in.json"]);
        assert_eq!(a.command.as_deref(), Some("render"));
        assert_eq!(a.take_positionals(), vec!["out.ppm", "in.json"]);
    }

    #[test]
    fn missing_required() {
        let mut a = parse(&["x"]);
        assert!(a.req_str("out").is_err());
    }

    #[test]
    fn kv_list() {
        let m = parse_kv_list("a=1,b=two").unwrap();
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "two");
        assert!(parse_kv_list("oops").is_err());
    }
}
