//! Bounded MPMC channel with blocking backpressure and explicit close.
//!
//! Built on `Mutex<VecDeque> + Condvar` — simple, correct, and fast enough
//! that it never shows in serving profiles (one send/recv pair per
//! multi-millisecond PJRT execution). Semantics:
//!
//! * `send` blocks while full; returns `Err(SendError)` once closed.
//! * `recv` blocks while empty; returns `Err(RecvError)` once closed AND
//!   drained — in-flight items are never lost on close.
//! * Any handle may [`Sender::close`]/[`Receiver::close`]; dropping all
//!   Senders also closes, and so does dropping all Receivers — a sender
//!   parked on a full queue with no receiver left alive would otherwise
//!   wait forever (the interleaving model in `tests/interleave_models.rs`
//!   surfaces exactly that as a deadlock).
//!
//! All synchronization goes through [`crate::exec::sync`]: poison-safe
//! lock helpers in production, instrumented shims under
//! `--features loom-models` so the close/wakeup protocol is exhaustively
//! interleaved by `exec::interleave`.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use crate::exec::sync::atomic::{AtomicUsize, Ordering};
use crate::exec::sync::{self, Condvar, Mutex};

/// Error returned by [`Sender::send`] on a closed channel; carries the
/// rejected value back to the caller.
#[derive(Debug)]
pub struct SendError<T>(pub T);

/// Error returned by [`Receiver::recv`] on a closed-and-drained channel.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

struct Shared<T> {
    q: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    senders: AtomicUsize,
    receivers: AtomicUsize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Shared<T> {
    /// Set `closed` under the lock and wake every parked thread on both
    /// sides. The flag and the wakeups must agree: the flag is only ever
    /// set while the queue mutex is held, so a parked thread cannot
    /// re-check the predicate between the flag write and its notify.
    fn close(&self) {
        let mut st = sync::lock(&self.q);
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Create a bounded channel of capacity `cap` (>= 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1, "channel capacity must be >= 1");
    let shared = Arc::new(Shared {
        q: Mutex::new(State { items: VecDeque::with_capacity(cap), closed: false }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        cap,
        senders: AtomicUsize::new(1),
        receivers: AtomicUsize::new(1),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

/// Sending half of a bounded channel (clonable; MPMC).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half of a bounded channel (clonable; MPMC).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.senders.fetch_add(1, Ordering::Relaxed);
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.receivers.fetch_add(1, Ordering::Relaxed);
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if self.shared.senders.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last sender gone: close so receivers drain and stop. The
            // count can only hit zero once (cloning requires a live
            // sender), so this close races nothing but parked receivers —
            // which Shared::close wakes under the lock.
            self.shared.close();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if self.shared.receivers.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last receiver gone: nobody can ever drain the queue again,
            // so close to fail parked and future senders instead of
            // leaving them blocked on backpressure forever.
            self.shared.close();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send with backpressure. Fails only if the channel closed.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = sync::lock(&self.shared.q);
        loop {
            if st.closed {
                return Err(SendError(item));
            }
            if st.items.len() < self.shared.cap {
                st.items.push_back(item);
                drop(st);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = sync::wait(&self.shared.not_full, st);
        }
    }

    /// Non-blocking send: `Err` with the value if full or closed.
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = sync::lock(&self.shared.q);
        if st.closed || st.items.len() >= self.shared.cap {
            return Err(SendError(item));
        }
        st.items.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel; senders fail fast, receivers drain then stop.
    pub fn close(&self) {
        self.shared.close();
    }

    /// Queue depth right now (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        sync::lock(&self.shared.q).items.len()
    }

    /// Whether the queue is empty right now (diagnostic; racy by nature).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; drains remaining items after close, then errors.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = sync::lock(&self.shared.q);
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(RecvError);
            }
            st = sync::wait(&self.shared.not_empty, st);
        }
    }

    /// Receive with a timeout; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, dur: Duration) -> Result<Option<T>, RecvError> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = sync::lock(&self.shared.q);
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed {
                return Err(RecvError);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = sync::wait_timeout(&self.shared.not_empty, st, deadline - now);
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Err(RecvError);
                }
                return Ok(None);
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<Option<T>, RecvError> {
        let mut st = sync::lock(&self.shared.q);
        if let Some(item) = st.items.pop_front() {
            drop(st);
            self.shared.not_full.notify_one();
            return Ok(Some(item));
        }
        if st.closed {
            return Err(RecvError);
        }
        Ok(None)
    }

    /// Drain up to `max` immediately-available items (batching helper:
    /// the coordinator's batcher uses this to opportunistically fill a
    /// chunk without waiting).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = sync::lock(&self.shared.q);
        let n = st.items.len().min(max);
        let out: Vec<T> = st.items.drain(..n).collect();
        drop(st);
        if !out.is_empty() {
            self.shared.not_full.notify_all();
        }
        out
    }

    /// Close the channel; senders fail fast, receivers drain then stop.
    /// (Symmetric with [`Sender::close`] — "any handle may close".)
    pub fn close(&self) {
        self.shared.close();
    }

    /// Queue depth right now (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        sync::lock(&self.shared.q).items.len()
    }

    /// Whether the queue is empty right now (diagnostic; racy by nature).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the channel has been closed (items may still be queued).
    pub fn is_closed(&self) -> bool {
        sync::lock(&self.shared.q).closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn backpressure_blocks_until_recv() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until the main thread receives
            tx.send(3).unwrap();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
        t.join().unwrap();
    }

    #[test]
    fn close_drains_then_errors() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert!(tx.send(3).is_err());
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn dropping_all_senders_closes() {
        let (tx, rx) = bounded::<u32>(4);
        let tx2 = tx.clone();
        tx.send(7).unwrap();
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn dropping_all_receivers_closes() {
        // Regression (ISSUE 6 satellite): with every receiver gone the
        // queue can never drain, so senders must fail instead of blocking
        // on backpressure forever.
        let (tx, rx) = bounded::<u32>(1);
        let rx2 = rx.clone();
        drop(rx);
        drop(rx2);
        assert!(tx.try_send(1).is_err(), "closed channel must reject sends");
        assert!(tx.send(2).is_err(), "blocking send must fail, not park");
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn dropping_last_receiver_wakes_parked_sender() {
        // Regression (ISSUE 6 satellite): a sender already parked on a
        // full queue must be woken — not leaked — when the last receiver
        // drops.
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap(); // fill the queue
        let t = thread::spawn(move || tx.send(2));
        thread::sleep(Duration::from_millis(20)); // let the sender park
        drop(rx);
        let res = t.join().unwrap();
        assert!(res.is_err(), "parked sender must observe the close");
    }

    #[test]
    fn receiver_close_fails_senders_and_drains() {
        // Regression (ISSUE 6 satellite): close from the receiving side —
        // the documented "any handle may close" contract.
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        rx.close();
        assert!(tx.send(2).is_err());
        assert_eq!(rx.recv().unwrap(), 1, "queued items still drain");
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = bounded(1);
        tx.try_send(1).unwrap();
        assert!(tx.try_send(2).is_err());
    }

    #[test]
    fn try_recv_empty() {
        let (tx, rx) = bounded::<u32>(1);
        assert_eq!(rx.try_recv(), Ok(None));
        tx.send(5).unwrap();
        assert_eq!(rx.try_recv(), Ok(Some(5)));
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn recv_timeout_times_out() {
        let (_tx, rx) = bounded::<u32>(1);
        let t0 = std::time::Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(30)), Ok(None));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn drain_up_to_takes_available() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain_up_to(3), vec![0, 1, 2]);
        assert_eq!(rx.drain_up_to(10), vec![3, 4]);
        assert_eq!(rx.drain_up_to(10), Vec::<i32>::new());
    }

    #[test]
    #[cfg_attr(miri, ignore = "large thread fan-out; covered natively")]
    fn mpmc_stress() {
        let (tx, rx) = bounded(4);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..250 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut expect: Vec<i32> =
            (0..4).flat_map(|p| (0..250).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }
}
