//! Batched execution backend for the IG hot path.
//!
//! Stage 2 of every explanation is "evaluate a fused point stream": a
//! list of `(alpha, weight)` points, each a full forward+backward model
//! pass. Before this module the engines handed the whole stream to
//! `Model::ig_points`, which walked it one point at a time with fresh
//! `Vec` allocations per point on a single core. This module is the
//! substrate that replaces that walk:
//!
//! * [`PointBatch`] — one planar, contiguous `points × features` f32
//!   buffer. [`PointBatch::fill`] fuses the interpolation
//!   `x′ + α(x − x′)` into the write, so interpolated images are never
//!   materialized as per-point `Vec`s anywhere in the pipeline.
//! * [`ScratchArena`] — per-worker (thread-local) reusable scratch for
//!   the analytic kernel's logits/softmax/gradient intermediates; a
//!   steady-state worker performs zero per-point heap allocations.
//! * [`BatchPlan`] / [`BatchOut`] — the chunk-evaluation contract the
//!   [`Model`](crate::ig::Model) trait's `eval_batch` implements: one
//!   contiguous run of points in, a chunk-local f64 partial plus the
//!   per-point target probabilities out.
//! * [`BatchExec`] — the dispatch policy: evaluate chunks inline
//!   ([`BatchExec::Sequential`]) or fan them out across the existing
//!   [`ThreadPool`] ([`BatchExec::parallel`]), with a **deterministic
//!   ordered reduction** either way.
//!
//! # Determinism contract
//!
//! [`run_chunks`] shards a point stream into fixed-size chunks
//! ([`chunk_spans`]), evaluates each chunk into its own f64 partial, and
//! reduces the chunk partials **in chunk-index order** — regardless of
//! the order workers finish. Chunk contents, chunk boundaries, and the
//! reduction order are all pure functions of `(n_points, chunk)`, so for
//! a fixed chunk size the result is bit-identical at *any* worker count,
//! including the sequential path (property-tested in
//! `tests/engine_properties.rs` at worker counts {1, 2, 4, 8}). This
//! invariant is what keeps the schedule-cache goldens and the Python
//! parity suite valid no matter how the serving host is provisioned.
//! Changing `chunk` re-associates the f64 sums and may move attributions
//! at the ~1e-16 relative scale — see `docs/TUNING.md`.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use super::ThreadPool;

/// Default points per execution chunk.
///
/// Large enough that chunk-dispatch overhead (one pool task + one f64
/// reduction per chunk) is negligible next to a chunk's model passes,
/// small enough that the paper's operating points (m ∈ {16..256}) still
/// shard across several workers. Mirrored as `igref.BATCH_CHUNK` on the
/// Python side; the `fig_hotpath` bench justifies the value (see
/// `docs/TUNING.md` §Execution backend).
pub const DEFAULT_CHUNK: usize = 64;

/// Split `n` points into contiguous `(start, len)` spans of at most
/// `chunk` points each (the final span carries the remainder).
///
/// This layout is part of the determinism contract: chunk boundaries are
/// a pure function of `(n, chunk)`, mirrored bit-for-bit by
/// `igref.chunk_spans` and pinned by shared goldens on both sides.
pub fn chunk_spans(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    assert!(chunk >= 1, "chunk must be >= 1");
    let mut out = Vec::with_capacity(n.div_ceil(chunk));
    let mut start = 0;
    while start < n {
        let len = chunk.min(n - start);
        out.push((start, len));
        start += len;
    }
    out
}

/// A planar `points × features` batch of interpolated images: one
/// contiguous f32 buffer, row `k` holding `x′ + α_k (x − x′)`.
///
/// The buffer is reused across fills (capacity only grows), so the
/// steady-state cost of materializing a batch is the fused interpolation
/// writes themselves — no per-point allocation, ever.
#[derive(Debug, Default)]
pub struct PointBatch {
    features: usize,
    rows: usize,
    buf: Vec<f32>,
}

impl PointBatch {
    /// An empty batch (first [`PointBatch::fill`] sizes it).
    pub fn new() -> PointBatch {
        PointBatch::default()
    }

    /// Fill the batch with one row per alpha: `row_k[i] = x′_i + α_k (x_i − x′_i)`.
    ///
    /// The interpolation is fused into the buffer write — the exact f32
    /// expression the scalar reference kernel uses per point, lane-blocked
    /// through [`simd::interpolate`](super::simd::interpolate) (elementwise,
    /// so lane width cannot change the bits), so a filled row is
    /// bit-identical to the per-point materialization it replaces
    /// (property-tested in this module).
    pub fn fill(&mut self, x: &[f32], baseline: &[f32], alphas: &[f32]) {
        assert_eq!(x.len(), baseline.len(), "endpoint width mismatch");
        self.features = x.len();
        self.rows = alphas.len();
        // resize (not clear+resize): only a grown tail is zero-filled, and
        // every row is overwritten by the fused interpolation below.
        self.buf.resize(self.rows * self.features, 0.0);
        for (row, &a) in self.buf.chunks_mut(self.features.max(1)).zip(alphas) {
            super::simd::interpolate(row, x, baseline, a);
        }
    }

    /// Row `k` as a flat feature slice.
    pub fn row(&self, k: usize) -> &[f32] {
        &self.buf[k * self.features..(k + 1) * self.features]
    }

    /// Number of filled rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Whether the batch holds no rows.
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Feature width of the filled rows.
    pub fn features(&self) -> usize {
        self.features
    }

    /// The whole planar buffer (`rows × features`, row-major).
    pub fn as_flat(&self) -> &[f32] {
        &self.buf[..self.rows * self.features]
    }
}

/// One contiguous chunk of a fused point stream, borrowed from the
/// caller — the unit [`Model::eval_batch`](crate::ig::Model::eval_batch)
/// evaluates.
#[derive(Debug, Clone, Copy)]
pub struct BatchPlan<'a> {
    /// The explained input image (full feature width).
    pub x: &'a [f32],
    /// The baseline x′.
    pub baseline: &'a [f32],
    /// Interpolation constants of this chunk's points.
    pub alphas: &'a [f32],
    /// Quadrature weights (zero weight ⇒ forward-only point).
    pub weights: &'a [f32],
    /// The explained class.
    pub target: usize,
    /// Resident-tensor slot `x`/`baseline` were registered under with the
    /// executing backend ([`crate::exec::gather::GatherExec`]), when the
    /// caller holds one. Backends with a resident path (e.g.
    /// `runtime::PjrtModel`) then skip re-uploading the endpoints per
    /// chunk; every other backend ignores it. `None` = self-contained
    /// plan (the default everywhere outside the serving path).
    pub slot: Option<u64>,
}

impl BatchPlan<'_> {
    /// Points in this chunk.
    pub fn len(&self) -> usize {
        self.alphas.len()
    }

    /// Whether the chunk is empty.
    pub fn is_empty(&self) -> bool {
        self.alphas.is_empty()
    }
}

/// Output of one chunk evaluation: the chunk-local partial attribution
/// (f64-accumulated in point order) and p(target) at every point.
#[derive(Debug, Clone)]
pub struct BatchOut {
    /// (F,) chunk-local weighted gradient sum.
    pub partial: Vec<f64>,
    /// Target-class probability at each of the chunk's points.
    pub target_probs: Vec<f64>,
}

/// Per-worker reusable scratch for batched kernels: the planar point
/// batch plus f64 slots for logits, softmax probabilities, and the
/// probability-weighted row average the softmax gradient needs.
///
/// Access goes through [`ScratchArena::with`], which hands out the
/// calling thread's arena — one arena per worker thread, reused across
/// chunks and requests, so a warmed-up worker allocates nothing on the
/// hot path. Not re-entrant: `with` must not be nested on one thread.
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Planar interpolated-point buffer.
    pub batch: PointBatch,
    /// (C,) per-point logits slot.
    pub logits: Vec<f64>,
    /// (C,) per-point softmax slot.
    pub probs: Vec<f64>,
    /// (F,) probability-weighted average weight row (softmax gradient).
    pub wavg: Vec<f64>,
}

impl ScratchArena {
    /// Run `f` with the calling thread's arena (created on first use).
    pub fn with<R>(f: impl FnOnce(&mut ScratchArena) -> R) -> R {
        thread_local! {
            static ARENA: RefCell<ScratchArena> = RefCell::new(ScratchArena::default());
        }
        ARENA.with(|a| f(&mut a.borrow_mut()))
    }
}

/// How a fused point stream is executed: inline on the calling thread,
/// or sharded across a [`ThreadPool`]. Both paths use the same chunking
/// and the same ordered reduction, so at equal `chunk` they produce
/// bit-identical attributions (see the module doc).
#[derive(Clone)]
pub enum BatchExec {
    /// Evaluate chunks inline, in order, on the calling thread.
    Sequential,
    /// Fan chunks out across `pool`; results reduce in chunk order.
    Parallel {
        /// The worker pool chunks are dispatched on.
        pool: Arc<ThreadPool>,
        /// Points per chunk (the work-sharding grain).
        chunk: usize,
    },
}

impl BatchExec {
    /// The sequential policy (what the public fixed-signature engines use).
    pub fn sequential() -> BatchExec {
        BatchExec::Sequential
    }

    /// Parallel dispatch on `pool` at the default chunk size.
    pub fn parallel(pool: Arc<ThreadPool>) -> BatchExec {
        BatchExec::Parallel { pool, chunk: DEFAULT_CHUNK }
    }

    /// Parallel dispatch with an explicit chunk size (>= 1). Changing the
    /// chunk size re-associates the f64 reduction — see `docs/TUNING.md`.
    pub fn parallel_with_chunk(pool: Arc<ThreadPool>, chunk: usize) -> BatchExec {
        assert!(chunk >= 1, "chunk must be >= 1");
        BatchExec::Parallel { pool, chunk }
    }

    /// Points per execution chunk under this policy.
    pub fn chunk(&self) -> usize {
        match self {
            BatchExec::Sequential => DEFAULT_CHUNK,
            BatchExec::Parallel { chunk, .. } => *chunk,
        }
    }

    /// Worker threads this policy can occupy (1 for sequential).
    pub fn workers(&self) -> usize {
        match self {
            BatchExec::Sequential => 1,
            BatchExec::Parallel { pool, .. } => pool.worker_count(),
        }
    }
}

impl std::fmt::Debug for BatchExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchExec::Sequential => write!(f, "Sequential"),
            BatchExec::Parallel { pool, chunk } => {
                write!(f, "Parallel {{ workers: {}, chunk: {} }}", pool.worker_count(), chunk)
            }
        }
    }
}

/// Shard `n` points into `exec.chunk()`-sized chunks, evaluate each via
/// `eval(start, len)`, and reduce the chunk outputs with the
/// deterministic ordered reduction (chunk partials summed in chunk-index
/// order; per-point probabilities concatenated in stream order).
///
/// Under [`BatchExec::Parallel`] chunks run on the pool via
/// [`ThreadPool::scoped_map`]: a chunk that *panics* fails the whole
/// evaluation with `Err` after every sibling chunk has settled — the
/// pool and any concurrent evaluations survive. Under
/// [`BatchExec::Sequential`] a panic propagates to the caller unchanged
/// (the pre-batch behaviour); an `Err` from `eval` fails the evaluation
/// on both paths.
pub fn run_chunks<E>(exec: &BatchExec, n: usize, features: usize, eval: E) -> Result<BatchOut>
where
    E: Fn(usize, usize) -> Result<BatchOut> + Sync,
{
    // Deterministic ordered reduction: chunk index order, always.
    fn reduce(acc: &mut BatchOut, out: BatchOut, features: usize) -> Result<()> {
        ensure!(out.partial.len() == features, "chunk partial width {} != {features}", out.partial.len());
        for (a, v) in acc.partial.iter_mut().zip(&out.partial) {
            *a += v;
        }
        acc.target_probs.extend(out.target_probs);
        Ok(())
    }

    let spans = chunk_spans(n, exec.chunk());
    let mut acc =
        BatchOut { partial: vec![0f64; features], target_probs: Vec::with_capacity(n) };
    match exec {
        // Inline: evaluate in order and FAIL FAST — a chunk's Err (e.g. a
        // dead device) stops the stream before later chunks pay for it.
        BatchExec::Sequential => {
            for &(s, l) in &spans {
                reduce(&mut acc, eval(s, l)?, features)?;
            }
        }
        // Pool: chunks are already in flight together, so all settle
        // before the first Err surfaces (panics are mapped to Err after
        // every sibling has been joined — the pool survives).
        BatchExec::Parallel { pool, .. } => {
            let outs = pool
                .scoped_map(spans.len(), |ci| {
                    let (s, l) = spans[ci];
                    eval(s, l)
                })
                .map_err(|panic| anyhow!("batch chunk panicked: {panic}"))?;
            for out in outs {
                reduce(&mut acc, out?, features)?;
            }
        }
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{self, TestRng};

    #[test]
    fn chunk_spans_layout() {
        // Shared goldens with igref.chunk_spans (test_batch_parity.py):
        // the span layout is part of the cross-language contract.
        assert_eq!(chunk_spans(0, 64), vec![]);
        assert_eq!(chunk_spans(1, 64), vec![(0, 1)]);
        assert_eq!(chunk_spans(64, 64), vec![(0, 64)]);
        assert_eq!(chunk_spans(65, 64), vec![(0, 64), (64, 1)]);
        assert_eq!(chunk_spans(257, 64), vec![(0, 64), (64, 64), (128, 64), (192, 64), (256, 1)]);
        assert_eq!(chunk_spans(7, 3), vec![(0, 3), (3, 3), (6, 1)]);
    }

    #[test]
    fn chunk_spans_cover_exactly() {
        testutil::prop(50, 11, |rng| {
            let n = rng.range(0, 2000);
            let chunk = rng.range(1, 129);
            let spans = chunk_spans(n, chunk);
            let mut next = 0;
            for &(s, l) in &spans {
                assert_eq!(s, next, "spans must be contiguous");
                assert!(l >= 1 && l <= chunk);
                next = s + l;
            }
            assert_eq!(next, n, "spans must cover the stream exactly");
        });
    }

    #[test]
    fn point_batch_fill_matches_per_point_interpolation() {
        // The satellite property: the fused planar fill is bit-identical
        // to the per-point scratch-buffer materialization it replaces.
        testutil::prop(30, 123, |rng| {
            let f = rng.range(1, 40);
            let n = rng.range(0, 20);
            let x = rng.vec_f32(f, 0.0, 1.0);
            let b = rng.vec_f32(f, 0.0, 1.0);
            let alphas = rng.vec_f32(n, 0.0, 1.0);
            let mut batch = PointBatch::new();
            batch.fill(&x, &b, &alphas);
            assert_eq!(batch.rows(), n);
            assert_eq!(batch.features(), f);
            for (k, &a) in alphas.iter().enumerate() {
                let row = batch.row(k);
                for i in 0..f {
                    let expect = b[i] + a * (x[i] - b[i]);
                    assert_eq!(row[i].to_bits(), expect.to_bits(), "row {k} feature {i}");
                }
            }
        });
    }

    #[test]
    fn point_batch_reuse_shrinks_and_grows() {
        let mut batch = PointBatch::new();
        let x = vec![1.0f32; 8];
        let b = vec![0.0f32; 8];
        batch.fill(&x, &b, &[0.25, 0.5, 0.75]);
        assert_eq!(batch.rows(), 3);
        assert_eq!(batch.as_flat().len(), 24);
        batch.fill(&x, &b, &[0.5]);
        assert_eq!(batch.rows(), 1);
        assert_eq!(batch.as_flat(), &[0.5; 8]);
        assert!(!batch.is_empty());
        batch.fill(&x, &b, &[]);
        assert!(batch.is_empty());
    }

    #[test]
    fn scratch_arena_is_per_thread_and_reused() {
        ScratchArena::with(|a| {
            a.logits.resize(8, 0.0);
            a.logits[0] = 42.0;
        });
        // Same thread: the slot persists (reuse).
        ScratchArena::with(|a| {
            assert_eq!(a.logits.len(), 8);
            assert_eq!(a.logits[0], 42.0);
        });
        // Another thread: a fresh arena.
        std::thread::spawn(|| {
            ScratchArena::with(|a| assert!(a.logits.is_empty()));
        })
        .join()
        .unwrap();
    }

    fn toy_eval(start: usize, len: usize) -> Result<BatchOut> {
        // Per-point contribution i + 1 into a 2-wide partial; probs = alpha index.
        let mut partial = vec![0f64; 2];
        let mut probs = Vec::new();
        for k in start..start + len {
            partial[0] += (k + 1) as f64;
            partial[1] += 0.5;
            probs.push(k as f64);
        }
        Ok(BatchOut { partial, target_probs: probs })
    }

    #[test]
    fn run_chunks_sequential_reduces_in_order() {
        let out = run_chunks(&BatchExec::Sequential, 10, 2, toy_eval).unwrap();
        assert_eq!(out.partial, vec![55.0, 5.0]);
        assert_eq!(out.target_probs, (0..10).map(|k| k as f64).collect::<Vec<_>>());
    }

    #[test]
    fn run_chunks_empty_stream() {
        let out = run_chunks(&BatchExec::Sequential, 0, 3, toy_eval).unwrap();
        assert_eq!(out.partial, vec![0.0; 3]);
        assert!(out.target_probs.is_empty());
    }

    #[test]
    fn run_chunks_parallel_matches_sequential_bitwise() {
        let mut rng = TestRng::new(7);
        let contrib: Vec<f64> = (0..200).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let eval = |start: usize, len: usize| -> Result<BatchOut> {
            let mut partial = vec![0f64; 1];
            let mut probs = Vec::new();
            for k in start..start + len {
                partial[0] += contrib[k];
                probs.push(contrib[k]);
            }
            Ok(BatchOut { partial, target_probs: probs })
        };
        for workers in [1usize, 2, 4, 8] {
            let pool = Arc::new(ThreadPool::new(workers));
            for chunk in [1usize, 7, 64] {
                let seq = run_chunks(
                    &BatchExec::Parallel { pool: pool.clone(), chunk },
                    contrib.len(),
                    1,
                    eval,
                )
                .unwrap();
                // Sequential reference at the SAME chunk size: pin via a
                // single-worker pool vs inline manual reduction.
                let mut expect = 0f64;
                for &(s, l) in &chunk_spans(contrib.len(), chunk) {
                    let mut local = 0f64;
                    for k in s..s + l {
                        local += contrib[k];
                    }
                    expect += local;
                }
                assert_eq!(seq.partial[0].to_bits(), expect.to_bits(), "workers={workers} chunk={chunk}");
                assert_eq!(seq.target_probs, contrib, "probs keep stream order");
            }
        }
    }

    #[test]
    fn run_chunks_parallel_panic_fails_with_err() {
        let pool = Arc::new(ThreadPool::new(2));
        let exec = BatchExec::parallel_with_chunk(pool.clone(), 4);
        let eval = |start: usize, _len: usize| -> Result<BatchOut> {
            if start == 4 {
                panic!("poisoned chunk at {start}");
            }
            Ok(BatchOut { partial: vec![0.0], target_probs: vec![] })
        };
        let err = run_chunks(&exec, 12, 1, eval).unwrap_err().to_string();
        assert!(err.contains("poisoned chunk"), "{err}");
        // The pool survives: a fresh evaluation succeeds.
        let ok = run_chunks(&exec, 12, 1, |_, l| {
            Ok(BatchOut { partial: vec![l as f64], target_probs: vec![] })
        })
        .unwrap();
        assert_eq!(ok.partial, vec![12.0]);
    }

    #[test]
    fn run_chunks_err_from_eval_propagates() {
        let out = run_chunks(&BatchExec::Sequential, 10, 1, |s, _| {
            if s >= 64 {
                unreachable!()
            }
            anyhow::bail!("device down")
        });
        assert!(out.unwrap_err().to_string().contains("device down"));
    }

    #[test]
    fn run_chunks_sequential_fails_fast() {
        // A failing chunk on the sequential path must stop the stream
        // immediately: later chunks never pay for a dead backend.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let out = run_chunks(&BatchExec::Sequential, 5 * DEFAULT_CHUNK, 1, |_, _| {
            calls.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("device down")
        });
        assert!(out.is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 1, "must stop at the first failing chunk");
    }

    #[test]
    fn exec_accessors() {
        assert_eq!(BatchExec::sequential().chunk(), DEFAULT_CHUNK);
        assert_eq!(BatchExec::Sequential.workers(), 1);
        let pool = Arc::new(ThreadPool::new(3));
        let p = BatchExec::parallel(pool.clone());
        assert_eq!(p.chunk(), DEFAULT_CHUNK);
        assert_eq!(p.workers(), 3);
        let pc = BatchExec::parallel_with_chunk(pool, 8);
        assert_eq!(pc.chunk(), 8);
        assert!(format!("{pc:?}").contains("chunk: 8"));
    }
}
