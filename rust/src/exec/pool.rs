//! Fixed-size thread pool with joinable, panic-contained task handles.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use super::channel::{bounded, Sender};
use crate::exec::sync::{self, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs FIFO.
///
/// Tasks submitted via [`ThreadPool::spawn`] return a [`JoinHandle`] whose
/// `join` yields `Err` if the task panicked — the pool itself survives
/// panics (important for the coordinator: one poisoned request must not
/// take down the serving loop).
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (>= 1). Queue capacity is `4 * n` — enough to keep
    /// workers fed, small enough to exert backpressure on floods.
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = bounded::<Job>(4 * n);
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("nuig-pool-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            // Panic containment happens inside the job
                            // wrapper built by `spawn`, so a raw panic here
                            // means a bug in the pool itself — let it abort
                            // the worker loudly in tests.
                            job();
                        }
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a task; blocks if the queue is full (backpressure).
    pub fn spawn<T, F>(&self, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slot = Arc::new(Slot::new());
        let slot2 = slot.clone();
        let job: Job = Box::new(move || {
            let result = catch_unwind(AssertUnwindSafe(f));
            slot2.fill(result.map_err(panic_message));
        });
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .unwrap_or_else(|_| panic!("pool queue closed"));
        JoinHandle { slot }
    }

    /// Run `f` over `0..n` in parallel, collecting results in index order.
    /// Propagates the first panic as an `Err(message)`.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>, String>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = f.clone();
                self.spawn(move || f(i))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }

    /// Run `f` over `0..n` on the pool, collecting results in index
    /// order, while **borrowing from the caller's stack frame**.
    ///
    /// Unlike [`ThreadPool::parallel_map`], `f` need not be `'static`:
    /// every task is joined before this function returns — including when
    /// it unwinds mid-submission — so borrows lent to the workers cannot
    /// dangle. This is the primitive the batched IG backend
    /// (`exec::batch::run_chunks`) shards chunk plans on.
    ///
    /// Panic containment: a panicking task poisons only this call — the
    /// first (lowest-index) panic message is returned as `Err` after all
    /// sibling tasks have settled, and the pool plus any concurrent
    /// `scoped_map`/`spawn` users keep running.
    ///
    /// Deadlock hazard: must not be called from a task already running on
    /// the same pool (the caller would block on workers it occupies).
    pub fn scoped_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>, String>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        /// Joins any not-yet-joined handles on drop, so an unwind between
        /// spawn and join still waits out every task that borrows `f`.
        struct JoinAll<T>(Vec<Option<JoinHandle<T>>>);
        impl<T> Drop for JoinAll<T> {
            fn drop(&mut self) {
                for h in self.0.iter_mut() {
                    if let Some(h) = h.take() {
                        let _ = h.join();
                    }
                }
            }
        }

        // SAFETY: the only lifetime being erased is the borrow of `f`,
        // and the erasure is sound because every task that can observe
        // `f_static` is joined before this frame releases the borrow
        // (join-before-return):
        //  * every exit path — normal return, an `Err` collected below,
        //    or an unwind between spawn and join — runs `guard`'s drop,
        //    and `guard` is declared *after* the `f` parameter, hence
        //    dropped before `f`;
        //  * `join` always returns, because a task's result slot is
        //    filled even on panic (`catch_unwind` inside `spawn`'s
        //    wrapper) — a task cannot exit without filling its slot;
        //  * after its slot is filled a worker holds no reference to the
        //    job closure (the boxed job is consumed by the call), so no
        //    worker can touch `f_static` after `join` returns.
        // `F: Sync` makes the shared reference thread-safe. The borrow
        // lifecycle is exercised under Miri by `scoped_map_miri_borrow`
        // (nightly CI runs `cargo miri test` on this module), and the
        // `unsafe-safety` lint in tools/nuig-analyze keeps this comment
        // attached to the block.
        let f_ref: &(dyn Fn(usize) -> T + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) -> T + Sync) =
            unsafe { std::mem::transmute(f_ref) };

        let mut guard = JoinAll(Vec::with_capacity(n));
        for i in 0..n {
            guard.0.push(Some(self.spawn(move || f_static(i))));
        }
        let mut out = Vec::with_capacity(n);
        let mut first_panic: Option<String> = None;
        for slot in guard.0.iter_mut() {
            match slot.take().expect("each handle joined once").join() {
                Ok(v) => out.push(v),
                Err(msg) => {
                    if first_panic.is_none() {
                        first_panic = Some(msg);
                    }
                }
            }
        }
        match first_panic {
            None => Ok(out),
            Some(msg) => Err(msg),
        }
    }

    /// Number of worker threads in the pool.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            tx.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn panic_message(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

/// One-shot result slot shared between a task and its handle.
struct Slot<T> {
    state: Mutex<Option<Result<T, String>>>,
    done: Condvar,
}

impl<T> Slot<T> {
    fn new() -> Self {
        Slot { state: Mutex::new(None), done: Condvar::new() }
    }

    fn fill(&self, v: Result<T, String>) {
        let mut g = sync::lock(&self.state);
        *g = Some(v);
        drop(g);
        self.done.notify_all();
    }
}

/// Handle to a pool task; `join` blocks until completion.
pub struct JoinHandle<T> {
    slot: Arc<Slot<T>>,
}

impl<T> JoinHandle<T> {
    /// Wait for the task; `Err(panic_message)` if it panicked.
    pub fn join(self) -> Result<T, String> {
        let mut g = sync::lock(&self.slot.state);
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = sync::wait(&self.slot.done, g);
        }
    }

    /// Non-blocking completion check.
    pub fn is_finished(&self) -> bool {
        sync::lock(&self.slot.state).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn runs_tasks() {
        let pool = ThreadPool::new(4);
        let h = pool.spawn(|| 2 + 2);
        assert_eq!(h.join().unwrap(), 4);
    }

    #[test]
    fn parallel_map_ordered() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map(32, |i| i * i).unwrap();
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn panic_contained() {
        let pool = ThreadPool::new(2);
        let bad = pool.spawn(|| -> u32 { panic!("boom {}", 42) });
        let good = pool.spawn(|| 7u32);
        let err = bad.join().unwrap_err();
        assert!(err.contains("boom 42"), "{err}");
        assert_eq!(good.join().unwrap(), 7); // pool survived
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn all_workers_used() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = counter.clone();
                pool.spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    // Hold the worker so each task lands on a distinct thread.
                    while c.load(Ordering::SeqCst) < 4 {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn drop_joins_pending_work() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..16 {
                let c = counter.clone();
                pool.spawn(move || {
                    std::thread::sleep(Duration::from_millis(2));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scoped_map_borrows_caller_state() {
        // The point of scoped_map: the closure borrows non-'static data.
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..100).collect();
        let out = pool.scoped_map(100, |i| data[i] * 2).unwrap();
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(data.len(), 100, "borrow returned intact");
    }

    #[test]
    fn scoped_map_panic_poisons_call_not_pool() {
        let pool = ThreadPool::new(2);
        let err = pool
            .scoped_map(8, |i| {
                if i == 3 {
                    panic!("chunk {i} poisoned");
                }
                i
            })
            .unwrap_err();
        assert!(err.contains("chunk 3 poisoned"), "{err}");
        // The pool survives and serves the next call.
        assert_eq!(pool.scoped_map(4, |i| i + 1).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn scoped_map_sibling_requests_survive_a_panic() {
        // Two concurrent "requests" share the pool; one has a poisoned
        // chunk. The poisoned one fails with Err, the sibling completes.
        let pool = Arc::new(ThreadPool::new(4));
        let good_pool = pool.clone();
        let good = std::thread::spawn(move || {
            let data: Vec<usize> = (0..64).collect();
            good_pool.scoped_map(64, |i| {
                std::thread::sleep(Duration::from_micros(200));
                data[i]
            })
        });
        let bad = pool.scoped_map(16, |i| {
            if i % 5 == 0 {
                panic!("boom");
            }
            i
        });
        assert!(bad.is_err());
        let good = good.join().unwrap().unwrap();
        assert_eq!(good, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_miri_borrow() {
        // Miri-exercised regression for the lifetime-erasing transmute in
        // scoped_map (ISSUE 6 satellite): small enough that Miri's
        // interpreter finishes quickly, while still covering the full
        // lend-borrow-join round trip (including a panicking task, whose
        // unwind path must also join before the borrow is released).
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..8).collect();
        let out = pool.scoped_map(8, |i| data[i] + 1).unwrap();
        assert_eq!(out, (1..9).collect::<Vec<_>>());
        let err = pool
            .scoped_map(4, |i| {
                if i == 2 {
                    panic!("borrowing task panicked");
                }
                data[i]
            })
            .unwrap_err();
        assert!(err.contains("borrowing task panicked"), "{err}");
        assert_eq!(data.len(), 8, "borrow survives both exit paths");
    }

    #[test]
    fn scoped_map_empty() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.scoped_map(0, |i| i).unwrap(), Vec::<usize>::new());
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock heavy; covered natively")]
    fn is_finished() {
        let pool = ThreadPool::new(1);
        let h = pool.spawn(|| std::thread::sleep(Duration::from_millis(30)));
        assert!(!h.is_finished());
        std::thread::sleep(Duration::from_millis(80));
        assert!(h.is_finished());
        h.join().unwrap();
    }
}
