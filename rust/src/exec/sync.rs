//! Synchronization facade for the serving substrate.
//!
//! Every serving-path module (`exec::channel`, `exec::pool`,
//! `exec::gather`, `coordinator`, `runtime::service`) takes its mutex,
//! condvar, and atomic primitives from here instead of `std::sync`
//! directly. The facade buys two properties:
//!
//! * **Poison recovery.** [`lock`], [`wait`], and [`wait_timeout`] recover
//!   a poisoned mutex instead of unwrapping it. A panic on one serving
//!   thread already fails its own request (chunk panics map to `Err` and
//!   settle the request exactly once); letting the *next* thread that
//!   touches the same lock panic too would cascade a single bad request
//!   into a dead coordinator. Every invariant guarded by these locks is
//!   re-validated by settlement idempotence (`RequestState::try_complete`)
//!   and ordered commit (`Accum::add`), so observing a post-panic value is
//!   safe — `nuig-analyze` lint `lock-unwrap-serving` enforces that the
//!   serving path never bypasses these helpers.
//! * **Model checking.** Under `--features loom-models` the re-exported
//!   types route to the instrumented shims in [`crate::exec::interleave`],
//!   which explore thread interleavings deterministically (a vendored,
//!   loom-shaped explorer — see that module for why loom itself is not a
//!   dependency). Production code is oblivious: the shim types passthrough
//!   to `std` behaviour outside an active model.
//!
//! The facade deliberately re-exports the `std::sync` *names* so switching
//! a module onto it is a one-line `use` change.

use std::sync::PoisonError;
use std::time::Duration;

#[cfg(feature = "loom-models")]
pub use crate::exec::interleave::shim::{atomic, Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(not(feature = "loom-models"))]
pub use std::sync::{atomic, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// See the module doc for why the serving path recovers rather than
/// propagates poison: the panicking thread's request has already failed,
/// and the data under these locks stays consistent across unwinds
/// (commits are ordered and settlement is idempotent).
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv` releasing `g`, recovering the guard on poison.
///
/// Callers must re-check their predicate in a loop exactly as with
/// [`std::sync::Condvar::wait`]; the model-checking shim never delivers a
/// spurious wakeup, so a predicate loop that only works because of
/// spurious wakeups shows up as a deadlock under the interleaving models.
pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv` for at most `dur`, recovering the guard on poison.
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = lock(&m2);
            panic!("poison the lock");
        })
        .join();
        // A poisoned serving lock must still hand out its (consistent) value.
        assert_eq!(*lock(&m), 7);
        *lock(&m) = 8;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn wait_timeout_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let (g, res) = wait_timeout(&cv, lock(&m), Duration::from_millis(1));
        assert!(res.timed_out());
        drop(g);
    }

    #[test]
    fn wait_wakes_on_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = pair.clone();
        let h = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut done = lock(m);
            while !*done {
                done = wait(cv, done);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock(m) = true;
            cv.notify_all();
        }
        h.join().unwrap();
    }
}
