//! Deterministic fault injection at the [`GatherExec`] seam: the chaos
//! harness behind `tests/chaos_resilience.rs`.
//!
//! Chaos testing is only useful when a failing run can be replayed, so
//! nothing here reads a clock or a global RNG. A [`FaultPlan`] is a
//! seeded, **step-indexed** list of [`FaultEvent`]s — "kill shard 1 at
//! its 3rd gather call, revive it at its 9th" — and [`FaultInjector`]
//! wraps any [`GatherExec`] backend, applying each shard's events when
//! that shard's own gather-call ordinal reaches the event's step. The
//! ordinal is per-shard (not global), so the injection point of every
//! event is a pure function of the chunk sequence the shard receives:
//! same plan + same chunk sequence ⇒ same faults, same settlement log.
//!
//! The injector models device-state loss faithfully: a [`FaultAction::Kill`]
//! clears the shard's view of the resident registrations (exactly what
//! dying a PJRT device thread does to its resident tensors), so chunks
//! referencing those slots fail until either a [`FaultAction::Revive`]
//! or a [`GatherExec::respawn_shard`] replays the host copies from the
//! injector's [`ResidentPool`] — the same replay contract
//! `runtime::ShardedRuntime` implements for real device shards
//! (`docs/INVARIANTS.md` §I8).
//!
//! Because a lane's output row is a pure function of the lane alone
//! (the [`gather`](crate::exec::gather) determinism contract), any
//! chunk the injector fails can be retried on a sibling shard or on the
//! respawned shard with **bit-identical** results — which is what the
//! chaos suite asserts at feeder counts {1, 2, 4}.

use std::collections::{BTreeSet, VecDeque};
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::exec::gather::{GatherExec, GatherLane, GatherOut, ResidentPool, ShardHealth};
use crate::exec::sync::atomic::{AtomicU64, Ordering};
use crate::exec::sync::{self, Mutex};

/// What a [`FaultEvent`] does to its shard when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// The shard dies: health goes [`ShardHealth::Dead`] and its view of
    /// the resident registrations is cleared (device state is lost).
    Kill,
    /// The shard comes back: health goes [`ShardHealth::Live`] and every
    /// live [`ResidentPool`] slot is replayed into it (the in-plan
    /// analogue of [`GatherExec::respawn_shard`]).
    Revive,
    /// The shard hiccups: the gather call busy-waits for `spins`
    /// bounded spin-loop iterations before executing. Outcome-neutral —
    /// stalls perturb timing, never results.
    Stall {
        /// Bounded spin-loop iterations (clamped at execution time).
        spins: u32,
    },
}

/// One step-indexed fault: `action` fires when `shard`'s gather-call
/// ordinal reaches `at` (0-based — `at == 0` fires on the shard's first
/// gather call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// The shard the event targets.
    pub shard: usize,
    /// The shard-local gather-call ordinal at which the event fires.
    pub at: u64,
    /// What happens.
    pub action: FaultAction,
}

/// A reproducible chaos scenario: fault events sorted by
/// `(shard, at)`, applied lazily as each shard's gather calls advance.
///
/// Same plan + same per-shard chunk sequence ⇒ the same faults fire at
/// the same points, so a failing chaos run replays exactly from its
/// seed (the acceptance contract of `tests/chaos_resilience.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A plan from explicit events; they are (stably) sorted by
    /// `(shard, at)`, so same-step events keep their given order.
    pub fn new(events: Vec<FaultEvent>) -> FaultPlan {
        FaultPlan::with_seed(0, events)
    }

    /// [`FaultPlan::new`] tagged with the seed it was derived from (for
    /// log provenance).
    pub fn with_seed(seed: u64, mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by_key(|e| (e.shard, e.at));
        FaultPlan { seed, events }
    }

    /// Derive a kill/revive(/stall) scenario over `shards` shards from
    /// `seed` alone (xorshift64* — no global RNG, no clock). Every
    /// shard gets one kill/revive pair inside the first `horizon`
    /// gather calls, and about half get an outcome-neutral stall; the
    /// same seed always yields the same plan.
    pub fn from_seed(seed: u64, shards: usize, horizon: u64) -> FaultPlan {
        let horizon = horizon.max(4);
        let mut state = seed | 1;
        let mut next = move || -> u64 {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            state.wrapping_mul(0x2545_F491_4F6C_DD1D)
        };
        let mut events = Vec::new();
        for shard in 0..shards {
            let kill_at = 1 + next() % (horizon / 2);
            let revive_at = kill_at + 1 + next() % (horizon / 2);
            events.push(FaultEvent { shard, at: kill_at, action: FaultAction::Kill });
            events.push(FaultEvent { shard, at: revive_at, action: FaultAction::Revive });
            if next() % 2 == 0 {
                let spins = (next() % 64) as u32;
                let at = next() % horizon;
                events.push(FaultEvent { shard, at, action: FaultAction::Stall { spins } });
            }
        }
        FaultPlan::with_seed(seed, events)
    }

    /// A permanent-outage sentinel for `shard`: a kill at `at` followed
    /// by an unreachable hold-down event, so the shard stays dead *and*
    /// [`GatherExec::respawn_shard`] keeps refusing (pending events
    /// pin it down) — the scenario that exercises pure re-routing to
    /// sibling shards rather than respawn.
    pub fn kill_forever(shard: usize, at: u64) -> Vec<FaultEvent> {
        vec![
            FaultEvent { shard, at, action: FaultAction::Kill },
            FaultEvent { shard, at: u64::MAX, action: FaultAction::Stall { spins: 0 } },
        ]
    }

    /// The seed this plan was derived from (0 for explicit plans).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The events, sorted by `(shard, at)`.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }
}

/// What a scripted chaos *client* does to one of its requests — the
/// front-end-facing counterpart of [`FaultAction`]. Where device faults
/// attack the gather seam, client faults attack the serving seam: the
/// two graceful-degradation paths of the front-end's cancellation tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientFaultAction {
    /// The client drops its connection before the request settles (the
    /// request must settle server-side exactly once as a disconnect,
    /// freeing the resident slot).
    Disconnect,
    /// The client's deadline expires mid-refinement (the request must
    /// settle with the last converged round as a partial, or a typed
    /// deadline rejection when none converged).
    DeadlineExpire,
}

/// One client-side fault: `action` applies to the request with 0-based
/// submission ordinal `at` on the scripted client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClientFaultEvent {
    /// Client-local submission ordinal the fault targets.
    pub at: u64,
    /// What the client does to that request.
    pub action: ClientFaultAction,
}

/// A reproducible client-chaos scenario: which of a client's requests
/// get disconnected or deadline-expired, derived from a seed alone
/// (same xorshift64* stream discipline as [`FaultPlan::from_seed`] —
/// no global RNG, no clock, so a failing sweep seed replays exactly).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientFaultPlan {
    seed: u64,
    events: Vec<ClientFaultEvent>,
}

impl ClientFaultPlan {
    /// Derive a plan over `requests` submissions from `seed`: roughly a
    /// third of the requests are faulted, split between
    /// [`ClientFaultAction::Disconnect`] and
    /// [`ClientFaultAction::DeadlineExpire`] by the seed stream. The
    /// same seed always yields the same plan.
    pub fn from_seed(seed: u64, requests: u64) -> ClientFaultPlan {
        let mut state = seed | 1;
        let mut next = move || -> u64 {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            state.wrapping_mul(0x2545_F491_4F6C_DD1D)
        };
        let mut events = Vec::new();
        for at in 0..requests {
            if next() % 3 != 0 {
                continue;
            }
            let action = if next() % 2 == 0 {
                ClientFaultAction::Disconnect
            } else {
                ClientFaultAction::DeadlineExpire
            };
            events.push(ClientFaultEvent { at, action });
        }
        ClientFaultPlan { seed, events }
    }

    /// The fault (if any) scripted for submission ordinal `at`.
    pub fn action_for(&self, at: u64) -> Option<ClientFaultAction> {
        self.events.iter().find(|e| e.at == at).map(|e| e.action)
    }

    /// The seed this plan was derived from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The scripted events, in submission order.
    pub fn events(&self) -> &[ClientFaultEvent] {
        &self.events
    }
}

/// Per-shard injector state: lifecycle health, the shard's (simulated)
/// view of resident registrations, and its not-yet-fired events.
struct ShardState {
    health: ShardHealth,
    resident: BTreeSet<u64>,
    pending: VecDeque<FaultEvent>,
}

/// A [`GatherExec`] middlebox that injects a [`FaultPlan`] into an inner
/// backend, and implements the full elastic-lifecycle surface
/// ([`GatherExec::shard_health`] / [`GatherExec::drain_shard`] /
/// [`GatherExec::respawn_shard`]) over it.
///
/// The injector owns the host-copy [`ResidentPool`] (the replay source
/// for revive/respawn) and a per-shard resident *view* that kill events
/// clear — so a killed shard rejects chunks exactly the way a dead
/// device thread does, and the no-stranded-slots invariant is directly
/// observable ([`FaultInjector::resident_on`]).
pub struct FaultInjector {
    inner: Arc<dyn GatherExec>,
    pool: ResidentPool,
    shards: Vec<Mutex<ShardState>>,
    calls: Vec<AtomicU64>,
    respawns: AtomicU64,
    log: Mutex<Vec<(u64, FaultEvent)>>,
}

impl FaultInjector {
    /// Wrap `inner`, arming `plan`. The shard count is `inner.shards()`;
    /// events targeting shards beyond it are rejected loudly (a typo'd
    /// plan must not silently test nothing).
    pub fn new(inner: Arc<dyn GatherExec>, plan: &FaultPlan) -> Result<FaultInjector> {
        let n = inner.shards();
        let mut pending: Vec<VecDeque<FaultEvent>> = (0..n).map(|_| VecDeque::new()).collect();
        for ev in plan.events() {
            ensure!(ev.shard < n, "fault plan targets shard {} but backend has {n}", ev.shard);
            pending[ev.shard].push_back(*ev);
        }
        let shards = pending
            .into_iter()
            .map(|p| {
                Mutex::new(ShardState {
                    health: ShardHealth::Live,
                    resident: BTreeSet::new(),
                    pending: p,
                })
            })
            .collect();
        Ok(FaultInjector {
            inner,
            pool: ResidentPool::new(),
            shards,
            calls: (0..n).map(|_| AtomicU64::new(0)).collect(),
            respawns: AtomicU64::new(0),
            log: Mutex::new(Vec::new()),
        })
    }

    /// Events applied so far as `(fired-at-step, event)`, in application
    /// order — the reproducibility witness: two runs over the same plan
    /// and chunk sequence produce identical logs.
    pub fn event_log(&self) -> Vec<(u64, FaultEvent)> {
        sync::lock(&self.log).clone()
    }

    /// Successful [`GatherExec::respawn_shard`] calls so far.
    pub fn respawn_count(&self) -> u64 {
        self.respawns.load(Ordering::SeqCst)
    }

    /// Gather calls `shard` has received (its event clock).
    pub fn calls_on(&self, shard: usize) -> u64 {
        self.calls[shard].load(Ordering::SeqCst)
    }

    /// Not-yet-fired events for `shard`.
    pub fn pending_on(&self, shard: usize) -> usize {
        sync::lock(&self.shards[shard]).pending.len()
    }

    /// `shard`'s current resident view, sorted — equals the live pool
    /// slots for every `Live` shard once no events are pending (the
    /// no-stranded-slots assertion of the chaos suite).
    pub fn resident_on(&self, shard: usize) -> Vec<u64> {
        sync::lock(&self.shards[shard]).resident.iter().copied().collect()
    }

    /// Live slots in the injector's host-copy pool, sorted.
    pub fn pool_slots(&self) -> Vec<u64> {
        self.pool.snapshot_sorted().iter().map(|(s, _)| *s).collect()
    }
}

impl GatherExec for FaultInjector {
    fn features(&self) -> usize {
        self.inner.features()
    }

    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }

    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.inner.forward(imgs, rows)
    }

    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        self.pool.register(slot, x, baseline)?;
        if let Err(e) = self.inner.register_request(slot, x, baseline) {
            self.pool.evict(slot);
            return Err(e);
        }
        // Dead/draining shards are skipped: they pick the slot up on
        // revive/respawn replay (pool first, then shard views, so a
        // concurrent replay that snapshots between the two still sees
        // the slot in the pool — no stranding window).
        for st in &self.shards {
            let mut st = sync::lock(st);
            if st.health == ShardHealth::Live {
                st.resident.insert(slot);
            }
        }
        Ok(())
    }

    fn evict_request(&self, slot: u64) {
        self.pool.evict(slot);
        for st in &self.shards {
            sync::lock(st).resident.remove(&slot);
        }
        self.inner.evict_request(slot);
    }

    fn resident_len(&self) -> usize {
        self.pool.len()
    }

    fn shards(&self) -> usize {
        self.shards.len()
    }

    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
        ensure!(shard < self.shards.len(), "shard {shard} out of range");
        let step = self.calls[shard].fetch_add(1, Ordering::SeqCst);
        let mut stall_spins: u32 = 0;
        {
            let mut st = sync::lock(&self.shards[shard]);
            while let Some(ev) = st.pending.front().copied() {
                if ev.at > step {
                    break;
                }
                st.pending.pop_front();
                match ev.action {
                    FaultAction::Kill => {
                        st.health = ShardHealth::Dead;
                        // Device state is gone with the shard.
                        st.resident.clear();
                    }
                    FaultAction::Revive => {
                        st.health = ShardHealth::Live;
                        st.resident = self.pool.snapshot_sorted().iter().map(|(s, _)| *s).collect();
                    }
                    FaultAction::Stall { spins } => stall_spins = stall_spins.saturating_add(spins),
                }
                sync::lock(&self.log).push((step, ev));
            }
            match st.health {
                ShardHealth::Live => {}
                ShardHealth::Draining => bail!("shard {shard} is draining (chaos)"),
                ShardHealth::Dead => bail!("shard {shard} is down (chaos)"),
            }
            for lane in lanes {
                if !st.resident.contains(&lane.slot) {
                    bail!("slot {} is not resident on shard {shard} (chaos)", lane.slot);
                }
            }
        }
        for _ in 0..stall_spins.min(4096) {
            std::hint::spin_loop();
        }
        self.inner.eval_gather(shard, lanes)
    }

    fn shard_health(&self, shard: usize) -> ShardHealth {
        sync::lock(&self.shards[shard]).health
    }

    fn drain_shard(&self, shard: usize) {
        let mut st = sync::lock(&self.shards[shard]);
        if st.health == ShardHealth::Live {
            st.health = ShardHealth::Draining;
        }
    }

    fn respawn_shard(&self, shard: usize) -> Result<()> {
        ensure!(shard < self.shards.len(), "shard {shard} out of range");
        let mut st = sync::lock(&self.shards[shard]);
        if !st.pending.is_empty() {
            bail!(
                "shard {shard} is held down by the fault plan ({} events pending)",
                st.pending.len()
            );
        }
        // Replay every live host copy — the same re-registration replay
        // ShardedRuntime performs against a fresh device shard.
        st.resident = self.pool.snapshot_sorted().iter().map(|(s, _)| *s).collect();
        st.health = ShardHealth::Live;
        self.respawns.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal deterministic inner backend: row k = `alpha * weight +
    /// slot` broadcast over 2 features. Pure per lane by construction.
    struct PureExec {
        pool: ResidentPool,
        shards: usize,
    }

    impl PureExec {
        fn new(shards: usize) -> PureExec {
            PureExec { pool: ResidentPool::new(), shards }
        }
    }

    impl GatherExec for PureExec {
        fn features(&self) -> usize {
            2
        }
        fn num_classes(&self) -> usize {
            2
        }
        fn forward(&self, _imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
            Ok(vec![0.5; rows * 2])
        }
        fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
            self.pool.register(slot, x, baseline)
        }
        fn evict_request(&self, slot: u64) {
            self.pool.evict(slot);
        }
        fn resident_len(&self) -> usize {
            self.pool.len()
        }
        fn shards(&self) -> usize {
            self.shards
        }
        fn eval_gather(&self, _shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
            let mut rows = Vec::with_capacity(lanes.len() * 2);
            for lane in lanes {
                ensure!(self.pool.entry(lane.slot).is_some(), "slot {} unknown", lane.slot);
                let v = lane.alpha * lane.weight + lane.slot as f32;
                rows.push(v);
                rows.push(v + 1.0);
            }
            Ok(GatherOut { rows, features: 2 })
        }
    }

    fn lane(slot: u64) -> GatherLane {
        GatherLane { slot, alpha: 0.5, weight: 0.25, target: 0 }
    }

    fn injector(shards: usize, plan: &FaultPlan) -> FaultInjector {
        FaultInjector::new(Arc::new(PureExec::new(shards)), plan).unwrap()
    }

    #[test]
    fn from_seed_is_deterministic_and_ordered() {
        let a = FaultPlan::from_seed(42, 4, 32);
        let b = FaultPlan::from_seed(42, 4, 32);
        assert_eq!(a, b, "same seed, same plan");
        assert_ne!(a, FaultPlan::from_seed(43, 4, 32), "different seed, different plan");
        assert_eq!(a.seed(), 42);
        // Sorted by (shard, at); every shard has a kill strictly before
        // its revive.
        for w in a.events().windows(2) {
            assert!((w[0].shard, w[0].at) <= (w[1].shard, w[1].at), "{w:?}");
        }
        for shard in 0..4 {
            let kill = a
                .events()
                .iter()
                .find(|e| e.shard == shard && e.action == FaultAction::Kill)
                .unwrap();
            let revive = a
                .events()
                .iter()
                .find(|e| e.shard == shard && e.action == FaultAction::Revive)
                .unwrap();
            assert!(kill.at < revive.at, "shard {shard}: kill {} revive {}", kill.at, revive.at);
        }
    }

    #[test]
    fn kill_window_fails_then_revive_replays() {
        let plan = FaultPlan::new(vec![
            FaultEvent { shard: 0, at: 1, action: FaultAction::Kill },
            FaultEvent { shard: 0, at: 3, action: FaultAction::Revive },
        ]);
        let inj = injector(1, &plan);
        inj.register_request(7, &[1.0, 2.0], &[0.0, 0.0]).unwrap();
        // step 0: live.
        let out = inj.eval_gather(0, &[lane(7)]).unwrap();
        assert_eq!(out.row(0), &[0.5 * 0.25 + 7.0, 0.5 * 0.25 + 8.0]);
        // steps 1-2: dead window (kill fired at step 1, resident view gone).
        assert!(inj.eval_gather(0, &[lane(7)]).unwrap_err().to_string().contains("down"));
        assert_eq!(inj.shard_health(0), ShardHealth::Dead);
        assert!(inj.resident_on(0).is_empty(), "kill clears the resident view");
        assert!(inj.eval_gather(0, &[lane(7)]).is_err());
        // step 3: revive fired — replay restored slot 7, identical bits.
        let back = inj.eval_gather(0, &[lane(7)]).unwrap();
        assert_eq!(back.rows, out.rows, "revive replay is bit-identical");
        assert_eq!(inj.resident_on(0), vec![7]);
        // The event log records both firings at their steps.
        let log = inj.event_log();
        assert_eq!(log.len(), 2);
        assert_eq!((log[0].0, log[0].1.action), (1, FaultAction::Kill));
        assert_eq!((log[1].0, log[1].1.action), (3, FaultAction::Revive));
    }

    #[test]
    fn event_log_is_reproducible_across_runs() {
        let plan = FaultPlan::from_seed(0xC0FFEE, 2, 16);
        let mut logs = Vec::new();
        for _ in 0..2 {
            let inj = injector(2, &plan);
            inj.register_request(1, &[1.0, 1.0], &[0.0, 0.0]).unwrap();
            let mut outcomes = Vec::new();
            for step in 0..24u64 {
                let shard = (step % 2) as usize;
                outcomes.push(inj.eval_gather(shard, &[lane(1)]).is_ok());
            }
            logs.push((inj.event_log(), outcomes));
        }
        assert_eq!(logs[0], logs[1], "same plan + same call sequence = same log");
    }

    #[test]
    fn respawn_blocked_while_plan_pending_then_replays() {
        let plan = FaultPlan::new(FaultPlan::kill_forever(0, 0));
        let inj = injector(2, &plan);
        inj.register_request(3, &[1.0, 0.0], &[0.0, 0.0]).unwrap();
        inj.register_request(9, &[2.0, 0.0], &[0.0, 0.0]).unwrap();
        assert!(inj.eval_gather(0, &[lane(3)]).is_err(), "kill at step 0");
        // The hold-down sentinel (at = u64::MAX) keeps respawn refusing.
        let err = inj.respawn_shard(0).unwrap_err().to_string();
        assert!(err.contains("held down"), "{err}");
        assert_eq!(inj.respawn_count(), 0);
        // Sibling shard is unaffected.
        inj.eval_gather(1, &[lane(3)]).unwrap();

        // A plan that exhausts: kill only, then respawn is allowed and
        // replays every live slot (no stranded residents).
        let plan = FaultPlan::new(vec![FaultEvent { shard: 0, at: 0, action: FaultAction::Kill }]);
        let inj = injector(1, &plan);
        inj.register_request(3, &[1.0, 0.0], &[0.0, 0.0]).unwrap();
        inj.register_request(9, &[2.0, 0.0], &[0.0, 0.0]).unwrap();
        assert!(inj.eval_gather(0, &[lane(3)]).is_err());
        inj.respawn_shard(0).unwrap();
        assert_eq!(inj.respawn_count(), 1);
        assert_eq!(inj.shard_health(0), ShardHealth::Live);
        assert_eq!(inj.resident_on(0), inj.pool_slots(), "replay restores every slot");
        inj.eval_gather(0, &[lane(3), lane(9)]).unwrap();
    }

    #[test]
    fn drain_fences_new_chunks_and_respawn_undrains() {
        let inj = injector(2, &FaultPlan::new(vec![]));
        inj.register_request(5, &[1.0, 0.0], &[0.0, 0.0]).unwrap();
        inj.drain_shard(0);
        assert_eq!(inj.shard_health(0), ShardHealth::Draining);
        let err = inj.eval_gather(0, &[lane(5)]).unwrap_err().to_string();
        assert!(err.contains("draining"), "{err}");
        // Siblings keep serving; a drained shard can be brought back.
        inj.eval_gather(1, &[lane(5)]).unwrap();
        inj.respawn_shard(0).unwrap();
        assert_eq!(inj.shard_health(0), ShardHealth::Live);
        inj.eval_gather(0, &[lane(5)]).unwrap();
    }

    #[test]
    fn registration_tracks_health_and_eviction_is_global() {
        let plan = FaultPlan::new(vec![FaultEvent { shard: 1, at: 0, action: FaultAction::Kill }]);
        let inj = injector(2, &plan);
        inj.register_request(1, &[1.0, 0.0], &[0.0, 0.0]).unwrap();
        // Fire the kill on shard 1, then register another request: only
        // the live shard picks it up directly.
        assert!(inj.eval_gather(1, &[lane(1)]).is_err());
        inj.register_request(2, &[2.0, 0.0], &[0.0, 0.0]).unwrap();
        assert_eq!(inj.resident_on(0), vec![1, 2]);
        assert!(inj.resident_on(1).is_empty());
        // Respawn replays both; eviction then removes everywhere.
        inj.respawn_shard(1).unwrap();
        assert_eq!(inj.resident_on(1), vec![1, 2]);
        inj.evict_request(1);
        assert_eq!(inj.resident_on(0), vec![2]);
        assert_eq!(inj.resident_on(1), vec![2]);
        assert_eq!(inj.resident_len(), 1);
        // Duplicate registration still fails loudly through the wrapper.
        assert!(inj.register_request(2, &[0.0, 0.0], &[0.0, 0.0]).is_err());
    }

    #[test]
    fn stall_is_outcome_neutral() {
        let plan = FaultPlan::new(vec![FaultEvent {
            shard: 0,
            at: 0,
            action: FaultAction::Stall { spins: 10_000 },
        }]);
        let inj = injector(1, &plan);
        inj.register_request(4, &[1.0, 2.0], &[0.0, 0.0]).unwrap();
        let stalled = inj.eval_gather(0, &[lane(4)]).unwrap();
        let clean = injector(1, &FaultPlan::new(vec![]));
        clean.register_request(4, &[1.0, 2.0], &[0.0, 0.0]).unwrap();
        let unfaulted = clean.eval_gather(0, &[lane(4)]).unwrap();
        assert_eq!(stalled.rows, unfaulted.rows, "stalls never change bits");
    }

    #[test]
    fn client_plan_is_deterministic_and_mixed() {
        let a = ClientFaultPlan::from_seed(64, 256);
        let b = ClientFaultPlan::from_seed(64, 256);
        assert_eq!(a, b, "same seed, same plan");
        assert_ne!(a, ClientFaultPlan::from_seed(65, 256));
        assert_eq!(a.seed(), 64);
        // Events come out in submission order and cover both actions
        // over a long enough run.
        for w in a.events().windows(2) {
            assert!(w[0].at < w[1].at, "{w:?}");
        }
        let discos = a
            .events()
            .iter()
            .filter(|e| e.action == ClientFaultAction::Disconnect)
            .count();
        let expiries = a.events().len() - discos;
        assert!(discos > 0 && expiries > 0, "both fault kinds present ({discos}/{expiries})");
        // Roughly a third faulted: loose band, exact per-seed.
        assert!(a.events().len() > 40 && a.events().len() < 160, "{}", a.events().len());
        // Lookup agrees with the event list.
        for ev in a.events() {
            assert_eq!(a.action_for(ev.at), Some(ev.action));
        }
        let faulted: BTreeSet<u64> = a.events().iter().map(|e| e.at).collect();
        for at in 0..256 {
            if !faulted.contains(&at) {
                assert_eq!(a.action_for(at), None);
            }
        }
    }

    #[test]
    fn plan_rejects_out_of_range_shard() {
        let plan = FaultPlan::new(vec![FaultEvent { shard: 5, at: 0, action: FaultAction::Kill }]);
        assert!(FaultInjector::new(Arc::new(PureExec::new(2)), &plan).is_err());
    }
}
