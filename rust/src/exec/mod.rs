//! Execution substrate: thread pool, bounded MPMC channel, cancellation.
//!
//! `tokio` is not in the vendored registry, and the coordinator's
//! concurrency needs are thread-shaped anyway (PJRT execution is a
//! blocking FFI call), so this module provides the three primitives the
//! serving layer is built on:
//!
//! * [`ThreadPool`] — fixed worker pool with joinable task handles and
//!   panic containment (a panicking task poisons only its handle).
//! * [`channel::bounded`] — a Condvar-based bounded MPMC channel with
//!   blocking/backpressure semantics and explicit close.
//! * [`CancelToken`] — cooperative cancellation shared across threads.

pub mod channel;
mod pool;
mod token;

pub use channel::{bounded, Receiver, RecvError, SendError, Sender};
pub use pool::{JoinHandle, ThreadPool};
pub use token::CancelToken;
