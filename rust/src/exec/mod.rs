//! Execution substrate: thread pool, bounded MPMC channel, cancellation.
//!
//! `tokio` is not in the vendored registry, and the coordinator's
//! concurrency needs are thread-shaped anyway (PJRT execution is a
//! blocking FFI call), so this module provides the three primitives the
//! serving layer is built on:
//!
//! * [`ThreadPool`] — fixed worker pool with joinable task handles and
//!   panic containment (a panicking task poisons only its handle), plus
//!   [`ThreadPool::scoped_map`] for lending stack borrows to workers;
//! * [`channel::bounded`] — a Condvar-based bounded MPMC channel with
//!   blocking/backpressure semantics and explicit close.
//! * [`CancelToken`] — cooperative cancellation shared across threads,
//!   with parent/child linkage: a child observes its parent's cancel,
//!   a cancelled child leaves its parent and siblings untouched — the
//!   serving front-end's cancellation tree (docs/INVARIANTS.md §I11).
//! * [`batch`] — the batched IG execution backend: planar point batches,
//!   per-worker scratch arenas, and deterministic chunked dispatch
//!   ([`BatchExec`]) over the pool.
//! * [`gather`] — the serving-side face of the same backend:
//!   gather-indexed cross-request chunks over resident request tensors
//!   (the [`gather::GatherExec`] surface the coordinator's sharded
//!   feeders drive).
//! * [`simd`] — fixed-width lane kernels under `batch`: the portable
//!   (autovectorizable) and runtime-dispatched AVX2/NEON bodies of the
//!   interpolate / dot / accumulate hot loops, with the lane-major
//!   reduction order that keeps every backend bit-identical
//!   (docs/INVARIANTS.md §I13).
//! * [`fault`] — the deterministic chaos harness: seeded, step-indexed
//!   [`fault::FaultPlan`]s injected at the [`gather::GatherExec`] seam
//!   by [`fault::FaultInjector`], making kill/revive/stall runs
//!   reproducible, plus seeded client-side
//!   [`fault::ClientFaultPlan`]s (Disconnect / DeadlineExpire) driven
//!   over real front-end connections (`tests/chaos_resilience.rs`).

pub mod batch;
pub mod channel;
pub mod fault;
pub mod gather;
pub mod interleave;
mod pool;
pub mod simd;
pub mod sync;
mod token;

pub use batch::BatchExec;
pub use fault::{
    ClientFaultAction, ClientFaultEvent, ClientFaultPlan, FaultAction, FaultEvent, FaultInjector,
    FaultPlan,
};
pub use gather::{GatherExec, GatherLane, GatherOut, ResidentPool, ShardHealth};
pub use channel::{bounded, Receiver, RecvError, SendError, Sender};
pub use pool::{JoinHandle, ThreadPool};
pub use token::CancelToken;
