//! Hierarchical cooperative cancellation.
//!
//! A [`CancelToken`] is a node in a cancellation *tree*: cancelling a
//! token cancels its whole subtree — children, grandchildren, … — and
//! nothing else. The serving front-end builds a three-level tree from
//! these (coordinator shutdown → connection → request), so coordinator
//! shutdown, a client disconnect, and a per-request deadline each cancel
//! exactly their own scope without disturbing sibling requests
//! (docs/INVARIANTS.md §I11).
//!
//! Semantics:
//!
//! * `cancel()` is idempotent and propagates **eagerly** down the tree,
//!   so `is_cancelled()` stays a single O(1) atomic load — workers poll
//!   it on hot paths.
//! * A child created from an already-cancelled parent starts cancelled.
//!   The registration handshake (register first, then check the parent's
//!   flag) closes the race against a concurrent `cancel()`: either the
//!   parent's snapshot sees the child, or the child sees the parent's
//!   flag — in both interleavings the child ends up cancelled.
//! * `Clone` shares the *same* node (the pre-tree behaviour): clones see
//!   each other's cancellation instantly. Use [`CancelToken::child`] for
//!   a new subtree scope.
//!
//! All synchronization goes through [`crate::exec::sync`] so the
//! cancel-vs-settle model in `tests/interleave_models.rs` can explore
//! the token's interleavings under `--features loom-models`.

use std::sync::{Arc, Weak};

use crate::exec::sync::atomic::{AtomicBool, Ordering};
use crate::exec::sync::{self, Mutex};

/// One node of the cancellation tree: the flag plus the live children
/// the flag must propagate into.
struct Node {
    flag: AtomicBool,
    children: Mutex<Vec<Weak<Node>>>,
}

impl Node {
    fn fresh() -> Arc<Node> {
        Arc::new(Node { flag: AtomicBool::new(false), children: Mutex::new(Vec::new()) })
    }

    fn cancel(&self) {
        // First caller wins; the flag is set BEFORE the children snapshot
        // so a child registering concurrently either lands in the
        // snapshot or observes the flag at registration (never neither).
        if self.flag.swap(true, Ordering::AcqRel) {
            return;
        }
        // Take the list: every child below is notified here, and any
        // future child self-cancels at registration.
        let kids: Vec<Weak<Node>> = std::mem::take(&mut *sync::lock(&self.children));
        for kid in kids {
            if let Some(kid) = kid.upgrade() {
                kid.cancel();
            }
        }
    }
}

/// A cheaply-cloneable cancellation flag with parent/child linkage (see
/// the module doc). The coordinator hands one to every worker;
/// `cancel()` is idempotent and visible across threads with
/// acquire/release ordering.
#[derive(Clone)]
pub struct CancelToken {
    node: Arc<Node>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken { node: Node::fresh() }
    }
}

impl CancelToken {
    /// A fresh, uncancelled root token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Signal cancellation to all clones and to every descendant token.
    /// Idempotent; siblings and ancestors are untouched.
    pub fn cancel(&self) {
        self.node.cancel();
    }

    /// Has this token (or an ancestor) signalled cancellation?
    pub fn is_cancelled(&self) -> bool {
        self.node.flag.load(Ordering::Acquire)
    }

    /// A new token one level below this one: cancelled when `self` (or
    /// any ancestor) cancels, while its own `cancel()` stays scoped to
    /// its own subtree. A child of an already-cancelled token starts
    /// cancelled.
    pub fn child(&self) -> CancelToken {
        let node = Node::fresh();
        {
            let mut kids = sync::lock(&self.node.children);
            // Prune dead subtrees so long-lived roots (the coordinator
            // token under millions of requests) stay O(live children).
            kids.retain(|w| w.strong_count() > 0);
            kids.push(Arc::downgrade(&node));
        }
        // Registration handshake: the parent's cancel() sets its flag
        // before snapshotting children, so checking the flag AFTER
        // registering closes the race window (see module doc).
        if self.node.flag.load(Ordering::Acquire) {
            node.cancel();
        }
        CancelToken { node }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn propagates_across_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn across_threads() {
        let t = CancelToken::new();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            while !t2.is_cancelled() {
                std::thread::yield_now();
            }
            true
        });
        t.cancel();
        assert!(h.join().unwrap());
    }

    #[test]
    fn idempotent() {
        let t = CancelToken::new();
        t.cancel();
        t.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn child_observes_parent_cancel() {
        let root = CancelToken::new();
        let conn = root.child();
        let req = conn.child();
        assert!(!req.is_cancelled());
        root.cancel();
        assert!(conn.is_cancelled(), "children cancel with the parent");
        assert!(req.is_cancelled(), "propagation reaches grandchildren");
    }

    #[test]
    fn child_cancel_is_scoped_to_its_subtree() {
        // The subtree-isolation contract (I11): a request deadline must
        // not cancel its siblings or its connection.
        let root = CancelToken::new();
        let conn = root.child();
        let req_a = conn.child();
        let req_b = conn.child();
        req_a.cancel();
        assert!(req_a.is_cancelled());
        assert!(!req_b.is_cancelled(), "sibling untouched");
        assert!(!conn.is_cancelled(), "parent untouched");
        assert!(!root.is_cancelled(), "root untouched");
    }

    #[test]
    fn mid_level_cancel_takes_subtree_only() {
        let root = CancelToken::new();
        let conn_a = root.child();
        let conn_b = root.child();
        let req = conn_a.child();
        conn_a.cancel();
        assert!(req.is_cancelled(), "a disconnect cancels the connection's requests");
        assert!(!conn_b.is_cancelled(), "sibling connection keeps serving");
        assert!(!root.is_cancelled());
    }

    #[test]
    fn child_of_cancelled_parent_starts_cancelled() {
        let root = CancelToken::new();
        root.cancel();
        assert!(root.child().is_cancelled());
        // And transitively, after the children list was already drained.
        let conn = root.child();
        assert!(conn.child().is_cancelled());
    }

    #[test]
    fn concurrent_child_registration_never_escapes_cancel() {
        // The registration race: children spawned while the parent
        // cancels must end up cancelled, whichever side wins.
        for _ in 0..64 {
            let root = CancelToken::new();
            let spawner = root.clone();
            let h = std::thread::spawn(move || {
                let kids: Vec<CancelToken> = (0..8).map(|_| spawner.child()).collect();
                kids
            });
            root.cancel();
            for kid in h.join().unwrap() {
                // A child created strictly after cancel() returned must
                // observe it; ones created during may observe it either
                // at registration or via the snapshot — both paths set
                // the flag before child() returns or cancel() returns.
                while !kid.is_cancelled() {
                    std::thread::yield_now();
                }
            }
        }
    }

    #[test]
    fn dropped_children_are_pruned() {
        let root = CancelToken::new();
        for _ in 0..1000 {
            let _ = root.child(); // dropped immediately
        }
        let live = root.child();
        // The prune in child() keeps the list bounded by live children.
        assert!(sync::lock(&root.node.children).len() <= 2);
        root.cancel();
        assert!(live.is_cancelled());
    }
}
