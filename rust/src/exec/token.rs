//! Cooperative cancellation token.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A cheaply-cloneable flag for cooperative shutdown. The coordinator
/// hands one to every worker; `cancel()` is idempotent and visible across
/// threads with acquire/release ordering.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Signal cancellation to all clones.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Has any clone signalled cancellation?
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn propagates_across_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn across_threads() {
        let t = CancelToken::new();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            while !t2.is_cancelled() {
                std::thread::yield_now();
            }
            true
        });
        t.cancel();
        assert!(h.join().unwrap());
    }

    #[test]
    fn idempotent() {
        let t = CancelToken::new();
        t.cancel();
        t.cancel();
        assert!(t.is_cancelled());
    }
}
