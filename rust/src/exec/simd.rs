//! Fixed-width SIMD lane kernels for the planar hot path.
//!
//! Every arithmetic kernel on the interpolate → eval → weight →
//! accumulate cycle lives here as an explicit width-[`LANES`] lane
//! loop with a masked scalar tail. The portable bodies are plain
//! indexed loops over `chunks_exact` blocks — shaped so LLVM
//! autovectorizes them on any target — and the one order-bearing
//! kernel, [`dot_f32`], additionally has hand-written AVX2 / NEON
//! paths behind the `simd-intrinsics` feature with runtime CPU
//! detection and a portable fallback.
//!
//! # The lane-major reduction contract (docs/INVARIANTS.md §I13)
//!
//! Float addition is not associative, so a vectorized dot product is
//! only deterministic if its reduction *order* is part of the spec.
//! The canonical order is **lane-major**: element `i` accumulates
//! into f64 lane accumulator `i % LANES`; the tail of a
//! non-multiple-of-[`LANES`] vector lands in lane positions
//! `0..tail`; the final horizontal reduce is the sequential left
//! fold `((acc[0] + acc[1]) + acc[2]) + …`. Every backend — the
//! scalar reference (`ig_points_scalar`), the portable lane loop,
//! AVX2, NEON, and the `igref.py` numpy mirror — computes this exact
//! order, so results are **bit-identical across backends**, pinned
//! by cross-language goldens in this module's tests and
//! `python/tests/test_batch_parity.py`.
//!
//! There is deliberately no FMA anywhere: each product rounds, then
//! each add rounds, on every backend. A fused multiply-add would be
//! faster but would fork the bit pattern between machines with and
//! without FMA units, breaking I13.
//!
//! The elementwise kernels ([`interpolate`], [`accum_scaled`],
//! [`accum_grad`], [`commit_row`]) have no cross-element reduction:
//! each output element depends on the same-index inputs only, so
//! lane-blocking them is bitwise-free at any width. They are written
//! as lane loops anyway so the whole hot path vectorizes uniformly.
//!
//! # Backend dispatch rule
//!
//! [`dot_f32`] dispatches at runtime: with the `simd-intrinsics`
//! feature enabled, it probes the CPU once (std caches the result)
//! and takes the AVX2 path on x86-64 or the NEON path on aarch64;
//! otherwise — feature off, other architectures, or an x86-64 CPU
//! without AVX2 — it runs the portable lane loop. [`backend`] reports
//! which path is live so benches and logs can record it.

/// Lane width of every kernel in this module, in f32 elements.
///
/// This is a *contract constant*, not a tuning knob: the lane-major
/// accumulation order (and therefore the bit pattern of every dot
/// product) is defined in terms of it, it is pinned by cross-language
/// goldens, and `igref.py` mirrors it as `SIMD_LANES`. Changing it
/// changes attribution bits and requires regenerating the goldens.
/// Eight f32 lanes is one AVX2 register and two NEON registers.
pub const LANES: usize = 8;

/// Name of the dot-product backend that [`dot_f32`] will actually
/// run on this process: `"avx2"`, `"neon"`, or `"portable"`.
pub fn backend() -> &'static str {
    #[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
    }
    #[cfg(all(feature = "simd-intrinsics", target_arch = "aarch64"))]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return "neon";
        }
    }
    "portable"
}

/// Lane-major dot product of two equal-length f32 slices in f64.
///
/// Each product is widened to f64 before multiplying (two roundings:
/// one for the multiply, one for each add — never an FMA), element
/// `i` accumulates into lane `i % LANES`, and the lanes reduce with
/// [`reduce_lanes`]. Bit-identical on every backend (I13).
///
/// # Panics
/// Panics if the slices have different lengths.
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot operand width mismatch");
    #[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: the AVX2 target feature was just verified at
            // runtime, which is the only precondition of `dot_avx2`.
            return unsafe { x86::dot_avx2(a, b) };
        }
    }
    #[cfg(all(feature = "simd-intrinsics", target_arch = "aarch64"))]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: the NEON target feature was just verified at
            // runtime, which is the only precondition of `dot_neon`.
            return unsafe { arm::dot_neon(a, b) };
        }
    }
    dot_portable(a, b)
}

/// Portable lane-major dot body: full blocks via `chunks_exact`,
/// then the shared masked tail, then the ordered horizontal reduce.
fn dot_portable(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let blocks_a = a.chunks_exact(LANES);
    let blocks_b = b.chunks_exact(LANES);
    let tail_a = blocks_a.remainder();
    let tail_b = blocks_b.remainder();
    for (xa, xb) in blocks_a.zip(blocks_b) {
        for l in 0..LANES {
            acc[l] += xa[l] as f64 * xb[l] as f64;
        }
    }
    accumulate_tail(&mut acc, tail_a, tail_b);
    reduce_lanes(&acc)
}

/// Masked scalar tail shared by every [`dot_f32`] backend: the final
/// `n % LANES` elements land in lane positions `0..tail`, exactly as
/// if the vector were zero-padded to a full block.
fn accumulate_tail(acc: &mut [f64; LANES], a: &[f32], b: &[f32]) {
    for (l, (&xa, &xb)) in a.iter().zip(b).enumerate() {
        acc[l] += xa as f64 * xb as f64;
    }
}

/// Canonical horizontal reduce: the sequential left fold
/// `((acc[0] + acc[1]) + acc[2]) + …` — never a pairwise/tree
/// reduce, which would produce different bits.
pub fn reduce_lanes(acc: &[f64; LANES]) -> f64 {
    let mut total = acc[0];
    for &v in &acc[1..] {
        total += v;
    }
    total
}

/// Fused interpolation write: `out[i] = baseline[i] + alpha *
/// (x[i] - baseline[i])` in f32, lane-blocked. Elementwise, so the
/// result is bitwise-independent of lane width.
pub fn interpolate(out: &mut [f32], x: &[f32], baseline: &[f32], alpha: f32) {
    debug_assert_eq!(out.len(), x.len());
    debug_assert_eq!(out.len(), baseline.len());
    let mut o_blocks = out.chunks_exact_mut(LANES);
    let mut x_blocks = x.chunks_exact(LANES);
    let mut b_blocks = baseline.chunks_exact(LANES);
    for ((o, xv), bv) in (&mut o_blocks).zip(&mut x_blocks).zip(&mut b_blocks) {
        for l in 0..LANES {
            o[l] = bv[l] + alpha * (xv[l] - bv[l]);
        }
    }
    let o = o_blocks.into_remainder();
    let xv = x_blocks.remainder();
    let bv = b_blocks.remainder();
    for l in 0..o.len() {
        o[l] = bv[l] + alpha * (xv[l] - bv[l]);
    }
}

/// Scaled f64 accumulation of an f32 row: `acc[i] += scale *
/// row[i] as f64`, lane-blocked. Elementwise per index; the
/// cross-*class* accumulation order is owned by the caller.
pub fn accum_scaled(acc: &mut [f64], scale: f64, row: &[f32]) {
    debug_assert_eq!(acc.len(), row.len());
    let mut a_blocks = acc.chunks_exact_mut(LANES);
    let mut r_blocks = row.chunks_exact(LANES);
    for (a, r) in (&mut a_blocks).zip(&mut r_blocks) {
        for l in 0..LANES {
            a[l] += scale * r[l] as f64;
        }
    }
    let a = a_blocks.into_remainder();
    let r = r_blocks.remainder();
    for l in 0..a.len() {
        a[l] += scale * r[l] as f64;
    }
}

/// Fused weighted-gradient accumulation — the inner statement of the
/// IG sum. For each feature `i`:
///
/// ```text
/// g          = p_target * (target_row[i] as f64 - wavg[i]) * scale
/// partial[i] += weight * g * ((x[i] - baseline[i]) as f64)
/// ```
///
/// Multiplications left-to-right in f64, the `x − baseline` delta
/// subtracted in **f32** before widening (as the scalar reference
/// does), no FMA — the exact statement `ig_points_scalar` executes,
/// lane-blocked. Elementwise per feature, so bitwise-independent of
/// lane width.
#[allow(clippy::too_many_arguments)]
pub fn accum_grad(
    partial: &mut [f64],
    weight: f64,
    p_target: f64,
    scale: f64,
    target_row: &[f32],
    wavg: &[f64],
    x: &[f32],
    baseline: &[f32],
) {
    debug_assert_eq!(partial.len(), target_row.len());
    debug_assert_eq!(partial.len(), wavg.len());
    debug_assert_eq!(partial.len(), x.len());
    debug_assert_eq!(partial.len(), baseline.len());
    let n = partial.len();
    let full = n - n % LANES;
    for j in (0..full).step_by(LANES) {
        for l in 0..LANES {
            let i = j + l;
            let g = p_target * (target_row[i] as f64 - wavg[i]) * scale;
            partial[i] += weight * g * (x[i] - baseline[i]) as f64;
        }
    }
    for i in full..n {
        let g = p_target * (target_row[i] as f64 - wavg[i]) * scale;
        partial[i] += weight * g * (x[i] - baseline[i]) as f64;
    }
}

/// Row commit into an f64 accumulator: `values[i] += row[i] as f64`,
/// lane-blocked. The cross-*row* commit order (lane-index order,
/// docs/INVARIANTS.md §I4) is owned by the caller.
pub fn commit_row(values: &mut [f64], row: &[f32]) {
    debug_assert_eq!(values.len(), row.len());
    let mut v_blocks = values.chunks_exact_mut(LANES);
    let mut r_blocks = row.chunks_exact(LANES);
    for (v, r) in (&mut v_blocks).zip(&mut r_blocks) {
        for l in 0..LANES {
            v[l] += r[l] as f64;
        }
    }
    let v = v_blocks.into_remainder();
    let r = r_blocks.remainder();
    for l in 0..v.len() {
        v[l] += r[l] as f64;
    }
}

#[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
mod x86 {
    //! AVX2 path for the order-bearing dot kernel. Eight f32 lanes
    //! are widened to two `__m256d` accumulators (lanes 0–3 and 4–7)
    //! so the in-register layout *is* the lane-major accumulator
    //! array — stores land in `acc[0..8]` and the shared tail +
    //! ordered reduce run in safe code.

    use super::{accumulate_tail, reduce_lanes, LANES};
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_castps256_ps128, _mm256_cvtps_pd, _mm256_extractf128_ps,
        _mm256_loadu_ps, _mm256_mul_pd, _mm256_setzero_pd, _mm256_storeu_pd,
    };

    /// Lane-major dot via AVX2. Bit-identical to `dot_portable`:
    /// same widen-multiply-add per lane, same tail, same reduce.
    ///
    /// # Safety
    /// The caller must have verified at runtime that the CPU
    /// supports AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let full = n - n % LANES;
        let mut acc = [0.0f64; LANES];
        // SAFETY: `j + LANES <= full <= a.len() == b.len()` bounds
        // every 8-f32 load, and `acc` is exactly LANES f64s so the
        // two 4-f64 stores at offsets 0 and 4 are in bounds;
        // `loadu`/`storeu` have no alignment requirement.
        unsafe {
            let mut acc_lo = _mm256_setzero_pd();
            let mut acc_hi = _mm256_setzero_pd();
            let mut j = 0;
            while j < full {
                let va = _mm256_loadu_ps(a.as_ptr().add(j));
                let vb = _mm256_loadu_ps(b.as_ptr().add(j));
                let a_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(va));
                let a_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(va));
                let b_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(vb));
                let b_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(vb));
                acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(a_lo, b_lo));
                acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(a_hi, b_hi));
                j += LANES;
            }
            store_halves(&mut acc, acc_lo, acc_hi);
        }
        accumulate_tail(&mut acc, &a[full..], &b[full..]);
        reduce_lanes(&acc)
    }

    /// Spill the two 4-wide register accumulators into the lane
    /// array: `acc_lo` → lanes 0–3, `acc_hi` → lanes 4–7.
    ///
    /// # Safety
    /// Requires AVX (implied by the caller's AVX2 check).
    #[target_feature(enable = "avx2")]
    unsafe fn store_halves(acc: &mut [f64; LANES], acc_lo: __m256d, acc_hi: __m256d) {
        // SAFETY: `acc` is LANES == 8 f64s, so offsets 0 and 4 each
        // admit an unaligned 4-f64 store.
        unsafe {
            _mm256_storeu_pd(acc.as_mut_ptr(), acc_lo);
            _mm256_storeu_pd(acc.as_mut_ptr().add(4), acc_hi);
        }
    }
}

#[cfg(all(feature = "simd-intrinsics", target_arch = "aarch64"))]
mod arm {
    //! NEON path for the order-bearing dot kernel. Eight f32 lanes
    //! are widened to four `float64x2_t` accumulators (lane pairs
    //! 01/23/45/67), stored back as the lane-major accumulator
    //! array; the shared tail + ordered reduce run in safe code.

    use super::{accumulate_tail, reduce_lanes, LANES};
    use std::arch::aarch64::{
        float64x2_t, vaddq_f64, vcvt_f64_f32, vcvt_high_f64_f32, vdupq_n_f64, vget_low_f32,
        vld1q_f32, vmulq_f64, vst1q_f64,
    };

    /// Lane-major dot via NEON. Bit-identical to `dot_portable`:
    /// same widen-multiply-add per lane, same tail, same reduce.
    ///
    /// # Safety
    /// The caller must have verified at runtime that the CPU
    /// supports NEON (`is_aarch64_feature_detected!("neon")`).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let full = n - n % LANES;
        let mut acc = [0.0f64; LANES];
        // SAFETY: `j + LANES <= full <= a.len() == b.len()` bounds
        // every pair of 4-f32 loads, and `acc` is exactly LANES f64s
        // so the four 2-f64 stores at offsets 0/2/4/6 are in bounds.
        unsafe {
            let mut acc0: float64x2_t = vdupq_n_f64(0.0);
            let mut acc1: float64x2_t = vdupq_n_f64(0.0);
            let mut acc2: float64x2_t = vdupq_n_f64(0.0);
            let mut acc3: float64x2_t = vdupq_n_f64(0.0);
            let mut j = 0;
            while j < full {
                let va0 = vld1q_f32(a.as_ptr().add(j));
                let va1 = vld1q_f32(a.as_ptr().add(j + 4));
                let vb0 = vld1q_f32(b.as_ptr().add(j));
                let vb1 = vld1q_f32(b.as_ptr().add(j + 4));
                let a01 = vcvt_f64_f32(vget_low_f32(va0));
                let a23 = vcvt_high_f64_f32(va0);
                let a45 = vcvt_f64_f32(vget_low_f32(va1));
                let a67 = vcvt_high_f64_f32(va1);
                let b01 = vcvt_f64_f32(vget_low_f32(vb0));
                let b23 = vcvt_high_f64_f32(vb0);
                let b45 = vcvt_f64_f32(vget_low_f32(vb1));
                let b67 = vcvt_high_f64_f32(vb1);
                acc0 = vaddq_f64(acc0, vmulq_f64(a01, b01));
                acc1 = vaddq_f64(acc1, vmulq_f64(a23, b23));
                acc2 = vaddq_f64(acc2, vmulq_f64(a45, b45));
                acc3 = vaddq_f64(acc3, vmulq_f64(a67, b67));
                j += LANES;
            }
            vst1q_f64(acc.as_mut_ptr(), acc0);
            vst1q_f64(acc.as_mut_ptr().add(2), acc1);
            vst1q_f64(acc.as_mut_ptr().add(4), acc2);
            vst1q_f64(acc.as_mut_ptr().add(6), acc3);
        }
        accumulate_tail(&mut acc, &a[full..], &b[full..]);
        reduce_lanes(&acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 32-bit xorshift-multiply mixer — full-mantissa pseudo-random
    /// f32s so reduction *order* is visible in the bits (powers of
    /// two would make every order bit-identical and the goldens
    /// vacuous). Mirrored verbatim in `test_batch_parity.py`.
    fn mix(mut k: u32) -> u32 {
        k ^= k >> 16;
        k = k.wrapping_mul(0x45D9_F3B);
        k ^= k >> 16;
        k = k.wrapping_mul(0x45D9_F3B);
        k ^= k >> 16;
        k
    }

    /// Deterministic test vector in [-1, 1): element `i` of the
    /// stream named by `salt`. Cross-language golden generator —
    /// MUST match `test_batch_parity.py::_tvec` verbatim.
    fn tvec(n: usize, salt: u32) -> Vec<f32> {
        (0..n as u32)
            .map(|i| {
                let k = mix(i.wrapping_mul(2_654_435_761).wrapping_add(salt.wrapping_mul(40_503)));
                (k as f64 / 4_294_967_296.0 * 2.0 - 1.0) as f32
            })
            .collect()
    }

    /// Literal transcription of the lane-major spec, independent of
    /// the blocked implementation: `acc[i % LANES] += a*b`, then the
    /// sequential fold. The implementation must match this bitwise.
    fn dot_spec(a: &[f32], b: &[f32]) -> f64 {
        let mut acc = [0.0f64; LANES];
        for (i, (&xa, &xb)) in a.iter().zip(b).enumerate() {
            acc[i % LANES] += xa as f64 * xb as f64;
        }
        reduce_lanes(&acc)
    }

    /// Plain sequential left-to-right dot — the order lane-major
    /// deliberately does NOT compute (except where n forces it).
    fn dot_sequential(a: &[f32], b: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for (&xa, &xb) in a.iter().zip(b) {
            total += xa as f64 * xb as f64;
        }
        total
    }

    /// Cross-language goldens for the lane-major dot, shared
    /// verbatim with `test_batch_parity.py::TestLaneMajorOrder`.
    /// Widths cover a sub-lane vector, one exact block, block+tail,
    /// a prime, a prime several blocks in, and the bench width.
    const DOT_GOLDENS: &[(usize, u32, u32, u64)] = &[
        (7, 1, 2, 0x3FFE_47B4_6C4B_7578),
        (8, 3, 4, 0xBFDF_3205_52EE_70F0),
        (9, 5, 6, 0xBFFE_B6A1_EA3E_24A9),
        (13, 7, 8, 0xBFC4_C2A4_F2D6_AA7C),
        (67, 9, 10, 0x3FF2_3867_CEBD_4200),
        (3072, 11, 12, 0x4026_61CB_22E1_D7F6),
    ];

    #[test]
    fn dot_matches_cross_language_goldens() {
        for &(n, sa, sb, bits) in DOT_GOLDENS {
            let a = tvec(n, sa);
            let b = tvec(n, sb);
            assert_eq!(
                dot_f32(&a, &b).to_bits(),
                bits,
                "lane-major dot golden mismatch at n={n} (backend {})",
                backend()
            );
        }
    }

    #[test]
    fn dot_matches_lane_major_spec_at_all_tail_widths() {
        for n in [0, 1, 6, 7, 8, 9, 13, 16, 17, 31, 37, 64, 67, 101, 3072] {
            let a = tvec(n, 21);
            let b = tvec(n, 22);
            assert_eq!(
                dot_f32(&a, &b).to_bits(),
                dot_spec(&a, &b).to_bits(),
                "dispatched dot diverged from lane-major spec at n={n} (backend {})",
                backend()
            );
            assert_eq!(
                dot_portable(&a, &b).to_bits(),
                dot_spec(&a, &b).to_bits(),
                "portable dot diverged from lane-major spec at n={n}"
            );
        }
    }

    /// The goldens must actually pin the *order*: at these widths the
    /// sequential fold produces different bits, so a backend that
    /// quietly reassociated would fail the golden test.
    #[test]
    fn lane_major_order_differs_from_sequential_where_it_must() {
        let seq_bits = [
            (13usize, 7u32, 8u32, 0xBFC4_C2A4_F2D6_AA80u64),
            (67, 9, 10, 0x3FF2_3867_CEBD_4202),
            (3072, 11, 12, 0x4026_61CB_22E1_D7EE),
        ];
        for &(n, sa, sb, bits) in &seq_bits {
            let a = tvec(n, sa);
            let b = tvec(n, sb);
            let seq = dot_sequential(&a, &b);
            assert_eq!(seq.to_bits(), bits, "sequential pin drifted at n={n}");
            assert_ne!(
                dot_f32(&a, &b).to_bits(),
                seq.to_bits(),
                "lane-major and sequential bits coincide at n={n}: golden cannot pin order"
            );
        }
    }

    #[test]
    fn backend_name_is_one_of_the_contract_set() {
        assert!(["portable", "avx2", "neon"].contains(&backend()));
    }

    #[test]
    #[should_panic(expected = "dot operand width mismatch")]
    fn dot_rejects_mismatched_widths() {
        dot_f32(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn interpolate_matches_scalar_statement_bitwise() {
        for n in [0, 1, 7, 8, 9, 13, 37, 100] {
            let x = tvec(n, 31);
            let baseline = tvec(n, 32);
            for &alpha in &[0.0f32, 0.125, 0.37, 1.0] {
                let mut out = vec![0.0f32; n];
                interpolate(&mut out, &x, &baseline, alpha);
                for i in 0..n {
                    let want = baseline[i] + alpha * (x[i] - baseline[i]);
                    assert_eq!(out[i].to_bits(), want.to_bits(), "n={n} i={i}");
                }
            }
        }
    }

    #[test]
    fn accum_scaled_matches_scalar_statement_bitwise() {
        for n in [0, 1, 7, 8, 9, 13, 37] {
            let row = tvec(n, 41);
            let mut acc: Vec<f64> = tvec(n, 42).iter().map(|&v| v as f64).collect();
            let mut want = acc.clone();
            accum_scaled(&mut acc, 0.37, &row);
            for i in 0..n {
                want[i] += 0.37 * row[i] as f64;
                assert_eq!(acc[i].to_bits(), want[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn accum_grad_matches_scalar_statement_bitwise() {
        for n in [0, 1, 7, 8, 9, 13, 37] {
            let trow = tvec(n, 51);
            let x = tvec(n, 52);
            let baseline = tvec(n, 53);
            let wavg: Vec<f64> = tvec(n, 54).iter().map(|&v| v as f64).collect();
            let mut partial: Vec<f64> = tvec(n, 55).iter().map(|&v| v as f64).collect();
            let mut want = partial.clone();
            let (weight, pt, scale) = (0.21f64, 0.62f64, 0.0044f64);
            accum_grad(&mut partial, weight, pt, scale, &trow, &wavg, &x, &baseline);
            for i in 0..n {
                let g = pt * (trow[i] as f64 - wavg[i]) * scale;
                want[i] += weight * g * (x[i] - baseline[i]) as f64;
                assert_eq!(partial[i].to_bits(), want[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn commit_row_matches_scalar_statement_bitwise() {
        for n in [0, 1, 7, 8, 9, 13, 37] {
            let row = tvec(n, 61);
            let mut values: Vec<f64> = tvec(n, 62).iter().map(|&v| v as f64).collect();
            let mut want = values.clone();
            commit_row(&mut values, &row);
            for i in 0..n {
                want[i] += row[i] as f64;
                assert_eq!(values[i].to_bits(), want[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
    #[test]
    fn avx2_matches_portable_bitwise() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("avx2 unavailable on this CPU; intrinsic parity not exercised");
            return;
        }
        for n in [0, 1, 7, 8, 9, 13, 16, 17, 31, 37, 64, 67, 101, 3072] {
            let a = tvec(n, 71);
            let b = tvec(n, 72);
            // SAFETY: AVX2 support was just verified at runtime.
            let intr = unsafe { super::x86::dot_avx2(&a, &b) };
            assert_eq!(
                intr.to_bits(),
                dot_portable(&a, &b).to_bits(),
                "avx2 dot diverged from portable at n={n}"
            );
        }
    }

    #[cfg(all(feature = "simd-intrinsics", target_arch = "aarch64"))]
    #[test]
    fn neon_matches_portable_bitwise() {
        if !std::arch::is_aarch64_feature_detected!("neon") {
            eprintln!("neon unavailable on this CPU; intrinsic parity not exercised");
            return;
        }
        for n in [0, 1, 7, 8, 9, 13, 16, 17, 31, 37, 64, 67, 101, 3072] {
            let a = tvec(n, 71);
            let b = tvec(n, 72);
            // SAFETY: NEON support was just verified at runtime.
            let intr = unsafe { super::arm::dot_neon(&a, &b) };
            assert_eq!(
                intr.to_bits(),
                dot_portable(&a, &b).to_bits(),
                "neon dot diverged from portable at n={n}"
            );
        }
    }
}
