//! Gather-indexed cross-request execution: the serving-side face of the
//! batched backend.
//!
//! The coordinator's device chunks mix gradient points from *different*
//! requests (cross-request continuous batching, the paper's §V argument).
//! Before this module the feeder materialized every chunk by copying each
//! lane's full image and baseline into freshly allocated
//! `chunk × features` host buffers — `O(chunk × features)` host bytes per
//! chunk for endpoints the backend had already seen on every previous
//! chunk of the same request. This module replaces that with a
//! **gather-indexed plan** over **resident request tensors**:
//!
//! * [`GatherLane`] — one device-batch slot as a *reference*:
//!   `(slot, alpha, weight, target)`. A chunk is just a slice of these —
//!   `O(chunk)` bytes, no feature-width payload.
//! * [`GatherExec`] — the execution surface the coordinator drives:
//!   register a request's endpoints **once** at admission
//!   ([`GatherExec::register_request`]), execute gather chunks that
//!   reference them by slot ([`GatherExec::eval_gather`]), evict on
//!   settlement ([`GatherExec::evict_request`]). Implemented by the PJRT
//!   runtime (`runtime::RuntimeHandle`, `runtime::ShardedRuntime` — the
//!   device thread owns the resident tensors and a reused staging
//!   buffer) and by `ig::model::AnalyticExec` (closed-form model +
//!   [`ResidentPool`]) so the whole serving path is testable and
//!   benchable without artifacts.
//! * [`GatherOut`] — the planar per-lane partial rows
//!   (`lanes × features`, row `k` = `w_k · ∂p_{t_k}/∂x|_{α_k} ⊙ (x_k −
//!   x′_k)`) the feeder scatters into request accumulators.
//!
//! # Determinism contract
//!
//! A lane's output row is a pure function of the lane (its resident
//! endpoints, alpha, weight, target) — never of its neighbours in the
//! chunk or of which shard executed it. Combined with the coordinator's
//! ordered lane commit (`coordinator::state`), attributions are
//! bit-identical (0 ULP) at **any feeder count** — the serving-layer
//! extension of `exec::batch`'s any-worker-count guarantee, property-
//! tested at feeder counts {1, 2, 4} in `tests/sharded_feeder.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::exec::sync::{self, Mutex};

/// One device-batch slot of a cross-request gather chunk: a *reference*
/// to a request's resident endpoint tensors plus the lane's scalars.
///
/// This is the entire per-lane payload the feeder moves per chunk —
/// `O(chunk)` bytes total, replacing the `chunk × features` endpoint
/// copies the pre-gather feeder materialized.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GatherLane {
    /// Resident-tensor slot the lane's endpoints were registered under
    /// (the coordinator uses the request id).
    pub slot: u64,
    /// Interpolation constant of this gradient point.
    pub alpha: f32,
    /// Quadrature weight of this gradient point.
    pub weight: f32,
    /// The lane's explained class.
    pub target: usize,
}

/// Lifecycle state of one backend shard (see `docs/ARCHITECTURE.md`
/// §"Shard lifecycle" for the full live → draining → dead → respawned
/// diagram).
///
/// * `Live` — accepting gather chunks.
/// * `Draining` — administratively fenced: the shard rejects new gather
///   chunks so its queued work migrates to sibling shards. Because a
///   lane's row is a pure function of the lane (never of the executing
///   shard), migration preserves the 0-ULP identity
///   (`docs/INVARIANTS.md` §I7).
/// * `Dead` — the shard's device state (resident tensors included) is
///   gone; chunks targeting it must be re-routed or the shard respawned
///   ([`GatherExec::respawn_shard`], §I8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Accepting gather chunks.
    Live,
    /// Fenced for rebalancing: rejects new chunks, siblings take over.
    Draining,
    /// Device state lost; needs a respawn before serving again.
    Dead,
}

/// Planar per-lane output of one gather chunk: `lanes × features` f32
/// partial rows, row `k` belonging to the chunk's lane `k`.
#[derive(Debug, Clone)]
pub struct GatherOut {
    /// Row-major `lanes × features` partial rows.
    pub rows: Vec<f32>,
    /// Feature width of each row.
    pub features: usize,
}

impl GatherOut {
    /// Lane `k`'s partial row.
    pub fn row(&self, k: usize) -> &[f32] {
        &self.rows[k * self.features..(k + 1) * self.features]
    }

    /// Number of lane rows carried.
    pub fn lanes(&self) -> usize {
        if self.features == 0 {
            0
        } else {
            self.rows.len() / self.features
        }
    }
}

/// The execution surface the serving coordinator drives — resident
/// request tensors plus gather-indexed cross-request chunks.
///
/// One backend instance may expose several device `shards` (independent
/// submission streams); the coordinator pins each feeder worker to one
/// shard. Registration is backend-global: a chunk may execute on any
/// shard, so every shard must be able to resolve every live slot.
pub trait GatherExec: Send + Sync {
    /// Model input width F.
    fn features(&self) -> usize;

    /// Number of output classes C.
    fn num_classes(&self) -> usize;

    /// Forward-only probabilities for `rows` images packed row-major in
    /// `imgs` (`rows × features`); returns `rows × classes` f32
    /// probabilities. Stage-1 probes go through this.
    fn forward(&self, imgs: &[f32], rows: usize) -> Result<Vec<f32>>;

    /// Upload a request's endpoints once; subsequent gather lanes
    /// reference them by `slot`. Slots are caller-assigned (the
    /// coordinator uses the request id) and must be unique among live
    /// registrations.
    fn register_request(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()>;

    /// Release a request's resident tensors. Must be a no-op for unknown
    /// slots (eviction and late chunk failures may race benignly).
    fn evict_request(&self, slot: u64);

    /// Live resident registrations (the coordinator's pool gauge; for
    /// sharded backends, per-shard — registration is broadcast).
    fn resident_len(&self) -> usize;

    /// Independent device submission streams this backend exposes; the
    /// coordinator pins feeder `i` to shard `i % shards()`.
    fn shards(&self) -> usize {
        1
    }

    /// Execute one cross-request gather chunk on `shard`: one gradient
    /// model pass per lane, returning the planar per-lane partial rows.
    /// Each row must be a pure function of its lane alone (see the
    /// module doc's determinism contract). Lanes referencing an
    /// unregistered slot fail the whole chunk.
    fn eval_gather(&self, shard: usize, lanes: &[GatherLane]) -> Result<GatherOut>;

    /// Lifecycle state of `shard`. Single-shard / always-healthy
    /// backends keep the default (`Live` forever); elastic backends
    /// (`runtime::ShardedRuntime`, the chaos `FaultInjector`) report
    /// real health so the feeder failover can route around outages.
    fn shard_health(&self, _shard: usize) -> ShardHealth {
        ShardHealth::Live
    }

    /// Administratively fence `shard`: it stops accepting new gather
    /// chunks (`eval_gather` fails) so queued work migrates to sibling
    /// shards. No-op default for backends without a lifecycle.
    fn drain_shard(&self, _shard: usize) {}

    /// Bring a dead or draining `shard` back to `Live`, replaying every
    /// live resident registration into it so no slot is stranded
    /// (`docs/INVARIANTS.md` §I8). No-op default for backends without a
    /// lifecycle; elastic backends return an error when the shard
    /// cannot be revived yet.
    fn respawn_shard(&self, _shard: usize) -> Result<()> {
        Ok(())
    }
}

/// A host-side resident-tensor pool: the reusable registration store for
/// in-process [`GatherExec`] backends (`ig::model::AnalyticExec`; the
/// PJRT device thread keeps its own non-`Send` twin with device
/// buffers).
///
/// Entries are handed out as `Arc`s ([`ResidentPool::entry`]), so the
/// pool's mutex is held only for the map lookup — never across the
/// caller's per-lane compute. Concurrent shards therefore share the
/// pool without serializing their gather work on it.
#[derive(Debug, Default)]
pub struct ResidentPool {
    entries: Mutex<HashMap<u64, Arc<(Vec<f32>, Vec<f32>)>>>,
}

impl ResidentPool {
    /// An empty pool.
    pub fn new() -> ResidentPool {
        ResidentPool::default()
    }

    /// Store `(x, baseline)` under `slot`; duplicate live slots are a
    /// caller bug and fail loudly.
    pub fn register(&self, slot: u64, x: &[f32], baseline: &[f32]) -> Result<()> {
        ensure!(x.len() == baseline.len(), "endpoint width mismatch");
        let mut map = sync::lock(&self.entries);
        if map.contains_key(&slot) {
            bail!("resident slot {slot} already registered");
        }
        map.insert(slot, Arc::new((x.to_vec(), baseline.to_vec())));
        Ok(())
    }

    /// Drop `slot`'s entry; `true` if it was present.
    pub fn evict(&self, slot: u64) -> bool {
        sync::lock(&self.entries).remove(&slot).is_some()
    }

    /// `slot`'s `(x, baseline)` entry, shared — the lock is released
    /// before the caller computes on it. `None` when not registered.
    pub fn entry(&self, slot: u64) -> Option<Arc<(Vec<f32>, Vec<f32>)>> {
        sync::lock(&self.entries).get(&slot).cloned()
    }

    /// Run `f` over `slot`'s `(x, baseline)` without copying them out;
    /// `None` when the slot is not registered. NOTE: holds the pool
    /// lock for the duration of `f` — keep `f` cheap, or use
    /// [`ResidentPool::entry`] for heavy per-lane work.
    pub fn with_entry<R>(&self, slot: u64, f: impl FnOnce(&[f32], &[f32]) -> R) -> Option<R> {
        let map = sync::lock(&self.entries);
        map.get(&slot).map(|e| f(&e.0, &e.1))
    }

    /// Every live registration as `(slot, entry)` pairs sorted by slot —
    /// the deterministic replay source for shard respawn
    /// ([`GatherExec::respawn_shard`]): re-registering in slot order
    /// makes the replay sequence a pure function of pool content, so
    /// chaos runs with the same `FaultPlan` re-upload identically.
    pub fn snapshot_sorted(&self) -> Vec<(u64, Arc<(Vec<f32>, Vec<f32>)>)> {
        let map = sync::lock(&self.entries);
        // nuig:allow(hash-iter): iteration order cannot leak — the snapshot is sorted by slot immediately below
        let mut all: Vec<_> = map.iter().map(|(s, e)| (*s, Arc::clone(e))).collect();
        all.sort_by_key(|(slot, _)| *slot);
        all
    }

    /// Live registrations.
    pub fn len(&self) -> usize {
        sync::lock(&self.entries).len()
    }

    /// Whether no registrations are live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_register_get_evict() {
        let pool = ResidentPool::new();
        assert!(pool.is_empty());
        pool.register(7, &[1.0, 2.0], &[0.0, 0.5]).unwrap();
        assert_eq!(pool.len(), 1);
        let got = pool.with_entry(7, |x, b| (x.to_vec(), b.to_vec())).unwrap();
        assert_eq!(got.0, vec![1.0, 2.0]);
        assert_eq!(got.1, vec![0.0, 0.5]);
        assert!(pool.with_entry(8, |_, _| ()).is_none());
        // The shared-entry accessor: lock released, data intact.
        let shared = pool.entry(7).unwrap();
        assert_eq!(shared.0, vec![1.0, 2.0]);
        assert!(pool.entry(8).is_none());
        assert!(pool.evict(7));
        assert!(!pool.evict(7), "second evict is a no-op");
        assert!(pool.is_empty());
    }

    #[test]
    fn pool_rejects_duplicate_slot_and_width_mismatch() {
        let pool = ResidentPool::new();
        pool.register(1, &[0.0; 4], &[0.0; 4]).unwrap();
        assert!(pool.register(1, &[0.0; 4], &[0.0; 4]).is_err(), "duplicate live slot");
        assert!(pool.register(2, &[0.0; 4], &[0.0; 3]).is_err(), "width mismatch");
        // Evicting frees the slot for re-registration (id reuse after a
        // settled request is legal).
        pool.evict(1);
        pool.register(1, &[1.0; 4], &[0.0; 4]).unwrap();
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn gather_out_rows() {
        let out = GatherOut { rows: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], features: 3 };
        assert_eq!(out.lanes(), 2);
        assert_eq!(out.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(out.row(1), &[4.0, 5.0, 6.0]);
        let empty = GatherOut { rows: vec![], features: 0 };
        assert_eq!(empty.lanes(), 0);
    }

    #[test]
    fn gather_lane_is_copy() {
        let l = GatherLane { slot: 3, alpha: 0.5, weight: 0.25, target: 1 };
        let m = l;
        assert_eq!(l, m);
    }

    #[test]
    fn pool_snapshot_is_sorted_by_slot() {
        let pool = ResidentPool::new();
        for slot in [9u64, 2, 40, 17] {
            pool.register(slot, &[slot as f32], &[0.0]).unwrap();
        }
        let snap = pool.snapshot_sorted();
        let slots: Vec<u64> = snap.iter().map(|(s, _)| *s).collect();
        assert_eq!(slots, vec![2, 9, 17, 40]);
        assert_eq!(snap[1].1 .0, vec![9.0], "entries travel with their slots");
        // A snapshot is a point-in-time copy: later evictions don't
        // invalidate held entries.
        pool.evict(9);
        assert_eq!(snap[1].1 .0, vec![9.0]);
    }

    #[test]
    fn lifecycle_defaults_are_always_live() {
        struct Fixed;
        impl GatherExec for Fixed {
            fn features(&self) -> usize {
                1
            }
            fn num_classes(&self) -> usize {
                1
            }
            fn forward(&self, _imgs: &[f32], rows: usize) -> Result<Vec<f32>> {
                Ok(vec![1.0; rows])
            }
            fn register_request(&self, _slot: u64, _x: &[f32], _b: &[f32]) -> Result<()> {
                Ok(())
            }
            fn evict_request(&self, _slot: u64) {}
            fn resident_len(&self) -> usize {
                0
            }
            fn eval_gather(&self, _shard: usize, lanes: &[GatherLane]) -> Result<GatherOut> {
                Ok(GatherOut { rows: vec![0.0; lanes.len()], features: 1 })
            }
        }
        let exec: &dyn GatherExec = &Fixed;
        assert_eq!(exec.shard_health(0), ShardHealth::Live);
        exec.drain_shard(0);
        assert_eq!(exec.shard_health(0), ShardHealth::Live, "default drain is a no-op");
        exec.respawn_shard(0).unwrap();
    }
}
