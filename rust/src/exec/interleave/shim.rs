//! Instrumented `std::sync` stand-ins for the interleaving explorer.
//!
//! These types have two personalities:
//!
//! * **Passthrough** — on a thread that is not part of an active model
//!   (everything outside [`super::explore`]), they delegate straight to
//!   their `std::sync` counterparts. This is what lets the whole crate
//!   build and run its normal test suite with the facade
//!   ([`crate::exec::sync`]) routed here under `--features loom-models`.
//! * **Modeled** — on a model thread, every operation reports to the
//!   execution's scheduler: a preemption point before the operation, and
//!   logical blocking (mutex contention, condvar parks, joins) handed to
//!   the single-token scheduler so the explorer controls every
//!   interleaving.
//!
//! The API mirrors the `std::sync` signatures (`lock()` returns a
//! `LockResult`, condvar waits return `LockResult`) so the facade helpers
//! compile against either personality unchanged. Only the surface the
//! serving substrate actually uses is implemented.

use std::cell::RefCell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, LockResult, PoisonError};
use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard, OnceLock};
use std::time::Duration;

use super::Execution;

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Execution>, usize)>> = const { RefCell::new(None) };
}

pub(crate) fn enter_model(exec: Arc<Execution>, id: usize) {
    CURRENT.with(|c| *c.borrow_mut() = Some((exec, id)));
}

pub(crate) fn leave_model() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}

fn ctx() -> Option<(Arc<Execution>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Mutex with the same `lock() -> LockResult` shape as
/// [`std::sync::Mutex`]; modeled acquisition is a scheduler decision
/// point and logical blocking.
pub struct Mutex<T> {
    inner: StdMutex<T>,
    id: OnceLock<usize>,
}

impl<T> Mutex<T> {
    /// A new unlocked mutex.
    pub fn new(t: T) -> Mutex<T> {
        Mutex { inner: StdMutex::new(t), id: OnceLock::new() }
    }

    fn rid(&self, exec: &Arc<Execution>) -> usize {
        *self.id.get_or_init(|| exec.new_resource())
    }

    /// Acquire the lock (blocking). Mirrors [`std::sync::Mutex::lock`].
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match ctx() {
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), model: None }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    model: None,
                })),
            },
            Some((exec, me)) => {
                let rid = self.rid(&exec);
                exec.acquire(me, rid);
                // The logical owner is unique, so the std-level lock below
                // is uncontended by construction.
                let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
                Ok(MutexGuard { lock: self, inner: Some(g), model: Some((exec, rid)) })
            }
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

/// Guard returned by [`Mutex::lock`]; releasing it (drop) releases the
/// std-level lock first, then the modeled ownership.
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
    /// `(execution, mutex resource id)` when the guard is model-owned.
    model: Option<(Arc<Execution>, usize)>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after release")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        self.inner.take();
        if let Some((exec, rid)) = self.model.take() {
            exec.release(rid);
        }
    }
}

/// Result of a timed condvar wait; mirrors
/// [`std::sync::WaitTimeoutResult::timed_out`].
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// Whether the wait ended by timeout rather than notification.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Condition variable with the [`std::sync::Condvar`] wait/notify shape.
/// The modeled variant never delivers spurious wakeups, and a modeled
/// timed wait only times out when no other thread can run (see the
/// module doc of [`crate::exec::interleave`]).
pub struct Condvar {
    inner: StdCondvar,
    id: OnceLock<usize>,
}

impl Condvar {
    /// A new condition variable.
    pub fn new() -> Condvar {
        Condvar { inner: StdCondvar::new(), id: OnceLock::new() }
    }

    fn rid(&self, exec: &Arc<Execution>) -> usize {
        *self.id.get_or_init(|| exec.new_resource())
    }

    fn wait_impl<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        timeout: Option<Duration>,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match guard.model.take() {
            None => {
                let lock = guard.lock;
                let inner = guard.inner.take().expect("guard accessed after release");
                drop(guard);
                match timeout {
                    None => match self.inner.wait(inner) {
                        Ok(g) => Ok((
                            MutexGuard { lock, inner: Some(g), model: None },
                            WaitTimeoutResult(false),
                        )),
                        Err(p) => Err(PoisonError::new((
                            MutexGuard { lock, inner: Some(p.into_inner()), model: None },
                            WaitTimeoutResult(false),
                        ))),
                    },
                    Some(dur) => match self.inner.wait_timeout(inner, dur) {
                        Ok((g, r)) => Ok((
                            MutexGuard { lock, inner: Some(g), model: None },
                            WaitTimeoutResult(r.timed_out()),
                        )),
                        Err(p) => {
                            let (g, r) = p.into_inner();
                            Err(PoisonError::new((
                                MutexGuard { lock, inner: Some(g), model: None },
                                WaitTimeoutResult(r.timed_out()),
                            )))
                        }
                    },
                }
            }
            Some((exec, mutex_rid)) => {
                let (_, me) = ctx().expect("model-owned guard used off a model thread");
                let lock = guard.lock;
                // Drop the std-level guard now; the *logical* release
                // happens inside cv_wait atomically with registration.
                guard.inner.take();
                drop(guard);
                let cv_rid = self.rid(&exec);
                let fired = exec.cv_wait(me, cv_rid, mutex_rid, timeout.is_some());
                let g = lock.inner.lock().unwrap_or_else(PoisonError::into_inner);
                Ok((
                    MutexGuard { lock, inner: Some(g), model: Some((exec, mutex_rid)) },
                    WaitTimeoutResult(fired),
                ))
            }
        }
    }

    /// Block until notified. Mirrors [`std::sync::Condvar::wait`].
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match self.wait_impl(guard, None) {
            Ok((g, _)) => Ok(g),
            Err(p) => Err(PoisonError::new(p.into_inner().0)),
        }
    }

    /// Block until notified or `dur` elapses. Mirrors
    /// [`std::sync::Condvar::wait_timeout`].
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        self.wait_impl(guard, Some(dur))
    }

    /// Wake one waiter (scheduler-chosen under a model; lost if no waiter
    /// is registered, exactly as with `std`).
    pub fn notify_one(&self) {
        match ctx() {
            None => self.inner.notify_one(),
            Some((exec, me)) => {
                let rid = self.rid(&exec);
                exec.cv_notify(me, rid, false);
            }
        }
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        match ctx() {
            None => self.inner.notify_all(),
            Some((exec, me)) => {
                let rid = self.rid(&exec);
                exec.cv_notify(me, rid, true);
            }
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Condvar")
    }
}

/// Instrumented atomics: every access is a scheduler preemption point
/// under a model, passthrough otherwise. Explored at the given ordering
/// (the single-token scheduler makes every modeled execution sequentially
/// consistent — the explorer checks interleavings, not weak-memory
/// reorderings; see the module doc).
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    use super::ctx;

    fn preempt() {
        if let Some((exec, me)) = ctx() {
            exec.yield_point(me);
        }
    }

    macro_rules! shim_atomic {
        ($name:ident, $std:ty, $prim:ty, int) => {
            shim_atomic!($name, $std, $prim, base);

            impl $name {
                /// Add, returning the previous value (preemption point).
                pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                    preempt();
                    self.inner.fetch_add(v, order)
                }

                /// Subtract, returning the previous value (preemption point).
                pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                    preempt();
                    self.inner.fetch_sub(v, order)
                }
            }
        };
        ($name:ident, $std:ty, $prim:ty, base) => {
            /// Instrumented counterpart of the matching `std::sync::atomic` type.
            #[derive(Default)]
            pub struct $name {
                inner: $std,
            }

            impl $name {
                /// A new atomic holding `v`.
                pub const fn new(v: $prim) -> Self {
                    Self { inner: <$std>::new(v) }
                }

                /// Load (preemption point under a model).
                pub fn load(&self, order: Ordering) -> $prim {
                    preempt();
                    self.inner.load(order)
                }

                /// Store (preemption point under a model).
                pub fn store(&self, v: $prim, order: Ordering) {
                    preempt();
                    self.inner.store(v, order)
                }

                /// Swap, returning the previous value (preemption point).
                pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                    preempt();
                    self.inner.swap(v, order)
                }

                /// Compare-and-exchange (preemption point).
                pub fn compare_exchange(
                    &self,
                    current: $prim,
                    new: $prim,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$prim, $prim> {
                    preempt();
                    self.inner.compare_exchange(current, new, success, failure)
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    self.inner.fmt(f)
                }
            }
        };
    }

    shim_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool, base);
    shim_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize, int);
    shim_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64, int);
    shim_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32, int);
}

struct ModelJoin<T> {
    exec: Arc<Execution>,
    target: usize,
    join_rid: usize,
    slot: Arc<StdMutex<Option<T>>>,
}

/// Join handle for [`spawn`]; modeled joins block through the scheduler.
pub struct JoinHandle<T> {
    inner: Option<std::thread::JoinHandle<()>>,
    passthrough: Option<std::thread::JoinHandle<T>>,
    model: Option<ModelJoin<T>>,
}

impl<T> JoinHandle<T> {
    /// Wait for the thread to finish and return its value. A modeled
    /// thread that panicked aborts the whole execution, so this only
    /// returns `Err` in passthrough mode.
    pub fn join(mut self) -> std::thread::Result<T> {
        if let Some(h) = self.passthrough.take() {
            return h.join();
        }
        let mj = self.model.take().expect("join handle already consumed");
        let (_, me) = ctx().expect("modeled join off a model thread");
        mj.exec.join_wait(me, mj.target, mj.join_rid);
        // The model thread has reached Finished; its OS thread is in
        // teardown and joins without scheduler involvement.
        let _ = self.inner.take().expect("join handle already consumed").join();
        match mj.slot.lock().unwrap_or_else(PoisonError::into_inner).take() {
            Some(v) => Ok(v),
            // Target unwound (execution aborting): unwind the joiner too.
            None => panic::panic_any(super::Abort),
        }
    }
}

/// Spawn a thread. Under a model the child registers with the execution
/// and does not run until the scheduler picks it; outside a model this is
/// [`std::thread::spawn`].
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match ctx() {
        None => JoinHandle { inner: None, passthrough: Some(std::thread::spawn(f)), model: None },
        Some((exec, _)) => {
            let id = exec.register_thread();
            let join_rid = exec.new_resource();
            let slot: Arc<StdMutex<Option<T>>> = Arc::new(StdMutex::new(None));
            let (slot2, exec2) = (slot.clone(), exec.clone());
            let os = std::thread::spawn(move || {
                enter_model(exec2.clone(), id);
                // Park until scheduled for the first time.
                {
                    let core = exec2.lock_core();
                    let _ = exec2.park(core, id);
                }
                let r = panic::catch_unwind(AssertUnwindSafe(f));
                match r {
                    Ok(v) => {
                        *slot2.lock().unwrap_or_else(PoisonError::into_inner) = Some(v);
                        exec2.finish(id, join_rid, None);
                    }
                    Err(e) => {
                        let msg = super::panic_message(Err(e));
                        exec2.finish(id, join_rid, msg);
                    }
                }
                leave_model();
            });
            JoinHandle {
                inner: Some(os),
                passthrough: None,
                model: Some(ModelJoin { exec, target: id, join_rid, slot }),
            }
        }
    }
}
