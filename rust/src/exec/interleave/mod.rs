//! Deterministic thread-interleaving explorer for the concurrency models.
//!
//! The `loom-models` test suite (ISSUE 6) needs to exhaustively explore
//! thread interleavings of the hand-rolled serving primitives —
//! [`crate::exec::channel::bounded`], `Accum` ordered commit,
//! [`crate::exec::gather::ResidentPool`], and `LaneScheduler` shutdown.
//! The vendored registry only carries the `xla` closure, so upstream
//! `loom` is not available as a dependency; this module is a small,
//! loom-shaped explorer built on the same idea loom uses:
//!
//! * Threads in a model run one at a time. Every instrumented operation
//!   (mutex acquire, condvar wait/notify, atomic access) is a *decision
//!   point* where the scheduler chooses the next runnable thread.
//! * One execution = one vector of decisions. The explorer replays the
//!   model under depth-first enumeration of decision vectors until the
//!   space is exhausted (or a run cap is hit, reported in the
//!   [`Report`]).
//! * A state where no live thread is runnable is a **deadlock** and fails
//!   the model with the decision trace — this is how lost condvar
//!   notifications surface deterministically.
//! * The modeled [`shim::Condvar`] never delivers spurious wakeups, so a
//!   predicate loop that only terminates via spurious wakeups also shows
//!   up as a deadlock.
//!
//! Differences from loom, kept deliberately: atomics are explored at
//! `SeqCst` only (the substrate's invariants do not rely on weaker-order
//! reorderings — see `docs/INVARIANTS.md`), and there is no partial-order
//! reduction, so models must stay small (a handful of threads, a handful
//! of operations each). The [`shim`] types passthrough to `std` behaviour
//! on any thread that is not part of an active model, which is what lets
//! the whole crate compile against them under `--features loom-models`
//! while only the model tests drive exploration.
//!
//! Models must create every shim primitive *inside* the model closure:
//! resource identity is per-execution, and the closure is re-run from
//! scratch for every explored schedule. Wall-clock timeouts inside a
//! model are modeled logically: a timed wait only fires its timeout when
//! no other thread can run (timeouts are "long"), which keeps timed waits
//! from masking genuine lost-wakeup deadlocks.

pub mod shim;

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{
    Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard, PoisonError,
};

/// Sentinel panic payload used to unwind model threads when an execution
/// is aborted (failure elsewhere, deadlock, step cap). Never user-visible:
/// the panic hook installed by [`Explorer::run`] swallows it.
struct Abort;

/// Scheduling state of one model thread.
#[derive(Clone, Copy, Debug, PartialEq)]
enum TState {
    /// Eligible to be scheduled.
    Runnable,
    /// Parked on a resource (mutex, condvar waiter list, or join).
    Blocked {
        /// Resource the thread is parked on.
        rid: usize,
        /// Whether the park is a timed wait (eligible for a modeled
        /// timeout when nothing else can run).
        timed: bool,
    },
    /// The thread's closure has returned (or unwound).
    Finished,
}

/// One schedulable resource: a mutex (uses `held` + `waiters`), a condvar
/// (uses `waiters`), or a thread's join point (uses `waiters`).
#[derive(Default)]
struct Resource {
    held: bool,
    waiters: Vec<usize>,
}

struct Core {
    states: Vec<TState>,
    /// Thread currently holding the run token.
    current: usize,
    /// Decision trace of this execution: `(options, chosen)` per point.
    trace: Vec<(usize, usize)>,
    /// Forced decision prefix for deterministic replay.
    prefix: Vec<usize>,
    resources: Vec<Resource>,
    /// Set per thread when its timed wait was ended by a modeled timeout.
    timeout_fired: Vec<bool>,
    abort: bool,
    failure: Option<String>,
    max_steps: usize,
}

impl Core {
    /// Record one scheduling decision with `n` options and return the
    /// chosen index (forced by the replay prefix, 0 past its end).
    fn decide(&mut self, n: usize) -> Result<usize, String> {
        debug_assert!(n >= 1);
        if self.trace.len() >= self.max_steps {
            return Err(format!(
                "execution exceeded {} decision points (livelock or unbounded model)",
                self.max_steps
            ));
        }
        let d = self.trace.len();
        let pick = if d < self.prefix.len() {
            let p = self.prefix[d];
            if p >= n {
                return Err(format!(
                    "nondeterministic model: replay decision {d} wants option {p} of {n} — \
                     the closure must be deterministic given the schedule"
                ));
            }
            p
        } else {
            0
        };
        self.trace.push((n, pick));
        Ok(pick)
    }
}

/// One model execution: the single-token scheduler all shim operations
/// report to. Threads park on `cv` until `current` names them.
pub(crate) struct Execution {
    m: StdMutex<Core>,
    cv: StdCondvar,
}

type CoreGuard<'a> = StdMutexGuard<'a, Core>;

impl Execution {
    fn new(prefix: Vec<usize>, max_steps: usize) -> Execution {
        Execution {
            m: StdMutex::new(Core {
                states: Vec::new(),
                current: 0,
                trace: Vec::new(),
                prefix,
                resources: Vec::new(),
                timeout_fired: Vec::new(),
                abort: false,
                failure: None,
                max_steps,
            }),
            cv: StdCondvar::new(),
        }
    }

    fn lock_core(&self) -> CoreGuard<'_> {
        self.m.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record `msg` as the execution failure, abort every thread, and
    /// unwind the caller.
    fn abort_now(&self, mut core: CoreGuard<'_>, msg: String) -> ! {
        if core.failure.is_none() {
            core.failure = Some(msg);
        }
        core.abort = true;
        self.cv.notify_all();
        drop(core);
        panic::panic_any(Abort);
    }

    pub(crate) fn register_thread(&self) -> usize {
        let mut core = self.lock_core();
        core.states.push(TState::Runnable);
        core.timeout_fired.push(false);
        core.states.len() - 1
    }

    pub(crate) fn new_resource(&self) -> usize {
        let mut core = self.lock_core();
        core.resources.push(Resource::default());
        core.resources.len() - 1
    }

    /// Choose the next running thread. Returns `Err` on deadlock or step
    /// cap; notifies all parked threads about the new `current`.
    fn schedule(&self, core: &mut Core) -> Result<(), String> {
        let runnable: Vec<usize> = (0..core.states.len())
            .filter(|&i| core.states[i] == TState::Runnable)
            .collect();
        if !runnable.is_empty() {
            let pick = core.decide(runnable.len())?;
            core.current = runnable[pick];
            self.cv.notify_all();
            return Ok(());
        }
        // Nothing runnable: a timed waiter may fire its modeled timeout.
        let timed: Vec<usize> = (0..core.states.len())
            .filter(|&i| matches!(core.states[i], TState::Blocked { timed: true, .. }))
            .collect();
        if !timed.is_empty() {
            let pick = core.decide(timed.len())?;
            let t = timed[pick];
            if let TState::Blocked { rid, .. } = core.states[t] {
                core.resources[rid].waiters.retain(|&w| w != t);
            }
            core.states[t] = TState::Runnable;
            core.timeout_fired[t] = true;
            core.current = t;
            self.cv.notify_all();
            return Ok(());
        }
        if core.states.iter().all(|s| matches!(s, TState::Finished)) {
            self.cv.notify_all(); // wake the controller
            return Ok(());
        }
        let blocked: Vec<usize> = (0..core.states.len())
            .filter(|&i| matches!(core.states[i], TState::Blocked { .. }))
            .collect();
        Err(format!(
            "deadlock: threads {blocked:?} are blocked with nothing runnable \
             (lost notification or lock cycle); trace: {:?}",
            core.trace
        ))
    }

    /// Park until this thread holds the run token again (or the execution
    /// aborts, in which case the thread unwinds).
    fn park(&self, mut core: CoreGuard<'_>, me: usize) -> CoreGuard<'_> {
        loop {
            if core.abort {
                drop(core);
                panic::panic_any(Abort);
            }
            if core.current == me && core.states[me] == TState::Runnable {
                return core;
            }
            core = self.cv.wait(core).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Preemption point: let the scheduler pick any runnable thread
    /// (including the caller) before the caller's next shared-state op.
    pub(crate) fn yield_point(&self, me: usize) {
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            panic::panic_any(Abort);
        }
        match self.schedule(&mut core) {
            Ok(()) => {}
            Err(m) => self.abort_now(core, m),
        }
        if core.current != me {
            let _ = self.park(core, me);
        }
    }

    /// Park the caller on `rid` and hand the token to another thread.
    /// Returns once the caller is unblocked *and* rescheduled.
    fn block_on<'a>(
        &'a self,
        mut core: CoreGuard<'a>,
        me: usize,
        rid: usize,
        timed: bool,
    ) -> CoreGuard<'a> {
        core.states[me] = TState::Blocked { rid, timed };
        core.resources[rid].waiters.push(me);
        match self.schedule(&mut core) {
            Ok(()) => {}
            Err(m) => self.abort_now(core, m),
        }
        self.park(core, me)
    }

    /// Acquire modeled mutex `rid` for thread `me` (blocking).
    pub(crate) fn acquire(&self, me: usize, rid: usize) {
        loop {
            self.yield_point(me);
            let core = self.lock_core();
            if core.abort {
                drop(core);
                panic::panic_any(Abort);
            }
            let mut core = core;
            if !core.resources[rid].held {
                core.resources[rid].held = true;
                return;
            }
            let _ = self.block_on(core, me, rid, false);
            // Woken by a release: loop and re-contend.
        }
    }

    /// Release modeled mutex `rid`; every waiter re-contends.
    pub(crate) fn release(&self, rid: usize) {
        let mut core = self.lock_core();
        core.resources[rid].held = false;
        let ws = std::mem::take(&mut core.resources[rid].waiters);
        for w in ws {
            core.states[w] = TState::Runnable;
        }
    }

    /// Modeled condvar wait: atomically release `mutex_rid` and park on
    /// `cv_rid`; re-acquires the mutex before returning. Returns whether a
    /// modeled timeout (timed waits only) ended the park.
    pub(crate) fn cv_wait(&self, me: usize, cv_rid: usize, mutex_rid: usize, timed: bool) -> bool {
        // Preemption point *before* the release+register step: this is the
        // window where a notifier that does not hold the mutex can fire
        // ahead of the registration — the classic lost-notification race.
        self.yield_point(me);
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            panic::panic_any(Abort);
        }
        core.resources[mutex_rid].held = false;
        let ws = std::mem::take(&mut core.resources[mutex_rid].waiters);
        for w in ws {
            core.states[w] = TState::Runnable;
        }
        core.timeout_fired[me] = false;
        let core = self.block_on(core, me, cv_rid, timed);
        let fired = core.timeout_fired[me];
        drop(core);
        self.acquire(me, mutex_rid);
        fired
    }

    /// Modeled notify: wake one (scheduler-chosen) waiter or all waiters.
    /// Notifying an empty waiter set is a no-op, exactly as with
    /// [`std::sync::Condvar`] — which is what makes lost notifications
    /// reproducible.
    pub(crate) fn cv_notify(&self, me: usize, cv_rid: usize, all: bool) {
        self.yield_point(me);
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            panic::panic_any(Abort);
        }
        if core.resources[cv_rid].waiters.is_empty() {
            return;
        }
        if all {
            let ws = std::mem::take(&mut core.resources[cv_rid].waiters);
            for w in ws {
                core.states[w] = TState::Runnable;
            }
        } else {
            let n = core.resources[cv_rid].waiters.len();
            let pick = match core.decide(n) {
                Ok(p) => p,
                Err(m) => self.abort_now(core, m),
            };
            let w = core.resources[cv_rid].waiters.remove(pick);
            core.states[w] = TState::Runnable;
        }
    }

    /// Block `me` until thread with join resource `join_rid` finishes.
    pub(crate) fn join_wait(&self, me: usize, target: usize, join_rid: usize) {
        let core = self.lock_core();
        if core.abort {
            drop(core);
            panic::panic_any(Abort);
        }
        if core.states[target] == TState::Finished {
            return;
        }
        let _ = self.block_on(core, me, join_rid, false);
    }

    /// Mark `me` finished, wake joiners, and hand off the token. A
    /// non-`Abort` panic payload fails the whole execution.
    pub(crate) fn finish(&self, me: usize, join_rid: usize, panic_msg: Option<String>) {
        let mut core = self.lock_core();
        core.states[me] = TState::Finished;
        let ws = std::mem::take(&mut core.resources[join_rid].waiters);
        for w in ws {
            core.states[w] = TState::Runnable;
        }
        if let Some(msg) = panic_msg {
            if core.failure.is_none() {
                core.failure = Some(format!("model thread {me} panicked: {msg}"));
            }
            core.abort = true;
            self.cv.notify_all();
            return;
        }
        if core.abort {
            self.cv.notify_all();
            return;
        }
        if let Err(m) = self.schedule(&mut core) {
            if core.failure.is_none() {
                core.failure = Some(m);
            }
            core.abort = true;
            self.cv.notify_all();
        }
    }
}

/// Outcome of one [`Explorer::run`]: how many executions ran and whether
/// the decision space was fully enumerated (false only when the run cap
/// was hit first).
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Executions (distinct schedules) explored.
    pub executions: usize,
    /// True when every schedule was visited before the cap.
    pub exhausted: bool,
}

/// Exploration budget knobs. The defaults suit the in-tree models (a few
/// threads, a few operations each); raise `max_runs` locally when growing
/// a model.
#[derive(Debug, Clone, Copy)]
pub struct Explorer {
    /// Cap on explored schedules before giving up (reported, not fatal).
    pub max_runs: usize,
    /// Cap on decision points within one execution (fatal: a model that
    /// hits it is livelocked or unbounded).
    pub max_steps: usize,
}

impl Default for Explorer {
    fn default() -> Self {
        Explorer { max_runs: 60_000, max_steps: 10_000 }
    }
}

/// Run `f` under every schedule the default [`Explorer`] budget allows.
/// Panics (with the failing decision trace) if any schedule deadlocks,
/// panics, or fails an assertion.
pub fn explore(f: impl Fn() + Send + Sync + 'static) -> Report {
    Explorer::default().run(f)
}

impl Explorer {
    /// Explore `f` under this budget. See [`explore`].
    pub fn run(&self, f: impl Fn() + Send + Sync + 'static) -> Report {
        install_abort_hook();
        let f = Arc::new(f);
        let mut prefix: Vec<usize> = Vec::new();
        let mut executions = 0usize;
        loop {
            executions += 1;
            let (trace, failure) = run_one(prefix, self.max_steps, f.clone());
            if let Some(msg) = failure {
                panic!("interleave model failed on execution {executions}: {msg}");
            }
            match next_prefix(&trace) {
                Some(p) => prefix = p,
                None => return Report { executions, exhausted: true },
            }
            if executions >= self.max_runs {
                eprintln!(
                    "interleave: exploration capped at {} executions (space not exhausted)",
                    self.max_runs
                );
                return Report { executions, exhausted: false };
            }
        }
    }
}

/// First depth-first successor of `trace`: bump the deepest decision that
/// still has an unexplored option, dropping everything after it.
fn next_prefix(trace: &[(usize, usize)]) -> Option<Vec<usize>> {
    let mut i = trace.len();
    while i > 0 {
        i -= 1;
        let (n, c) = trace[i];
        if c + 1 < n {
            let mut p: Vec<usize> = trace[..i].iter().map(|&(_, c)| c).collect();
            p.push(c + 1);
            return Some(p);
        }
    }
    None
}

/// Execute the model once under the given decision prefix. Returns the
/// full trace and any failure.
fn run_one(
    prefix: Vec<usize>,
    max_steps: usize,
    f: Arc<dyn Fn() + Send + Sync>,
) -> (Vec<(usize, usize)>, Option<String>) {
    let exec = Arc::new(Execution::new(prefix, max_steps));
    let root = exec.register_thread();
    let root_join = exec.new_resource();
    {
        let mut core = exec.lock_core();
        core.current = root;
    }
    let exec2 = exec.clone();
    let h = std::thread::spawn(move || {
        shim::enter_model(exec2.clone(), root);
        let r = panic::catch_unwind(AssertUnwindSafe(|| f()));
        let msg = panic_message(r);
        exec2.finish(root, root_join, msg);
        shim::leave_model();
    });
    // Wait for every registered thread (root + everything it spawned) to
    // reach Finished; aborted executions converge here too because parked
    // threads unwind on abort.
    {
        let mut core = exec.lock_core();
        loop {
            if core.states.iter().all(|s| matches!(s, TState::Finished)) {
                break;
            }
            core = exec.cv.wait(core).unwrap_or_else(PoisonError::into_inner);
        }
    }
    let _ = h.join();
    let core = exec.lock_core();
    (core.trace.clone(), core.failure.clone())
}

/// Map a `catch_unwind` result to a failure message; the `Abort` sentinel
/// (scheduler-initiated unwind) is not a failure.
pub(crate) fn panic_message(r: Result<(), Box<dyn std::any::Any + Send>>) -> Option<String> {
    match r {
        Ok(()) => None,
        Err(p) => {
            if p.downcast_ref::<Abort>().is_some() {
                None
            } else if let Some(s) = p.downcast_ref::<&str>() {
                Some((*s).to_string())
            } else if let Some(s) = p.downcast_ref::<String>() {
                Some(s.clone())
            } else {
                Some("non-string panic payload".to_string())
            }
        }
    }
}

/// Install (once, process-wide) a panic hook that silences the `Abort`
/// sentinel unwinds; every other panic goes to the previously installed
/// hook unchanged.
fn install_abort_hook() {
    static INSTALLED: AtomicBool = AtomicBool::new(false);
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let prev = panic::take_hook();
    panic::set_hook(Box::new(move |info| {
        if info.payload().downcast_ref::<Abort>().is_some() {
            return;
        }
        prev(info);
    }));
}

#[cfg(test)]
mod tests {
    use super::shim::atomic::{AtomicUsize, Ordering};
    use super::shim::{self, Mutex};
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore = "spawns thousands of OS threads; covered natively")]
    fn explores_more_than_one_schedule() {
        // Two mutex-guarded increments: race-free, but the explorer must
        // still visit multiple schedules and exhaust the space.
        let report = explore(|| {
            let m = Arc::new(Mutex::new(0u32));
            let m2 = m.clone();
            let h = shim::spawn(move || {
                *m2.lock().unwrap() += 1;
            });
            *m.lock().unwrap() += 1;
            h.join().unwrap();
            assert_eq!(*m.lock().unwrap(), 2);
        });
        assert!(report.exhausted);
        assert!(report.executions > 1, "saw {} schedules", report.executions);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns thousands of OS threads; covered natively")]
    fn finds_lost_update() {
        // Unsynchronized read-modify-write through the instrumented
        // atomics: some schedule interleaves the two loads before either
        // store, losing an update. The explorer must find it.
        let r = panic::catch_unwind(|| {
            explore(|| {
                let a = Arc::new(AtomicUsize::new(0));
                let a2 = a.clone();
                let h = shim::spawn(move || {
                    let v = a2.load(Ordering::SeqCst);
                    a2.store(v + 1, Ordering::SeqCst);
                });
                let v = a.load(Ordering::SeqCst);
                a.store(v + 1, Ordering::SeqCst);
                h.join().unwrap();
                assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
            })
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("lost update"), "{msg}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns thousands of OS threads; covered natively")]
    fn finds_lock_order_deadlock() {
        let r = panic::catch_unwind(|| {
            explore(|| {
                let a = Arc::new(Mutex::new(()));
                let b = Arc::new(Mutex::new(()));
                let (a2, b2) = (a.clone(), b.clone());
                let h = shim::spawn(move || {
                    let _ga = a2.lock().unwrap();
                    let _gb = b2.lock().unwrap();
                });
                {
                    let _gb = b.lock().unwrap();
                    let _ga = a.lock().unwrap();
                }
                h.join().unwrap();
            })
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("deadlock"), "{msg}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns thousands of OS threads; covered natively")]
    fn finds_lost_notification() {
        // The notifier flips the flag and notifies WITHOUT holding the
        // mutex: a schedule exists where the waiter has checked the flag
        // but not yet registered — the notification is lost and the
        // waiter parks forever. Must surface as a deadlock.
        use super::shim::Condvar;
        let r = panic::catch_unwind(|| {
            explore(|| {
                let pair = Arc::new((Mutex::new(()), Condvar::new(), AtomicUsize::new(0)));
                let pair2 = pair.clone();
                let h = shim::spawn(move || {
                    let (_, cv, flag) = &*pair2;
                    flag.store(1, Ordering::SeqCst); // BUG: not under the mutex
                    cv.notify_one();
                });
                let (m, cv, flag) = &*pair;
                let mut g = m.lock().unwrap();
                while flag.load(Ordering::SeqCst) == 0 {
                    g = cv.wait(g).unwrap();
                }
                drop(g);
                h.join().unwrap();
            })
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("deadlock"), "{msg}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns thousands of OS threads; covered natively")]
    fn timed_wait_fires_when_idle() {
        // A timed wait nobody notifies must not deadlock: the modeled
        // timeout fires once nothing else can run.
        use super::shim::Condvar;
        let report = explore(|| {
            let m = Mutex::new(());
            let cv = Condvar::new();
            let g = m.lock().unwrap();
            let (g, res) = cv.wait_timeout(g, std::time::Duration::from_millis(1)).unwrap();
            assert!(res.timed_out());
            drop(g);
        });
        assert!(report.exhausted);
    }

    #[test]
    fn next_prefix_enumerates_depth_first() {
        assert_eq!(next_prefix(&[(1, 0), (1, 0)]), None);
        assert_eq!(next_prefix(&[(2, 0), (3, 2)]), Some(vec![1]));
        assert_eq!(next_prefix(&[(2, 1), (3, 1)]), Some(vec![1, 2]));
        assert_eq!(next_prefix(&[]), None);
    }
}
