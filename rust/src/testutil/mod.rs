//! Property-testing harness and seeded PRNG for tests (no `proptest`
//! offline — this is the minimal subset the suite needs: seeded random
//! input generation, many-case loops with failure reporting that includes
//! the case seed for reproduction).

/// Deterministic xorshift64* PRNG for tests. NOT the corpus generator —
/// that is `data::synth`'s counter-based splitmix64; this one is free to
/// evolve without breaking cross-language pins.
#[derive(Debug, Clone)]
pub struct TestRng {
    state: u64,
}

impl TestRng {
    /// Seeded RNG (seed 0 is remapped to 1: xorshift needs nonzero state).
    pub fn new(seed: u64) -> Self {
        TestRng { state: seed.max(1) }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Random f32 vector with entries in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range_f64(lo as f64, hi as f64) as f32).collect()
    }

    /// Random f64 vector with entries in [lo, hi).
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.range_f64(lo, hi)).collect()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `cases` property cases. Each case gets a fresh `TestRng` derived
/// from the base seed and case index; a failing case panics with the case
/// index and seed so it can be replayed exactly.
///
/// ```no_run
/// // (no_run: doctest binaries lack the xla_extension rpath)
/// nuig::testutil::prop(100, 42, |rng| {
///     let v = rng.range_f64(0.0, 10.0);
///     assert!(v >= 0.0 && v < 10.0);
/// });
/// ```
pub fn prop<Ft: FnMut(&mut TestRng)>(cases: usize, base_seed: u64, mut f: Ft) {
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = TestRng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = if let Some(s) = e.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else {
                "property failed".to_string()
            };
            panic!("property case {case}/{cases} failed (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two f64 slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = TestRng::new(7);
        let mut b = TestRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_ranges() {
        let mut r = TestRng::new(42);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let k = r.range(3, 10);
            assert!((3..10).contains(&k));
            let x = r.range_f64(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&x));
        }
    }

    #[test]
    fn prop_passes() {
        prop(50, 1, |rng| {
            let v = rng.vec_f32(8, 0.0, 1.0);
            assert_eq!(v.len(), 8);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn prop_reports_seed_on_failure() {
        prop(10, 2, |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn allclose() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, 0.0);
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn allclose_fails_with_index() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.1], 1e-9, 1e-9);
    }
}
