//! # nuig — Non-Uniform Integrated Gradients, served.
//!
//! A three-layer reproduction of *"Non-Uniform Interpolation in Integrated
//! Gradients for Low-Latency Explainable-AI"* (Bhat & Raychowdhury,
//! ISCAS 2023):
//!
//! * **L1/L2 (build time)** — Pallas kernels + a JAX MiniInception model,
//!   AOT-lowered to HLO text by `python/compile/aot.py`. Python never runs
//!   at serving time.
//! * **L3 (this crate)** — a Rust explanation-serving coordinator that
//!   loads the AOT artifacts through PJRT (`runtime`), implements the
//!   paper's two-stage non-uniform interpolation algorithm (`ig`), and
//!   serves explanation requests with cross-request continuous batching
//!   (`coordinator`).
//!
//! The supporting substrates (`jsonio`, `cli`, `exec`, `metrics`, `data`,
//! `viz`, `bench`) are implemented from scratch: the build environment
//! vendors only the `xla` crate closure, and a reproduction should own its
//! substrate anyway.
//!
//! Start with the repo-root `README.md` for the paper claims and module
//! map, and `docs/ARCHITECTURE.md` for the serving path end-to-end.

// Every public item is part of the reproduction's documented surface;
// keep rustdoc complete (CI runs `cargo doc` with warnings denied).
#![warn(missing_docs)]
// Unsafe operations must be visible even inside `unsafe fn` bodies; every
// unsafe block carries a `// SAFETY:` comment (enforced by nuig-analyze).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod ig;
pub mod jsonio;
pub mod metrics;
pub mod runtime;
pub mod testutil;
pub mod viz;

/// Crate-wide result alias (anyhow-backed; the only external dep besides xla).
pub type Result<T> = anyhow::Result<T>;
