//! Typed run configuration with validation and JSON round-trip.
//!
//! One config type per layer of the stack, composed into [`NuigConfig`]:
//! the CLI builds it from flags, the coordinator/server consumes it, and
//! bench harnesses construct it programmatically. Everything validates
//! eagerly (`validate()`) so misconfiguration fails before artifacts load.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::scheduler::Policy;
use crate::ig::{Allocation, Rule, Scheme};
use crate::jsonio::Json;

/// Where artifacts live and which executables to load.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Directory holding the AOT artifacts (manifest, HLO, params).
    pub artifacts_dir: PathBuf,
    /// Verify the manifest's corpus checksum against the local generator.
    pub verify_corpus: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { artifacts_dir: PathBuf::from("artifacts"), verify_corpus: true }
    }
}

/// IG algorithm configuration (per request defaults).
#[derive(Debug, Clone)]
pub struct IgConfig {
    /// Interpolation scheme (uniform vs non-uniform).
    pub scheme: Scheme,
    /// Total interpolation steps m (stage-2 budget).
    pub m: usize,
    /// Quadrature rule.
    pub rule: Rule,
    /// Stage-1 step-allocation policy.
    pub allocation: Allocation,
}

impl Default for IgConfig {
    fn default() -> Self {
        IgConfig {
            scheme: Scheme::NonUniform { n_int: 4 },
            m: 64,
            rule: Rule::Trapezoid,
            allocation: Allocation::Sqrt,
        }
    }
}

/// Coordinator / serving configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Chunk width K of the batched executables (fixed by the artifacts).
    pub chunk: usize,
    /// Router worker threads (request preparation / reduction).
    pub workers: usize,
    /// Bounded request-queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
    /// Max microseconds the batcher waits to fill a chunk before
    /// dispatching a partial one (continuous-batching knob).
    pub batch_wait_us: u64,
    /// Lane-scheduling policy (which request's points fill the next
    /// device chunk): fifo | round-robin | shortest-first.
    pub policy: Policy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            chunk: 16,
            workers: 2,
            queue_capacity: 64,
            batch_wait_us: 200,
            policy: Policy::Fifo,
        }
    }
}

/// The composed configuration.
#[derive(Debug, Clone, Default)]
pub struct NuigConfig {
    /// Artifact loading configuration.
    pub runtime: RuntimeConfig,
    /// Per-request IG defaults.
    pub ig: IgConfig,
    /// Serving-layer configuration.
    pub coordinator: CoordinatorConfig,
}

impl NuigConfig {
    /// Validate all cross-field constraints eagerly (fail before load).
    pub fn validate(&self) -> Result<()> {
        if self.ig.m < 1 {
            bail!("ig.m must be >= 1, got {}", self.ig.m);
        }
        if let Scheme::NonUniform { n_int } = self.ig.scheme {
            if n_int < 1 {
                bail!("non-uniform scheme needs n_int >= 1");
            }
            if self.ig.m < n_int {
                bail!("ig.m ({}) must be >= n_int ({n_int}): every interval needs a step", self.ig.m);
            }
            if n_int > 64 {
                bail!("n_int {n_int} is unreasonably large (paper shows n_int > 8 already degrades)");
            }
        }
        if self.coordinator.chunk == 0 || self.coordinator.workers == 0 {
            bail!("coordinator.chunk and coordinator.workers must be >= 1");
        }
        if self.coordinator.queue_capacity == 0 {
            bail!("coordinator.queue_capacity must be >= 1");
        }
        Ok(())
    }

    /// Serialize (for run provenance in bench output headers).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "runtime",
                Json::obj(vec![
                    ("artifacts_dir", Json::Str(self.runtime.artifacts_dir.display().to_string())),
                    ("verify_corpus", self.runtime.verify_corpus.into()),
                ]),
            ),
            (
                "ig",
                Json::obj(vec![
                    ("scheme", Json::Str(self.ig.scheme.to_string())),
                    ("m", self.ig.m.into()),
                    ("rule", Json::Str(self.ig.rule.to_string())),
                    ("allocation", Json::Str(self.ig.allocation.to_string())),
                ]),
            ),
            (
                "coordinator",
                Json::obj(vec![
                    ("chunk", self.coordinator.chunk.into()),
                    ("workers", self.coordinator.workers.into()),
                    ("queue_capacity", self.coordinator.queue_capacity.into()),
                    ("batch_wait_us", (self.coordinator.batch_wait_us as usize).into()),
                    ("policy", Json::Str(self.coordinator.policy.to_string())),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        NuigConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_zero_m() {
        let mut c = NuigConfig::default();
        c.ig.m = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_m_below_n_int() {
        let mut c = NuigConfig::default();
        c.ig.scheme = Scheme::NonUniform { n_int: 8 };
        c.ig.m = 4;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("n_int"), "{err}");
    }

    #[test]
    fn rejects_huge_n_int() {
        let mut c = NuigConfig::default();
        c.ig.scheme = Scheme::NonUniform { n_int: 100 };
        c.ig.m = 200;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_zero_workers() {
        let mut c = NuigConfig::default();
        c.coordinator.workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn uniform_scheme_ignores_n_int_constraint() {
        let mut c = NuigConfig::default();
        c.ig.scheme = Scheme::Uniform;
        c.ig.m = 1;
        c.validate().unwrap();
    }

    #[test]
    fn to_json_has_sections() {
        let j = NuigConfig::default().to_json();
        assert!(j.get("ig").is_ok());
        assert_eq!(j.get("coordinator").unwrap().get("chunk").unwrap().as_usize().unwrap(), 16);
    }
}
