//! Typed run configuration with validation and JSON round-trip.
//!
//! One config type per layer of the stack, composed into [`NuigConfig`]:
//! the CLI builds it from flags, the coordinator/server consumes it, and
//! bench harnesses construct it programmatically. Everything validates
//! eagerly (`validate()`) so misconfiguration fails before artifacts load.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::request::LatencyBudget;
use crate::coordinator::scheduler::{Policy, StealConfig};
use crate::ig::{Allocation, AnytimePolicy, Rule, Scheme};
use crate::jsonio::Json;

/// Where artifacts live and which executables to load.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Directory holding the AOT artifacts (manifest, HLO, params).
    pub artifacts_dir: PathBuf,
    /// Verify the manifest's corpus checksum against the local generator.
    pub verify_corpus: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { artifacts_dir: PathBuf::from("artifacts"), verify_corpus: true }
    }
}

/// IG algorithm configuration (per request defaults).
#[derive(Debug, Clone)]
pub struct IgConfig {
    /// Interpolation scheme (uniform vs non-uniform).
    pub scheme: Scheme,
    /// Total interpolation steps m (stage-2 budget).
    pub m: usize,
    /// Quadrature rule.
    pub rule: Rule,
    /// Stage-1 step-allocation policy.
    pub allocation: Allocation,
}

impl Default for IgConfig {
    fn default() -> Self {
        IgConfig {
            scheme: Scheme::NonUniform { n_int: 4 },
            m: 64,
            rule: Rule::Trapezoid,
            allocation: Allocation::Sqrt,
        }
    }
}

/// The schedule policy one latency tier maps to (see
/// [`LatencyBudget`] for the qualitative contract and `docs/TUNING.md`
/// for how the defaults were picked).
#[derive(Debug, Clone, Copy)]
pub struct TierPolicy {
    /// Initial grid intervals m of round 0. Raised to `4 * n_int` at
    /// admission so the sqrt allocation keeps a non-uniform shape under
    /// refinement doubling (the same floor the adaptive driver applies).
    pub m0: usize,
    /// Hard cap on refinement rounds (1 = a single fixed-m round; round
    /// r runs at `m0 << (r - 1)` intervals, so the interval budget is
    /// `m0 << (max_rounds - 1)`).
    pub max_rounds: usize,
    /// Convergence target gating early exit between rounds (ignored at
    /// `max_rounds == 1`).
    pub delta_target: f64,
}

impl TierPolicy {
    /// The anytime gate this tier induces at an (admission-floored)
    /// initial level of `m0` intervals; `None` when the tier is a single
    /// fixed round.
    pub fn anytime(&self, m0: usize) -> Option<AnytimePolicy> {
        if self.max_rounds <= 1 {
            return None;
        }
        Some(AnytimePolicy { delta_target: self.delta_target, max_m: m0 << (self.max_rounds - 1) })
    }
}

/// Deadline-aware admission configuration: the budget → schedule mapping
/// plus the probe-schedule cache bounds.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Hard-deadline tier: one coarse round, cache-served when warm.
    pub tight: TierPolicy,
    /// Soft-deadline tier: anytime with a modest round cap.
    pub standard: TierPolicy,
    /// Quality tier: anytime to threshold under the full budget.
    pub thorough: TierPolicy,
    /// Probe-schedule cache capacity in entries; 0 disables the cache
    /// (every request probes and builds its schedule from scratch, the
    /// pre-cache behaviour).
    pub cache_capacity: usize,
    /// Cache shard count (bounds lock contention; clamped to capacity).
    pub cache_shards: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            // m0 = 16 is the sqrt allocation's resolution floor at the
            // paper's n_int = 4 (4 steps per interval); see docs/TUNING.md.
            tight: TierPolicy { m0: 16, max_rounds: 1, delta_target: 0.0 },
            standard: TierPolicy { m0: 16, max_rounds: 3, delta_target: 0.01 },
            thorough: TierPolicy { m0: 16, max_rounds: 6, delta_target: 0.002 },
            // Cache off by default: enabling it switches served schedules
            // to the canonical (quantized-signature) form — opt in per
            // deployment. The fig_warmcache bench and the serving example
            // run with it on.
            cache_capacity: 0,
            cache_shards: 8,
        }
    }
}

impl AdmissionConfig {
    /// The schedule policy for `tier`; `None` for
    /// [`LatencyBudget::Unbounded`] (no admission rewriting).
    pub fn tier(&self, tier: LatencyBudget) -> Option<&TierPolicy> {
        match tier {
            LatencyBudget::Unbounded => None,
            LatencyBudget::Tight => Some(&self.tight),
            LatencyBudget::Standard => Some(&self.standard),
            LatencyBudget::Thorough => Some(&self.thorough),
        }
    }

    /// Whether the probe-schedule cache is enabled.
    pub fn cache_enabled(&self) -> bool {
        self.cache_capacity > 0
    }
}

/// Admission-side load shedding: high-water marks over the two overload
/// gauges (resident-pool occupancy, lane-queue depth) plus the
/// `retry_after` hint base. When either gauge crosses its mark,
/// tight-tier requests are rejected **before** stage 1 (zero probe
/// passes) with a [`crate::coordinator::request::ShedRejection`] carrying
/// a deterministic retry-after hint; standard/thorough tiers keep
/// queueing — they have slack to wait, tight-tier requests would blow
/// their deadline in the queue anyway. Both marks default to 0
/// (shedding disabled), so existing deployments are unchanged until
/// they opt in. The decision and hint math is mirrored bit-for-bit in
/// `igref.shed_decision` / `igref.shed_retry_after_ms` (integer-only,
/// no clocks) and parity-tested in `python/tests/test_resilience_parity.py`.
#[derive(Debug, Clone, Copy)]
pub struct ShedConfig {
    /// Resident-pool occupancy at/above which tight-tier requests shed;
    /// 0 disables this gauge. Must sit at or below `resident_cap` —
    /// above it the hard cap rejects first and the hint is never sent.
    pub resident_high_water: usize,
    /// Lane-queue depth (queued interpolation points) at/above which
    /// tight-tier requests shed; 0 disables this gauge.
    pub lane_high_water: usize,
    /// Base retry-after hint in milliseconds; the emitted hint is
    /// `base × overload factor` (capped at 16×), where the factor is
    /// the worst ceil-ratio of gauge to mark across enabled gauges.
    pub retry_after_ms: u64,
}

impl Default for ShedConfig {
    fn default() -> Self {
        // Marks of 0 = shedding off; the base hint only matters once a
        // deployment opts in by raising a mark.
        ShedConfig { resident_high_water: 0, lane_high_water: 0, retry_after_ms: 25 }
    }
}

impl ShedConfig {
    /// Hint growth cap: the retry-after hint saturates at
    /// `retry_after_ms × 16` however deep the overload runs.
    pub const MAX_FACTOR: u64 = 16;

    /// Whether any shedding gauge is enabled.
    pub fn enabled(&self) -> bool {
        self.resident_high_water > 0 || self.lane_high_water > 0
    }

    /// Shed decision: `true` when any enabled gauge sits at or above its
    /// high-water mark. Pure and clock-free (mirrored in
    /// `igref.shed_decision`).
    pub fn should_shed(&self, resident_len: usize, lane_depth: usize) -> bool {
        (self.resident_high_water > 0 && resident_len >= self.resident_high_water)
            || (self.lane_high_water > 0 && lane_depth >= self.lane_high_water)
    }

    /// Deterministic overload factor: the worst `ceil(gauge / mark)`
    /// across enabled gauges, clamped to `1..=`[`ShedConfig::MAX_FACTOR`].
    /// Integer-only so the python mirror is exact.
    pub fn overload_factor(&self, resident_len: usize, lane_depth: usize) -> u64 {
        let ratio = |gauge: usize, mark: usize| -> u64 {
            if mark == 0 {
                0
            } else {
                (gauge as u64).div_ceil(mark as u64)
            }
        };
        ratio(resident_len, self.resident_high_water)
            .max(ratio(lane_depth, self.lane_high_water))
            .clamp(1, Self::MAX_FACTOR)
    }

    /// The retry-after hint for a shed decision at the given gauge
    /// readings: `retry_after_ms × overload_factor` (mirrored in
    /// `igref.shed_retry_after_ms`).
    pub fn retry_after(&self, resident_len: usize, lane_depth: usize) -> Duration {
        Duration::from_millis(
            self.retry_after_ms.saturating_mul(self.overload_factor(resident_len, lane_depth)),
        )
    }
}

/// Coordinator / serving configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Chunk width K of the batched executables (fixed by the artifacts).
    pub chunk: usize,
    /// Router worker threads (request preparation / reduction).
    pub workers: usize,
    /// Feeder worker threads (gather-chunk dispatch + scatter). Feeder
    /// `i` is pinned to device shard `i % devices`; attributions are
    /// bit-identical at any feeder count (ordered lane commit).
    pub feeders: usize,
    /// Device shards the coordinator drives (one device thread each;
    /// the runtime must be loaded with at least this many —
    /// `Runtime::load_sharded`). Resident request tensors are broadcast
    /// to every shard, so per-request resident memory scales with this.
    pub devices: usize,
    /// Resident-pool admission bound: live `(x, baseline)` registrations
    /// per device shard. Requests arriving with the pool at the cap are
    /// rejected at admission (soft bound — concurrent routers may
    /// overshoot by `workers − 1` entries). Size it above the in-flight
    /// request ceiling; see `docs/TUNING.md`.
    pub resident_cap: usize,
    /// Bounded request-queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
    /// Max microseconds the batcher waits to fill a chunk before
    /// dispatching a partial one (continuous-batching knob).
    pub batch_wait_us: u64,
    /// Lane-scheduling policy (which request's points fill the next
    /// device chunk): fifo | round-robin | shortest-first.
    pub policy: Policy,
    /// Deadline-aware admission: tier policies + probe-schedule cache.
    pub admission: AdmissionConfig,
    /// Admission load shedding (high-water marks + retry-after hint);
    /// disabled by default.
    pub shed: ShedConfig,
    /// Tiered-scheduler work-stealing knobs: staging prefetch depth,
    /// the steal toggle, and the tier-starvation bound. Stealing never
    /// changes results — attributions are bit-identical at any steal
    /// interleaving (ordered lane commit; docs/INVARIANTS.md I10).
    pub steal: StealConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            chunk: 16,
            workers: 2,
            feeders: 1,
            devices: 1,
            // Default queue capacity (64 requests) + lane-queue
            // run-ahead tops out far below this; the cap exists to bound
            // resident memory when callers raise the queues.
            resident_cap: 1024,
            queue_capacity: 64,
            batch_wait_us: 200,
            policy: Policy::Fifo,
            admission: AdmissionConfig::default(),
            shed: ShedConfig::default(),
            steal: StealConfig::default(),
        }
    }
}

/// Serving front-end configuration (the network surface over the
/// coordinator — [`crate::coordinator::Frontend`]). Separate from
/// [`CoordinatorConfig`] because the front-end is optional: embedded and
/// bench deployments drive the coordinator in-process with no listener.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Listen spec: `tcp:HOST:PORT` (port 0 = ephemeral), `unix:/path`,
    /// or bare `HOST:PORT` (TCP).
    pub listen: String,
    /// Bounded accepted-connection queue between the listener and the
    /// connection workers. A connection arriving with the queue full is
    /// turned away immediately with a typed backlog REJECT carrying the
    /// coordinator's retry-after hint — explicit backpressure instead of
    /// an unbounded accept backlog.
    pub conn_backlog: usize,
    /// Connection worker threads (each serves one connection at a time;
    /// size to the expected concurrent-connection count).
    pub conn_workers: usize,
    /// Hard cap on a single wire frame (decode rejects larger before
    /// buffering; bounds per-connection memory).
    pub max_frame_bytes: usize,
    /// Per-connection round-stream buffer depth (converged-round updates
    /// queued between the feeders and the connection writer; overflow
    /// drops the stream update, never the settlement).
    pub stream_depth: usize,
    /// Default per-request deadline in milliseconds applied when a
    /// REQUEST frame carries none; 0 = no default deadline.
    pub default_deadline_ms: u64,
    /// How long [`crate::coordinator::Frontend::shutdown`] waits for
    /// in-flight requests to settle before cancelling the front-end
    /// subtree (stragglers then settle as disconnects).
    pub drain_timeout_ms: u64,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            listen: "tcp:127.0.0.1:0".to_string(),
            conn_backlog: 64,
            conn_workers: 2,
            max_frame_bytes: 16 << 20,
            stream_depth: 64,
            default_deadline_ms: 0,
            drain_timeout_ms: 5000,
        }
    }
}

impl FrontendConfig {
    /// Validate eagerly (called by `Frontend::start` before binding).
    pub fn validate(&self) -> Result<()> {
        if self.listen.is_empty() {
            bail!("frontend.listen must be set (tcp:HOST:PORT or unix:/path)");
        }
        if self.conn_backlog == 0 || self.conn_workers == 0 {
            bail!("frontend.conn_backlog and frontend.conn_workers must be >= 1");
        }
        if self.max_frame_bytes < crate::coordinator::frontend::framing::MIN_FRAME_CAP {
            bail!(
                "frontend.max_frame_bytes ({}) must be >= {} (smallest complete frame)",
                self.max_frame_bytes,
                crate::coordinator::frontend::framing::MIN_FRAME_CAP
            );
        }
        if self.stream_depth == 0 {
            bail!("frontend.stream_depth must be >= 1");
        }
        Ok(())
    }

    /// Serialize (for run provenance in bench output headers).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("listen", Json::Str(self.listen.clone())),
            ("conn_backlog", self.conn_backlog.into()),
            ("conn_workers", self.conn_workers.into()),
            ("max_frame_bytes", self.max_frame_bytes.into()),
            ("stream_depth", self.stream_depth.into()),
            ("default_deadline_ms", (self.default_deadline_ms as usize).into()),
            ("drain_timeout_ms", (self.drain_timeout_ms as usize).into()),
        ])
    }
}

/// The composed configuration.
#[derive(Debug, Clone, Default)]
pub struct NuigConfig {
    /// Artifact loading configuration.
    pub runtime: RuntimeConfig,
    /// Per-request IG defaults.
    pub ig: IgConfig,
    /// Serving-layer configuration.
    pub coordinator: CoordinatorConfig,
}

impl NuigConfig {
    /// Validate all cross-field constraints eagerly (fail before load).
    pub fn validate(&self) -> Result<()> {
        if self.ig.m < 1 {
            bail!("ig.m must be >= 1, got {}", self.ig.m);
        }
        if let Scheme::NonUniform { n_int } = self.ig.scheme {
            if n_int < 1 {
                bail!("non-uniform scheme needs n_int >= 1");
            }
            if self.ig.m < n_int {
                bail!("ig.m ({}) must be >= n_int ({n_int}): every interval needs a step", self.ig.m);
            }
            if n_int > 64 {
                bail!("n_int {n_int} is unreasonably large (paper shows n_int > 8 already degrades)");
            }
        }
        if self.coordinator.chunk == 0 || self.coordinator.workers == 0 {
            bail!("coordinator.chunk and coordinator.workers must be >= 1");
        }
        if self.coordinator.queue_capacity == 0 {
            bail!("coordinator.queue_capacity must be >= 1");
        }
        if self.coordinator.feeders == 0 || self.coordinator.devices == 0 {
            bail!("coordinator.feeders and coordinator.devices must be >= 1");
        }
        if self.coordinator.devices > self.coordinator.feeders {
            bail!(
                "coordinator.devices ({}) > feeders ({}): a shard without a feeder never \
                 receives work",
                self.coordinator.devices,
                self.coordinator.feeders
            );
        }
        if self.coordinator.resident_cap == 0 {
            bail!("coordinator.resident_cap must be >= 1");
        }
        if self.coordinator.resident_cap < self.coordinator.queue_capacity {
            bail!(
                "coordinator.resident_cap ({}) < queue_capacity ({}): admission would reject \
                 requests the queue admits under steady load",
                self.coordinator.resident_cap,
                self.coordinator.queue_capacity
            );
        }
        let adm = &self.coordinator.admission;
        for (name, tier) in [("tight", &adm.tight), ("standard", &adm.standard), ("thorough", &adm.thorough)] {
            if tier.m0 < 1 {
                bail!("admission.{name}.m0 must be >= 1");
            }
            if tier.max_rounds < 1 || tier.max_rounds > 12 {
                bail!("admission.{name}.max_rounds must be in 1..=12 (round r costs m0 * 2^(r-1) intervals)");
            }
            if !tier.delta_target.is_finite() || tier.delta_target < 0.0 {
                bail!("admission.{name}.delta_target must be finite and >= 0");
            }
        }
        if adm.cache_enabled() && adm.cache_shards == 0 {
            bail!("admission.cache_shards must be >= 1 when the cache is enabled");
        }
        let shed = &self.coordinator.shed;
        if shed.enabled() && shed.retry_after_ms == 0 {
            bail!("coordinator.shed.retry_after_ms must be >= 1 when a high-water mark is set");
        }
        if shed.resident_high_water > self.coordinator.resident_cap {
            bail!(
                "coordinator.shed.resident_high_water ({}) > resident_cap ({}): the hard cap \
                 rejects first and the retry-after hint is never sent",
                shed.resident_high_water,
                self.coordinator.resident_cap
            );
        }
        self.coordinator.steal.validate().map_err(|e| anyhow::anyhow!("coordinator.{e}"))?;
        Ok(())
    }

    /// Serialize (for run provenance in bench output headers).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "runtime",
                Json::obj(vec![
                    ("artifacts_dir", Json::Str(self.runtime.artifacts_dir.display().to_string())),
                    ("verify_corpus", self.runtime.verify_corpus.into()),
                ]),
            ),
            (
                "ig",
                Json::obj(vec![
                    ("scheme", Json::Str(self.ig.scheme.to_string())),
                    ("m", self.ig.m.into()),
                    ("rule", Json::Str(self.ig.rule.to_string())),
                    ("allocation", Json::Str(self.ig.allocation.to_string())),
                ]),
            ),
            (
                "coordinator",
                Json::obj(vec![
                    ("chunk", self.coordinator.chunk.into()),
                    ("workers", self.coordinator.workers.into()),
                    ("feeders", self.coordinator.feeders.into()),
                    ("devices", self.coordinator.devices.into()),
                    ("resident_cap", self.coordinator.resident_cap.into()),
                    ("queue_capacity", self.coordinator.queue_capacity.into()),
                    ("batch_wait_us", (self.coordinator.batch_wait_us as usize).into()),
                    ("policy", Json::Str(self.coordinator.policy.to_string())),
                    ("admission", admission_json(&self.coordinator.admission)),
                    ("shed", shed_json(&self.coordinator.shed)),
                    ("steal", steal_json(&self.coordinator.steal)),
                ]),
            ),
        ])
    }
}

fn tier_json(t: &TierPolicy) -> Json {
    Json::obj(vec![
        ("m0", t.m0.into()),
        ("max_rounds", t.max_rounds.into()),
        ("delta_target", Json::Num(t.delta_target)),
    ])
}

fn shed_json(s: &ShedConfig) -> Json {
    Json::obj(vec![
        ("resident_high_water", s.resident_high_water.into()),
        ("lane_high_water", s.lane_high_water.into()),
        ("retry_after_ms", (s.retry_after_ms as usize).into()),
    ])
}

fn steal_json(s: &StealConfig) -> Json {
    Json::obj(vec![
        ("stealing", s.stealing.into()),
        ("local_prefetch", s.local_prefetch.into()),
        ("starvation_limit", s.starvation_limit.into()),
    ])
}

fn admission_json(a: &AdmissionConfig) -> Json {
    Json::obj(vec![
        ("tight", tier_json(&a.tight)),
        ("standard", tier_json(&a.standard)),
        ("thorough", tier_json(&a.thorough)),
        ("cache_capacity", a.cache_capacity.into()),
        ("cache_shards", a.cache_shards.into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        NuigConfig::default().validate().unwrap();
    }

    #[test]
    fn admission_tier_lookup_and_anytime_mapping() {
        let adm = AdmissionConfig::default();
        assert!(adm.tier(LatencyBudget::Unbounded).is_none());
        let tight = adm.tier(LatencyBudget::Tight).unwrap();
        assert_eq!(tight.max_rounds, 1);
        assert!(tight.anytime(16).is_none(), "round cap 1 = a single fixed round");
        let std_tier = adm.tier(LatencyBudget::Standard).unwrap();
        let any = std_tier.anytime(16).unwrap();
        assert_eq!(any.max_m, 16 << (std_tier.max_rounds - 1));
        assert_eq!(any.delta_target, std_tier.delta_target);
        assert!(!adm.cache_enabled(), "cache is opt-in");
    }

    #[test]
    fn rejects_bad_admission_tiers() {
        let mut c = NuigConfig::default();
        c.coordinator.admission.standard.max_rounds = 0;
        assert!(c.validate().is_err());
        let mut c = NuigConfig::default();
        c.coordinator.admission.thorough.max_rounds = 13;
        assert!(c.validate().is_err());
        let mut c = NuigConfig::default();
        c.coordinator.admission.tight.delta_target = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = NuigConfig::default();
        c.coordinator.admission.tight.m0 = 0;
        assert!(c.validate().is_err());
        let mut c = NuigConfig::default();
        c.coordinator.admission.cache_capacity = 64;
        c.coordinator.admission.cache_shards = 0;
        assert!(c.validate().is_err());
        c.coordinator.admission.cache_shards = 4;
        c.validate().unwrap();
    }

    #[test]
    fn shed_disabled_by_default_and_decision_math() {
        let shed = ShedConfig::default();
        assert!(!shed.enabled());
        assert!(!shed.should_shed(usize::MAX, usize::MAX), "disabled gauges never shed");

        let shed = ShedConfig { resident_high_water: 8, lane_high_water: 0, retry_after_ms: 25 };
        assert!(!shed.should_shed(7, usize::MAX), "disabled lane gauge is ignored");
        assert!(shed.should_shed(8, 0), "at the mark = shed");
        assert!(shed.should_shed(9, 0));
        // Factor is the ceil-ratio of gauge to mark, clamped to 1..=16.
        assert_eq!(shed.overload_factor(8, 0), 1);
        assert_eq!(shed.overload_factor(9, 0), 2);
        assert_eq!(shed.overload_factor(17, 0), 3);
        assert_eq!(shed.overload_factor(usize::MAX, 0), ShedConfig::MAX_FACTOR);
        assert_eq!(shed.retry_after(9, 0), Duration::from_millis(50));

        // Two enabled gauges: worst factor wins; either crossing sheds.
        let shed = ShedConfig { resident_high_water: 8, lane_high_water: 64, retry_after_ms: 10 };
        assert!(shed.should_shed(0, 64));
        assert!(!shed.should_shed(7, 63));
        assert_eq!(shed.overload_factor(8, 256), 4, "lane gauge dominates");
        assert_eq!(shed.retry_after(8, 256), Duration::from_millis(40));
        // The pinned golden shared with python/tests/test_resilience_parity.py.
        assert_eq!(shed.retry_after(20, 100).as_millis(), 30);
    }

    #[test]
    fn rejects_bad_shed_config() {
        let mut c = NuigConfig::default();
        c.coordinator.shed.resident_high_water = 16;
        c.coordinator.shed.retry_after_ms = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("retry_after_ms"), "{err}");
        // retry_after_ms = 0 is fine while shedding is disabled.
        let mut c = NuigConfig::default();
        c.coordinator.shed.retry_after_ms = 0;
        c.validate().unwrap();
        // The resident mark must sit below the hard cap.
        let mut c = NuigConfig::default();
        c.coordinator.shed.resident_high_water = c.coordinator.resident_cap + 1;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("resident_high_water"), "{err}");
        // A valid opted-in shape.
        let mut c = NuigConfig::default();
        c.coordinator.shed =
            ShedConfig { resident_high_water: 64, lane_high_water: 4096, retry_after_ms: 25 };
        c.validate().unwrap();
    }

    #[test]
    fn rejects_zero_m() {
        let mut c = NuigConfig::default();
        c.ig.m = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_m_below_n_int() {
        let mut c = NuigConfig::default();
        c.ig.scheme = Scheme::NonUniform { n_int: 8 };
        c.ig.m = 4;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("n_int"), "{err}");
    }

    #[test]
    fn rejects_huge_n_int() {
        let mut c = NuigConfig::default();
        c.ig.scheme = Scheme::NonUniform { n_int: 100 };
        c.ig.m = 200;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_zero_workers() {
        let mut c = NuigConfig::default();
        c.coordinator.workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_bad_feeder_and_resident_config() {
        let mut c = NuigConfig::default();
        c.coordinator.feeders = 0;
        assert!(c.validate().is_err());
        let mut c = NuigConfig::default();
        c.coordinator.devices = 0;
        assert!(c.validate().is_err());
        // A shard without a feeder never receives work.
        let mut c = NuigConfig::default();
        c.coordinator.feeders = 2;
        c.coordinator.devices = 4;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("feeder"), "{err}");
        // Resident cap must admit at least the request queue.
        let mut c = NuigConfig::default();
        c.coordinator.resident_cap = 0;
        assert!(c.validate().is_err());
        let mut c = NuigConfig::default();
        c.coordinator.resident_cap = 8;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("resident_cap"), "{err}");
        // Valid sharded shape: feeders >= devices, generous pool.
        let mut c = NuigConfig::default();
        c.coordinator.feeders = 4;
        c.coordinator.devices = 2;
        c.validate().unwrap();
    }

    #[test]
    fn uniform_scheme_ignores_n_int_constraint() {
        let mut c = NuigConfig::default();
        c.ig.scheme = Scheme::Uniform;
        c.ig.m = 1;
        c.validate().unwrap();
    }

    #[test]
    fn to_json_has_sections() {
        let j = NuigConfig::default().to_json();
        assert!(j.get("ig").is_ok());
        assert_eq!(j.get("coordinator").unwrap().get("chunk").unwrap().as_usize().unwrap(), 16);
        assert_eq!(j.get("coordinator").unwrap().get("feeders").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("coordinator").unwrap().get("devices").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            j.get("coordinator").unwrap().get("resident_cap").unwrap().as_usize().unwrap(),
            1024
        );
        let adm = j.get("coordinator").unwrap().get("admission").unwrap();
        assert_eq!(adm.get("tight").unwrap().get("max_rounds").unwrap().as_usize().unwrap(), 1);
        assert_eq!(adm.get("cache_capacity").unwrap().as_usize().unwrap(), 0);
        let shed = j.get("coordinator").unwrap().get("shed").unwrap();
        assert_eq!(shed.get("resident_high_water").unwrap().as_usize().unwrap(), 0);
        assert_eq!(shed.get("retry_after_ms").unwrap().as_usize().unwrap(), 25);
        let steal = j.get("coordinator").unwrap().get("steal").unwrap();
        assert_eq!(steal.get("local_prefetch").unwrap().as_usize().unwrap(), 2);
        assert_eq!(steal.get("starvation_limit").unwrap().as_usize().unwrap(), 64);
    }

    #[test]
    fn frontend_config_validates_and_serializes() {
        let c = FrontendConfig::default();
        c.validate().unwrap();
        let j = c.to_json();
        assert_eq!(j.get("listen").unwrap().as_str().unwrap(), "tcp:127.0.0.1:0");
        assert_eq!(j.get("conn_backlog").unwrap().as_usize().unwrap(), 64);
        assert_eq!(j.get("default_deadline_ms").unwrap().as_usize().unwrap(), 0);
        assert_eq!(j.get("drain_timeout_ms").unwrap().as_usize().unwrap(), 5000);

        let mut c = FrontendConfig::default();
        c.listen = String::new();
        assert!(c.validate().is_err());
        let mut c = FrontendConfig::default();
        c.conn_workers = 0;
        assert!(c.validate().is_err());
        let mut c = FrontendConfig::default();
        c.conn_backlog = 0;
        assert!(c.validate().is_err());
        let mut c = FrontendConfig::default();
        c.stream_depth = 0;
        assert!(c.validate().is_err());
        // A frame cap below the smallest complete frame could never
        // carry a response.
        let mut c = FrontendConfig::default();
        c.max_frame_bytes = 16;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("max_frame_bytes"), "{err}");
    }

    #[test]
    fn steal_knobs_validated() {
        // Defaults: stealing on, one staged chunk, bounded starvation.
        let c = NuigConfig::default();
        assert!(c.coordinator.steal.stealing);
        c.validate().unwrap();
        let mut c = NuigConfig::default();
        c.coordinator.steal.local_prefetch = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("local_prefetch"), "{err}");
        let mut c = NuigConfig::default();
        c.coordinator.steal.starvation_limit = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("starvation_limit"), "{err}");
        // Stealing off with deep prefetch is a legal (pinned) shape.
        let mut c = NuigConfig::default();
        c.coordinator.steal =
            StealConfig { stealing: false, local_prefetch: 8, starvation_limit: 16 };
        c.validate().unwrap();
    }
}
