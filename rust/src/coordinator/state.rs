//! In-flight request state: the accumulator each device lane writes into
//! and the countdown that triggers finalization.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::exec::channel::Sender;
use crate::ig::{Attribution, IgOptions};
use crate::metrics::StageBreakdown;

use super::request::ExplainResponse;

/// Shared state for one in-flight request. Lanes (device batch slots)
/// hold an `Arc<RequestState>`; the last lane to land finalizes.
pub struct RequestState {
    pub id: u64,
    pub image: Arc<Vec<f32>>,
    pub baseline: Arc<Vec<f32>>,
    pub target: usize,
    pub opts: IgOptions,
    /// f64 attribution accumulator (lanes add under the mutex; adds are
    /// ~3k doubles per lane — negligible next to a device execution).
    pub acc: Mutex<Vec<f64>>,
    /// Gradient-point lanes still outstanding.
    pub remaining: AtomicUsize,
    /// Total gradient evaluations — the fused schedule's point count, so
    /// one lane == one model evaluation, exactly.
    pub steps: usize,
    pub probe_passes: usize,
    /// f(x) − f(x′) from stage 1.
    pub endpoint_gap: f64,
    pub breakdown: Mutex<StageBreakdown>,
    pub submitted_at: Instant,
    pub queue_wait: std::time::Duration,
    pub reply: Sender<anyhow::Result<ExplainResponse>>,
    /// Set once on finalize/fail; makes completion idempotent (a request
    /// spanning several chunks may see a late failure after finishing).
    pub completed: AtomicBool,
    /// The coordinator's in-flight gauge; decremented exactly once.
    pub in_flight: Arc<AtomicUsize>,
}

impl RequestState {
    /// Claim completion; `true` for exactly one caller.
    fn try_complete(&self) -> bool {
        if self.completed.swap(true, Ordering::AcqRel) {
            return false;
        }
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
        true
    }

    /// Add one lane's partial row; returns `true` if this was the last
    /// outstanding lane (caller must then [`RequestState::finalize`]).
    pub fn add_lane(&self, partial: &[f32]) -> bool {
        {
            let mut acc = self.acc.lock().unwrap();
            debug_assert_eq!(acc.len(), partial.len());
            for (a, &p) in acc.iter_mut().zip(partial) {
                *a += p as f64;
            }
        }
        self.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }

    /// Build and send the response. Idempotent; first caller wins.
    pub fn finalize(&self) {
        if !self.try_complete() {
            return;
        }
        let values = self.acc.lock().unwrap().clone();
        let sum: f64 = values.iter().sum();
        let delta = (sum - self.endpoint_gap).abs();
        let attribution = Attribution {
            values,
            target: self.target,
            steps: self.steps,
            probe_passes: self.probe_passes,
            delta,
            endpoint_gap: self.endpoint_gap,
            breakdown: *self.breakdown.lock().unwrap(),
        };
        let resp = ExplainResponse {
            id: self.id,
            attribution,
            total_latency: self.submitted_at.elapsed(),
            queue_wait: self.queue_wait,
        };
        // The client may have dropped its handle; that's fine.
        let _ = self.reply.send(Ok(resp));
    }

    /// Abort with an error (probe failure, device down, ...). Idempotent;
    /// a no-op if the request already finalized.
    pub fn fail(&self, err: anyhow::Error) {
        if !self.try_complete() {
            return;
        }
        let _ = self.reply.send(Err(err));
    }
}

/// One device-batch slot: a gradient point belonging to a request.
#[derive(Clone)]
pub struct Lane {
    pub state: Arc<RequestState>,
    pub alpha: f32,
    pub weight: f32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ResponseHandle;
    use crate::ig::IgOptions;

    fn mk_state(n_lanes: usize, gap: f64) -> (Arc<RequestState>, ResponseHandle) {
        let (tx, handle) = ResponseHandle::pair(1);
        let st = Arc::new(RequestState {
            id: 1,
            image: Arc::new(vec![1.0; 4]),
            baseline: Arc::new(vec![0.0; 4]),
            target: 0,
            opts: IgOptions::default(),
            acc: Mutex::new(vec![0.0; 4]),
            remaining: AtomicUsize::new(n_lanes),
            steps: n_lanes,
            probe_passes: 0,
            endpoint_gap: gap,
            breakdown: Mutex::new(StageBreakdown::default()),
            submitted_at: Instant::now(),
            queue_wait: std::time::Duration::ZERO,
            reply: tx,
            completed: AtomicBool::new(false),
            in_flight: Arc::new(AtomicUsize::new(1)),
        });
        (st, handle)
    }

    #[test]
    fn countdown_and_accumulate() {
        let (st, handle) = mk_state(3, 0.9);
        assert!(!st.add_lane(&[0.1, 0.0, 0.0, 0.0]));
        assert!(!st.add_lane(&[0.2, 0.1, 0.0, 0.0]));
        assert!(st.add_lane(&[0.3, 0.1, 0.1, 0.0]));
        st.finalize();
        let resp = handle.wait().unwrap();
        let a = &resp.attribution;
        // Lane rows are f32; accumulate tolerance accordingly.
        assert!((a.sum() - 0.9).abs() < 1e-6);
        assert!(a.delta < 1e-6);
        assert_eq!(a.steps, 3);
    }

    #[test]
    fn delta_reflects_incompleteness() {
        let (st, handle) = mk_state(1, 1.0);
        assert!(st.add_lane(&[0.25, 0.25, 0.0, 0.0]));
        st.finalize();
        let resp = handle.wait().unwrap();
        assert!((resp.attribution.delta - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fail_delivers_error() {
        let (st, handle) = mk_state(2, 0.0);
        st.fail(anyhow::anyhow!("device exploded"));
        let err = handle.wait().unwrap_err().to_string();
        assert!(err.contains("device exploded"));
    }

    #[test]
    fn completion_is_idempotent() {
        let (st, handle) = mk_state(1, 0.5);
        assert!(st.add_lane(&[0.5, 0.0, 0.0, 0.0]));
        st.finalize();
        st.fail(anyhow::anyhow!("late failure must be ignored"));
        st.finalize();
        // in_flight decremented exactly once.
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        assert!(handle.wait().is_ok());
    }

    #[test]
    fn fail_then_finalize_keeps_error() {
        let (st, handle) = mk_state(1, 0.5);
        st.fail(anyhow::anyhow!("boom"));
        st.finalize();
        assert!(handle.wait().is_err());
    }

    #[test]
    fn concurrent_lane_adds() {
        let (st, handle) = mk_state(16, 16.0);
        let threads: Vec<_> = (0..16)
            .map(|_| {
                let st = st.clone();
                std::thread::spawn(move || {
                    if st.add_lane(&[1.0, 0.0, 0.0, 0.0]) {
                        st.finalize();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let resp = handle.wait().unwrap();
        assert!((resp.attribution.values[0] - 16.0).abs() < 1e-9);
        assert!(resp.attribution.delta < 1e-9);
    }
}
