//! In-flight request state: the accumulator each device lane writes into,
//! the countdown that triggers round completion, and the anytime
//! refinement state machine (finalize vs refine-and-re-enqueue).
//!
//! With several feeder workers, a request's lane rows land in chunk-
//! completion order — nondeterministic across runs and feeder counts.
//! The accumulator therefore commits rows in **lane-index order**
//! ([`Accum`]): in-order rows fold into the f64 sum immediately,
//! out-of-order rows park until their index comes up. Since every f64
//! addition then happens in the same order no matter how chunks raced,
//! attributions are bit-identical (0 ULP) at any feeder count — the
//! serving-layer face of `exec::batch`'s ordered-reduction contract.
//! Parking is bounded by dispatch disorder (≈ feeders × chunk rows), not
//! by the round size: the lane scheduler emits each request's lanes in
//! index order, so only chunk-completion races park rows.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::exec::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::exec::sync::{self, Mutex};

use crate::exec::channel::Sender;
use crate::exec::gather::GatherExec;
use crate::ig::schedule::Schedule;
use crate::ig::{AnytimePolicy, Attribution, IgOptions};
use crate::metrics::StageBreakdown;

use super::request::{ExplainResponse, LatencyBudget, RoundUpdate};

/// A completed anytime round, captured at the moment the round's last
/// lane landed — **before** the accumulator is rescaled for the next
/// round. `values` are therefore bit-identical to what a standalone run
/// stopped at `round` would deliver (docs/INVARIANTS.md §I12); the
/// deadline path streams exactly these bits as the partial response.
#[derive(Clone)]
pub struct RoundSnapshot {
    /// Attribution values at this round (F f64s, ordered-commit exact).
    pub values: Vec<f64>,
    /// Completeness residual δ at this round.
    pub delta: f64,
    /// 1-based round number.
    pub round: usize,
    /// Total gradient evaluations dispatched through this round.
    pub evals: usize,
}

/// RAII eviction of a request's resident endpoint tensors: dropped when
/// the last in-flight reference to the [`RequestState`] goes away
/// (settlement + every queued lane drained), so no live chunk can ever
/// reference an evicted slot — even when a failure settles the request
/// while later chunks of it are still queued.
pub struct ResidentGuard {
    backend: Arc<dyn GatherExec>,
    slot: u64,
}

impl ResidentGuard {
    /// Guard `slot` (already registered with `backend`).
    pub fn new(backend: Arc<dyn GatherExec>, slot: u64) -> ResidentGuard {
        ResidentGuard { backend, slot }
    }
}

impl Drop for ResidentGuard {
    fn drop(&mut self) {
        self.backend.evict_request(self.slot);
    }
}

/// The ordered lane accumulator (see the module doc): f64 values plus
/// the in-order commit cursor and the parked out-of-order rows.
pub struct Accum {
    /// (F,) f64 attribution values committed so far.
    pub values: Vec<f64>,
    /// Next lane index (round-local) to commit.
    next: u32,
    /// Rows that arrived ahead of their turn, keyed by lane index.
    parked: BTreeMap<u32, Vec<f32>>,
}

impl Accum {
    /// A zeroed accumulator of `features` width.
    pub fn new(features: usize) -> Accum {
        Accum { values: vec![0f64; features], next: 0, parked: BTreeMap::new() }
    }

    fn commit(values: &mut [f64], row: &[f32]) {
        debug_assert_eq!(values.len(), row.len());
        // Lane-blocked elementwise add (`values[i] += row[i]`): per-index,
        // so lane width cannot change bits — the cross-row commit order
        // (lane-index order, docs/INVARIANTS.md §I4) stays with `add`.
        crate::exec::simd::commit_row(values, row);
    }

    /// Fold `row` in at lane index `idx`, committing any parked rows
    /// that become in-order.
    fn add(&mut self, idx: u32, row: &[f32]) {
        if idx == self.next {
            Self::commit(&mut self.values, row);
            self.next += 1;
            while let Some(parked) = self.parked.remove(&self.next) {
                Self::commit(&mut self.values, &parked);
                self.next += 1;
            }
        } else {
            self.parked.insert(idx, row.to_vec());
        }
    }

    /// Start a new round: reset the cursor (all prior rows committed).
    fn reset_round(&mut self) {
        debug_assert!(self.parked.is_empty(), "round completed with parked rows");
        self.next = 0;
        self.parked.clear();
    }
}

/// Mutable anytime-refinement state for one request (present only when
/// the request opted in via `ExplainRequest::anytime`).
pub struct AnytimeRounds {
    /// The convergence gate (target residual + interval budget).
    pub policy: AnytimePolicy,
    /// The current round's fused schedule; refined in place between
    /// rounds so the novel midpoint lanes can be derived.
    pub schedule: Mutex<Schedule>,
    /// Total gradient lanes dispatched across rounds — equals the current
    /// schedule's length (refinement never re-evaluates an alpha).
    pub evals: AtomicUsize,
    /// δ after each completed round (the residual trajectory).
    pub residuals: Mutex<Vec<f64>>,
}

/// What the feeder must do once a request's round has fully landed.
pub enum RoundOutcome {
    /// Done (fixed-m, converged, or budget-capped): finalize + reply.
    Finalize,
    /// Unconverged and in budget: re-enqueue these novel-midpoint chunk
    /// plans as the next refinement round.
    Refine(Vec<ChunkPlan>),
}

/// Shared state for one in-flight request. Lanes (device batch slots)
/// hold an `Arc<RequestState>`; the last lane of a round to land triggers
/// [`RequestState::on_round_complete`], which either finalizes or starts
/// the next refinement round.
pub struct RequestState {
    /// Submission id (monotonic, coordinator-assigned).
    pub id: u64,
    /// The explained input image.
    pub image: Arc<Vec<f32>>,
    /// The baseline x′.
    pub baseline: Arc<Vec<f32>>,
    /// Explained class.
    pub target: usize,
    /// The request's algorithm options (post-admission: tier rewrites
    /// are already applied).
    pub opts: IgOptions,
    /// The latency tier this request was admitted under (per-tier
    /// accounting at completion).
    pub budget: LatencyBudget,
    /// Ordered f64 attribution accumulator (lanes commit under the
    /// mutex in lane-index order — see [`Accum`]; adds are ~3k doubles
    /// per lane — negligible next to a device execution). On refinement
    /// the whole vector is scaled by `Schedule::REFINE_CARRY` (carried
    /// weights halve exactly).
    pub acc: Mutex<Accum>,
    /// Gradient-point lanes still outstanding in the current round.
    pub remaining: AtomicUsize,
    /// Round-0 gradient evaluations — the initial fused schedule's point
    /// count, so one lane == one model evaluation, exactly. For anytime
    /// requests the live total lives in `AnytimeRounds::evals`.
    pub steps: usize,
    /// Stage-1 forward passes (probe) this request performed.
    pub probe_passes: usize,
    /// f(x) − f(x′) from stage 1.
    pub endpoint_gap: f64,
    /// Wall-clock stage decomposition, filled in as stages complete.
    pub breakdown: Mutex<StageBreakdown>,
    /// When the request entered `submit`.
    pub submitted_at: Instant,
    /// Time spent in the request queue before a router picked it up.
    pub queue_wait: std::time::Duration,
    /// One-shot reply channel to the caller's `ResponseHandle`.
    pub reply: Sender<anyhow::Result<ExplainResponse>>,
    /// Set once on finalize/fail; makes completion idempotent (a request
    /// spanning several chunks may see a late failure after finishing).
    pub completed: AtomicBool,
    /// The coordinator's in-flight gauge; decremented exactly once.
    pub in_flight: Arc<AtomicUsize>,
    /// Anytime refinement state; `None` = single fixed-m round.
    pub anytime: Option<AnytimeRounds>,
    /// Resident-tensor eviction guard: fires when the last in-flight
    /// reference to this state drops. `None` in unit tests and for
    /// backends without residency.
    pub resident: Option<ResidentGuard>,
    /// Last **converged** anytime round, refreshed by
    /// [`RequestState::on_round_complete`] before each refinement; the
    /// deadline path settles from it ([`RequestState::finalize_partial`]).
    /// Stays `None` for fixed-m requests and before round 1 lands.
    pub last_round: Mutex<Option<RoundSnapshot>>,
    /// Optional per-round subscriber (the serving front-end's writer):
    /// each converged round is offered with a non-blocking `try_send` so
    /// a slow client can never stall a feeder — missed rounds are simply
    /// superseded by later ones. `None` for in-process callers.
    pub round_tx: Option<Sender<RoundUpdate>>,
}

impl RequestState {
    /// Claim completion; `true` for exactly one caller.
    fn try_complete(&self) -> bool {
        if self.completed.swap(true, Ordering::AcqRel) {
            return false;
        }
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
        true
    }

    /// Add one lane's partial row at round-local lane index `idx`;
    /// returns `true` if this was the last outstanding lane of the
    /// current round (caller must then call
    /// [`RequestState::on_round_complete`] and act on the outcome).
    ///
    /// Rows commit into the f64 accumulator in **lane-index order**
    /// regardless of arrival order (see [`Accum`]), so the final sum is
    /// bit-identical at any feeder count. The final arrival necessarily
    /// drains every parked row (all indices are then present), so a
    /// `true` return implies the accumulator is fully committed.
    pub fn add_lane(&self, idx: u32, partial: &[f32]) -> bool {
        sync::lock(&self.acc).add(idx, partial);
        self.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }

    /// Decide what happens after a round fully lands: finalize, or refine
    /// the schedule and hand back the next round's novel points as chunk
    /// plans of at most `chunk` points each (the caller's device width).
    ///
    /// Only the thread that observed `add_lane` return `true` may call
    /// this (the feeder); it is not re-entrant within a round. The
    /// refinement step mirrors `engine::refine_loop` exactly: the
    /// accumulator is scaled by `Schedule::REFINE_CARRY` (every carried
    /// lane's weight halves bit-exactly under refinement) and only the
    /// novel midpoints are re-enqueued — no gradient is ever recomputed.
    pub fn on_round_complete(self: &Arc<Self>, chunk: usize) -> RoundOutcome {
        // A request that already settled (e.g. a device failure on an
        // earlier chunk of this round) must not spawn refinement rounds
        // from a partial accumulator; the caller's finalize() is then a
        // no-op and no further lanes are enqueued.
        if self.completed.load(Ordering::Acquire) {
            return RoundOutcome::Finalize;
        }
        let Some(any) = &self.anytime else {
            return RoundOutcome::Finalize;
        };
        let (values, delta) = {
            let acc = sync::lock(&self.acc);
            // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
            let sum: f64 = acc.values.iter().sum();
            (acc.values.clone(), (sum - self.endpoint_gap).abs())
        };
        let round = {
            let mut residuals = sync::lock(&any.residuals);
            residuals.push(delta);
            residuals.len()
        };
        // Snapshot the converged round BEFORE any refinement rescale:
        // these are the exact bits a deadline-expired request streams as
        // its partial response (I12), and the round update a subscribed
        // front-end connection relays to its client.
        let snap = RoundSnapshot {
            values,
            delta,
            round,
            evals: any.evals.load(Ordering::Acquire),
        };
        if let Some(tx) = &self.round_tx {
            // Non-blocking: a full (slow client) or closed (disconnected
            // client) stream must never stall the feeder.
            let _ = tx.try_send(RoundUpdate {
                id: self.id,
                round: snap.round,
                delta: snap.delta,
                values: snap.values.clone(),
            });
        }
        *sync::lock(&self.last_round) = Some(snap);

        let mut sched = sync::lock(&any.schedule);
        if !any.policy.should_refine(delta, sched.m_total) {
            return RoundOutcome::Finalize;
        }
        let refined = match sched.refine() {
            // Validated at submit (endpoint-inclusive rule); defensive.
            Err(_) => return RoundOutcome::Finalize,
            Ok(r) => r,
        };
        let novel = refined.novel_vs(&sched);
        {
            let mut acc = sync::lock(&self.acc);
            for v in acc.values.iter_mut() {
                *v *= Schedule::REFINE_CARRY;
            }
            // New round: the next round's lanes re-index from 0.
            acc.reset_round();
        }
        self.remaining.store(novel.len(), Ordering::Release);
        any.evals.fetch_add(novel.len(), Ordering::AcqRel);
        *sched = refined;
        drop(sched);

        let points: Vec<(f32, f32)> =
            novel.iter().map(|p| (p.alpha as f32, p.weight as f32)).collect();
        RoundOutcome::Refine(ChunkPlan::build(self, &points, chunk))
    }

    /// Undo the state mutations of a refinement round whose novel lanes
    /// could never be enqueued (scheduler closed during shutdown drain):
    /// restore the accumulator scale — halving is a power-of-two scale,
    /// so doubling restores it bit-exactly — and the eval count, so a
    /// subsequent [`RequestState::finalize`] delivers the just-completed
    /// round's attribution unchanged (the anytime best-effort contract).
    pub fn abort_refinement(&self, novel_lanes: usize) {
        let Some(any) = &self.anytime else { return };
        {
            let mut acc = sync::lock(&self.acc);
            for v in acc.values.iter_mut() {
                *v /= Schedule::REFINE_CARRY;
            }
        }
        any.evals.fetch_sub(novel_lanes, Ordering::AcqRel);
    }

    /// Refinement rounds completed so far (1 for fixed-m requests).
    pub fn rounds(&self) -> usize {
        self.anytime
            .as_ref()
            .map(|a| sync::lock(&a.residuals).len().max(1))
            .unwrap_or(1)
    }

    /// Build and send the response. Idempotent; first caller wins.
    /// Returns `true` iff this call actually completed the request (so
    /// callers can attribute completion stats exactly once — a request
    /// that already failed must not also count as completed).
    pub fn finalize(&self) -> bool {
        if !self.try_complete() {
            return false;
        }
        let values = sync::lock(&self.acc).values.clone();
        // nuig:allow(float-reduce): sequential in-order Vec iteration — fixed order
        let sum: f64 = values.iter().sum();
        let delta = (sum - self.endpoint_gap).abs();
        let (steps, rounds, residuals) = match &self.anytime {
            None => (self.steps, 1, vec![delta]),
            Some(any) => {
                let residuals = sync::lock(&any.residuals).clone();
                (
                    any.evals.load(Ordering::Acquire),
                    residuals.len().max(1),
                    if residuals.is_empty() { vec![delta] } else { residuals },
                )
            }
        };
        let attribution = Attribution {
            values,
            target: self.target,
            steps,
            probe_passes: self.probe_passes,
            delta,
            endpoint_gap: self.endpoint_gap,
            rounds,
            residuals,
            breakdown: *sync::lock(&self.breakdown),
        };
        let resp = ExplainResponse {
            id: self.id,
            attribution,
            total_latency: self.submitted_at.elapsed(),
            queue_wait: self.queue_wait,
            partial: false,
        };
        // The client may have dropped its handle; that's fine.
        let _ = self.reply.send(Ok(resp));
        true
    }

    /// Settle with the last **converged** round's attribution as a
    /// partial response — the deadline-expiry path. Returns `true` iff
    /// this call settled the request; `false` when no round has
    /// converged yet (nothing deterministic to stream — the caller
    /// settles with [`crate::coordinator::request::DeadlineExceeded`]
    /// instead) or when the request already settled (a racing
    /// [`RequestState::finalize`]/[`RequestState::fail`] won — at most
    /// one reply is ever sent, pinned by the cancel-vs-settle model in
    /// `tests/interleave_models.rs`).
    ///
    /// The delivered bits are the round snapshot taken at round
    /// completion, so they are 0-ULP identical to a standalone run
    /// stopped at that round (I12).
    pub fn finalize_partial(&self) -> bool {
        if sync::lock(&self.last_round).is_none() {
            // Don't claim completion: with no converged round the
            // deadline degenerates to a typed rejection, and a racing
            // finalize()/fail() may still settle normally.
            return false;
        }
        if !self.try_complete() {
            return false;
        }
        // Re-read after claiming: a later round may have converged since
        // the gate above — deliver the freshest snapshot.
        let snap = sync::lock(&self.last_round).clone().expect("snapshot never reverts to None");
        let residuals = match &self.anytime {
            None => vec![snap.delta],
            Some(any) => {
                let mut r = sync::lock(&any.residuals).clone();
                r.truncate(snap.round);
                if r.is_empty() {
                    vec![snap.delta]
                } else {
                    r
                }
            }
        };
        let attribution = Attribution {
            values: snap.values,
            target: self.target,
            steps: snap.evals,
            probe_passes: self.probe_passes,
            delta: snap.delta,
            endpoint_gap: self.endpoint_gap,
            rounds: snap.round,
            residuals,
            breakdown: *sync::lock(&self.breakdown),
        };
        let resp = ExplainResponse {
            id: self.id,
            attribution,
            total_latency: self.submitted_at.elapsed(),
            queue_wait: self.queue_wait,
            partial: true,
        };
        let _ = self.reply.send(Ok(resp));
        true
    }

    /// Abort with an error (probe failure, device down, ...). Idempotent;
    /// a no-op if the request already settled. Returns `true` iff this
    /// call actually failed the request, so callers can count a request
    /// spanning several failed device chunks exactly once.
    pub fn fail(&self, err: anyhow::Error) -> bool {
        if !self.try_complete() {
            return false;
        }
        let _ = self.reply.send(Err(err));
        true
    }
}

/// One device-batch slot: a gradient point belonging to a request.
#[derive(Clone)]
pub struct Lane {
    /// The owning request's shared state (accumulator + countdown).
    pub state: Arc<RequestState>,
    /// Interpolation constant of this gradient point.
    pub alpha: f32,
    /// Quadrature weight of this gradient point.
    pub weight: f32,
    /// Round-local lane index — the accumulator's commit key (see
    /// [`Accum`]); assigned in fused-schedule order at plan build.
    pub idx: u32,
}

/// A contiguous run of ONE request's gradient points — the unit routers
/// enqueue and refinement rounds re-enqueue.
///
/// The lane scheduler holds chunk plans and pops single device [`Lane`]s
/// off the front plan, so device-batch assembly (and the scheduling
/// policies' lane-granular semantics) are unchanged while the queue
/// carries `O(points / chunk)` entries — one `Arc` clone and one
/// allocation per *chunk* instead of per point.
pub struct ChunkPlan {
    /// The owning request's shared state.
    pub state: Arc<RequestState>,
    /// `(alpha, weight)` of each point, in fused-schedule order.
    pub points: Vec<(f32, f32)>,
    /// Round-local lane index of `points[0]` (point `k` of this plan is
    /// lane `base + k` of its round).
    pub base: u32,
}

impl ChunkPlan {
    /// Split `points` into plans of at most `chunk` points each (the
    /// schedule-order chunking mirror of `exec::batch::chunk_spans`),
    /// with round-local lane indices assigned in order from 0.
    pub fn build(state: &Arc<RequestState>, points: &[(f32, f32)], chunk: usize) -> Vec<ChunkPlan> {
        assert!(chunk >= 1, "chunk must be >= 1");
        points
            .chunks(chunk)
            .enumerate()
            .map(|(i, c)| ChunkPlan {
                state: state.clone(),
                points: c.to_vec(),
                base: (i * chunk) as u32,
            })
            .collect()
    }

    /// Points carried by this plan.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Whether the plan carries no points.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ResponseHandle;
    use crate::ig::IgOptions;

    fn mk_state(n_lanes: usize, gap: f64) -> (Arc<RequestState>, ResponseHandle) {
        mk_state_anytime(n_lanes, gap, None)
    }

    fn mk_state_anytime(
        n_lanes: usize,
        gap: f64,
        anytime: Option<AnytimeRounds>,
    ) -> (Arc<RequestState>, ResponseHandle) {
        let (tx, handle) = ResponseHandle::pair(1);
        let st = Arc::new(RequestState {
            id: 1,
            image: Arc::new(vec![1.0; 4]),
            baseline: Arc::new(vec![0.0; 4]),
            target: 0,
            opts: IgOptions::default(),
            budget: LatencyBudget::Unbounded,
            acc: Mutex::new(Accum::new(4)),
            remaining: AtomicUsize::new(n_lanes),
            steps: n_lanes,
            probe_passes: 0,
            endpoint_gap: gap,
            breakdown: Mutex::new(StageBreakdown::default()),
            submitted_at: Instant::now(),
            queue_wait: std::time::Duration::ZERO,
            reply: tx,
            completed: AtomicBool::new(false),
            in_flight: Arc::new(AtomicUsize::new(1)),
            anytime,
            resident: None,
            last_round: Mutex::new(None),
            round_tx: None,
        });
        (st, handle)
    }

    #[test]
    fn countdown_and_accumulate() {
        let (st, handle) = mk_state(3, 0.9);
        assert!(!st.add_lane(0, &[0.1, 0.0, 0.0, 0.0]));
        assert!(!st.add_lane(1, &[0.2, 0.1, 0.0, 0.0]));
        assert!(st.add_lane(2, &[0.3, 0.1, 0.1, 0.0]));
        st.finalize();
        let resp = handle.wait().unwrap();
        let a = &resp.attribution;
        // Lane rows are f32; accumulate tolerance accordingly.
        assert!((a.sum() - 0.9).abs() < 1e-6);
        assert!(a.delta < 1e-6);
        assert_eq!(a.steps, 3);
    }

    #[test]
    fn delta_reflects_incompleteness() {
        let (st, handle) = mk_state(1, 1.0);
        assert!(st.add_lane(0, &[0.25, 0.25, 0.0, 0.0]));
        st.finalize();
        let resp = handle.wait().unwrap();
        assert!((resp.attribution.delta - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fail_delivers_error() {
        let (st, handle) = mk_state(2, 0.0);
        assert!(st.fail(anyhow::anyhow!("device exploded")), "first fail settles");
        assert!(!st.fail(anyhow::anyhow!("second chunk failed too")), "later fails are no-ops");
        let err = handle.wait().unwrap_err().to_string();
        assert!(err.contains("device exploded"));
    }

    #[test]
    fn completion_is_idempotent() {
        let (st, handle) = mk_state(1, 0.5);
        assert!(st.add_lane(0, &[0.5, 0.0, 0.0, 0.0]));
        st.finalize();
        st.fail(anyhow::anyhow!("late failure must be ignored"));
        st.finalize();
        // in_flight decremented exactly once.
        assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
        assert!(handle.wait().is_ok());
    }

    #[test]
    fn fail_then_finalize_keeps_error() {
        let (st, handle) = mk_state(1, 0.5);
        st.fail(anyhow::anyhow!("boom"));
        st.finalize();
        assert!(handle.wait().is_err());
    }

    fn mk_anytime(delta_target: f64, max_m: usize, m0: usize) -> AnytimeRounds {
        let schedule =
            Schedule::uniform(m0, crate::ig::Rule::Trapezoid).expect("valid uniform schedule");
        AnytimeRounds {
            policy: AnytimePolicy::with_max_m(delta_target, max_m).unwrap(),
            evals: AtomicUsize::new(schedule.len()),
            schedule: Mutex::new(schedule),
            residuals: Mutex::new(Vec::new()),
        }
    }

    #[test]
    fn fixed_m_round_completion_finalizes() {
        let (st, handle) = mk_state(1, 0.5);
        assert!(st.add_lane(0, &[0.5, 0.0, 0.0, 0.0]));
        assert!(matches!(st.on_round_complete(16), RoundOutcome::Finalize));
        st.finalize();
        let a = handle.wait().unwrap().attribution;
        assert_eq!(a.rounds, 1);
        assert_eq!(a.residuals, vec![a.delta]);
    }

    #[test]
    fn converged_anytime_round_finalizes_with_trajectory() {
        // acc sums to the gap exactly: δ = 0 ≤ target → finalize.
        let (st, handle) = mk_state_anytime(3, 1.0, Some(mk_anytime(0.01, 64, 2)));
        st.add_lane(0, &[0.5, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[0.25, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[0.25, 0.0, 0.0, 0.0]));
        assert!(matches!(st.on_round_complete(16), RoundOutcome::Finalize));
        st.finalize();
        let a = handle.wait().unwrap().attribution;
        assert_eq!(a.rounds, 1);
        assert_eq!(a.residuals.len(), 1);
        assert!(a.delta < 1e-6);
        assert_eq!(a.steps, 3, "anytime evals == dispatched lanes");
    }

    #[test]
    fn unconverged_round_refines_with_novel_midpoint_lanes() {
        // m0 = 2 (3 lanes, alphas 0/.5/1); δ far above target → refine.
        let (st, _handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 64, 2)));
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[2.0, 0.0, 0.0, 0.0]));
        let plans = match st.on_round_complete(16) {
            RoundOutcome::Refine(p) => p,
            RoundOutcome::Finalize => panic!("must refine"),
        };
        // Novel points are the two midpoints of the 3-point grid, at the
        // refined interior weight (0.25 for m = 4) — one chunk plan at
        // device width 16.
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].len(), 2);
        assert!(!plans[0].is_empty());
        let alphas: Vec<f32> = plans[0].points.iter().map(|&(a, _)| a).collect();
        assert_eq!(alphas, vec![0.25, 0.75]);
        assert!(plans[0].points.iter().all(|&(_, w)| (w - 0.25).abs() < 1e-6));
        // Accumulator carried at half weight; countdown reset for round 2.
        assert_eq!(st.acc.lock().unwrap().values[0], 2.0);
        assert_eq!(st.remaining.load(Ordering::Acquire), 2);
        let any = st.anytime.as_ref().unwrap();
        assert_eq!(any.evals.load(Ordering::Acquire), 5, "3 + 2 novel");
        assert_eq!(any.schedule.lock().unwrap().m_total, 4);
        assert_eq!(st.rounds(), 1, "round 2 not yet complete");
    }

    #[test]
    fn failed_request_never_refines() {
        // A device failure on one chunk settles the request; a later
        // chunk completing the round must not spawn refinement lanes
        // from the partial accumulator (and finalize stays a no-op).
        let (st, handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 64, 2)));
        st.fail(anyhow::anyhow!("device down"));
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
        assert!(matches!(st.on_round_complete(16), RoundOutcome::Finalize));
        assert!(!st.finalize(), "already settled: finalize must report a no-op");
        assert!(handle.wait().is_err());
    }

    #[test]
    fn aborted_refinement_restores_the_completed_round() {
        // A refinement whose lanes can't be enqueued (shutdown) must not
        // corrupt the delivered attribution: the halved accumulator and
        // bumped eval count are rolled back bit-exactly.
        let (st, handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 64, 2)));
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
        let plans = match st.on_round_complete(16) {
            RoundOutcome::Refine(p) => p,
            RoundOutcome::Finalize => panic!("must refine"),
        };
        st.abort_refinement(plans.iter().map(|p| p.len()).sum());
        st.finalize();
        let a = handle.wait().unwrap().attribution;
        assert_eq!(a.values[0], 3.0, "accumulator restored, not halved");
        assert_eq!(a.steps, 3, "evals roll back to the dispatched lanes");
        assert_eq!(a.rounds, 1);
        assert_eq!(a.residuals, vec![a.delta], "trajectory matches the delivered round");
    }

    #[test]
    fn budget_cap_finalizes_unconverged() {
        // max_m == m0: no refinement allowed, deliver best effort.
        let (st, handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 2, 2)));
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
        assert!(matches!(st.on_round_complete(16), RoundOutcome::Finalize));
        st.finalize();
        let a = handle.wait().unwrap().attribution;
        assert!(a.delta > 1.0, "unconverged best effort is still delivered");
        assert_eq!(a.rounds, 1);
    }

    #[test]
    fn two_round_refinement_accumulates_and_reports() {
        let (st, handle) = mk_state_anytime(3, 4.0, Some(mk_anytime(0.51, 64, 2)));
        for k in 0..2 {
            st.add_lane(k, &[1.0, 0.0, 0.0, 0.0]);
        }
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0])); // acc 3.0, δ = 1.0 > .51
        let plans = match st.on_round_complete(1) {
            RoundOutcome::Refine(p) => p,
            RoundOutcome::Finalize => panic!("round 1 must refine"),
        };
        // chunk = 1: each novel midpoint rides its own plan.
        assert_eq!(plans.len(), 2);
        assert!(plans.iter().all(|p| p.len() == 1));
        // Round 2: carried 1.5 + novel 2.0 → δ = 0.5 ≤ target → finalize
        // (lane indices restart at 0 — the accumulator's round reset).
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]));
        assert!(matches!(st.on_round_complete(1), RoundOutcome::Finalize));
        st.finalize();
        let a = handle.wait().unwrap().attribution;
        assert_eq!(a.rounds, 2);
        assert_eq!(a.residuals.len(), 2);
        assert!((a.residuals[0] - 1.0).abs() < 1e-9);
        assert!((a.residuals[1] - 0.5).abs() < 1e-9);
        assert_eq!(a.delta, a.residuals[1]);
        assert_eq!(a.steps, 5);
        assert!((a.values[0] - 3.5).abs() < 1e-9);
    }

    #[test]
    fn partial_without_converged_round_declines() {
        // Deadline before round 1 lands: nothing to stream, request NOT
        // claimed — a later finalize still settles normally.
        let (st, handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 64, 2)));
        assert!(!st.finalize_partial(), "no snapshot yet");
        assert!(!st.completed.load(Ordering::Acquire), "completion not claimed");
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
        let _ = st.on_round_complete(16);
        assert!(st.finalize(), "normal completion still available");
        assert!(!handle.wait().unwrap().partial);
    }

    #[test]
    fn partial_delivers_last_converged_round_bits() {
        // Round 1 lands, refinement begins; deadline fires mid-round-2.
        // The partial must be the round-1 snapshot — pre-rescale bits.
        let (st, handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 64, 2)));
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
        let plans = match st.on_round_complete(16) {
            RoundOutcome::Refine(p) => p,
            RoundOutcome::Finalize => panic!("must refine"),
        };
        // Mid-round-2: one novel lane landed, one still outstanding.
        st.add_lane(0, &[9.0, 0.0, 0.0, 0.0]);
        assert!(st.finalize_partial(), "snapshot available → partial settles");
        assert!(!st.finalize(), "already settled");
        let resp = handle.wait().unwrap();
        assert!(resp.partial);
        let a = &resp.attribution;
        assert_eq!(a.values[0].to_bits(), 3.0f64.to_bits(), "round-1 bits, not the carried half");
        assert_eq!(a.rounds, 1);
        assert_eq!(a.steps, 3, "evals at the snapshot, not the refined total");
        assert_eq!(a.residuals.len(), 1);
        drop(plans);
    }

    #[test]
    fn partial_and_finalize_settle_exactly_once_concurrently() {
        // The cancel-vs-settle race at the unit level: whichever path
        // wins, exactly one reply is delivered and in_flight hits 0.
        for _ in 0..32 {
            let (st, handle) = mk_state_anytime(3, 10.0, Some(mk_anytime(1e-9, 64, 2)));
            st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
            st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
            assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
            let _ = st.on_round_complete(16); // snapshot now exists
            let st2 = st.clone();
            let t = std::thread::spawn(move || st2.finalize_partial());
            let won_final = st.finalize();
            let won_partial = t.join().unwrap();
            assert!(
                won_final ^ won_partial,
                "exactly one settle path may win (final {won_final}, partial {won_partial})"
            );
            assert_eq!(st.in_flight.load(Ordering::Acquire), 0);
            let resp = handle.wait().unwrap();
            assert_eq!(resp.partial, won_partial);
        }
    }

    #[test]
    fn round_stream_offers_each_converged_round() {
        let (stream_tx, stream_rx) = crate::exec::channel::bounded(8);
        let (tx, _handle) = ResponseHandle::pair(1);
        let schedule = Schedule::uniform(2, crate::ig::Rule::Trapezoid).unwrap();
        let st = Arc::new(RequestState {
            id: 1,
            image: Arc::new(vec![1.0; 4]),
            baseline: Arc::new(vec![0.0; 4]),
            target: 0,
            opts: IgOptions::default(),
            budget: LatencyBudget::Unbounded,
            acc: Mutex::new(Accum::new(4)),
            remaining: AtomicUsize::new(3),
            steps: 3,
            probe_passes: 0,
            endpoint_gap: 10.0,
            breakdown: Mutex::new(StageBreakdown::default()),
            submitted_at: Instant::now(),
            queue_wait: std::time::Duration::ZERO,
            reply: tx,
            completed: AtomicBool::new(false),
            in_flight: Arc::new(AtomicUsize::new(1)),
            anytime: Some(AnytimeRounds {
                policy: AnytimePolicy::with_max_m(1e-9, 64).unwrap(),
                evals: AtomicUsize::new(schedule.len()),
                schedule: Mutex::new(schedule),
                residuals: Mutex::new(Vec::new()),
            }),
            resident: None,
            last_round: Mutex::new(None),
            round_tx: Some(stream_tx),
        });
        st.add_lane(0, &[1.0, 0.0, 0.0, 0.0]);
        st.add_lane(1, &[1.0, 0.0, 0.0, 0.0]);
        assert!(st.add_lane(2, &[1.0, 0.0, 0.0, 0.0]));
        let _ = st.on_round_complete(16);
        let upd = stream_rx.try_recv().unwrap().expect("round 1 streamed");
        assert_eq!(upd.id, 1);
        assert_eq!(upd.round, 1);
        assert_eq!(upd.values[0].to_bits(), 3.0f64.to_bits());
        assert!((upd.delta - 7.0).abs() < 1e-9);
        // The snapshot matches the streamed update bit-for-bit.
        let snap = st.last_round.lock().unwrap().clone().unwrap();
        assert_eq!(snap.values[0].to_bits(), upd.values[0].to_bits());
        assert_eq!(snap.round, 1);
    }

    #[test]
    fn concurrent_lane_adds() {
        let (st, handle) = mk_state(16, 16.0);
        let threads: Vec<_> = (0..16u32)
            .map(|k| {
                let st = st.clone();
                std::thread::spawn(move || {
                    if st.add_lane(k, &[1.0, 0.0, 0.0, 0.0]) {
                        st.finalize();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let resp = handle.wait().unwrap();
        assert!((resp.attribution.values[0] - 16.0).abs() < 1e-9);
        assert!(resp.attribution.delta < 1e-9);
    }

    #[test]
    fn ordered_commit_is_arrival_order_invariant() {
        // The sharded-feeder determinism property at the unit level: the
        // SAME rows delivered in any arrival order commit to bit-identical
        // f64 sums, because commits happen in lane-index order.
        let rows: Vec<[f32; 4]> = (0..7)
            .map(|k| {
                let v = 0.1f32 + 0.37 * k as f32;
                [v, -v * 0.5, v * v, 1.0 / (1.0 + v)]
            })
            .collect();
        let commit_in = |order: &[usize]| -> Vec<u64> {
            let (st, handle) = mk_state(rows.len(), 0.0);
            for &k in order {
                st.add_lane(k as u32, &rows[k]);
            }
            st.finalize();
            handle.wait().unwrap().attribution.values.iter().map(|v| v.to_bits()).collect()
        };
        let reference = commit_in(&[0, 1, 2, 3, 4, 5, 6]);
        assert_eq!(commit_in(&[6, 5, 4, 3, 2, 1, 0]), reference, "reverse arrival");
        assert_eq!(commit_in(&[3, 0, 6, 1, 5, 2, 4]), reference, "shuffled arrival");
        // Chunk-shaped disorder (two feeders finishing out of order).
        assert_eq!(commit_in(&[4, 5, 6, 0, 1, 2, 3]), reference, "chunk swap");
    }

    #[test]
    fn chunk_plans_carry_round_local_bases() {
        let (st, _handle) = mk_state(7, 0.0);
        let points: Vec<(f32, f32)> = (0..7).map(|k| (k as f32, 1.0)).collect();
        let plans = ChunkPlan::build(&st, &points, 3);
        assert_eq!(plans.len(), 3);
        assert_eq!(plans.iter().map(|p| p.base).collect::<Vec<_>>(), vec![0, 3, 6]);
        assert_eq!(plans.iter().map(|p| p.len()).collect::<Vec<_>>(), vec![3, 3, 1]);
    }
}
