//! Listening sockets and accepted byte streams for the serving
//! front-end: one abstraction over TCP (`tcp:HOST:PORT`) and Unix
//! domain sockets (`unix:/path`), so the framed protocol, connection
//! lifecycle, and tests are transport-agnostic.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// An accepted client connection (blocking; reads carry a timeout so
/// the connection loops can poll their cancellation tokens).
pub enum ConnStream {
    /// A TCP client.
    Tcp(TcpStream),
    /// A Unix-domain client.
    #[cfg(unix)]
    Unix(UnixStream),
}

impl ConnStream {
    /// A second handle onto the same socket (reader/writer split).
    pub fn try_clone(&self) -> io::Result<ConnStream> {
        match self {
            ConnStream::Tcp(s) => s.try_clone().map(ConnStream::Tcp),
            #[cfg(unix)]
            ConnStream::Unix(s) => s.try_clone().map(ConnStream::Unix),
        }
    }

    /// Bound the blocking time of reads (`None` = block forever).
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            ConnStream::Tcp(s) => s.set_read_timeout(dur),
            #[cfg(unix)]
            ConnStream::Unix(s) => s.set_read_timeout(dur),
        }
    }

    /// Shut down both directions; subsequent reads see EOF, writes fail.
    pub fn shutdown(&self) {
        match self {
            ConnStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            ConnStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for ConnStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            ConnStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            ConnStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for ConnStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            ConnStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            ConnStream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            ConnStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            ConnStream::Unix(s) => s.flush(),
        }
    }
}

/// A bound listening socket.
pub enum ListenerSocket {
    /// TCP listener.
    Tcp(TcpListener),
    /// Unix-domain listener, with the path for unlink-on-shutdown.
    #[cfg(unix)]
    Unix(UnixListener, std::path::PathBuf),
}

impl ListenerSocket {
    /// Bind `spec`: `tcp:HOST:PORT` (port 0 = ephemeral), `unix:/path`
    /// (a stale socket file is replaced), or a bare `HOST:PORT`
    /// (treated as TCP, the CLI convenience form).
    pub fn bind(spec: &str) -> Result<ListenerSocket> {
        if let Some(addr) = spec.strip_prefix("tcp:") {
            let l = TcpListener::bind(addr).with_context(|| format!("binding tcp:{addr}"))?;
            return Ok(ListenerSocket::Tcp(l));
        }
        if let Some(path) = spec.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let path = std::path::PathBuf::from(path);
                // A stale socket file from an unclean exit blocks the
                // bind; replace it. A *live* listener is not detected —
                // the deployment owns path uniqueness.
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)
                    .with_context(|| format!("binding unix:{}", path.display()))?;
                return Ok(ListenerSocket::Unix(l, path));
            }
            #[cfg(not(unix))]
            bail!("unix: listeners are not supported on this platform");
        }
        if spec.contains(':') {
            let l = TcpListener::bind(spec).with_context(|| format!("binding tcp:{spec}"))?;
            return Ok(ListenerSocket::Tcp(l));
        }
        bail!("listen spec {spec:?} must be tcp:HOST:PORT or unix:/path")
    }

    /// The resolved address in bind-spec form (`tcp:127.0.0.1:41873`),
    /// so an ephemeral-port bind can be dialled back.
    pub fn local_spec(&self) -> String {
        match self {
            ListenerSocket::Tcp(l) => match l.local_addr() {
                Ok(a) => format!("tcp:{a}"),
                Err(_) => "tcp:?".to_string(),
            },
            #[cfg(unix)]
            ListenerSocket::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }

    /// Switch the accept loop between blocking and polling mode.
    pub fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            ListenerSocket::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            ListenerSocket::Unix(l, _) => l.set_nonblocking(nb),
        }
    }

    /// Accept one connection. The accepted stream is always switched to
    /// blocking mode (it may inherit the listener's non-blocking flag on
    /// some platforms), with timeouts applied per-read by the connection.
    pub fn accept(&self) -> io::Result<ConnStream> {
        let stream = match self {
            ListenerSocket::Tcp(l) => ConnStream::Tcp(l.accept()?.0),
            #[cfg(unix)]
            ListenerSocket::Unix(l, _) => ConnStream::Unix(l.accept()?.0),
        };
        match &stream {
            ConnStream::Tcp(s) => s.set_nonblocking(false)?,
            #[cfg(unix)]
            ConnStream::Unix(s) => s.set_nonblocking(false)?,
        }
        Ok(stream)
    }

    /// Remove a Unix listener's socket file (no-op for TCP). Called on
    /// front-end shutdown.
    pub fn cleanup(&self) {
        #[cfg(unix)]
        if let ListenerSocket::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Dial a listen spec (tests and the CLI client side).
pub fn connect(spec: &str) -> Result<ConnStream> {
    if let Some(addr) = spec.strip_prefix("tcp:") {
        let s = TcpStream::connect(addr).with_context(|| format!("connecting tcp:{addr}"))?;
        return Ok(ConnStream::Tcp(s));
    }
    if let Some(path) = spec.strip_prefix("unix:") {
        #[cfg(unix)]
        {
            let s = UnixStream::connect(path).with_context(|| format!("connecting unix:{path}"))?;
            return Ok(ConnStream::Unix(s));
        }
        #[cfg(not(unix))]
        bail!("unix: sockets are not supported on this platform");
    }
    if spec.contains(':') {
        let s = TcpStream::connect(spec).with_context(|| format!("connecting tcp:{spec}"))?;
        return Ok(ConnStream::Tcp(s));
    }
    bail!("connect spec {spec:?} must be tcp:HOST:PORT or unix:/path")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_ephemeral_bind_reports_dialable_spec() {
        let l = ListenerSocket::bind("tcp:127.0.0.1:0").unwrap();
        let spec = l.local_spec();
        assert!(spec.starts_with("tcp:127.0.0.1:"), "{spec}");
        assert!(!spec.ends_with(":0"), "the resolved port is reported: {spec}");
        let _client = connect(&spec).unwrap();
        let served = l.accept().unwrap();
        served.shutdown();
    }

    #[test]
    fn bare_host_port_is_tcp() {
        let l = ListenerSocket::bind("127.0.0.1:0").unwrap();
        assert!(matches!(l, ListenerSocket::Tcp(_)));
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        let err = ListenerSocket::bind("carrier-pigeon").unwrap_err();
        assert!(err.to_string().contains("tcp:HOST:PORT"), "{err}");
        let err = connect("carrier-pigeon").unwrap_err();
        assert!(err.to_string().contains("tcp:HOST:PORT"), "{err}");
    }

    #[cfg(unix)]
    #[test]
    fn unix_bind_replaces_stale_socket_and_cleans_up() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("nuig-frontend-test-{}.sock", std::process::id()));
        let spec = format!("unix:{}", path.display());
        // First bind creates the file; binding again (stale file from an
        // "unclean exit") must replace it rather than fail.
        let l1 = ListenerSocket::bind(&spec).unwrap();
        drop(l1);
        let l2 = ListenerSocket::bind(&spec).unwrap();
        assert!(path.exists());
        let _client = connect(&spec).unwrap();
        let served = l2.accept().unwrap();
        served.shutdown();
        l2.cleanup();
        assert!(!path.exists(), "cleanup unlinks the socket file");
    }
}
